//! Connection-open negotiation.
//!
//! A framed client opens with an 8-byte hello; the server answers with an
//! 8-byte ack or reject and the connection then speaks frames.  A legacy
//! client sends no hello — its first byte is JSON (`{`, whitespace, …) —
//! and the server falls back to the line-oriented protocol, so every
//! pre-existing tool keeps working unchanged.  `0xB5` cannot begin a JSON
//! line (or any UTF-8 text line), which makes the dispatch unambiguous on
//! the first byte.
//!
//! Byte layout (all three messages are exactly [`LEN`] bytes):
//!
//! | off | client hello     | server ack       | server reject       |
//! |-----|------------------|------------------|---------------------|
//! | 0   | `0xB5`           | `0xB5`           | `0xB5`              |
//! | 1   | `0x52` (hello)   | `0x53` (ok)      | `0x5E` (reject)     |
//! | 2-3 | version, u16 LE  | version, u16 LE  | server version      |
//! | 4   | encoding         | encoding         | reject reason       |
//! | 5-7 | reserved, zero   | reserved, zero   | reserved, zero      |

use crate::PROTO_VERSION;

/// Size of every handshake message.
pub const LEN: usize = 8;

/// First byte of every handshake message (and of nothing else).
pub const MAGIC: u8 = 0xB5;

const KIND_HELLO: u8 = 0x52;
const KIND_OK: u8 = 0x53;
const KIND_REJECT: u8 = 0x5E;

/// Reject reason: the client's protocol version is not supported.
pub const REJECT_VERSION: u8 = 1;
/// Reject reason: the requested encoding is unknown to the server.
pub const REJECT_ENCODING: u8 = 2;

/// Payload encoding carried inside frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    /// Frames carry UTF-8 JSON text (framing without the binary codec).
    Json = 1,
    /// Frames carry [`crate::bin`]-encoded values.
    Binary = 2,
}

impl Encoding {
    pub fn from_byte(b: u8) -> Option<Encoding> {
        match b {
            1 => Some(Encoding::Json),
            2 => Some(Encoding::Binary),
            _ => None,
        }
    }
}

/// The server's verdict on a client hello.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HelloVerdict {
    /// Accept: answer with [`ok_bytes`] and speak frames in this encoding.
    Accept { version: u16, encoding: Encoding },
    /// Reject: answer with [`reject_bytes`]`(reason)` and close.
    Reject { reason: u8 },
}

/// Client hello for `encoding`, at an explicit version (tests use a wrong
/// one to provoke rejection; real clients pass [`PROTO_VERSION`]).
pub fn hello_bytes(version: u16, encoding: Encoding) -> [u8; LEN] {
    let v = version.to_le_bytes();
    [MAGIC, KIND_HELLO, v[0], v[1], encoding as u8, 0, 0, 0]
}

/// Server ack confirming the negotiated version and encoding.
pub fn ok_bytes(version: u16, encoding: Encoding) -> [u8; LEN] {
    let v = version.to_le_bytes();
    [MAGIC, KIND_OK, v[0], v[1], encoding as u8, 0, 0, 0]
}

/// Server reject carrying the server's own version and a reason code.
pub fn reject_bytes(reason: u8) -> [u8; LEN] {
    let v = PROTO_VERSION.to_le_bytes();
    [MAGIC, KIND_REJECT, v[0], v[1], reason, 0, 0, 0]
}

/// Server-side evaluation of a complete hello message whose first byte is
/// already known to be [`MAGIC`].  A malformed second byte is treated as a
/// version problem: the client is clearly framed-family but not speaking
/// anything we know.
pub fn evaluate_hello(msg: &[u8; LEN]) -> HelloVerdict {
    if msg[1] != KIND_HELLO {
        return HelloVerdict::Reject { reason: REJECT_VERSION };
    }
    let version = u16::from_le_bytes([msg[2], msg[3]]);
    if version != PROTO_VERSION {
        return HelloVerdict::Reject { reason: REJECT_VERSION };
    }
    match Encoding::from_byte(msg[4]) {
        Some(encoding) => HelloVerdict::Accept { version, encoding },
        None => HelloVerdict::Reject { reason: REJECT_ENCODING },
    }
}

/// Client-side evaluation of the server's 8-byte answer.
pub fn evaluate_ack(msg: &[u8; LEN]) -> Result<Encoding, AckError> {
    if msg[0] != MAGIC {
        return Err(AckError::NotFramed);
    }
    let version = u16::from_le_bytes([msg[2], msg[3]]);
    match msg[1] {
        KIND_OK => match Encoding::from_byte(msg[4]) {
            Some(e) => Ok(e),
            None => Err(AckError::Malformed),
        },
        KIND_REJECT => Err(AckError::Rejected {
            server_version: version,
            reason: msg[4],
        }),
        _ => Err(AckError::Malformed),
    }
}

#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum AckError {
    #[error("server does not speak the framed protocol")]
    NotFramed,
    #[error(
        "server rejected the handshake (server version {server_version}, \
         reason {reason})"
    )]
    Rejected { server_version: u16, reason: u8 },
    #[error("malformed handshake answer")]
    Malformed,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_ack_roundtrip_both_encodings() {
        for enc in [Encoding::Json, Encoding::Binary] {
            let hello = hello_bytes(PROTO_VERSION, enc);
            assert_eq!(
                evaluate_hello(&hello),
                HelloVerdict::Accept { version: PROTO_VERSION, encoding: enc }
            );
            assert_eq!(
                evaluate_ack(&ok_bytes(PROTO_VERSION, enc)),
                Ok(enc)
            );
        }
    }

    #[test]
    fn version_mismatch_is_rejected_with_server_version() {
        let hello = hello_bytes(PROTO_VERSION + 9, Encoding::Binary);
        assert_eq!(
            evaluate_hello(&hello),
            HelloVerdict::Reject { reason: REJECT_VERSION }
        );
        assert_eq!(
            evaluate_ack(&reject_bytes(REJECT_VERSION)),
            Err(AckError::Rejected {
                server_version: PROTO_VERSION,
                reason: REJECT_VERSION,
            })
        );
    }

    #[test]
    fn unknown_encoding_is_rejected() {
        let mut hello = hello_bytes(PROTO_VERSION, Encoding::Json);
        hello[4] = 0x7f;
        assert_eq!(
            evaluate_hello(&hello),
            HelloVerdict::Reject { reason: REJECT_ENCODING }
        );
    }

    #[test]
    fn magic_cannot_start_a_json_line() {
        // The legacy protocol's first byte is always ASCII (a JSON value
        // or whitespace); 0xB5 is a UTF-8 continuation byte and can never
        // appear first in well-formed text.
        assert!(MAGIC >= 0x80);
    }
}
