//! Tagged binary encoding of [`Json`] values — the payload format of
//! binary-mode frames.
//!
//! One byte of tag, then a fixed layout per tag (all integers little
//! endian):
//!
//! | tag    | value                                                    |
//! |--------|----------------------------------------------------------|
//! | `0x00` | null                                                     |
//! | `0x01` | false                                                    |
//! | `0x02` | true                                                     |
//! | `0x03` | number: f64, 8 bytes                                     |
//! | `0x04` | string: u32 byte length + UTF-8 bytes                    |
//! | `0x05` | array: u32 count + that many values                      |
//! | `0x06` | object: u32 count + (u32 key length + key + value) each  |
//! | `0x07` | packed u16 array: u32 count + that many u16s             |
//!
//! `0x07` is the fast path for ECG sample windows (12-bit ADC codes): a
//! 2048-sample channel is 4100 bytes instead of ~18 KiB of `0x05` + f64
//! elements.  The encoder picks it automatically for non-empty arrays of
//! integral numbers in `0..=65535`; the decoder expands it back to a
//! plain array of numbers, so the two forms are semantically identical.
//!
//! The decoder is written for hostile input: every read is bounds
//! checked, collection counts are validated against the remaining bytes
//! *before* any allocation, recursion depth is capped, and trailing
//! garbage after the value is an error.  It must never panic — the
//! framing-robustness suite feeds it random bytes.

use crate::json::Json;

const TAG_NULL: u8 = 0x00;
const TAG_FALSE: u8 = 0x01;
const TAG_TRUE: u8 = 0x02;
const TAG_NUM: u8 = 0x03;
const TAG_STR: u8 = 0x04;
const TAG_ARR: u8 = 0x05;
const TAG_OBJ: u8 = 0x06;
const TAG_U16S: u8 = 0x07;

/// Nesting cap: deeper input is rejected rather than risking stack
/// overflow on attacker-chosen `[[[[…]]]]` payloads.
const MAX_DEPTH: usize = 64;

#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum BinError {
    #[error("binary value truncated")]
    Truncated,
    #[error("unknown binary tag 0x{0:02x}")]
    BadTag(u8),
    #[error("binary string is not valid UTF-8")]
    Utf8,
    #[error("trailing bytes after binary value")]
    TrailingBytes,
    #[error("binary value nested deeper than {MAX_DEPTH} levels")]
    TooDeep,
}

/// Encode one value; the inverse of [`decode`] up to the `0x05`/`0x07`
/// array-representation choice (which decodes to the same [`Json`]).
pub fn encode(v: &Json) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    encode_value(v, &mut out);
    out
}

fn encode_value(v: &Json, out: &mut Vec<u8>) {
    match v {
        Json::Null => out.push(TAG_NULL),
        Json::Bool(false) => out.push(TAG_FALSE),
        Json::Bool(true) => out.push(TAG_TRUE),
        Json::Num(n) => {
            out.push(TAG_NUM);
            out.extend_from_slice(&n.to_le_bytes());
        }
        Json::Str(s) => {
            out.push(TAG_STR);
            out.extend_from_slice(&(s.len() as u32).to_le_bytes());
            out.extend_from_slice(s.as_bytes());
        }
        Json::Arr(items) => {
            if !items.is_empty() && items.iter().all(is_packable_u16) {
                out.push(TAG_U16S);
                out.extend_from_slice(&(items.len() as u32).to_le_bytes());
                for item in items {
                    // lint:allow(panic-macro: is_packable_u16 admits only Json::Num)
                    let Json::Num(n) = item else { unreachable!() };
                    out.extend_from_slice(&(*n as u16).to_le_bytes());
                }
            } else {
                out.push(TAG_ARR);
                out.extend_from_slice(&(items.len() as u32).to_le_bytes());
                for item in items {
                    encode_value(item, out);
                }
            }
        }
        Json::Obj(map) => {
            out.push(TAG_OBJ);
            out.extend_from_slice(&(map.len() as u32).to_le_bytes());
            for (k, val) in map {
                out.extend_from_slice(&(k.len() as u32).to_le_bytes());
                out.extend_from_slice(k.as_bytes());
                encode_value(val, out);
            }
        }
    }
}

fn is_packable_u16(v: &Json) -> bool {
    matches!(v, Json::Num(n)
        if n.fract() == 0.0 && *n >= 0.0 && *n <= f64::from(u16::MAX))
}

/// Decode one value, requiring that it consume the whole buffer.
pub fn decode(buf: &[u8]) -> Result<Json, BinError> {
    let mut r = Reader { buf, pos: 0 };
    let v = r.value(0)?;
    if r.pos != buf.len() {
        return Err(BinError::TrailingBytes);
    }
    Ok(v)
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], BinError> {
        // checked_add: on 32-bit hosts `pos + n` can wrap for a hostile
        // u32 length, turning a too-long read into a short in-bounds one.
        let end = self.pos.checked_add(n).ok_or(BinError::Truncated)?;
        let s = self.buf.get(self.pos..end).ok_or(BinError::Truncated)?;
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, BinError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, BinError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a collection count and sanity-check it against the bytes left:
    /// each element occupies at least `min_elem_bytes`, so a count that
    /// cannot possibly fit is rejected before `Vec::with_capacity`.
    fn count(&mut self, min_elem_bytes: usize) -> Result<usize, BinError> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem_bytes) > self.remaining() {
            return Err(BinError::Truncated);
        }
        Ok(n)
    }

    fn string(&mut self) -> Result<String, BinError> {
        let len = self.u32()? as usize;
        if len > self.remaining() {
            return Err(BinError::Truncated);
        }
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| BinError::Utf8)
    }

    fn value(&mut self, depth: usize) -> Result<Json, BinError> {
        if depth >= MAX_DEPTH {
            return Err(BinError::TooDeep);
        }
        match self.u8()? {
            TAG_NULL => Ok(Json::Null),
            TAG_FALSE => Ok(Json::Bool(false)),
            TAG_TRUE => Ok(Json::Bool(true)),
            TAG_NUM => {
                let b = self.take(8)?;
                let mut raw = [0u8; 8];
                raw.copy_from_slice(b);
                Ok(Json::Num(f64::from_le_bytes(raw)))
            }
            TAG_STR => Ok(Json::Str(self.string()?)),
            TAG_ARR => {
                let n = self.count(1)?;
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    items.push(self.value(depth + 1)?);
                }
                Ok(Json::Arr(items))
            }
            TAG_OBJ => {
                // Each entry is at least a 4-byte key length + 1-byte tag.
                let n = self.count(5)?;
                let mut map = std::collections::BTreeMap::new();
                for _ in 0..n {
                    let k = self.string()?;
                    let v = self.value(depth + 1)?;
                    map.insert(k, v);
                }
                Ok(Json::Obj(map))
            }
            TAG_U16S => {
                let n = self.count(2)?;
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    let b = self.take(2)?;
                    items.push(Json::Num(f64::from(u16::from_le_bytes([
                        b[0], b[1],
                    ]))));
                }
                Ok(Json::Arr(items))
            }
            tag => Err(BinError::BadTag(tag)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: Json) {
        assert_eq!(decode(&encode(&v)).unwrap(), v, "roundtrip {v}");
    }

    #[test]
    fn scalars_roundtrip() {
        roundtrip(Json::Null);
        roundtrip(Json::Bool(true));
        roundtrip(Json::Bool(false));
        roundtrip(Json::Num(0.0));
        roundtrip(Json::Num(-276.5));
        roundtrip(Json::Num(1e300));
        roundtrip(Json::Str(String::new()));
        roundtrip(Json::Str("chip 0: ok \"quoted\" ünïcode".into()));
    }

    #[test]
    fn collections_roundtrip() {
        roundtrip(Json::Arr(vec![]));
        roundtrip(Json::Arr(vec![
            Json::Num(1.5),
            Json::Str("x".into()),
            Json::Null,
            Json::Arr(vec![Json::Bool(true)]),
        ]));
        let mut m = std::collections::BTreeMap::new();
        m.insert("cmd".to_string(), Json::Str("classify".into()));
        m.insert("trace".to_string(), Json::Arr(vec![Json::Num(7.0)]));
        roundtrip(Json::Obj(m));
    }

    #[test]
    fn sample_windows_take_the_packed_path() {
        let window: Vec<Json> =
            (0..2048u32).map(|i| Json::Num(f64::from(i % 4096))).collect();
        let v = Json::Arr(window);
        let bytes = encode(&v);
        assert_eq!(bytes[0], TAG_U16S);
        assert_eq!(bytes.len(), 1 + 4 + 2 * 2048);
        assert_eq!(decode(&bytes).unwrap(), v);
        // Non-integral or out-of-range elements force the general form.
        let general = Json::Arr(vec![Json::Num(0.5)]);
        assert_eq!(encode(&general)[0], TAG_ARR);
        let negative = Json::Arr(vec![Json::Num(-1.0)]);
        assert_eq!(encode(&negative)[0], TAG_ARR);
        let wide = Json::Arr(vec![Json::Num(65536.0)]);
        assert_eq!(encode(&wide)[0], TAG_ARR);
    }

    #[test]
    fn hostile_inputs_are_typed_errors_not_panics() {
        assert_eq!(decode(&[]), Err(BinError::Truncated));
        assert_eq!(decode(&[0xff]), Err(BinError::BadTag(0xff)));
        assert_eq!(decode(&[TAG_NUM, 1, 2]), Err(BinError::Truncated));
        // Count claims 4 billion elements with 3 bytes left.
        let mut huge = vec![TAG_ARR];
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        huge.extend_from_slice(&[0, 0, 0]);
        assert_eq!(decode(&huge), Err(BinError::Truncated));
        // Invalid UTF-8 in a string.
        let mut bad = vec![TAG_STR];
        bad.extend_from_slice(&2u32.to_le_bytes());
        bad.extend_from_slice(&[0xc3, 0x28]);
        assert_eq!(decode(&bad), Err(BinError::Utf8));
        // Trailing garbage after a complete value.
        assert_eq!(decode(&[TAG_NULL, 0]), Err(BinError::TrailingBytes));
        // Nesting bomb: 100 nested single-element arrays.
        let mut bomb = Vec::new();
        for _ in 0..100 {
            bomb.push(TAG_ARR);
            bomb.extend_from_slice(&1u32.to_le_bytes());
        }
        bomb.push(TAG_NULL);
        assert_eq!(decode(&bomb), Err(BinError::TooDeep));
    }
}
