//! Length-prefixed framing: each frame is a 4-byte little-endian payload
//! length followed by the payload bytes.  The prefix is validated against
//! [`crate::MAX_FRAME`] *before* any allocation, so a hostile peer cannot
//! make the server reserve gigabytes with four bytes of input.

use crate::MAX_FRAME;

/// Size of the length prefix.
pub const HEADER_LEN: usize = 4;

#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum FrameError {
    /// `len` is u64: the offending length comes straight off the wire
    /// and must survive reporting even where it exceeds `usize::MAX`.
    #[error("frame of {len} bytes exceeds the {max}-byte limit")]
    TooLarge { len: u64, max: usize },
}

/// Total length (header + payload) of the first frame in `buf`, if a
/// complete header is present.  `Ok(None)` means "need more bytes";
/// `Err(TooLarge)` is fatal for the connection and is raised as soon as
/// the header arrives, even if the payload never does.
pub fn first_frame_len(buf: &[u8]) -> Result<Option<usize>, FrameError> {
    if buf.len() < HEADER_LEN {
        return Ok(None);
    }
    // Widen to u64 before adding the header: on a 32-bit host
    // `HEADER_LEN + (u32::MAX as usize)` wraps, and the wrapped total
    // would sail under MAX_FRAME and be treated as a tiny valid frame.
    let len = u64::from(u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]));
    let total = HEADER_LEN as u64 + len;
    if total > MAX_FRAME as u64 {
        return Err(FrameError::TooLarge { len: total, max: MAX_FRAME });
    }
    Ok(Some(total as usize))
}

/// Append one framed payload to `out`.
///
/// Panics if `payload` exceeds [`MAX_FRAME`]; encoders own their payload
/// sizes, so this is a programming error rather than a wire condition.
pub fn encode_into(payload: &[u8], out: &mut Vec<u8>) {
    assert!(
        HEADER_LEN + payload.len() <= MAX_FRAME,
        "frame payload of {} bytes exceeds MAX_FRAME",
        payload.len()
    );
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_partial_frames() {
        let mut buf = Vec::new();
        encode_into(b"hello", &mut buf);
        encode_into(b"", &mut buf);
        assert_eq!(first_frame_len(&buf).unwrap(), Some(9));
        assert_eq!(&buf[HEADER_LEN..9], b"hello");
        assert_eq!(first_frame_len(&buf[9..]).unwrap(), Some(4));
        // Incomplete header: need more bytes, no error.
        assert_eq!(first_frame_len(&buf[..3]).unwrap(), None);
        // Complete header, incomplete payload: still a valid prefix.
        assert_eq!(first_frame_len(&buf[..6]).unwrap(), Some(9));
    }

    #[test]
    fn oversized_prefix_is_rejected_before_payload_arrives() {
        let mut buf = (MAX_FRAME as u32).to_le_bytes().to_vec();
        buf.push(0);
        assert_eq!(
            first_frame_len(&buf),
            Err(FrameError::TooLarge {
                len: (HEADER_LEN + MAX_FRAME) as u64,
                max: MAX_FRAME
            })
        );
        let huge = u32::MAX.to_le_bytes();
        assert!(first_frame_len(&huge).is_err());
    }

    #[test]
    fn largest_legal_frame_is_accepted() {
        let len = (MAX_FRAME - HEADER_LEN) as u32;
        assert_eq!(
            first_frame_len(&len.to_le_bytes()).unwrap(),
            Some(MAX_FRAME)
        );
    }
}
