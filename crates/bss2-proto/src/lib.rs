//! Wire protocol for the bss2 serving layer (DESIGN.md §14).
//!
//! This crate is the shared language between `bss2-client` and the server
//! in the core crate, deliberately free of any engine/fleet dependency:
//!
//! * [`json`] — the JSON value type and parser/writer used by both the
//!   legacy line-oriented protocol and the artifact formats.
//! * [`frame`] — length-prefixed binary framing (u32 LE prefix, 8 MiB cap).
//! * [`bin`] — compact tagged binary encoding of [`json::Json`] values,
//!   with a packed-u16 fast path for ECG sample arrays.
//! * [`handshake`] — the 8-byte magic/version/encoding negotiation that
//!   selects framed-binary, framed-JSON, or the legacy line protocol.
//!
//! Wire limits that both sides must agree on live here too, so the client
//! crate can validate requests before they ever hit a socket.

pub mod bin;
pub mod frame;
pub mod handshake;
pub mod json;

/// Protocol version spoken by this build (negotiated in the handshake).
pub const PROTO_VERSION: u16 = 1;

/// Hard cap on a single frame (header + payload), and on a single legacy
/// JSON line.  A full `classify_batch` of 64 two-channel windows is ~1.2 MiB
/// as text; 8 MiB leaves generous headroom without letting one connection
/// balloon the server's buffers.
pub const MAX_FRAME: usize = 8 * 1024 * 1024;

/// Cap on one request line in the legacy line-JSON mode (same budget).
pub const MAX_LINE: usize = MAX_FRAME;

/// Most traces one `classify_batch` request may carry.
pub const MAX_WIRE_BATCH: usize = 64;

/// Most measurement repetitions one `recalibrate` request may ask for.
pub const MAX_RECALIB_REPS: usize = 1024;

/// Most samples per channel in one `stream_push` chunk.
pub const MAX_STREAM_CHUNK: usize = 16384;

/// Pipelining depth: how many replies may be pending per connection before
/// the server stops reading further requests from it (backpressure).
pub const PENDING_REPLY_DEPTH: usize = 256;
