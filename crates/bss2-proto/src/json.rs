//! Minimal JSON parser + writer (serde is not available offline).
//!
//! Supports the full JSON grammar; numbers are kept as f64 (sufficient for
//! our artifacts: 6-bit weights, f32 calibration, hashes as strings).
//! The parser is a single-pass recursive-descent over bytes and comfortably
//! handles the multi-megabyte test-vector files.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // --- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that errors with the key name (for artifacts).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Strict unsigned-integer view: `Some` only for non-negative whole
    /// numbers.  Prefer this over [`as_usize`](Json::as_usize) when a
    /// malformed field must be *rejected* — the lossy cast there maps
    /// -1 and 0.5 to perfectly valid values.
    pub fn as_uint(&self) -> Option<u64> {
        self.as_f64()
            .filter(|v| *v >= 0.0 && v.fract() == 0.0)
            .map(|v| v as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a (possibly nested) numeric array into `out`.
    pub fn flatten_numbers(&self, out: &mut Vec<f64>) -> anyhow::Result<()> {
        match self {
            Json::Num(x) => out.push(*x),
            Json::Arr(v) => {
                for item in v {
                    item.flatten_numbers(out)?;
                }
            }
            other => anyhow::bail!("expected number/array, got {other:?}"),
        }
        Ok(())
    }

    pub fn to_f32_vec(&self) -> anyhow::Result<Vec<f32>> {
        let mut tmp = Vec::new();
        self.flatten_numbers(&mut tmp)?;
        Ok(tmp.into_iter().map(|x| x as f32).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    #[inline]
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    #[inline]
    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        let rest = self.b.get(self.pos..).unwrap_or_default();
        if rest.starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let digits = self.b.get(start..self.pos).unwrap_or_default();
        let text = std::str::from_utf8(digits)
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(c) if c < 0x80 => {
                    s.push(c as char);
                    self.pos += 1;
                }
                Some(_) => {
                    // Multi-byte UTF-8: copy the full scalar.
                    let tail = self.b.get(self.pos..).unwrap_or_default();
                    let rest = std::str::from_utf8(tail)
                        .map_err(|_| self.err("invalid utf8"))?;
                    let Some(ch) = rest.chars().next() else {
                        return Err(self.err("invalid utf8"));
                    };
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

// --- writer ------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => {
                            write!(f, "\\u{:04x}", c as u32)?
                        }
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn as_uint_rejects_what_as_usize_mangles() {
        assert_eq!(Json::Num(7.0).as_uint(), Some(7));
        assert_eq!(Json::Num(0.0).as_uint(), Some(0));
        assert_eq!(Json::Num(-1.0).as_uint(), None);
        assert_eq!(Json::Num(0.5).as_uint(), None);
        assert_eq!(Json::Str("7".into()).as_uint(), None);
        // ...whereas the lossy cast happily accepts the first two.
        assert_eq!(Json::Num(-1.0).as_usize(), Some(0));
        assert_eq!(Json::Num(0.5).as_usize(), Some(0));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#)
            .unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1], Json::Num(2.0));
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""é\t\"""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é\t\"");
    }

    #[test]
    fn reject_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"x"},"d":true}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn flatten_nested_numbers() {
        let v = Json::parse("[[1,2],[3,[4]]]").unwrap();
        let mut out = Vec::new();
        v.flatten_numbers(&mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn flatten_rejects_strings() {
        let v = Json::parse("[1,\"x\"]").unwrap();
        let mut out = Vec::new();
        assert!(v.flatten_numbers(&mut out).is_err());
    }

    #[test]
    fn big_flat_array_performance_smoke() {
        let body: Vec<String> = (0..50_000).map(|i| i.to_string()).collect();
        let text = format!("[{}]", body.join(","));
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 50_000);
    }
}
