//! Framed TCP client for the bss2 serving layer (DESIGN.md §14).
//!
//! Opens with the `bss2-proto` handshake (version + encoding), then
//! exchanges length-prefixed frames: JSON text or the compact binary
//! value encoding — the latter packs the 12-bit ECG sample arrays at two
//! bytes per sample instead of ~5 characters each.  The request/reply
//! values and their semantics are identical across encodings (and
//! identical to the legacy line protocol); only the bytes differ.
//!
//! The client is deliberately value-oriented: [`Client::call`] takes and
//! returns [`Json`], with thin typed helpers (`classify`, `stream_push`,
//! …) for the common commands.  Requests pipeline: any number of
//! `send*` calls may be issued before the matching `read_reply` calls —
//! the server resolves replies in request order.
//!
//! ```no_run
//! use bss2_client::{Client, Json, Options};
//!
//! let mut cl = Client::connect("127.0.0.1:7433", Options::default())?;
//! cl.ping()?;
//! let trace = vec![vec![2048u16; 2048], vec![2048u16; 2048]];
//! let reply = cl.classify(&trace)?;
//! assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
//! # Ok::<(), bss2_client::ClientError>(())
//! ```

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use bss2_proto::handshake::{self, AckError};
use bss2_proto::{bin, frame};

// Re-exported so consumers don't need a direct bss2-proto dependency to
// build requests or inspect replies.
pub use bss2_proto::handshake::Encoding;
pub use bss2_proto::json::Json;
pub use bss2_proto::PROTO_VERSION;

/// Connection options.
#[derive(Debug, Clone)]
pub struct Options {
    /// Frame payload encoding to request (default: [`Encoding::Binary`]).
    pub encoding: Encoding,
    /// Read timeout applied to every reply wait (default: none — block
    /// forever, like the legacy client).  An expired timeout surfaces as
    /// the typed [`ClientError::Timeout`].
    pub read_timeout: Option<Duration>,
    /// Protocol version to claim in the hello.  Defaults to
    /// [`PROTO_VERSION`]; tests override it to provoke the server's
    /// version rejection.
    pub protocol_version: u16,
}

impl Default for Options {
    fn default() -> Options {
        Options {
            encoding: Encoding::Binary,
            read_timeout: None,
            protocol_version: PROTO_VERSION,
        }
    }
}

impl Options {
    /// The framed-JSON fallback encoding.
    pub fn json() -> Options {
        Options { encoding: Encoding::Json, ..Options::default() }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum ClientError {
    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),
    /// The configured `read_timeout` expired while waiting for a reply.
    #[error("timed out waiting for a reply")]
    Timeout,
    /// The server closed the connection (mid-frame or between frames).
    #[error("server closed the connection")]
    Closed,
    /// The server rejected the hello: it speaks protocol version
    /// `server_version`, we asked for something else.
    #[error("server rejected handshake: it speaks protocol version {server_version}")]
    VersionMismatch { server_version: u16 },
    /// The server rejected the requested frame encoding.
    #[error("server rejected the requested encoding")]
    EncodingRejected,
    /// The server's bytes violate the framed protocol.
    #[error("protocol error: {0}")]
    Protocol(String),
}

fn io_to_client(e: std::io::Error) -> ClientError {
    // A `read_timeout` expiry surfaces as WouldBlock on unix and
    // TimedOut on windows; both mean the same thing to callers.
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            ClientError::Timeout
        }
        _ => ClientError::Io(e),
    }
}

/// A connected framed client.
pub struct Client {
    stream: TcpStream,
    /// Bytes read past the last complete frame.
    rbuf: Vec<u8>,
    encoding: Encoding,
}

impl Client {
    /// Connect and run the handshake.
    pub fn connect<A: ToSocketAddrs>(
        addr: A,
        options: Options,
    ) -> Result<Client, ClientError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(options.read_timeout)?;
        let mut client =
            Client { stream, rbuf: Vec::new(), encoding: options.encoding };
        client.stream.write_all(&handshake::hello_bytes(
            options.protocol_version,
            options.encoding,
        ))?;
        let mut ack = [0u8; handshake::LEN];
        client.read_exact_buffered(&mut ack)?;
        match handshake::evaluate_ack(&ack) {
            Ok(encoding) => {
                // The server echoes what it accepted; trust its answer.
                client.encoding = encoding;
                Ok(client)
            }
            Err(AckError::Rejected { server_version: _, reason })
                if reason == handshake::REJECT_ENCODING =>
            {
                Err(ClientError::EncodingRejected)
            }
            Err(AckError::Rejected { server_version, .. }) => {
                Err(ClientError::VersionMismatch { server_version })
            }
            Err(e) => Err(ClientError::Protocol(e.to_string())),
        }
    }

    /// The negotiated frame encoding.
    pub fn encoding(&self) -> Encoding {
        self.encoding
    }

    /// Adjust the reply-wait timeout on the live connection.
    pub fn set_read_timeout(
        &mut self,
        timeout: Option<Duration>,
    ) -> Result<(), ClientError> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    /// Split the connection: the clone shares the socket and encoding.
    /// Intended for one-direction-per-half use (a sender thread and a
    /// reader thread); the receive buffer is *not* shared, so only one
    /// half may ever call `read_reply`.
    pub fn try_clone(&self) -> Result<Client, ClientError> {
        Ok(Client {
            stream: self.stream.try_clone()?,
            rbuf: Vec::new(),
            encoding: self.encoding,
        })
    }

    /// Send one request frame without waiting for the reply (pipelining).
    pub fn send(&mut self, req: &Json) -> Result<(), ClientError> {
        let mut out = Vec::new();
        match self.encoding {
            Encoding::Json => {
                frame::encode_into(req.to_string().as_bytes(), &mut out)
            }
            Encoding::Binary => {
                frame::encode_into(&bin::encode(req), &mut out)
            }
        }
        self.stream.write_all(&out)?;
        Ok(())
    }

    /// Read the next reply frame and decode it.
    pub fn read_reply(&mut self) -> Result<Json, ClientError> {
        let payload = self.read_frame()?;
        match self.encoding {
            Encoding::Json => {
                let text = std::str::from_utf8(&payload).map_err(|_| {
                    ClientError::Protocol(
                        "reply frame is not valid UTF-8".into(),
                    )
                })?;
                Json::parse(text).map_err(|e| {
                    ClientError::Protocol(format!("bad reply json: {e}"))
                })
            }
            Encoding::Binary => bin::decode(&payload).map_err(|e| {
                ClientError::Protocol(format!("bad reply encoding: {e}"))
            }),
        }
    }

    /// Send one request and wait for its reply.
    pub fn call(&mut self, req: &Json) -> Result<Json, ClientError> {
        self.send(req)?;
        self.read_reply()
    }

    // -- typed helpers ------------------------------------------------

    pub fn ping(&mut self) -> Result<Json, ClientError> {
        self.call(&obj(&[("cmd", Json::Str("ping".into()))]))
    }

    /// Classify one two-channel trace of raw 12-bit samples.
    pub fn classify(&mut self, trace: &[Vec<u16>]) -> Result<Json, ClientError> {
        self.send_classify(trace)?;
        self.read_reply()
    }

    /// Pipelined [`Client::classify`]: send without reading the reply.
    pub fn send_classify(
        &mut self,
        trace: &[Vec<u16>],
    ) -> Result<(), ClientError> {
        self.send(&obj(&[
            ("cmd", Json::Str("classify".into())),
            ("trace", samples_json(trace)),
        ]))
    }

    pub fn classify_batch(
        &mut self,
        traces: &[Vec<Vec<u16>>],
    ) -> Result<Json, ClientError> {
        let arr =
            Json::Arr(traces.iter().map(|t| samples_json(t)).collect());
        self.call(&obj(&[
            ("cmd", Json::Str("classify_batch".into())),
            ("traces", arr),
        ]))
    }

    /// Open a streaming session (`hop` in samples, `None` for the
    /// server default).
    pub fn stream_open(
        &mut self,
        hop: Option<usize>,
    ) -> Result<Json, ClientError> {
        let mut fields = vec![("cmd", Json::Str("stream_open".into()))];
        if let Some(hop) = hop {
            fields.push(("hop", Json::Num(hop as f64)));
        }
        self.call(&obj(&fields))
    }

    /// Push one chunk of the continuous two-channel stream.  Window
    /// results arrive asynchronously via [`Client::read_reply`].
    pub fn stream_push(
        &mut self,
        chunk: &[Vec<u16>],
    ) -> Result<(), ClientError> {
        self.send(&obj(&[
            ("cmd", Json::Str("stream_push".into())),
            ("samples", samples_json(chunk)),
        ]))
    }

    pub fn stream_close(&mut self) -> Result<(), ClientError> {
        self.send(&obj(&[("cmd", Json::Str("stream_close".into()))]))
    }

    // -- framing ------------------------------------------------------

    /// Read until `buf` is full, consuming buffered bytes first.
    fn read_exact_buffered(
        &mut self,
        buf: &mut [u8],
    ) -> Result<(), ClientError> {
        while self.rbuf.len() < buf.len() {
            self.fill_rbuf()?;
        }
        buf.copy_from_slice(&self.rbuf[..buf.len()]);
        self.rbuf.drain(..buf.len());
        Ok(())
    }

    /// Read the next complete frame payload.
    fn read_frame(&mut self) -> Result<Vec<u8>, ClientError> {
        loop {
            match frame::first_frame_len(&self.rbuf) {
                Err(e) => {
                    return Err(ClientError::Protocol(e.to_string()));
                }
                Ok(Some(total)) if self.rbuf.len() >= total => {
                    let payload =
                        self.rbuf[frame::HEADER_LEN..total].to_vec();
                    self.rbuf.drain(..total);
                    return Ok(payload);
                }
                Ok(_) => self.fill_rbuf()?,
            }
        }
    }

    fn fill_rbuf(&mut self) -> Result<(), ClientError> {
        let mut chunk = [0u8; 8192];
        let n = self.stream.read(&mut chunk).map_err(io_to_client)?;
        if n == 0 {
            return Err(ClientError::Closed);
        }
        self.rbuf.extend_from_slice(&chunk[..n]);
        Ok(())
    }
}

/// Build a JSON object from (key, value) pairs.
fn obj(fields: &[(&str, Json)]) -> Json {
    let mut map = std::collections::BTreeMap::new();
    for (k, v) in fields {
        map.insert((*k).to_string(), v.clone());
    }
    Json::Obj(map)
}

/// Channels of u16 samples as nested JSON arrays.  Under the binary
/// encoding these hit the packed-u16 array representation on the wire.
fn samples_json(channels: &[Vec<u16>]) -> Json {
    Json::Arr(
        channels
            .iter()
            .map(|ch| {
                Json::Arr(
                    ch.iter().map(|&s| Json::Num(f64::from(s))).collect(),
                )
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_objects_have_the_wire_shape() {
        let req = obj(&[
            ("cmd", Json::Str("classify".into())),
            ("trace", samples_json(&[vec![1, 2], vec![3, 4]])),
        ]);
        assert_eq!(
            req.to_string(),
            "{\"cmd\":\"classify\",\"trace\":[[1,2],[3,4]]}"
        );
        // Binary: the sample arrays take the packed-u16 path.
        let bytes = bin::encode(&req);
        assert_eq!(bin::decode(&bytes).unwrap(), req);
    }

    #[test]
    fn timeout_maps_from_both_io_kinds() {
        for kind in [
            std::io::ErrorKind::WouldBlock,
            std::io::ErrorKind::TimedOut,
        ] {
            assert!(matches!(
                io_to_client(std::io::Error::new(kind, "t/o")),
                ClientError::Timeout
            ));
        }
        assert!(matches!(
            io_to_client(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "rst"
            )),
            ClientError::Io(_)
        ));
    }
}
