//! CLI-level determinism acceptance tests.
//!
//! These live in the bss2-cli crate because `CARGO_BIN_EXE_repro` is
//! only defined for the package that owns the `repro` binary; the
//! engine-level counterparts live in the bss2 crate's integration
//! suites (`tests/chaos.rs`, `tests/train_loop.rs`).

use bss2::util::json::Json;

/// Acceptance criterion: `repro chaos --chips 4 --seed 1` is
/// deterministic across runs — the survival report is byte-identical.
#[test]
fn chaos_cli_survival_report_is_deterministic() {
    let exe = env!("CARGO_BIN_EXE_repro");
    let run = || {
        std::process::Command::new(exe)
            .args(["chaos", "--chips", "4", "--seed", "1"])
            .output()
            .expect("repro chaos runs")
    };
    let a = run();
    assert!(
        a.status.success(),
        "chaos run failed: {}",
        String::from_utf8_lossy(&a.stderr)
    );
    let report = String::from_utf8_lossy(&a.stdout);
    assert!(report.contains("[chaos] verdict:"), "{report}");
    assert!(report.contains("0 lost"), "no reply may fall silent: {report}");
    let b = run();
    assert_eq!(
        a.stdout, b.stdout,
        "survival report must be byte-identical across runs"
    );
    // A different seed draws a different plan (and prints it).
    let c = std::process::Command::new(exe)
        .args(["chaos", "--chips", "4", "--seed", "2"])
        .output()
        .expect("repro chaos runs");
    assert!(c.status.success());
    assert_ne!(a.stdout, c.stdout, "different seed, different report");
}

/// `repro chaos --json` is the machine-readable twin of the survival
/// report: still byte-identical per seed (no wall-clock fields), and it
/// parses as one JSON object with the survival verdict.
#[test]
fn chaos_cli_json_report_is_deterministic_and_parses() {
    let exe = env!("CARGO_BIN_EXE_repro");
    let run = || {
        std::process::Command::new(exe)
            .args(["chaos", "--chips", "4", "--seed", "1", "--json"])
            .output()
            .expect("repro chaos runs")
    };
    let a = run();
    assert!(
        a.status.success(),
        "chaos --json run failed: {}",
        String::from_utf8_lossy(&a.stderr)
    );
    let b = run();
    assert_eq!(
        a.stdout, b.stdout,
        "json report must be byte-identical across runs"
    );
    let text = String::from_utf8_lossy(&a.stdout);
    let report = Json::parse(text.trim()).expect("json report parses");
    assert_eq!(
        report.get("lost").and_then(|v| v.as_uint()),
        Some(0),
        "{report}"
    );
    assert_eq!(report.get("seed").and_then(|v| v.as_uint()), Some(1));
    assert!(
        report.get("verdict").and_then(|v| v.as_str()).is_some(),
        "{report}"
    );
    assert_eq!(
        report.get("per_chip").and_then(|v| v.as_arr()).map(|a| a.len()),
        Some(4),
        "{report}"
    );
}

/// Acceptance criterion (ISSUE 8): two `repro train --seed S` runs
/// produce byte-identical `bss2-model-v1` artifacts (and byte-identical
/// stdout), while a different seed trains different weights.
#[test]
fn train_cli_artifact_is_deterministic_per_seed() {
    let exe = env!("CARGO_BIN_EXE_repro");
    let out = |tag: &str| {
        std::env::temp_dir().join(format!("bss2_train_determinism_{tag}.json"))
    };
    let run = |seed: &str, out_path: &std::path::Path| {
        std::process::Command::new(exe)
            .args([
                "train", "--epochs", "2", "--batch", "8", "--windows", "24",
                "--val-n", "4", "--seed", seed, "--out",
            ])
            .arg(out_path)
            .output()
            .expect("repro train runs")
    };
    let (pa, pb, pc) = (out("a"), out("b"), out("c"));
    let a = run("5", &pa);
    assert!(
        a.status.success(),
        "train run failed: {}",
        String::from_utf8_lossy(&a.stderr)
    );
    let b = run("5", &pb);
    assert!(b.status.success());
    assert_eq!(
        a.stdout, b.stdout,
        "training summary must be byte-identical across runs"
    );
    let bytes_a = std::fs::read(&pa).expect("artifact a written");
    let bytes_b = std::fs::read(&pb).expect("artifact b written");
    assert!(!bytes_a.is_empty());
    assert_eq!(bytes_a, bytes_b, "same seed must train identical artifacts");
    // The artifact parses and is stamped with a real substrate.
    let j = Json::parse(std::str::from_utf8(&bytes_a).unwrap()).unwrap();
    assert_eq!(
        j.get("format").and_then(|v| v.as_str()),
        Some("bss2-model-v1"),
        "{j}"
    );
    assert_ne!(
        j.get("substrate").and_then(|v| v.as_str()),
        Some("0000000000000000"),
        "training must stamp the substrate it ran against"
    );
    let c = run("6", &pc);
    assert!(c.status.success());
    let bytes_c = std::fs::read(&pc).expect("artifact c written");
    assert_ne!(bytes_a, bytes_c, "different seed, different artifact");
    for p in [pa, pb, pc] {
        let _ = std::fs::remove_file(p);
    }
}
