//! `repro` — the BSS-2 mobile system CLI (leader entrypoint).
//!
//! Subcommands map 1:1 onto the paper's experiments (DESIGN.md §5):
//!
//! ```text
//! repro selftest                     artifact <-> engine roundtrip checks
//! repro table1  [--n 500]            paper Table 1 on the held-out test set
//! repro fig4    [--out fig4.csv]     membrane-integration trace (Fig 4)
//! repro fig7    [--out fig7.csv]     preprocessing-chain stages (Fig 7)
//! repro fig8                         pretty-print the training curve (Fig 8)
//! repro throughput                   Eq. 1-3 rates + area efficiency
//! repro baselines                    §V platform comparison
//! repro classify [--n 10]            classify synthetic traces (quickstart)
//! repro serve   [--addr host:port] [--chips N]   experiment execution
//!                                    service over a fleet of N replicas
//! repro loadgen [--conns 1000]       connection-model A/B load bench
//! repro snn     [--neurons 4]        spiking (AdEx) operation-mode demo
//! ```

mod loadgen;

use bss2::asic::consts as c;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use bss2::coordinator::batch;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::dataset::Dataset;
use bss2::ecg::gen::{generate_trace, TraceStream};
use bss2::runtime::ArtifactDir;
use bss2::util::cli::Args;

/// Counting wrapper over the system allocator.  `repro bench --area
/// simcore` gates on allocations-per-classify — a deterministic,
/// host-speed-independent measure of hot-path heap churn (DESIGN.md
/// §17).  The counter is one relaxed atomic add per allocation: noise
/// for a CLI, and every other subcommand is unaffected beyond that.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Heap allocations observed so far (alloc + alloc_zeroed + realloc).
fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn main() {
    env_logger_init();
    let (cmd, args) = Args::from_env();
    let result = match cmd.as_str() {
        "selftest" => selftest(&args),
        "table1" => table1(&args),
        "fig4" => fig4(&args),
        "fig7" => fig7(&args),
        "fig8" => fig8(&args),
        "throughput" => throughput(&args),
        "baselines" => baselines_cmd(&args),
        "classify" => classify(&args),
        "calibrate" => calibrate(&args),
        "train" => train(&args),
        "bench" => bench(&args),
        "chaos" => chaos(&args),
        "serve" => serve(&args),
        "loadgen" => loadgen::run(&args),
        "monitor" => monitor(&args),
        "snn" => snn(&args),
        "audit" => audit(&args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command `{other}` (try help)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
repro — BrainScaleS-2 mobile system reproduction

USAGE: repro <command> [options]

COMMANDS:
  selftest     artifact/engine roundtrip checks (run after `make artifacts`)
  table1       reproduce paper Table 1 on the held-out test set
  fig4         membrane-integration trace  (--out fig4.csv --col 0)
  fig7         preprocessing stages        (--out fig7.csv --seed 42 --afib)
  fig8         pretty-print the training curve
  throughput   Eq. 1-3: peak/effective rates, area efficiency
  baselines    §V energy comparison vs published platforms
  classify     classify synthetic traces   (--n 10 --native --batch 8)
  calibrate    full-chip calibration run   (--reps 64 --chip 0 --idle-us T
                                            --out FILE; writes the per-chip
                                            profile artifact)
  train        in-the-loop training        (--epochs 8 --batch 16 --lr 0.4
                                            --windows 192 --val-n 25 --seed 1
                                            --chip 0 --fault-plan P --out FILE
                                            --fpn-seed S --no-drift
                                            --ideal-substrate): mini-batch
                                            training on the simulated analog
                                            substrate (FPN + drift armed by
                                            default) with straight-through
                                            gradients on host shadow weights;
                                            writes the bss2-model-v1 artifact
                                            `repro serve --native` adopts.
                                            Deterministic per --seed.
  serve        experiment service          (--addr 127.0.0.1:7001 --native
                                            --chips 4 --queue-depth 32
                                            --max-conns 256 --conn-model M
                                            --allow-remote-shutdown): speaks
                                            both line-delimited JSON and the
                                            framed binary protocol (clients
                                            opt in via the 8-byte handshake;
                                            see DESIGN.md §14)
  loadgen      serving-layer load bench    (--conns 1000 --chips 2
                                            --pipeline 8 --requests 64
                                            --classify-n 4 --encoding binary
                                            --mode both --read-timeout-ms T
                                            --out FILE --gate BASELINE):
                                            measures framed ping throughput
                                            under both connection models
                                            (gated speedup_vs_threaded_x)
                                            plus classify latency
                                            percentiles and shed/backoff
                                            histograms -> BENCH_loadgen.json
  monitor      continuous ECG stream demo  (--minutes 3 --hop 512 --chips 2
                                            --chunk 450 --seed 99): streams
                                            an episode-labeled recording
                                            through a stream_open/push/close
                                            session and reports per-window
                                            results + afib detection latency
  chaos        seeded fault-injection soak (--chips 4 --seed 1 --requests 240
                                            --redirects 2 --fault-plan FILE):
                                            drives classify/batch/stream
                                            traffic into a fleet with faults
                                            armed and prints a deterministic
                                            survival report (same seed =
                                            byte-identical report)
  bench        deterministic perf benchmark (--area serving|batch|stream|
                                            drift|train|simcore --n 64
                                            --out FILE --gate BASELINE):
                                            writes BENCH_<area>.json with
                                            gated simulated-time/energy
                                            metrics (simcore gates heap
                                            allocs/classify; passes/s and
                                            ns/pass go to info); --gate
                                            fails (exit 1) when a gated
                                            metric regresses >20% against
                                            the baseline file
  snn          spiking-mode (AdEx) demo    (--neurons 4 --current 150)
  audit        workspace static analysis   (--json --gate FILE
                                            --write-baseline FILE): the
                                            bss2-lint determinism/panic-
                                            safety/lock-discipline pass
                                            (DESIGN.md §16); with no flags
                                            it gates against
                                            LINT_BASELINE.json

OPTIONS (common):
  --artifacts DIR   artifact directory (default: ./artifacts or $BSS2_ARTIFACTS)
  --native          use the in-process array model instead of PJRT
  --noise-off       disable temporal analog noise (ablation)
  --batch B         classify: samples per batched program (amortises the
                    per-layer weight reconfiguration; default 1)
  --chips N         serve: fleet of N engine replicas (default 1)
  --queue-depth M   serve: per-chip admission bound in samples before
                    shedding (classify_batch requests count per sample)
  --fpn-seed S      native backend: draw a per-chip fixed-pattern
                    realisation from seed S instead of the model's
                    calibration vectors (heterogeneous-silicon regime)
  --drift           native backend: enable the analog drift field (OU
                    gain/offset wander + temperature; calib::drift)
  --auto-recalib    serve: age-/margin-triggered auto-recalibration (one
                    chip drains into `calibrating` while the rest serve)
  --max-conns N     serve: cap on concurrent client connections; excess
                    connects get an explicit shed reply (default 256)
  --conn-model M    serve: connection handling — `readiness` (poll(2)
                    worker set multiplexing every connection; the default
                    on unix) or `threaded` (two threads per connection)
  --allow-remote-shutdown
                    serve: honour the wire `shutdown` command (default
                    off — an open port must not be a kill switch)
  --fault-plan P    serve/chaos: arm a fault schedule on the simulated
                    hardware — a JSON file path or an inline JSON object
                    (see DESIGN.md §12 for the format)
  --redirects K     serve/chaos: transparent-failover budget — how often
                    one failed job may be retried on a healthy replica
                    before its error reaches the client (default 2)
  --trace-sample N  serve: keep every Nth request span whole in the trace
                    ring for the `trace` wire command (default 16; 0
                    disables the ring — per-stage histograms, `metrics`
                    and `fleet_stats` always record)
  --json            chaos/monitor: emit one machine-readable JSON summary
                    object instead of the human report (chaos --json is
                    byte-identical per seed, like the text report)
";

fn env_logger_init() {
    // log crate without env_logger: print warnings+ to stderr.
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::Level::Info
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let _ = log::set_logger(&LOGGER)
        .map(|()| log::set_max_level(log::LevelFilter::Info));
}

fn artifact_dir(args: &Args) -> ArtifactDir {
    match args.get("artifacts") {
        Some(p) => ArtifactDir::new(p),
        None => ArtifactDir::default_location(),
    }
}

fn engine_config(args: &Args) -> anyhow::Result<EngineConfig> {
    // A typo'd seed must error, not silently fall back to different
    // silicon (same contract as `u64_or` on every other numeric option).
    // Seeds read naturally in hex, so a `0x` prefix is accepted too.
    let fpn_seed = match args.get("fpn-seed") {
        Some(s) => {
            let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => s.parse::<u64>(),
            };
            Some(parsed.map_err(|e| anyhow::anyhow!("--fpn-seed `{s}`: {e}"))?)
        }
        None => None,
    };
    Ok(EngineConfig {
        use_pjrt: !args.flag("native"),
        noise_off: args.flag("noise-off"),
        nominal_calib: args.flag("nominal-calib"),
        noise_seed: args.u64_or("noise-seed", 0x5EED)?,
        chip: 0,
        fpn_seed,
        drift: args
            .flag("drift")
            .then(bss2::calib::drift::DriftParams::default),
    })
}

fn make_engine(args: &Args) -> anyhow::Result<Engine> {
    Engine::from_artifacts(&artifact_dir(args), engine_config(args)?)
}

// --- selftest -----------------------------------------------------------------

fn selftest(args: &Args) -> anyhow::Result<()> {
    let dir = artifact_dir(args);
    dir.require()?;
    println!("[selftest] artifacts: {}", dir.root.display());
    let manifest = dir.load_manifest()?;
    println!(
        "[selftest] manifest ok (k={}, n={}, {} MACs)",
        manifest.k_logical, manifest.n_cols, manifest.macs_total
    );

    // 1. VMM artifact vs exported golden vectors (bit-exact).
    let rt = bss2::runtime::Runtime::cpu()?;
    let vmm = rt.load_vmm(&dir.vmm_hlo())?;
    let tv = std::fs::read_to_string(dir.path("vmm_testvec.json"))?;
    let tv = bss2::util::json::Json::parse(&tv)
        .map_err(|e| anyhow::anyhow!("vmm_testvec: {e}"))?;
    let cases = tv.req("cases")?.as_arr().unwrap();
    for (i, case) in cases.iter().enumerate() {
        let x = case.req("x")?.to_f32_vec()?;
        let w = case.req("w")?.to_f32_vec()?;
        let gain = case.req("gain")?.to_f32_vec()?;
        let offset = case.req("offset")?.to_f32_vec()?;
        let noise = case.req("noise")?.to_f32_vec()?;
        let scale = case.req("scale")?.as_f64().unwrap() as f32;
        let expected = case.req("expected")?.to_f32_vec()?;
        let staged = vmm.stage_pass(&w, &gain, &offset, scale)?;
        let got = vmm.run_pass(&staged, &x, &noise)?;
        anyhow::ensure!(got == expected, "vmm case {i} mismatch");
        println!("[selftest] vmm case {i}: OK ({} cols bit-exact)", got.len());
    }

    // 2. Fused model vs 3-pass engine (noise off; must agree bit-exactly).
    let model_exe = rt.load_model(&dir.model_hlo())?;
    let trained = bss2::nn::weights::TrainedModel::load(&dir.weights())?;
    model_exe.stage(&trained)?;
    let mv = std::fs::read_to_string(dir.path("model_testvec.json"))?;
    let mv = bss2::util::json::Json::parse(&mv)
        .map_err(|e| anyhow::anyhow!("model_testvec: {e}"))?;
    let mut engine = Engine::from_artifacts(
        &dir,
        EngineConfig { noise_off: true, ..engine_config(args)? },
    )?;
    for (i, case) in mv.req("cases")?.as_arr().unwrap().iter().enumerate() {
        let act = case.req("act")?.to_f32_vec()?;
        let want = case.req("scores")?.to_f32_vec()?;
        let fused = model_exe.run(&act)?;
        anyhow::ensure!(
            (fused[0] - want[0]).abs() < 1e-4
                && (fused[1] - want[1]).abs() < 1e-4,
            "fused model case {i}: got {fused:?} want {want:?}"
        );
        let acts_i: Vec<i32> = act.iter().map(|&a| a as i32).collect();
        let inf = engine.classify_acts(&acts_i)?;
        // Engine pools with integer rounding; allow 1 LSB.
        anyhow::ensure!(
            (inf.scores[0] - want[0]).abs() <= 1.0
                && (inf.scores[1] - want[1]).abs() <= 1.0,
            "engine case {i}: got {:?} want {want:?}",
            inf.scores
        );
        println!("[selftest] model case {i}: fused+engine OK");
    }
    println!("[selftest] ALL OK");
    Ok(())
}

// --- table1 -------------------------------------------------------------------

fn table1(args: &Args) -> anyhow::Result<()> {
    let dir = artifact_dir(args);
    let n = args.usize_or("n", 500)?;
    let ds = Dataset::load(&dir.ecg_test())?;
    anyhow::ensure!(!ds.is_empty(), "empty test set");
    let traces: Vec<_> = ds
        .traces
        .iter()
        .take(n)
        .map(|t| (t.clone(), t.label))
        .collect();
    println!(
        "[table1] classifying {} held-out traces (batch size 1, {}) ...",
        traces.len(),
        if args.flag("native") { "native backend" } else { "PJRT artifact" }
    );
    let mut engine = make_engine(args)?;
    let t0 = std::time::Instant::now();
    let rep = batch::run_block(&mut engine, &traces)?;
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", rep.table1());
    println!(
        "[table1] host wall-clock {:.2} s ({:.2} ms/inference); simulated \
         block time {:.1} ms",
        wall,
        wall * 1e3 / traces.len() as f64,
        rep.block_time_s * 1e3
    );
    println!(
        "[table1] paper reference: 276 µs, 5.6 W, 1.56 mJ, det 93.7±0.7 %, \
         fp 14.0±1.0 %"
    );
    Ok(())
}

// --- figures ------------------------------------------------------------------

fn fig4(args: &Args) -> anyhow::Result<()> {
    use bss2::asic::array::{AnalogArray, ColumnCalib};
    let col = args.usize_or("col", 0)?;
    let out = args.str_or("out", "artifacts/fig4_membrane.csv");
    // A single neuron column integrating a staged pulse train (Fig 4):
    // batches of events arrive back-to-back at 8 ns.
    let mut array = AnalogArray::new(16, 8, ColumnCalib::nominal(8));
    let mut w = vec![0i8; 16 * 8];
    for r in 0..16 {
        w[r * 8 + col] = if r % 3 == 2 { -20 } else { 30 };
    }
    array.load_weights(&w);
    let batches: Vec<Vec<u8>> = (0..16)
        .map(|r| {
            let mut b = vec![0u8; 16];
            b[r] = (5 + 2 * (r % 13)) as u8;
            b
        })
        .collect();
    let trace = array.membrane_trace(&batches, col, 0.012);
    let mut csv = String::from("t_ns,v_membrane_lsb\n");
    for (i, v) in trace.iter().enumerate() {
        csv.push_str(&format!("{},{v}\n", (i + 1) * 8));
    }
    std::fs::write(&out, &csv)?;
    println!(
        "[fig4] membrane trace of column {col}: {} samples -> {out}",
        trace.len()
    );
    println!("[fig4] V_out after integration: {:.1} LSB", trace.last().unwrap());
    Ok(())
}

fn fig7(args: &Args) -> anyhow::Result<()> {
    use bss2::fpga::preprocess;
    let seed = args.u64_or("seed", 42)?;
    let afib = args.flag("afib");
    let out = args.str_or("out", "artifacts/fig7_preprocess.csv");
    let trace = generate_trace(seed, afib, 1.0);
    let stages = preprocess::fig7_trace(&trace.samples[0]);
    let mut csv =
        String::from("sample,raw_u12,derivative,pooled_bin,pooled_maxmin,act_u5\n");
    for i in 0..c::ECG_WINDOW {
        let bin = i / c::POOL_WINDOW;
        csv.push_str(&format!(
            "{},{},{},{},{},{}\n",
            i,
            stages.raw[i],
            stages.derivative[i],
            bin,
            stages.pooled[bin],
            stages.activations[bin]
        ));
    }
    std::fs::write(&out, &csv)?;
    println!(
        "[fig7] preprocessing stages (label={}): raw {} samples -> {} x 5-bit -> {out}",
        trace.label,
        c::ECG_WINDOW,
        stages.activations.len()
    );
    Ok(())
}

fn fig8(args: &Args) -> anyhow::Result<()> {
    let dir = artifact_dir(args);
    let csv = std::fs::read_to_string(dir.path("fig8_training.csv"))?;
    println!("[fig8] training metrics (paper Fig 8 analogue):\n");
    println!(
        "{:>5} {:>11} {:>9} {:>9} {:>9} {:>6}",
        "epoch", "train_loss", "val_loss", "val_acc", "det", "fp"
    );
    let mut last = None;
    for line in csv.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() >= 7 {
            println!(
                "{:>5} {:>11.4} {:>9.4} {:>9.3} {:>9.3} {:>6.3}",
                f[0],
                f[1].parse::<f64>().unwrap_or(0.0),
                f[2].parse::<f64>().unwrap_or(0.0),
                f[4].parse::<f64>().unwrap_or(0.0),
                f[5].parse::<f64>().unwrap_or(0.0),
                f[6].parse::<f64>().unwrap_or(0.0)
            );
            last = Some(line.to_string());
        }
    }
    if let Some(l) = last {
        let f: Vec<&str> = l.split(',').collect();
        println!(
            "\n[fig8] final: val_acc={} det={} fp={} (paper: det 0.937, fp 0.140)",
            f[4], f[5], f[6]
        );
    }
    Ok(())
}

fn throughput(_args: &Args) -> anyhow::Result<()> {
    println!(
        "[throughput] paper Eq. 1: peak synapse rate = {:.1} TOp/s (paper: 32.8)",
        c::peak_ops_per_s() / 1e12
    );
    println!(
        "[throughput] paper Eq. 2: effective VMM rate = {:.1} GOp/s (paper: ~52)",
        c::effective_ops_per_s() / 1e9
    );
    println!(
        "[throughput] paper Eq. 3: MAC area efficiency = {:.2} TOp/(s mm²) (paper: 2.6)",
        c::area_efficiency_tops_mm2()
    );
    println!(
        "[throughput] full-die target: {:.2} TOp/(s mm²) (paper: >1)",
        c::peak_ops_per_s() / 1e12 / c::DIE_MM2
    );
    Ok(())
}

fn baselines_cmd(args: &Args) -> anyhow::Result<()> {
    use bss2::power::energy::cr2032_years;
    let bss2_mj = args.f64_or("bss2-mj", 1.56)?;
    println!("[baselines] §V energy comparison (per classification):");
    for (name, j, ratio) in bss2::baselines::comparison_table(bss2_mj * 1e-3) {
        println!("  {:<38} {:>12.4} mJ   {:>7.1}x", name, j * 1e3, ratio);
    }
    println!(
        "[baselines] CR2032 at 2-minute intervals: {:.1} years (paper: ~5)",
        cr2032_years(bss2_mj * 1e-3, 120.0)
    );
    Ok(())
}

// --- classify / serve / snn ----------------------------------------------------

fn classify(args: &Args) -> anyhow::Result<()> {
    let n = args.usize_or("n", 10)?;
    let batch = args.usize_or("batch", 1)?.max(1);
    let mut engine = make_engine(args)?;
    let traces: Vec<_> = TraceStream::new(args.u64_or("seed", 1)?, 1.0)
        .take(n)
        .collect();
    let mut correct = 0;
    let mut idx = 0usize;
    for chunk in traces.chunks(batch) {
        // One batched program per chunk: weight reconfiguration and the
        // control overhead amortise over `batch` samples; a batch of 1 is
        // the paper's 276 µs single-trace path.
        let infs = engine.classify_batch(chunk)?;
        for (trace, inf) in chunk.iter().zip(&infs) {
            let ok = inf.pred == trace.label;
            correct += ok as usize;
            println!(
                "trace {idx:3}  label={} pred={} scores=[{:+6.1} {:+6.1}]  \
                 {:.0} µs  {:.2} mJ  {}",
                trace.label,
                inf.pred,
                inf.scores[0],
                inf.scores[1],
                inf.sim_time_s * 1e6,
                inf.energy.total_j() * 1e3,
                if ok { "ok" } else { "MISS" }
            );
            idx += 1;
        }
    }
    println!(
        "[classify] {correct}/{} correct (batch size {batch})",
        traces.len()
    );
    Ok(())
}

/// Full-chip calibration run: measure both array halves, apply the
/// profile, and persist it as a per-chip artifact (`calib_chip{N}.json`).
/// Falls back to a synthetic native engine when no artifacts are present,
/// so the calibration loop is exercisable out of the box.
fn calibrate(args: &Args) -> anyhow::Result<()> {
    use bss2::nn::weights::TrainedModel;
    use bss2::util::stats::Summary;

    let chip = args.usize_or("chip", 0)?;
    let reps = args.usize_or("reps", 64)?.max(1);
    let idle_us = args.u64_or("idle-us", 0)?;
    let dir = artifact_dir(args);
    // The config goes through the same `for_chip(N)` per-ordinal split
    // as the replica `serve` builds for this ordinal, and the seed
    // defaults stay symmetric (no seed = the model's own calibration
    // vectors define the substrate, same as `serve --native`) — so a
    // profile measured here describes exactly the silicon it will later
    // be applied to.  Serve verifies that via the profile's substrate
    // hash; pass the same `--fpn-seed` to both to calibrate a synthetic
    // per-chip fixed pattern instead.
    let cfg = engine_config(args)?.for_chip(chip);
    let mut engine = if dir.exists() {
        Engine::from_artifacts(&dir, EngineConfig { use_pjrt: false, ..cfg })?
    } else {
        println!(
            "[calibrate] no artifacts under {} — synthetic native engine",
            dir.root.display()
        );
        Engine::native(
            TrainedModel::synthetic(0xF1EE7),
            EngineConfig { use_pjrt: false, ..cfg },
        )
    };
    if idle_us > 0 {
        engine.advance_idle_us(idle_us);
        println!("[calibrate] aged chip by {idle_us} µs of idle chip time");
    }

    let t0 = engine.chip_time_us();
    let profile = engine.recalibrate(reps)?;
    for h in 0..2 {
        let g: Vec<f64> =
            profile.gain[h].iter().map(|&v| v as f64).collect();
        let o: Vec<f64> =
            profile.offset[h].iter().map(|&v| v as f64).collect();
        let (gs, os) = (Summary::from(&g), Summary::from(&o));
        println!(
            "[calibrate] half {h}: gain {:.4} ± {:.4}, offset {:+.3} ± {:.3} \
             LSB, residual {:.3} LSB",
            gs.mean, gs.std, os.mean, os.std, profile.residual_rms[h]
        );
    }
    println!(
        "[calibrate] chip {chip}: measured at t={t0} µs with {reps} reps \
         (cost {:.0} µs of chip time); profile applied to the serving path",
        bss2::calib::CalibProfile::measurement_cost_us(reps)
    );

    let out = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => dir.calib_profile(chip),
    };
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    profile.save(&out)?;
    println!("[calibrate] profile -> {}", out.display());
    Ok(())
}

/// Hardware-in-the-loop training: run the mini-batch loop against the
/// simulated analog substrate and write the versioned `bss2-model-v1`
/// artifact `repro serve --native` adopts.  Deterministic per `--seed` —
/// two runs with the same flags produce byte-identical artifacts.
fn train(args: &Args) -> anyhow::Result<()> {
    use bss2::train::{TrainConfig, Trainer, TRAIN_FPN_SEED};

    let dir = artifact_dir(args);
    let chip = args.usize_or("chip", 0)?;
    let mut ecfg = engine_config(args)?;
    // Gradient taps and per-step weight reload are native-only.
    ecfg.use_pjrt = false;
    // Train against realistic silicon by default: a fixed-pattern
    // realisation (TRAIN_FPN_SEED unless --fpn-seed chose one) with the
    // drift field advancing.  --ideal-substrate / --no-drift opt out
    // for ablations.
    if ecfg.fpn_seed.is_none() && !args.flag("ideal-substrate") {
        ecfg.fpn_seed = Some(TRAIN_FPN_SEED);
    }
    if ecfg.drift.is_none() && !args.flag("no-drift") {
        ecfg.drift = Some(bss2::calib::drift::DriftParams::default());
    }
    let defaults = TrainConfig::default();
    let cfg = TrainConfig {
        epochs: args.usize_or("epochs", defaults.epochs)?.max(1),
        batch: args.usize_or("batch", defaults.batch)?.max(1),
        windows: args.usize_or("windows", defaults.windows)?.max(2),
        val_per_class: args.usize_or("val-n", defaults.val_per_class)?.max(1),
        lr: args.f64_or("lr", defaults.lr)?,
        momentum: args.f64_or("momentum", defaults.momentum)?,
        temperature: args.f64_or("temperature", defaults.temperature)?,
        seed: args.u64_or("seed", defaults.seed)?,
        fault_plan: match args.get("fault-plan") {
            Some(p) => Some(bss2::fault::FaultPlan::load(p)?),
            None => None,
        },
        engine: ecfg.for_chip(chip),
        ..defaults
    };
    let out_path = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => dir.trained_model(),
    };
    println!(
        "[train] {} epochs x {} windows (batch {}), seed {}, \
         substrate: fpn {}, drift {}, faults {}",
        cfg.epochs,
        cfg.windows,
        cfg.batch,
        cfg.seed,
        match cfg.engine.fpn_seed {
            Some(s) => format!("{s:#x}"),
            None => "model-defined".into(),
        },
        if cfg.engine.drift.is_some() { "on" } else { "off" },
        if cfg.fault_plan.is_some() { "armed" } else { "none" }
    );
    let outcome = Trainer::run(&cfg)?;
    let r = &outcome.report;
    for e in 0..r.epoch_loss.len() {
        println!(
            "[train] epoch {:>2}: loss {:.4}  val det {:.3} fp {:.3}",
            e + 1,
            r.epoch_loss[e],
            r.epoch_val[e].0,
            r.epoch_val[e].1
        );
    }
    println!(
        "[train] final: det {:.3} fp {:.3} over {} train windows \
         ({} sinus / {} afib), {} steps, {:.1} µs chip time/step{}",
        r.final_det,
        r.final_fp,
        r.train_windows[0] + r.train_windows[1],
        r.train_windows[0],
        r.train_windows[1],
        r.steps,
        r.chip_us_per_step,
        if r.skipped_batches > 0 {
            format!(", {} batch(es) lost to faults", r.skipped_batches)
        } else {
            String::new()
        }
    );
    match r.epochs_to_target {
        Some(e) => println!("[train] target band reached at epoch {e}"),
        None => println!("[train] target band not reached"),
    }
    if let Some(parent) = out_path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent).map_err(|e| {
                anyhow::anyhow!("creating {}: {e}", parent.display())
            })?;
        }
    }
    outcome.artifact.save(&out_path)?;
    println!(
        "[train] artifact (substrate {:016x}) -> {}",
        outcome.artifact.substrate,
        out_path.display()
    );
    Ok(())
}

/// Deterministic perf benchmark with a persisted trajectory: run one
/// serving area against the native engine, write `BENCH_<area>.json`, and
/// optionally gate against a committed baseline file.
///
/// Gated metrics are *simulated* chip time and energy — pure functions of
/// the model, so a regression means the timing/energy model (or the code
/// path feeding it) changed, never that CI ran on a slower machine.  Host
/// wall-clock goes into `info` for trend-watching only.  The `train` area
/// gates training *quality* instead: the deterministic trained artifact's
/// detection rate on the accuracy pin's held-out seeds (higher is better).
/// The `simcore` area gates hot-loop heap churn (allocations per classify,
/// counted by the process-wide [`CountingAlloc`]) — deterministic per
/// binary, so it too is host-speed-independent.
fn bench(args: &Args) -> anyhow::Result<()> {
    use bss2::nn::weights::TrainedModel;
    use std::fmt::Write as _;

    let area = args.str_or("area", "serving");
    let n = args.usize_or("n", 64)?.max(1);
    let seed = args.u64_or("seed", 7)?;
    let default_out = format!("BENCH_{area}.json");
    let out = args.str_or("out", &default_out);
    let mk = |chip: usize| {
        Engine::native(
            TrainedModel::synthetic(0xF1EE7),
            EngineConfig {
                use_pjrt: false,
                noise_off: true,
                ..Default::default()
            }
            .for_chip(chip),
        )
    };

    // (metric name, value, polarity).  The polarity is written into the
    // file so the gate reads each metric's regression direction from the
    // committed baseline (time/energy gate lower-is-better; the train
    // area's detection rate gates higher-is-better).
    let mut gated: Vec<(&str, f64, &str)> = Vec::new();
    // Ungated context metrics, recorded in the file's `info` object.
    let mut info: Vec<(&str, f64)> = Vec::new();
    let t0 = std::time::Instant::now();
    match area.as_str() {
        "serving" => {
            // The paper's single-trace path: 276 µs/sample, ~1.56 mJ.
            let mut engine = mk(0);
            let traces: Vec<_> = TraceStream::new(seed, 1.0).take(n).collect();
            let (mut sim_s, mut e_j) = (0.0, 0.0);
            for t in &traces {
                let inf = &engine.classify_batch(std::slice::from_ref(t))?[0];
                sim_s += inf.sim_time_s;
                e_j += inf.energy.total_j();
            }
            gated.push(("us_per_sample", sim_s * 1e6 / n as f64, "lower"));
            gated.push((
                "energy_mj_per_sample",
                e_j * 1e3 / n as f64,
                "lower",
            ));
        }
        "batch" => {
            // Amortised path: one weight reconfiguration per layer per
            // batch (DESIGN.md §9).
            let batch = args.usize_or("batch", 32)?.max(1);
            let mut engine = mk(0);
            let traces: Vec<_> = TraceStream::new(seed, 1.0).take(n).collect();
            let (mut sim_s, mut e_j, mut served) = (0.0, 0.0, 0usize);
            for chunk in traces.chunks(batch) {
                for inf in engine.classify_batch(chunk)? {
                    sim_s += inf.sim_time_s;
                    e_j += inf.energy.total_j();
                    served += 1;
                }
            }
            gated.push((
                "us_per_sample",
                sim_s * 1e6 / served as f64,
                "lower",
            ));
            gated.push((
                "energy_mj_per_sample",
                e_j * 1e3 / served as f64,
                "lower",
            ));
        }
        "stream" => {
            // The monitoring path: preprocessed windows via classify_acts
            // (no per-window weight rewrite of the conv layer input).
            let mut engine = mk(0);
            let traces: Vec<_> = TraceStream::new(seed, 1.0).take(n).collect();
            let (mut sim_s, mut e_j) = (0.0, 0.0);
            for t in &traces {
                let acts: Vec<i32> =
                    bss2::fpga::preprocess::preprocess(&t.samples)
                        .into_iter()
                        .map(|a| a as i32)
                        .collect();
                let inf = engine.classify_acts(&acts)?;
                sim_s += inf.sim_time_s;
                e_j += inf.energy.total_j();
            }
            gated.push(("us_per_window", sim_s * 1e6 / n as f64, "lower"));
            gated.push((
                "energy_mj_per_window",
                e_j * 1e3 / n as f64,
                "lower",
            ));
        }
        "drift" => {
            // Drift-compensation loop: age a drifting chip, recalibrate,
            // and gate the residual and the measurement's chip-time cost.
            let reps = args.usize_or("reps", 32)?.max(1);
            let mut engine = Engine::native(
                TrainedModel::synthetic(0xF1EE7),
                EngineConfig {
                    use_pjrt: false,
                    noise_off: true,
                    fpn_seed: Some(0xD21F7),
                    drift: Some(bss2::calib::drift::DriftParams::default()),
                    ..Default::default()
                },
            );
            engine.advance_idle_us(5_000_000);
            let profile = engine.recalibrate(reps)?;
            let residual = (profile.residual_rms[0] as f64
                + profile.residual_rms[1] as f64)
                / 2.0;
            gated.push(("residual_rms_lsb", residual, "lower"));
            gated.push((
                "recalib_cost_us",
                bss2::calib::CalibProfile::measurement_cost_us(reps),
                "lower",
            ));
        }
        "train" => {
            // In-the-loop training quality: run a short training session
            // against the default training substrate (FPN + drift), then
            // evaluate the artifact on the accuracy pin's held-out (odd)
            // eval seeds with a *fresh* engine reconstructed from the
            // artifact — the exact serve-side adoption path.
            use bss2::train::{TrainConfig, Trainer};
            let cfg = TrainConfig {
                epochs: args.usize_or("epochs", 6)?.max(1),
                batch: args.usize_or("batch", 16)?.max(1),
                windows: 160,
                val_per_class: 16,
                seed,
                ..TrainConfig::default()
            };
            let outcome = Trainer::run(&cfg)?;
            let art = &outcome.artifact;
            let mut engine =
                Engine::native(art.model.clone(), art.engine_config());
            let per_class = n.min(50);
            let (mut det, mut fp) = (0usize, 0usize);
            for i in 0..per_class {
                let s = 2 * i as u64 + 1;
                let afib = generate_trace(20_000 + s, true, 1.0);
                let sinus = generate_trace(10_000 + s, false, 1.0);
                let pa = engine
                    .classify_batch(std::slice::from_ref(&afib))?[0]
                    .pred;
                let ps = engine
                    .classify_batch(std::slice::from_ref(&sinus))?[0]
                    .pred;
                det += usize::from(pa == 1);
                fp += usize::from(ps == 1);
            }
            let det_rate = det as f64 / per_class as f64;
            let fp_rate = fp as f64 / per_class as f64;
            gated.push(("detection_rate", det_rate, "higher"));
            info.push(("false_positive_rate", fp_rate));
            info.push(("margin", det_rate - fp_rate));
            info.push((
                "epochs_to_target",
                outcome.report.epochs_to_target.map_or(-1.0, |e| e as f64),
            ));
            info.push((
                "chip_us_per_step",
                outcome.report.chip_us_per_step,
            ));
        }
        "simcore" => {
            // The simulation-core hot loop (ROADMAP item 2): steady-state
            // `classify_batch` on the native engine with noise ON, so the
            // scratch-buffer executor *and* the flat batch-major noise
            // bank are both on the measured path (DESIGN.md §17).  The
            // gated metric is heap allocations per classify — a pure
            // function of the code path, so it gates hot-loop churn
            // regressions independently of CI host speed.  Raw pass rate
            // and wall time go to `info` for trend-watching.
            let batch = args.usize_or("batch", 8)?.max(1);
            let mut engine = Engine::native(
                TrainedModel::synthetic(0xF1EE7),
                EngineConfig { use_pjrt: false, ..Default::default() },
            );
            let traces: Vec<_> =
                TraceStream::new(seed, 1.0).take(batch).collect();
            // Warm-up batch: sizes every scratch buffer and performs the
            // fc1/fc2 weight reconfigurations before counting starts.
            engine.classify_batch(&traces)?;
            let a0 = alloc_count();
            let w0 = std::time::Instant::now();
            for _ in 0..n {
                engine.classify_batch(&traces)?;
            }
            let steady_us = w0.elapsed().as_secs_f64() * 1e6;
            let allocs = alloc_count() - a0;
            let classifies = (n * batch) as f64;
            let passes = 3.0 * classifies;
            gated.push((
                "allocs_per_classify",
                allocs as f64 / classifies,
                "lower",
            ));
            info.push(("batch", batch as f64));
            info.push(("ns_per_pass", steady_us * 1e3 / passes));
            info.push(("passes_per_s", passes / (steady_us / 1e6)));
        }
        other => anyhow::bail!(
            "unknown bench area `{other}` \
             (serving|batch|stream|drift|train|simcore)"
        ),
    }
    let wall_us = t0.elapsed().as_secs_f64() * 1e6;

    let mut s = format!(
        "{{\"schema\":\"bss2-bench-v1\",\"bench\":\"{area}\",\"gated\":{{"
    );
    for (i, (name, v, better)) in gated.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        write!(s, "\"{name}\":{{\"value\":{v:.4},\"better\":\"{better}\"}}")
            .unwrap();
    }
    write!(s, "}},\"info\":{{\"n\":{n},\"seed\":{seed}").unwrap();
    for (name, v) in &info {
        write!(s, ",\"{name}\":{v:.4}").unwrap();
    }
    write!(s, ",\"host_wall_us\":{wall_us:.1}}}}}").unwrap();
    s.push('\n');
    std::fs::write(&out, &s)?;
    println!("[bench] area {area} over {n} iteration(s):");
    for (name, v, _) in &gated {
        println!("[bench]   {name} = {v:.4}");
    }
    for (name, v) in &info {
        println!("[bench]   {name} = {v:.4} (info)");
    }
    println!("[bench] wrote {out}");

    if let Some(base_path) = args.get("gate") {
        let pairs: Vec<(&str, f64)> =
            gated.iter().map(|&(name, v, _)| (name, v)).collect();
        gate_against(base_path, &pairs)?;
    }
    Ok(())
}

/// Compare measured gated metrics against a committed baseline file and
/// fail on a >20% regression.  The regression *direction* comes from the
/// baseline's own `better` field (`"lower"` — the default — or
/// `"higher"`, e.g. the loadgen speedup), so a metric's polarity lives
/// in exactly one place: the baseline that gates it.
pub(crate) fn gate_against(
    base_path: &str,
    gated: &[(&str, f64)],
) -> anyhow::Result<()> {
    let text = std::fs::read_to_string(base_path)
        .map_err(|e| anyhow::anyhow!("--gate {base_path}: {e}"))?;
    let base = bss2::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("--gate {base_path}: {e}"))?;
    let bg = base.get("gated").ok_or_else(|| {
        anyhow::anyhow!("--gate {base_path}: no `gated` object")
    })?;
    let mut failures = Vec::new();
    for (name, v) in gated {
        let Some(metric) = bg.get(name) else {
            println!("[bench]   {name}: no baseline value (skipped)");
            continue;
        };
        let Some(b) = metric.get("value").and_then(|x| x.as_f64()) else {
            println!("[bench]   {name}: no baseline value (skipped)");
            continue;
        };
        let better = metric
            .get("better")
            .and_then(|x| x.as_str())
            .unwrap_or("lower");
        let fail = match better {
            "higher" => *v < b * 0.8,
            _ => *v > b * 1.2,
        };
        println!(
            "[bench]   {name}: {v:.4} vs baseline {b:.4} ({:+.1}%, \
             {better} is better){}",
            (v / b - 1.0) * 100.0,
            if fail { "  REGRESSION" } else { "" }
        );
        if fail {
            failures.push(*name);
        }
    }
    anyhow::ensure!(
        failures.is_empty(),
        "bench gate failed (>20% regression vs {base_path}): {}",
        failures.join(", ")
    );
    println!("[bench] gate vs {base_path}: OK");
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    use bss2::coordinator::service::ServeModel;
    use bss2::fleet::FleetConfig;
    let addr = args.str_or("addr", "127.0.0.1:7001");
    let chips = args.usize_or("chips", 1)?;
    let model = match args.get("conn-model") {
        Some(m) => ServeModel::parse(m)?,
        None => ServeModel::default(),
    };
    let queue_depth = args.usize_or("queue-depth", 32)?;
    let dir = artifact_dir(args);
    let cfg = engine_config(args)?;
    // --auto-recalib arms the drain -> calibrate -> re-admit loop.  Only
    // meaningful with --native --drift, where the substrate actually
    // wanders; PJRT replicas report themselves calibration-incapable and
    // the policy exempts them.
    let fleet_cfg = FleetConfig {
        chips,
        queue_depth,
        recalib: args
            .flag("auto-recalib")
            .then(bss2::calib::RecalibPolicy::default),
        // Off unless explicitly requested: an open serving port must not
        // double as an unauthenticated kill switch.
        allow_remote_shutdown: args.flag("allow-remote-shutdown"),
        max_connections: args.usize_or("max-conns", 256)?.max(1),
        redirects: args.usize_or("redirects", 2)? as u32,
        // Observability: keep every Nth full request span for the `trace`
        // wire command (0 = histograms only).
        trace_sample: args.u64_or("trace-sample", 16)?,
        // Deterministic fault injection on the simulated hardware (the
        // chaos/soak machinery; see `repro chaos` and DESIGN.md §12).
        fault_plan: match args.get("fault-plan") {
            Some(p) => Some(bss2::fault::FaultPlan::load(p)?),
            None => None,
        },
        ..Default::default()
    };
    let svc = bss2::coordinator::service::Service::start_fleet_with(
        &addr,
        fleet_cfg,
        model,
        move |chip| {
            // Native fleets can serve without build artifacts: fall back
            // to the built-in energy-detector base model (the same model
            // `repro train` improves on).  PJRT still requires artifacts
            // — `from_artifacts` reports the `make artifacts` hint.
            let mut engine = if !dir.exists() && !cfg.use_pjrt {
                log::info!(
                    "chip {chip}: no artifacts under {} — serving the \
                     built-in energy-detector base model",
                    dir.root.display()
                );
                Engine::native(
                    bss2::nn::weights::TrainedModel::energy_detector(),
                    cfg.clone().for_chip(chip),
                )
            } else {
                Engine::from_artifacts(&dir, cfg.clone().for_chip(chip))?
            };
            // Close the measurement -> serving loop: a profile written by
            // `repro calibrate` (or a previous serving run) is applied at
            // construction; a corrupt artifact fails the chip loudly
            // rather than serving uncompensated.  A profile that merely
            // doesn't *apply* — measured on different silicon (other
            // fpn-seed, other backend) or left behind by an older format
            // version — is skipped with a warning instead: its inverse
            // gain/offset would mis-correct this substrate, not
            // compensate it.
            let profile_path = dir.calib_profile(chip);
            if profile_path.exists() {
                match bss2::calib::CalibProfile::load(&profile_path) {
                    Ok(profile) => match engine.apply_profile(&profile) {
                        Ok(()) => log::info!(
                            "chip {chip}: applied calibration profile {} \
                             (measured at t={} µs, {} reps)",
                            profile_path.display(),
                            profile.chip_time_us,
                            profile.reps
                        ),
                        Err(e) => log::warn!(
                            "chip {chip}: ignoring calibration profile {}: \
                             {e}",
                            profile_path.display()
                        ),
                    },
                    // A leftover older-version artifact is stale, not
                    // corrupt: skip it (like any inapplicable profile)
                    // and let recalibration re-measure.
                    Err(e)
                        if e.downcast_ref::<bss2::calib::UnsupportedFormat>()
                            .is_some() =>
                    {
                        log::warn!(
                            "chip {chip}: ignoring calibration profile {}: \
                             {e}; re-run `repro calibrate`",
                            profile_path.display()
                        );
                    }
                    Err(e) => return Err(e),
                }
            }
            // Adopt a `repro train` artifact, same applicability policy
            // as the calibration profile above: corrupt fails the chip
            // loudly; a stale format version or a model trained on
            // *different silicon* is warn-skipped — weights learned
            // against foreign fixed-pattern noise would undo the
            // in-the-loop training, not transfer it.
            let model_path = dir.trained_model();
            if model_path.exists() {
                use bss2::train::artifact::{
                    ModelArtifact, UnsupportedFormat,
                };
                match ModelArtifact::load(&model_path) {
                    Ok(art) => match engine.substrate_hash() {
                        Some(h) if h == art.substrate => {
                            engine.load_model_weights(
                                &art.model.pass_weights,
                                art.model.scales,
                            )?;
                            log::info!(
                                "chip {chip}: serving trained model {} \
                                 (seed {}, val det {:.3} fp {:.3})",
                                model_path.display(),
                                art.seed,
                                art.metrics
                                    .get("val_det")
                                    .copied()
                                    .unwrap_or(f64::NAN),
                                art.metrics
                                    .get("val_fp")
                                    .copied()
                                    .unwrap_or(f64::NAN)
                            );
                        }
                        current => log::warn!(
                            "chip {chip}: ignoring trained model {}: \
                             trained on substrate {:016x}, this chip is \
                             {}; re-run `repro train` against this \
                             chip's substrate",
                            model_path.display(),
                            art.substrate,
                            match current {
                                Some(h) => format!("{h:016x}"),
                                None => "a PJRT backend \
                                         (no substrate identity)"
                                    .into(),
                            }
                        ),
                    },
                    Err(e)
                        if e.downcast_ref::<UnsupportedFormat>()
                            .is_some() =>
                    {
                        log::warn!(
                            "chip {chip}: ignoring trained model {}: \
                             {e}; re-run `repro train`",
                            model_path.display()
                        );
                    }
                    Err(e) => return Err(e),
                }
            }
            Ok(engine)
        },
    )?;
    println!(
        "[serve] experiment service on {} — fleet of {} chip{} \
         (queue depth {} samples/chip; {} connection model; \
         line-delimited JSON or framed binary after handshake; \
         {{\"cmd\":\"ping\"}} / classify / classify_batch / \
         stream_open|push|close / stats / fleet_stats / metrics / trace \
         / journal{})",
        svc.addr,
        svc.fleet.size(),
        if svc.fleet.size() == 1 { "" } else { "s" },
        queue_depth,
        model.as_str(),
        if args.flag("allow-remote-shutdown") {
            " / shutdown"
        } else {
            "; wire shutdown disabled"
        }
    );
    // Block until a client sends shutdown (if allowed) or the process is
    // killed, then drain and join the fleet.
    svc.run_until_shutdown();
    Ok(())
}

/// Continuous-monitoring demo: stream an episode-labeled synthetic ECG
/// recording through a `stream_open`/`stream_push`/`stream_close` session
/// against an in-process fleet, collect the asynchronously pushed
/// per-window results, and report ordering, sustained throughput, and the
/// afib detection latency per episode (windows from episode onset to the
/// first positive window).
///
/// Detection: with trained artifacts the wire `pred` is used directly;
/// without them the fleet runs the untrained *energy-detector* model
/// (`TrainedModel::energy_detector`) and the demo thresholds the served
/// score sum against the sinus lead-in (mean + 4σ) — afib's elevated
/// derivative energy is the detected feature.
fn monitor(args: &Args) -> anyhow::Result<()> {
    use bss2::coordinator::service::{Client, Service, MAX_STREAM_CHUNK};
    use bss2::ecg::stream::{ContinuousEcg, EpisodeConfig};
    use bss2::fleet::FleetConfig;
    use bss2::nn::weights::TrainedModel;
    use bss2::util::json::Json;
    use bss2::util::stats::Summary;

    let minutes = args.f64_or("minutes", 3.0)?.max(1.0);
    let hop = args.usize_or("hop", 512)?;
    let chips = args.usize_or("chips", 2)?;
    // 3 s per push by default; clamped to the wire limit per request.
    let chunk = args.usize_or("chunk", 450)?.clamp(1, MAX_STREAM_CHUNK);
    let seed = args.u64_or("seed", 99)?;
    let queue_depth = args.usize_or("queue-depth", 64)?;
    let json = args.flag("json");
    let dir = artifact_dir(args);
    let trained = dir.exists();
    if !trained && !json {
        println!(
            "[monitor] no artifacts under {} — untrained energy-detector \
             model (score-sum threshold vs the sinus lead-in)",
            dir.root.display()
        );
    }
    let cfg = engine_config(args)?;
    let svc = Service::start_fleet(
        "127.0.0.1:0",
        FleetConfig { chips, queue_depth, ..Default::default() },
        move |chip| {
            let cfg = cfg.clone().for_chip(chip);
            if trained {
                Engine::from_artifacts(&dir, cfg)
            } else {
                Ok(Engine::native(
                    TrainedModel::energy_detector(),
                    EngineConfig { use_pjrt: false, ..cfg },
                ))
            }
        },
    )?;

    let lead_in_s = 30.0;
    let mut ecg = ContinuousEcg::new(
        seed,
        1.0,
        EpisodeConfig {
            lead_in_s,
            sinus_s: (20.0, 45.0),
            afib_s: (12.0, 30.0),
        },
    );
    let total = (minutes * 60.0 * c::ECG_FS_HZ) as usize;

    // One connection, split: this thread pushes chunks, a collector
    // thread reads the asynchronously pushed result lines.
    let mut reader_cl = Client::connect(&svc.addr)?;
    let mut writer_cl = reader_cl.try_clone()?;
    writer_cl.send(&format!("{{\"cmd\":\"stream_open\",\"hop\":{hop}}}"))?;
    let ack = reader_cl.read_reply()?;
    anyhow::ensure!(
        ack.get("stream").and_then(|s| s.as_str()) == Some("open"),
        "stream_open failed: {ack}"
    );
    let collector =
        std::thread::spawn(move || -> anyhow::Result<Vec<Json>> {
            let mut lines = Vec::new();
            loop {
                let line = reader_cl.read_reply()?;
                let closed = line.get("stream").and_then(|s| s.as_str())
                    == Some("closed");
                lines.push(line);
                if closed {
                    return Ok(lines);
                }
            }
        });

    if !json {
        println!(
            "[monitor] streaming {:.1} min at {} Hz (hop {hop} = {:.2} s \
             per window step) into a {chips}-chip fleet ...",
            minutes,
            c::ECG_FS_HZ,
            hop as f64 / c::ECG_FS_HZ
        );
    }
    let t0 = std::time::Instant::now();
    let mut pushed = 0usize;
    while pushed < total {
        let n = chunk.min(total - pushed);
        let ch = ecg.next_chunk(n);
        writer_cl.stream_push(&ch)?;
        pushed += n;
    }
    writer_cl.stream_close()?;
    let lines = collector.join().expect("collector thread")?;
    let wall = t0.elapsed().as_secs_f64();

    // Split result lines from the close ack; verify in-order delivery.
    struct Win {
        window: u64,
        start: u64,
        scores: [f64; 2],
        pred: u8,
        chip: usize,
    }
    let mut wins: Vec<Win> = Vec::new();
    let mut sheds = 0u64;
    for l in &lines {
        if l.get("stream").and_then(|s| s.as_str()) == Some("closed") {
            continue;
        }
        // Session-level error lines carry no "window" field; surface the
        // server's own message instead of a parse error.
        let Some(window) = l.get("window").and_then(|v| v.as_uint()) else {
            anyhow::bail!(
                "stream session error: {}",
                l.get("error").and_then(|e| e.as_str()).unwrap_or("?")
            );
        };
        if l.get("ok") != Some(&Json::Bool(true)) {
            sheds += 1; // shed (or failed) window: no result delivered
            continue;
        }
        let scores = l
            .get("scores")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("result without scores: {l}"))?;
        wins.push(Win {
            window,
            start: l.get("start_sample").and_then(|v| v.as_uint()).unwrap_or(0),
            scores: [
                scores[0].as_f64().unwrap_or(0.0),
                scores[1].as_f64().unwrap_or(0.0),
            ],
            pred: l.get("pred").and_then(|v| v.as_uint()).unwrap_or(0) as u8,
            chip: l.get("chip").and_then(|v| v.as_usize()).unwrap_or(0),
        });
    }
    anyhow::ensure!(!wins.is_empty(), "no windows served");
    for pair in wins.windows(2) {
        anyhow::ensure!(
            pair[1].window > pair[0].window,
            "results out of order: window {} after {}",
            pair[1].window,
            pair[0].window
        );
    }

    // Detector: wire pred with trained artifacts, otherwise a score-sum
    // threshold calibrated on windows fully inside the sinus lead-in.
    let lead_end = (lead_in_s * c::ECG_FS_HZ) as u64;
    let lead: Vec<f64> = wins
        .iter()
        .filter(|w| w.start + c::ECG_WINDOW as u64 <= lead_end)
        .map(|w| w.scores[0] + w.scores[1])
        .collect();
    anyhow::ensure!(
        trained || lead.len() >= 2,
        "lead-in too short to calibrate the detector ({} windows)",
        lead.len()
    );
    let (thr, lead_summary) = if trained {
        (f64::INFINITY, None)
    } else {
        let s = Summary::from(&lead);
        (s.mean + 4.0 * s.std.max(0.5), Some(s))
    };
    let positive = |w: &Win| {
        if trained {
            w.pred == 1
        } else {
            w.scores[0] + w.scores[1] > thr
        }
    };
    if let Some(s) = &lead_summary {
        if !json {
            println!(
                "[monitor] lead-in score sum {:.1} ± {:.1} LSB -> threshold \
                 {thr:.1}",
                s.mean, s.std
            );
        }
    }

    // Per-episode detection latency.  `afib_all` keeps *every* afib
    // interval (even ones truncated by the end of the stream) for the
    // false-positive accounting below; latency is only measured for
    // episodes with at least one full window of signal.
    let win_len = c::ECG_WINDOW as u64;
    let afib_all: Vec<_> =
        ecg.episodes().into_iter().filter(|e| e.afib).collect();
    let episodes: Vec<_> = afib_all
        .iter()
        .copied()
        .filter(|e| e.start + win_len <= total as u64)
        .collect();
    let spread: std::collections::BTreeMap<usize, usize> =
        wins.iter().fold(Default::default(), |mut m, w| {
            *m.entry(w.chip).or_default() += 1;
            m
        });
    if !json {
        println!(
            "\n--- streamed monitoring summary ------------------------------"
        );
        println!(
            "  windows served:    {} in order (+{sheds} shed), {:.1} \
             windows/s sustained end to end",
            wins.len(),
            wins.len() as f64 / wall
        );
        println!("  chip spread:       {spread:?}");
        println!("  afib episodes:     {}", episodes.len());
    }
    let mut latencies = Vec::new();
    for ep in &episodes {
        // Index of the first window covering the onset, computed from
        // the hop grid (shed-proof: window *indices*, not positions in
        // the served vec, carry the latency).
        let hop64 = hop as u64;
        let onset_win =
            (ep.start + 1).saturating_sub(win_len).div_ceil(hop64);
        let mut det: Option<&Win> = None;
        for w in &wins {
            if w.start + win_len > ep.start && w.start < ep.end && positive(w)
            {
                det = Some(w);
                break;
            }
        }
        match det {
            Some(d) => {
                let lat_windows = d.window - onset_win;
                let lat_s =
                    (d.start + win_len - ep.start) as f64 / c::ECG_FS_HZ;
                latencies.push(lat_windows as f64);
                if !json {
                    println!(
                        "    episode at {:>7.1} s ({:>5.1} s long): \
                         detected after {lat_windows} window{} ({lat_s:.1} \
                         s of signal past onset)",
                        ep.start as f64 / c::ECG_FS_HZ,
                        ep.len() as f64 / c::ECG_FS_HZ,
                        if lat_windows == 1 { "" } else { "s" }
                    );
                }
            }
            None => {
                if !json {
                    println!(
                        "    episode at {:>7.1} s ({:>5.1} s long): MISSED",
                        ep.start as f64 / c::ECG_FS_HZ,
                        ep.len() as f64 / c::ECG_FS_HZ
                    );
                }
            }
        }
    }
    if !latencies.is_empty() && !json {
        println!(
            "  detection latency: {:.1} windows mean over {} detected \
             episode{}",
            latencies.iter().sum::<f64>() / latencies.len() as f64,
            latencies.len(),
            if latencies.len() == 1 { "" } else { "s" }
        );
    }
    // False-positive rate over pure-sinus windows (outside every afib
    // interval, including end-truncated ones excluded from latency).
    let (mut sinus_n, mut fp) = (0usize, 0usize);
    for w in &wins {
        let overlaps_episode = afib_all
            .iter()
            .any(|e| w.start + win_len > e.start && w.start < e.end);
        if !overlaps_episode {
            sinus_n += 1;
            if positive(w) {
                fp += 1;
            }
        }
    }
    if sinus_n > 0 && !json {
        println!("  false positives:   {fp}/{sinus_n} sinus windows");
    }
    if json {
        use std::fmt::Write as _;
        let mut s = format!(
            "{{\"windows\":{},\"shed\":{sheds},\"wall_s\":{wall:.3},\
             \"windows_per_s\":{:.1},\"chip_spread\":[",
            wins.len(),
            wins.len() as f64 / wall
        );
        for (i, (chip, served)) in spread.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            write!(s, "{{\"chip\":{chip},\"windows\":{served}}}").unwrap();
        }
        write!(
            s,
            "],\"episodes\":{},\"detected\":{}",
            episodes.len(),
            latencies.len()
        )
        .unwrap();
        if latencies.is_empty() {
            s.push_str(",\"mean_detection_windows\":null");
        } else {
            write!(
                s,
                ",\"mean_detection_windows\":{:.2}",
                latencies.iter().sum::<f64>() / latencies.len() as f64
            )
            .unwrap();
        }
        write!(
            s,
            ",\"false_positives\":{fp},\"sinus_windows\":{sinus_n}}}"
        )
        .unwrap();
        println!("{s}");
    }
    svc.stop();
    Ok(())
}

/// Seeded chaos soak: drive a deterministic mix of classify / batch /
/// stream-frame traffic into an in-process fleet with a fault plan armed,
/// then print a survival report.
///
/// Determinism contract: requests are dispatched **sequentially** (each
/// reply awaited before the next dispatch), so scheduler picks, failover
/// targets, probe ticks, and every chip's chip-time trajectory — and
/// therefore the entire printed report — are a pure function of the seed
/// and the plan.  `repro chaos --chips 4 --seed 1` prints byte-identical
/// reports on every run and every host.  (Wall-clock latencies exist in
/// telemetry but are deliberately not part of the report.)
fn chaos(args: &Args) -> anyhow::Result<()> {
    use bss2::ecg::gen::Trace;
    use bss2::fault::FaultPlan;
    use bss2::fleet::{
        BatchDispatchOutcome, ChipReply, DispatchOutcome, Fleet, FleetConfig,
    };
    use bss2::nn::weights::TrainedModel;
    use std::sync::mpsc;

    let chips = args.usize_or("chips", 4)?.max(1);
    let seed = args.u64_or("seed", 1)?;
    let requests = args.usize_or("requests", 240)?.max(1);
    let redirects = args.usize_or("redirects", 2)? as u32;
    let queue_depth = args.usize_or("queue-depth", 32)?;
    let probe_period = args.u64_or("probe-period", 8)?;

    // Expected chip time per replica over the run: the request load
    // spread across the fleet at ~300 µs per single-trace program.  The
    // random plan draws its fault windows inside this horizon so the
    // faults actually intersect the workload.
    let horizon_us = ((requests / chips).max(1) as u64) * 300;
    let plan = match args.get("fault-plan") {
        Some(p) => FaultPlan::load(p)?,
        None => FaultPlan::random(seed, chips, horizon_us),
    };
    // Serving floor: only *erroring* faults (chip death, frame drops)
    // can quarantine a chip — silent/slow faults never cost capacity.
    // Same definition as the chaos soak tests, so CLI verdicts and test
    // assertions can never disagree about what "survived" means.
    let floor = chips - plan.erroring_chips(chips);
    let json = args.flag("json");
    if !json {
        println!(
            "[chaos] seed {seed}, {chips} chips, {requests} samples, \
             redirect budget {redirects}, queue depth {queue_depth}, probe \
             period {probe_period}"
        );
        println!(
            "[chaos] fault plan ({} fault(s), horizon ~{horizon_us} µs):",
            plan.faults.len()
        );
        for f in &plan.faults {
            println!("[chaos]   - {}", f.describe());
        }
    }

    let fleet_plan = plan.clone();
    let fleet = Fleet::start(
        FleetConfig {
            chips,
            queue_depth,
            probe_period,
            redirects,
            fault_plan: Some(fleet_plan),
            ..Default::default()
        },
        |chip| {
            Ok(Engine::native(
                TrainedModel::synthetic(0xF1EE7),
                EngineConfig { use_pjrt: false, ..Default::default() }
                    .for_chip(chip),
            ))
        },
    )?;

    // Outcome tally, in samples.  `lost` counts replies that never came
    // — the invariant the failover design must hold at zero.
    let (mut ok, mut shed, mut failed, mut lost) = (0u64, 0u64, 0u64, 0u64);
    let mut settle = |n: u64, recv: Result<ChipReply, mpsc::RecvError>| match recv
    {
        Err(_) => lost += n,
        Ok(reply) => match reply.result {
            Ok(_) => ok += n,
            Err(_) => failed += n,
        },
    };

    let mut traces = bss2::ecg::gen::TraceStream::new(seed, 1.0);
    let mut sent = 0usize;
    let mut tick = 0usize;
    while sent < requests {
        let kind = tick % 8;
        tick += 1;
        if kind == 5 {
            // One 4-batch (amortised path; counts 4 samples).
            let b = 4.min(requests - sent);
            let batch: Vec<Trace> = (&mut traces).take(b).collect();
            sent += b;
            match fleet.dispatch_batch(batch) {
                BatchDispatchOutcome::Shed { .. } => shed += b as u64,
                BatchDispatchOutcome::Enqueued { rejected, resp, .. } => {
                    shed += rejected as u64;
                    settle((b - rejected) as u64, resp.recv());
                }
            }
        } else if kind == 7 {
            // One preprocessed stream frame (the monitoring path).
            let t = traces.next().unwrap();
            sent += 1;
            let acts: Vec<i32> =
                bss2::fpga::preprocess::preprocess(&t.samples)
                    .into_iter()
                    .map(|a| a as i32)
                    .collect();
            match fleet.dispatch_acts(acts) {
                DispatchOutcome::Shed { .. } => shed += 1,
                DispatchOutcome::Enqueued { resp, .. } => {
                    settle(1, resp.recv())
                }
            }
        } else {
            // Single-trace classify (the paper's 276 µs path).
            let t = traces.next().unwrap();
            sent += 1;
            match fleet.dispatch(t) {
                DispatchOutcome::Shed { .. } => shed += 1,
                DispatchOutcome::Enqueued { resp, .. } => {
                    settle(1, resp.recv())
                }
            }
        }
    }

    let healthy = fleet.healthy_count();
    let survived = lost == 0 && healthy >= floor.max(1);
    let verdict = if survived {
        "survived"
    } else if lost > 0 {
        "failed"
    } else {
        "degraded"
    };
    if json {
        // One machine-readable object; like the text report it contains
        // only seed-deterministic values (no wall-clock), so the same
        // seed prints byte-identical JSON.
        use std::fmt::Write as _;
        let mut s = format!(
            "{{\"seed\":{seed},\"chips\":{chips},\"samples\":{sent},\
             \"redirect_budget\":{redirects},\"faults\":["
        );
        for (i, f) in plan.faults.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&bss2::util::json::Json::Str(f.describe()).to_string());
        }
        write!(
            s,
            "],\"ok\":{ok},\"shed\":{shed},\"failed\":{failed},\
             \"lost\":{lost},\"redirects\":{},\"redirects_exhausted\":{},\
             \"fault_errors\":{},\"healthy\":{healthy},\"floor\":{floor},\
             \"per_chip\":[",
            fleet.redirect_count(),
            fleet.redirects_exhausted_count(),
            fleet.injected_fault_errors()
        )
        .unwrap();
        for (i, cs) in fleet.chip_snapshots().iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            write!(
                s,
                "{{\"chip\":{i},\"state\":\"{}\",\"served\":{},\
                 \"errors\":{}}}",
                cs.state.as_str(),
                cs.served,
                cs.errors
            )
            .unwrap();
        }
        write!(s, "],\"verdict\":\"{verdict}\"}}").unwrap();
        println!("{s}");
    } else {
        println!(
            "[chaos] outcome over {sent} samples: {ok} ok, {shed} shed, \
             {failed} failed, {lost} lost"
        );
        println!(
            "[chaos] failover: {} redirect(s), {} exhausted, {} injected \
             failure(s) observed",
            fleet.redirect_count(),
            fleet.redirects_exhausted_count(),
            fleet.injected_fault_errors()
        );
        println!(
            "[chaos] fleet end state: {healthy}/{chips} healthy \
             (erroring-fault floor {floor})"
        );
        for (i, s) in fleet.chip_snapshots().iter().enumerate() {
            println!(
                "[chaos]   - chip {i}: {:<12} served {:<6} errors {}",
                s.state.as_str(),
                s.served,
                s.errors
            );
        }
        println!(
            "[chaos] verdict: {}",
            if survived {
                "SURVIVED (every sample answered; serving floor held)"
            } else if lost > 0 {
                "FAILED (lost replies — a job fell into silence)"
            } else {
                "DEGRADED (served everything, but below the serving floor)"
            }
        );
    }
    fleet.shutdown();
    anyhow::ensure!(lost == 0, "{lost} replies were lost");
    Ok(())
}

/// `repro audit`: the bss2-lint static-analysis pass (DESIGN.md §16),
/// exposed through the main CLI so the gate needs no second entry point.
fn audit(args: &Args) -> anyhow::Result<()> {
    let opts = bss2_lint::Options {
        root: args.get("root").map(std::path::PathBuf::from),
        json: args.flag("json"),
        gate: args.get("gate").map(std::path::PathBuf::from),
        write_baseline: args
            .get("write-baseline")
            .map(std::path::PathBuf::from),
    };
    args.check_unknown()?;
    match bss2_lint::run(&opts) {
        Ok(0) => Ok(()),
        Ok(_) => anyhow::bail!("lint gate failed (see findings above)"),
        Err(e) => anyhow::bail!("{e}"),
    }
}

fn snn(args: &Args) -> anyhow::Result<()> {
    use bss2::asic::neuron::{AdexParams, SpikingPopulation};
    let n = args.usize_or("neurons", 4)?;
    let current = args.f64_or("current", 150.0)?;
    let dur = args.f64_or("dur-us", 500.0)?;
    println!(
        "[snn] AdEx population of {n} neurons, {current} LSB input, \
         {dur} µs accelerated time"
    );
    let mut pop = SpikingPopulation::new(n, AdexParams::default());
    pop.run_constant_input(current, dur);
    for (i, r) in pop.rates_hz(dur).iter().enumerate() {
        println!(
            "  neuron {i}: {} spikes, {:.0} Hz (accelerated) = {:.1} Hz bio",
            pop.neurons[i].spikes.len(),
            r,
            r / 1000.0
        );
    }
    println!(
        "[snn] the same substrate runs the CDNN showcase — paper §V argues \
         this combination is the key feature of BSS-2"
    );
    Ok(())
}
