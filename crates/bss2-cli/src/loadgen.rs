//! `repro loadgen` — serving-layer load generator and connection-model
//! A/B bench (DESIGN.md §14).
//!
//! Phase 1 (gated): N concurrent framed clients blast pipelined `ping`
//! requests at an in-process fleet under *both* connection models.
//! Ping never touches a chip, so the measured throughput isolates pure
//! connection handling — the quantity the readiness refactor changes.
//! The gated metric is `speedup_vs_threaded_x` (readiness req/s over
//! threaded req/s at equal chip count), higher-is-better.
//!
//! Phase 2 (info): the same client set drives `classify` requests into
//! the readiness model and records the end-to-end latency distribution
//! (p50/p95/p99), throughput, and the shed behaviour — shed rate, the
//! observed `queue_depth` hints, and a log2 histogram of the
//! `retry_after_us` backoff hints.  These go into `info` for
//! trend-watching; they depend on host speed and are not gated.
//!
//! Results land in `BENCH_loadgen.json` (bss2-bench-v1 schema, same
//! gate semantics as `repro bench --gate`).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::coordinator::service::{ServeModel, Service};
use bss2::fleet::FleetConfig;
use bss2::nn::weights::TrainedModel;
use bss2::util::cli::Args;
use bss2_client::{Client, Encoding, Json, Options};

pub fn run(args: &Args) -> anyhow::Result<()> {
    let conns = args.usize_or("conns", 1000)?.max(1);
    let chips = args.usize_or("chips", 2)?.max(1);
    let pipeline = args.usize_or("pipeline", 8)?.max(1);
    let per_conn = args.usize_or("requests", 64)?.max(1);
    let classify_n = args.usize_or("classify-n", 4)?;
    let queue_depth = args.usize_or("queue-depth", 32)?.max(1);
    let mode = args.str_or("mode", "both");
    let encoding = match args.str_or("encoding", "binary").as_str() {
        "binary" => Encoding::Binary,
        "json" => Encoding::Json,
        other => anyhow::bail!("unknown --encoding {other:?} (binary|json)"),
    };
    let timeout_ms = args.u64_or("read-timeout-ms", 30_000)?;
    let out = args.str_or("out", "BENCH_loadgen.json");
    anyhow::ensure!(
        matches!(mode.as_str(), "both" | "readiness" | "threaded"),
        "unknown --mode {mode:?} (both|readiness|threaded)"
    );

    // Every client plus its accepted peer costs a descriptor; the
    // default soft limit (often 1024) is below a 1000-connection run.
    raise_nofile(conns as u64 * 2 + 512);

    let t0 = Instant::now();

    let opts = Options {
        encoding,
        read_timeout: (timeout_ms > 0)
            .then(|| Duration::from_millis(timeout_ms)),
        ..Options::default()
    };
    let start = |model: ServeModel| -> anyhow::Result<Service> {
        Service::start_fleet_with(
            "127.0.0.1:0",
            FleetConfig {
                chips,
                queue_depth,
                max_connections: conns + 16,
                ..Default::default()
            },
            model,
            |chip| {
                Ok(Engine::native(
                    TrainedModel::synthetic(0xF1EE7),
                    EngineConfig {
                        use_pjrt: false,
                        noise_off: true,
                        ..Default::default()
                    }
                    .for_chip(chip),
                ))
            },
        )
    };

    println!(
        "[loadgen] {conns} connections x {per_conn} pings (pipeline depth \
         {pipeline}, {} frames) against a {chips}-chip fleet",
        encoding_name(encoding)
    );
    let mut threaded = None;
    if mode == "both" || mode == "threaded" {
        let svc = start(ServeModel::Threaded)?;
        let r = ping_blast(&svc, conns, per_conn, pipeline, &opts)?;
        svc.stop();
        println!(
            "[loadgen]   threaded:  {:>9.0} req/s ({} concurrent conns)",
            r.rps, r.concurrent
        );
        threaded = Some(r);
    }
    let mut readiness = None;
    let mut classify = None;
    if mode == "both" || mode == "readiness" {
        let svc = start(ServeModel::Readiness)?;
        let r = ping_blast(&svc, conns, per_conn, pipeline, &opts)?;
        println!(
            "[loadgen]   readiness: {:>9.0} req/s ({} concurrent conns)",
            r.rps, r.concurrent
        );
        readiness = Some(r);
        if classify_n > 0 {
            let c = classify_phase(&svc, conns, classify_n, &opts)?;
            println!(
                "[loadgen]   classify:  {:>9.0} req/s, {}/{} ok, {} shed \
                 ({:.0}% shed rate), p50/p95/p99 = {:.0}/{:.0}/{:.0} µs",
                c.rps,
                c.ok,
                c.sent,
                c.shed,
                100.0 * c.shed as f64 / c.sent.max(1) as f64,
                c.p50_us,
                c.p95_us,
                c.p99_us
            );
            classify = Some(c);
        }
        svc.stop();
    }

    // Gated metric: connection-handling speedup at equal chip count.
    let mut gated: Vec<(&str, f64)> = Vec::new();
    if let (Some(t), Some(r)) = (&threaded, &readiness) {
        let speedup = r.rps / t.rps.max(1e-9);
        println!("[loadgen] speedup_vs_threaded_x = {speedup:.2}");
        gated.push(("speedup_vs_threaded_x", speedup));
    }

    let mut s = String::from(
        "{\"schema\":\"bss2-bench-v1\",\"bench\":\"loadgen\",\"gated\":{",
    );
    for (i, (name, v)) in gated.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        write!(s, "\"{name}\":{{\"value\":{v:.4},\"better\":\"higher\"}}")
            .unwrap();
    }
    write!(
        s,
        "}},\"info\":{{\"conns\":{conns},\"chips\":{chips},\
         \"pipeline\":{pipeline},\"requests_per_conn\":{per_conn},\
         \"encoding\":\"{}\",\"host_wall_us\":{:.1}",
        encoding_name(encoding),
        t0.elapsed().as_secs_f64() * 1e6
    )
    .unwrap();
    if let Some(t) = &threaded {
        write!(
            s,
            ",\"threaded_rps\":{:.1},\"threaded_concurrent\":{}",
            t.rps, t.concurrent
        )
        .unwrap();
    }
    if let Some(r) = &readiness {
        write!(
            s,
            ",\"readiness_rps\":{:.1},\"readiness_concurrent\":{}",
            r.rps, r.concurrent
        )
        .unwrap();
    }
    if let Some(c) = &classify {
        write!(
            s,
            ",\"classify\":{{\"sent\":{},\"ok\":{},\"shed\":{},\
             \"errors\":{},\"rps\":{:.1},\"p50_us\":{:.1},\
             \"p95_us\":{:.1},\"p99_us\":{:.1},\"max_queue_depth\":{},\
             \"retry_after_us_hist\":[",
            c.sent,
            c.ok,
            c.shed,
            c.errors,
            c.rps,
            c.p50_us,
            c.p95_us,
            c.p99_us,
            c.max_queue_depth
        )
        .unwrap();
        for (i, (le, count)) in c.retry_hist.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            write!(s, "{{\"le_us\":{le},\"count\":{count}}}").unwrap();
        }
        s.push_str("]}");
    }
    s.push_str("}}\n");
    std::fs::write(&out, &s)?;
    println!("[loadgen] wrote {out}");

    if let Some(base_path) = args.get("gate") {
        super::gate_against(base_path, &gated)?;
    }
    Ok(())
}

fn encoding_name(enc: Encoding) -> &'static str {
    match enc {
        Encoding::Json => "json",
        Encoding::Binary => "binary",
    }
}

struct PingResult {
    rps: f64,
    /// Connections registered at the service while the blast ran.
    concurrent: usize,
}

/// Connect `conns` clients, then (behind a barrier, so the connect cost
/// never pollutes the timing) blast `per_conn` pings each, pipelined
/// `pipeline` deep, and measure aggregate throughput.
fn ping_blast(
    svc: &Service,
    conns: usize,
    per_conn: usize,
    pipeline: usize,
    opts: &Options,
) -> anyhow::Result<PingResult> {
    let addr = svc.addr;
    let barrier = Arc::new(Barrier::new(conns + 1));
    let mut joins = Vec::with_capacity(conns);
    for i in 0..conns {
        let barrier = barrier.clone();
        let opts = opts.clone();
        joins.push(
            std::thread::Builder::new()
                .name(format!("loadgen-{i}"))
                .stack_size(256 * 1024)
                .spawn(move || -> anyhow::Result<()> {
                    // Connect *before* the barrier; a failed connect must
                    // still reach the barrier or everyone deadlocks.
                    let connected = Client::connect(addr, opts);
                    barrier.wait();
                    let mut cl = connected?;
                    let ping = obj(&[("cmd", Json::Str("ping".into()))]);
                    let mut done = 0usize;
                    while done < per_conn {
                        let burst = pipeline.min(per_conn - done);
                        for _ in 0..burst {
                            cl.send(&ping)?;
                        }
                        for _ in 0..burst {
                            let r = cl.read_reply()?;
                            anyhow::ensure!(
                                r.get("ok") == Some(&Json::Bool(true)),
                                "ping failed: {r}"
                            );
                        }
                        done += burst;
                    }
                    Ok(())
                })?,
        );
    }
    barrier.wait();
    let t0 = Instant::now();
    let concurrent = svc.active_connections();
    let (mut failed, mut first_err) = (0usize, None);
    for j in joins {
        match j.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                failed += 1;
                first_err.get_or_insert(e);
            }
            Err(_) => {
                failed += 1;
                first_err
                    .get_or_insert(anyhow::anyhow!("client thread panicked"));
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    if let Some(e) = first_err {
        anyhow::bail!(
            "{failed} of {conns} client(s) failed; first error: {e:#}"
        );
    }
    Ok(PingResult {
        rps: (conns * per_conn) as f64 / wall.max(1e-9),
        concurrent,
    })
}

#[derive(Default)]
struct ClassifyStats {
    ok: u64,
    shed: u64,
    errors: u64,
    lat_us: Vec<f64>,
    retry_after_us: Vec<u64>,
    max_queue_depth: u64,
}

struct ClassifySummary {
    sent: u64,
    ok: u64,
    shed: u64,
    errors: u64,
    rps: f64,
    p50_us: f64,
    p95_us: f64,
    p99_us: f64,
    max_queue_depth: u64,
    /// log2-bucketed `retry_after_us` hints: upper bound -> count.
    retry_hist: BTreeMap<u64, u64>,
}

/// Unpipelined classify load: per-request latency is well defined, and
/// an undersized admission queue sheds — which is the point: the shed
/// replies carry the backoff hints this phase histograms.
fn classify_phase(
    svc: &Service,
    conns: usize,
    per_conn: usize,
    opts: &Options,
) -> anyhow::Result<ClassifySummary> {
    let addr = svc.addr;
    let barrier = Arc::new(Barrier::new(conns + 1));
    let mut joins = Vec::with_capacity(conns);
    for i in 0..conns {
        let barrier = barrier.clone();
        let opts = opts.clone();
        joins.push(
            std::thread::Builder::new()
                .name(format!("loadgen-c{i}"))
                .stack_size(256 * 1024)
                .spawn(move || -> anyhow::Result<ClassifyStats> {
                    let trace = bss2::ecg::gen::generate_trace(
                        0xC0FFEE ^ i as u64,
                        i % 7 == 0,
                        1.0,
                    );
                    let connected = Client::connect(addr, opts);
                    barrier.wait();
                    let mut cl = connected?;
                    let mut st = ClassifyStats::default();
                    for _ in 0..per_conn {
                        let t = Instant::now();
                        let reply = cl.classify(&trace.samples)?;
                        let us = t.elapsed().as_secs_f64() * 1e6;
                        if reply.get("ok") == Some(&Json::Bool(true)) {
                            st.ok += 1;
                            st.lat_us.push(us);
                        } else if reply.get("shed")
                            == Some(&Json::Bool(true))
                        {
                            st.shed += 1;
                            if let Some(r) = reply
                                .get("retry_after_us")
                                .and_then(|v| v.as_uint())
                            {
                                st.retry_after_us.push(r);
                            }
                            if let Some(q) = reply
                                .get("queue_depth")
                                .and_then(|v| v.as_uint())
                            {
                                st.max_queue_depth =
                                    st.max_queue_depth.max(q);
                            }
                        } else {
                            st.errors += 1;
                        }
                    }
                    Ok(st)
                })?,
        );
    }
    barrier.wait();
    let t0 = Instant::now();
    let mut all = ClassifyStats::default();
    let (mut failed, mut first_err) = (0usize, None);
    for j in joins {
        match j.join() {
            Ok(Ok(st)) => {
                all.ok += st.ok;
                all.shed += st.shed;
                all.errors += st.errors;
                all.lat_us.extend(st.lat_us);
                all.retry_after_us.extend(st.retry_after_us);
                all.max_queue_depth =
                    all.max_queue_depth.max(st.max_queue_depth);
            }
            Ok(Err(e)) => {
                failed += 1;
                first_err.get_or_insert(e);
            }
            Err(_) => {
                failed += 1;
                first_err
                    .get_or_insert(anyhow::anyhow!("client thread panicked"));
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    if let Some(e) = first_err {
        anyhow::bail!(
            "{failed} of {conns} classify client(s) failed; first error: \
             {e:#}"
        );
    }
    all.lat_us.sort_by(|a, b| a.total_cmp(b));
    let mut retry_hist = BTreeMap::new();
    for &us in &all.retry_after_us {
        *retry_hist.entry(us.max(1).next_power_of_two()).or_insert(0u64) +=
            1;
    }
    let sent = (conns * per_conn) as u64;
    Ok(ClassifySummary {
        sent,
        ok: all.ok,
        shed: all.shed,
        errors: all.errors,
        rps: sent as f64 / wall.max(1e-9),
        p50_us: percentile(&all.lat_us, 50.0),
        p95_us: percentile(&all.lat_us, 95.0),
        p99_us: percentile(&all.lat_us, 99.0),
        max_queue_depth: all.max_queue_depth,
        retry_hist,
    })
}

/// Nearest-rank percentile over an already-sorted slice.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn obj(fields: &[(&str, Json)]) -> Json {
    Json::Obj(
        fields
            .iter()
            .map(|(k, v)| ((*k).to_string(), v.clone()))
            .collect(),
    )
}

/// Best-effort RLIMIT_NOFILE bump up to the hard limit; a run that
/// still hits the limit fails with ordinary connect errors.
#[cfg(unix)]
fn raise_nofile(target: u64) {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = if cfg!(target_os = "macos") { 8 } else { 7 };
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 || r.cur >= target {
            return;
        }
        let want = target.min(r.max);
        let new = RLimit { cur: want, max: r.max };
        if setrlimit(RLIMIT_NOFILE, &new) == 0 {
            log::info!("raised RLIMIT_NOFILE {} -> {want}", r.cur);
        } else {
            log::warn!(
                "could not raise RLIMIT_NOFILE past {} (want {target}); \
                 large --conns runs may fail to connect",
                r.cur
            );
        }
    }
}

#[cfg(not(unix))]
fn raise_nofile(_target: u64) {}
