//! Minimal Rust lexer for the lint pass (DESIGN.md §16).
//!
//! Produces a flat token stream with line numbers.  Comments are stripped
//! (after harvesting `// lint:allow(rule: reason)` annotations), string and
//! char literals collapse into [`Kind::Str`] placeholders so adjacency
//! checks cannot be confused by their contents, lifetimes are dropped, and
//! `#[cfg(test)]` / `#[test]` items are removed so the rules only ever see
//! shipping code.  This is not a full lexer — just faithful enough that
//! token-pattern rules cannot be fooled by comments, strings, raw strings,
//! or char literals.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    /// String / char / byte-string literal (contents dropped).
    Str,
    /// Single punctuation character.
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == Kind::Punct && self.text.chars().next() == Some(c)
    }

    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == Kind::Ident && self.text == s
    }
}

/// One `// lint:allow(rule: reason)` annotation.
///
/// A trailing comment covers findings on its own line; a comment that has
/// the whole line to itself covers the next line that carries code.
#[derive(Debug, Clone)]
pub struct Allow {
    pub line: u32,
    pub own_line: bool,
    pub rule: String,
    pub reason: String,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub allows: Vec<Allow>,
}

pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut toks: Vec<Tok> = Vec::new();
    let mut allows: Vec<Allow> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut last_tok_line = 0u32;

    macro_rules! push {
        ($kind:expr, $text:expr, $line:expr) => {{
            toks.push(Tok { kind: $kind, text: $text, line: $line });
            last_tok_line = $line;
        }};
    }

    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
        } else if c.is_ascii_whitespace() {
            i += 1;
        } else if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            if let Some(a) = parse_allow(&src[start..i], line, last_tok_line != line) {
                allows.push(a);
            }
        } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
            i += 2;
            let mut depth = 1usize;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
        } else if c == b'"' {
            let tline = line;
            i = skip_escaped_string(b, i + 1, b'"', &mut line);
            push!(Kind::Str, String::new(), tline);
        } else if c == b'\'' {
            let nxt = b.get(i + 1).copied().unwrap_or(0);
            if (nxt.is_ascii_alphabetic() || nxt == b'_') && b.get(i + 2) != Some(&b'\'') {
                // Lifetime: drop the quote and the identifier.
                i += 2;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
            } else {
                let tline = line;
                i = skip_escaped_string(b, i + 1, b'\'', &mut line);
                push!(Kind::Str, String::new(), tline);
            }
        } else if c.is_ascii_digit() {
            let start = i;
            i += 1;
            let mut seen_dot = false;
            while i < b.len() {
                let d = b[i];
                if d.is_ascii_alphanumeric() || d == b'_' {
                    i += 1;
                } else if d == b'.'
                    && !seen_dot
                    && b.get(i + 1).map_or(false, |n| n.is_ascii_digit())
                {
                    seen_dot = true;
                    i += 1;
                } else {
                    break;
                }
            }
            push!(Kind::Num, src[start..i].to_string(), line);
        } else if c.is_ascii_alphabetic() || c == b'_' {
            if let Some((hashes, body)) = raw_string_start(b, i) {
                let tline = line;
                i = match hashes {
                    None => skip_escaped_string(b, body, b'"', &mut line),
                    Some(n) => skip_raw_string(b, body, n, &mut line),
                };
                push!(Kind::Str, String::new(), tline);
            } else if c == b'b' && b.get(i + 1) == Some(&b'\'') {
                let tline = line;
                i = skip_escaped_string(b, i + 2, b'\'', &mut line);
                push!(Kind::Str, String::new(), tline);
            } else {
                let start = i;
                i += 1;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                push!(Kind::Ident, src[start..i].to_string(), line);
            }
        } else {
            push!(Kind::Punct, (c as char).to_string(), line);
            i += 1;
        }
    }

    Lexed { toks: strip_tests(toks), allows }
}

/// Skip to just past the closing `quote`, honouring backslash escapes.
fn skip_escaped_string(b: &[u8], mut i: usize, quote: u8, line: &mut u32) -> usize {
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            c if c == quote => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// Skip to just past the `"###...` terminator of a raw string with
/// `hashes` leading `#`s.
fn skip_raw_string(b: &[u8], mut i: usize, hashes: usize, line: &mut u32) -> usize {
    while i < b.len() {
        if b[i] == b'\n' {
            *line += 1;
            i += 1;
        } else if b[i] == b'"' && b[i + 1..].iter().take(hashes).filter(|c| **c == b'#').count() == hashes
        {
            return i + 1 + hashes;
        } else {
            i += 1;
        }
    }
    i
}

/// Detect `r"`, `r#"`, `b"`, `br"`, `br#"` at position `i`.
///
/// Returns `(Some(n_hashes), content_start)` for raw strings and
/// `(None, content_start)` for a plain byte string.
fn raw_string_start(b: &[u8], i: usize) -> Option<(Option<usize>, usize)> {
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if j < b.len() && b[j] == b'r' && j + 1 < b.len() && (b[j + 1] == b'#' || b[j + 1] == b'"') {
        j += 1;
        let mut n = 0usize;
        while j < b.len() && b[j] == b'#' {
            n += 1;
            j += 1;
        }
        if j < b.len() && b[j] == b'"' {
            return Some((Some(n), j + 1));
        }
        return None;
    }
    if j > i && j < b.len() && b[j] == b'"' {
        // b"..."
        return Some((None, j + 1));
    }
    None
}

fn parse_allow(comment: &str, line: u32, own_line: bool) -> Option<Allow> {
    let pos = comment.find("lint:allow(")?;
    let rest = &comment[pos + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let body = &rest[..close];
    let (rule, reason) = match body.split_once(':') {
        Some((r, why)) => (r.trim().to_string(), why.trim().to_string()),
        None => (body.trim().to_string(), String::new()),
    };
    if rule.is_empty() {
        return None;
    }
    Some(Allow { line, own_line, rule, reason })
}

/// Remove `#[test]` / `#[cfg(test)]` items from the token stream so the
/// rules only see shipping code (`#[cfg(not(test))]` survives).
fn strip_tests(toks: Vec<Tok>) -> Vec<Tok> {
    let mut out: Vec<Tok> = Vec::with_capacity(toks.len());
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].is_punct('#') && toks.get(i + 1).map_or(false, |t| t.is_punct('[')) {
            let mut j = i + 2;
            let mut depth = 1usize;
            let mut is_test = false;
            while j < toks.len() && depth > 0 {
                if toks[j].is_punct('[') {
                    depth += 1;
                } else if toks[j].is_punct(']') {
                    depth -= 1;
                } else if toks[j].is_ident("test")
                    && !(j >= 2 && toks[j - 2].is_ident("not") && toks[j - 1].is_punct('('))
                {
                    is_test = true;
                }
                j += 1;
            }
            if is_test {
                i = skip_item(&toks, j);
                continue;
            }
            out.extend_from_slice(&toks[i..j]);
            i = j;
            continue;
        }
        out.push(toks[i].clone());
        i += 1;
    }
    out
}

/// Skip past the item that follows an attribute: any further stacked
/// attributes, then either a braced body or a `;`-terminated item.
fn skip_item(toks: &[Tok], mut j: usize) -> usize {
    while j < toks.len() && toks[j].is_punct('#') && toks.get(j + 1).map_or(false, |t| t.is_punct('['))
    {
        j += 1;
        let mut depth = 0usize;
        while j < toks.len() {
            if toks[j].is_punct('[') {
                depth += 1;
            } else if toks[j].is_punct(']') {
                depth -= 1;
                if depth == 0 {
                    j += 1;
                    break;
                }
            }
            j += 1;
        }
    }
    let mut depth = 0usize;
    while j < toks.len() {
        if toks[j].is_punct('{') {
            depth += 1;
        } else if toks[j].is_punct('}') {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                return j + 1;
            }
        } else if toks[j].is_punct(';') && depth == 0 {
            return j + 1;
        }
        j += 1;
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_are_opaque() {
        let src = r##"
            // HashMap in a comment
            /* Instant::now() in a block /* nested */ comment */
            let s = "HashMap::unwrap()";
            let r = r#"SystemTime "quoted" "#;
            let b = b"unwrap";
            let c = 'x';
            let bc = b'\'';
            let lt: &'static str = "ok";
        "##;
        let ids = idents(src);
        assert!(!ids.iter().any(|s| s == "HashMap" || s == "Instant" || s == "unwrap"));
        assert!(!ids.iter().any(|s| s == "static")); // lifetime idents are dropped
    }

    #[test]
    fn test_items_are_stripped() {
        let src = r#"
            fn keep() { v.lock(); }
            #[cfg(test)]
            mod tests {
                fn gone() { x.unwrap(); }
            }
            #[test]
            fn also_gone() { y.unwrap(); }
            #[cfg(not(test))]
            fn kept_too() { z.expect("m"); }
        "#;
        let ids = idents(src);
        assert!(ids.iter().any(|s| s == "keep"));
        assert!(ids.iter().any(|s| s == "kept_too"));
        assert!(ids.iter().any(|s| s == "expect"));
        assert!(!ids.iter().any(|s| s == "gone" || s == "also_gone" || s == "unwrap"));
    }

    #[test]
    fn allow_annotations_are_harvested() {
        let src = "let x = a.exp(); // lint:allow(det-float-intrinsic: tolerated here)\n\
                   // lint:allow(panic-index: next line)\n\
                   let y = v[i];\n";
        let lexed = lex(src);
        assert_eq!(lexed.allows.len(), 2);
        assert_eq!(lexed.allows[0].rule, "det-float-intrinsic");
        assert!(!lexed.allows[0].own_line);
        assert_eq!(lexed.allows[1].rule, "panic-index");
        assert!(lexed.allows[1].own_line);
        assert_eq!(lexed.allows[1].reason, "next line");
    }

    #[test]
    fn numbers_and_ranges() {
        let lexed = lex("a[1..n] + 2.5 + t.0");
        let texts: Vec<&str> = lexed.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["a", "[", "1", ".", ".", "n", "]", "+", "2.5", "+", "t", ".", "0"]);
    }
}
