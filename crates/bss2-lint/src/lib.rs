//! `bss2-lint` — workspace-wide determinism & concurrency static analysis
//! (DESIGN.md §16).
//!
//! A dependency-free, token-level pass over `rust/src/**` and
//! `crates/*/src/**` enforcing four rule families:
//!
//! * **determinism** — no wall-clock (`Instant`/`SystemTime`), no
//!   `HashMap`/`HashSet`, no libm float intrinsics in the sim-path modules
//!   that must replay byte-identically (`asic/`, `fpga/`, `nn/`, `calib/`,
//!   `fault/`, `train/`).
//! * **panic-safety** — no `unwrap`/`expect`/`panic!`-family macros/bare
//!   computed indexing in `coordinator/service/`, `fleet/`, and the
//!   `bss2-proto` decode paths.
//! * **lock-discipline** — a static Mutex/latch acquisition-order graph;
//!   cycles in the direct-nesting graph are findings.
//! * **wire-hygiene** — runtime-sized allocations in `bss2-proto` must
//!   follow a limit check, and every declared `MAX_*` limit must be used
//!   in at least one comparison somewhere in the workspace.
//!
//! Findings are suppressed per-line with `// lint:allow(rule: reason)`;
//! suppressed findings are reported as the *allow budget*.  Un-annotated
//! findings are summarised per `(rule, file)` in `LINT_BASELINE.json`; the
//! gate fails on any count increase (ratchet-down only) and on *any*
//! un-annotated determinism or lock-discipline finding.

pub mod lexer;
pub mod rules;

use rules::Edge;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

pub const BASELINE_FORMAT: &str = "bss2-lint-baseline-v1";

/// Families whose findings must always be fixed or annotated — the
/// baseline cannot absorb them.
pub const HARD_FAMILIES: &[&str] = &["determinism", "lock-discipline"];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub family: &'static str,
    pub file: String,
    pub line: u32,
    pub snippet: String,
    /// `Some(reason)` when a `lint:allow` annotation covers this finding.
    pub allow: Option<String>,
}

#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub lock_edges: Vec<Edge>,
    pub lock_info_edges: Vec<Edge>,
    pub files_scanned: usize,
}

/// Run every rule over `(relative_path, source)` pairs.
///
/// Paths drive rule scoping, so tests can feed fixture sources under
/// synthetic paths like `rust/src/asic/fixture.rs`.
pub fn scan_sources(files: &[(String, String)]) -> Report {
    let mut findings: Vec<Finding> = Vec::new();
    let mut facts: Vec<rules::FnFacts> = Vec::new();
    let mut decls: Vec<rules::LimitDecl> = Vec::new();
    let mut guarded: BTreeSet<String> = BTreeSet::new();
    // file -> [(covered line, rule, reason)]
    let mut allows: BTreeMap<String, Vec<(u32, String, String)>> = BTreeMap::new();

    for (path, src) in files {
        let lexed = lexer::lex(src);
        rules::file_findings(path, &lexed.toks, &mut findings);
        rules::wire_alloc_findings(path, &lexed.toks, &mut findings);
        rules::limit_decls(path, &lexed.toks, &mut decls);
        rules::guarded_limit_uses(&lexed.toks, &mut guarded);
        rules::lock_facts(path, &lexed.toks, &mut facts);
        for a in &lexed.allows {
            let target = if a.own_line {
                lexed.toks.iter().map(|t| t.line).filter(|l| *l > a.line).min()
            } else {
                Some(a.line)
            };
            if let Some(t) = target {
                allows
                    .entry(path.clone())
                    .or_default()
                    .push((t, a.rule.clone(), a.reason.clone()));
            }
        }
    }

    for d in &decls {
        if !guarded.contains(&d.name) {
            findings.push(Finding {
                rule: "wire-unguarded-limit",
                family: "wire-hygiene",
                file: d.file.clone(),
                line: d.line,
                snippet: d.name.clone(),
                allow: None,
            });
        }
    }

    let lock = rules::analyze_locks(&facts);
    findings.extend(lock.cycles);

    for f in &mut findings {
        if let Some(list) = allows.get(&f.file) {
            if let Some((_, _, reason)) =
                list.iter().find(|(l, r, _)| *l == f.line && r == f.rule)
            {
                f.allow = Some(reason.clone());
            }
        }
    }

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.snippet.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.snippet.as_str()))
    });

    Report {
        findings,
        lock_edges: lock.edges,
        lock_info_edges: lock.info_edges,
        files_scanned: files.len(),
    }
}

/// Collect the workspace source set: `rust/src/**` and `crates/*/src/**`
/// (vendor crates and `tests/` trees — including lint fixtures — are out).
pub fn collect_workspace(root: &Path) -> Result<Vec<(String, String)>, String> {
    let mut files = Vec::new();
    walk(&root.join("rust").join("src"), root, &mut files)?;
    let crates_dir = root.join("crates");
    let mut subs: Vec<PathBuf> = std::fs::read_dir(&crates_dir)
        .map_err(|e| format!("read {}: {e}", crates_dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .collect();
    subs.sort();
    for sub in subs {
        let src = sub.join("src");
        if src.is_dir() {
            walk(&src, root, &mut files)?;
        }
    }
    Ok(files)
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<(String, String)>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("read {}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = rd.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            walk(&p, root, out)?;
        } else if p.extension().map_or(false, |x| x == "rs") {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            let src =
                std::fs::read_to_string(&p).map_err(|e| format!("read {}: {e}", p.display()))?;
            out.push((rel, src));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Baseline
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BaselineEntry {
    pub rule: String,
    pub file: String,
    pub count: u32,
}

/// Group the report's un-annotated findings into baseline entries.
pub fn baseline_from(report: &Report) -> Vec<BaselineEntry> {
    let mut counts: BTreeMap<(String, String), u32> = BTreeMap::new();
    for f in &report.findings {
        if f.allow.is_none() {
            *counts.entry((f.file.clone(), f.rule.to_string())).or_insert(0) += 1;
        }
    }
    counts
        .into_iter()
        .map(|((file, rule), count)| BaselineEntry { rule, file, count })
        .collect()
}

pub fn render_baseline(entries: &[BaselineEntry]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"format\": \"{BASELINE_FORMAT}\",");
    s.push_str(
        "  \"note\": \"Un-annotated finding counts per (rule, file). The gate fails on any \
         increase; shrink entries by fixing findings or annotating them with lint:allow \
         (DESIGN.md S16).\",\n",
    );
    s.push_str("  \"entries\": [\n");
    for (i, e) in entries.iter().enumerate() {
        let comma = if i + 1 == entries.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    {{\"rule\": \"{}\", \"file\": \"{}\", \"count\": {}}}{comma}",
            esc(&e.rule),
            esc(&e.file),
            e.count
        );
    }
    s.push_str("  ]\n}\n");
    s
}

pub fn parse_baseline(text: &str) -> Result<Vec<BaselineEntry>, String> {
    if !text.contains(BASELINE_FORMAT) {
        return Err(format!("baseline is missing the `{BASELINE_FORMAT}` format marker"));
    }
    let mut entries = Vec::new();
    for line in text.lines() {
        let t = line.trim().trim_end_matches(',');
        if !t.starts_with("{\"rule\"") {
            continue;
        }
        let rule = json_str_field(t, "rule")
            .ok_or_else(|| format!("bad baseline line (no rule): {t}"))?;
        let file = json_str_field(t, "file")
            .ok_or_else(|| format!("bad baseline line (no file): {t}"))?;
        let count = json_num_field(t, "count")
            .ok_or_else(|| format!("bad baseline line (no count): {t}"))?;
        entries.push(BaselineEntry { rule, file, count });
    }
    Ok(entries)
}

fn json_str_field(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\": \"");
    let s = line.find(&pat)? + pat.len();
    let rest = &line[s..];
    let e = rest.find('"')?;
    Some(rest.get(..e)?.to_string())
}

fn json_num_field(line: &str, key: &str) -> Option<u32> {
    let pat = format!("\"{key}\": ");
    let s = line.find(&pat)? + pat.len();
    let digits: String = line.get(s..)?.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

// ---------------------------------------------------------------------------
// Gate
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct GateOutcome {
    pub failures: Vec<String>,
    pub notes: Vec<String>,
}

impl GateOutcome {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Ratchet-down gate: hard families must be clean (fixed or annotated);
/// every other `(rule, file)` count may only shrink relative to the
/// baseline.  Loose or stale baseline entries are notes, not failures, so
/// fixing findings never breaks the gate.
pub fn gate(report: &Report, baseline: &[BaselineEntry]) -> GateOutcome {
    let mut out = GateOutcome::default();
    let mut fresh: BTreeMap<(String, String), u32> = BTreeMap::new();
    for f in &report.findings {
        if f.allow.is_some() {
            continue;
        }
        if HARD_FAMILIES.contains(&f.family) {
            out.failures.push(format!(
                "{}:{}: [{}] {} — {} findings must be fixed or lint:allow-annotated",
                f.file, f.line, f.rule, f.snippet, f.family
            ));
            continue;
        }
        *fresh.entry((f.file.clone(), f.rule.to_string())).or_insert(0) += 1;
    }
    let mut base: BTreeMap<(String, String), u32> = BTreeMap::new();
    for b in baseline {
        base.insert((b.file.clone(), b.rule.clone()), b.count);
    }
    for ((file, rule), n) in &fresh {
        let b = base.get(&(file.clone(), rule.clone())).copied().unwrap_or(0);
        if *n > b {
            out.failures.push(format!(
                "{file}: [{rule}] {n} un-annotated finding(s), baseline allows {b} — fix or annotate the new ones"
            ));
        } else if *n < b {
            out.notes.push(format!(
                "{file}: [{rule}] baseline is loose ({b} allowed, {n} found) — run --write-baseline to tighten"
            ));
        }
    }
    for ((file, rule), b) in &base {
        if *b > 0 && !fresh.contains_key(&(file.clone(), rule.clone())) {
            out.notes.push(format!(
                "{file}: [{rule}] baseline entry is stale (no findings remain) — run --write-baseline"
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rendering
// ---------------------------------------------------------------------------

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

pub fn render_json(report: &Report) -> String {
    let mut s = String::new();
    s.push_str("{\n  \"findings\": [\n");
    for (i, f) in report.findings.iter().enumerate() {
        let comma = if i + 1 == report.findings.len() { "" } else { "," };
        let allow = match &f.allow {
            Some(r) => format!("\"{}\"", esc(r)),
            None => "null".to_string(),
        };
        let _ = writeln!(
            s,
            "    {{\"rule\": \"{}\", \"family\": \"{}\", \"file\": \"{}\", \"line\": {}, \
             \"snippet\": \"{}\", \"allow\": {}}}{comma}",
            f.rule,
            f.family,
            esc(&f.file),
            f.line,
            esc(&f.snippet),
            allow
        );
    }
    s.push_str("  ],\n  \"lock_edges\": [\n");
    let render_edges = |s: &mut String, edges: &[Edge]| {
        for (i, e) in edges.iter().enumerate() {
            let comma = if i + 1 == edges.len() { "" } else { "," };
            let _ = writeln!(
                s,
                "    {{\"from\": \"{}\", \"to\": \"{}\", \"file\": \"{}\", \"line\": {}}}{comma}",
                esc(&e.from),
                esc(&e.to),
                esc(&e.file),
                e.line
            );
        }
    };
    render_edges(&mut s, &report.lock_edges);
    s.push_str("  ],\n  \"lock_info_edges\": [\n");
    render_edges(&mut s, &report.lock_info_edges);
    s.push_str("  ]\n}\n");
    s
}

pub fn render_human(report: &Report) -> String {
    let mut s = String::new();
    let total = report.findings.len();
    let allowed = report.findings.iter().filter(|f| f.allow.is_some()).count();
    let _ = writeln!(
        s,
        "bss2-lint: {} finding(s) across {} file(s), {} annotated (allow budget)",
        total, report.files_scanned, allowed
    );
    let mut per_rule: BTreeMap<&str, (u32, u32)> = BTreeMap::new();
    for f in &report.findings {
        let e = per_rule.entry(f.rule).or_insert((0, 0));
        e.0 += 1;
        if f.allow.is_some() {
            e.1 += 1;
        }
    }
    for (rule, (n, a)) in &per_rule {
        let _ = writeln!(s, "  {rule:<24} total {n:>3}   allowed {a:>3}");
    }
    for f in &report.findings {
        if f.allow.is_none() {
            let _ = writeln!(s, "  {}:{}: [{}] {}", f.file, f.line, f.rule, f.snippet);
        }
    }
    if !report.lock_edges.is_empty() {
        s.push_str("lock acquisition order (direct nesting):\n");
        for e in &report.lock_edges {
            let _ = writeln!(s, "  {} -> {}  ({}:{})", e.from, e.to, e.file, e.line);
        }
    }
    if !report.lock_info_edges.is_empty() {
        s.push_str("lock order via calls (informational):\n");
        for e in &report.lock_info_edges {
            let _ = writeln!(s, "  {} -> {}  ({}:{})", e.from, e.to, e.file, e.line);
        }
    }
    s
}

// ---------------------------------------------------------------------------
// CLI driver (shared by the `bss2-lint` binary and `repro audit`)
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct Options {
    /// Workspace root; discovered by walking up from the CWD when absent.
    pub root: Option<PathBuf>,
    pub json: bool,
    pub gate: Option<PathBuf>,
    pub write_baseline: Option<PathBuf>,
}

/// Returns the process exit code: 0 clean, 1 gate failures.
/// IO/usage problems come back as `Err`.
pub fn run(opts: &Options) -> Result<i32, String> {
    let root = match &opts.root {
        Some(r) => r.clone(),
        None => find_root()?,
    };
    let files = collect_workspace(&root)?;
    let report = scan_sources(&files);

    if let Some(path) = &opts.write_baseline {
        let entries = baseline_from(&report);
        let abs = if path.is_absolute() { path.clone() } else { root.join(path) };
        std::fs::write(&abs, render_baseline(&entries))
            .map_err(|e| format!("write {}: {e}", abs.display()))?;
        println!("bss2-lint: wrote {} entr(ies) to {}", entries.len(), abs.display());
        return Ok(0);
    }

    if opts.json {
        print!("{}", render_json(&report));
    }

    // Gate against an explicit baseline, or the committed one when present.
    let gate_path = match &opts.gate {
        Some(p) => {
            let abs = if p.is_absolute() { p.clone() } else { root.join(p) };
            Some(abs)
        }
        None => {
            let default = root.join("LINT_BASELINE.json");
            default.exists().then_some(default)
        }
    };
    let Some(gp) = gate_path else {
        if !opts.json {
            print!("{}", render_human(&report));
        }
        return Ok(0);
    };
    let text = std::fs::read_to_string(&gp).map_err(|e| format!("read {}: {e}", gp.display()))?;
    let baseline = parse_baseline(&text)?;
    let outcome = gate(&report, &baseline);
    for n in &outcome.notes {
        eprintln!("bss2-lint note: {n}");
    }
    if outcome.passed() {
        if !opts.json {
            println!(
                "bss2-lint: gate clean — {} finding(s), {} annotated, baseline {}",
                report.findings.len(),
                report.findings.iter().filter(|f| f.allow.is_some()).count(),
                gp.display()
            );
        }
        Ok(0)
    } else {
        for f in &outcome.failures {
            eprintln!("bss2-lint FAIL: {f}");
        }
        eprintln!("bss2-lint: {} gate failure(s) vs {}", outcome.failures.len(), gp.display());
        Ok(1)
    }
}

fn find_root() -> Result<PathBuf, String> {
    let mut dir = std::env::current_dir().map_err(|e| format!("cwd: {e}"))?;
    for _ in 0..10 {
        let manifest = dir.join("Cargo.toml");
        if manifest.is_file() {
            if let Ok(text) = std::fs::read_to_string(&manifest) {
                if text.contains("[workspace]") {
                    return Ok(dir);
                }
            }
        }
        if !dir.pop() {
            break;
        }
    }
    Err("could not find the workspace root (run from inside the repo or pass --root)".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan_one(path: &str, src: &str) -> Report {
        scan_sources(&[(path.to_string(), src.to_string())])
    }

    #[test]
    fn baseline_roundtrip() {
        let entries = vec![
            BaselineEntry { rule: "panic-index".into(), file: "rust/src/fleet/pool.rs".into(), count: 3 },
            BaselineEntry { rule: "panic-unwrap".into(), file: "rust/src/x.rs".into(), count: 1 },
        ];
        let text = render_baseline(&entries);
        assert_eq!(parse_baseline(&text).unwrap(), entries);
        assert!(parse_baseline("{}").is_err(), "format marker required");
    }

    #[test]
    fn gate_ratchet_semantics() {
        let report = scan_one(
            "rust/src/fleet/x.rs",
            "fn f(v: &[u8], i: usize) -> u8 { v[i] }\n",
        );
        assert_eq!(report.findings.len(), 1);
        // No baseline entry -> new finding -> failure.
        assert!(!gate(&report, &[]).passed());
        // Exact entry -> pass.
        let base = vec![BaselineEntry {
            rule: "panic-index".into(),
            file: "rust/src/fleet/x.rs".into(),
            count: 1,
        }];
        assert!(gate(&report, &base).passed());
        // Loose entry -> pass with a note.
        let loose = vec![BaselineEntry {
            rule: "panic-index".into(),
            file: "rust/src/fleet/x.rs".into(),
            count: 5,
        }];
        let out = gate(&report, &loose);
        assert!(out.passed() && !out.notes.is_empty());
    }

    #[test]
    fn hard_families_ignore_the_baseline() {
        let report = scan_one(
            "rust/src/asic/x.rs",
            "use std::collections::HashMap;\n",
        );
        assert_eq!(report.findings.len(), 1);
        let base = vec![BaselineEntry {
            rule: "det-unordered-map".into(),
            file: "rust/src/asic/x.rs".into(),
            count: 99,
        }];
        assert!(!gate(&report, &base).passed(), "determinism findings cannot be baselined");
    }

    #[test]
    fn allow_annotation_feeds_the_budget() {
        let report = scan_one(
            "rust/src/asic/x.rs",
            "fn f(x: f64) -> f64 { x.exp() } // lint:allow(det-float-intrinsic: seeded noise shaping)\n",
        );
        assert_eq!(report.findings.len(), 1);
        assert_eq!(report.findings[0].allow.as_deref(), Some("seeded noise shaping"));
        assert!(gate(&report, &[]).passed());
    }
}
