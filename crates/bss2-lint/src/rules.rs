//! The four rule families (DESIGN.md §16): determinism, panic-safety,
//! lock discipline, and wire hygiene.
//!
//! Everything here works on the filtered token stream from [`crate::lexer`]
//! — no AST.  Scoping is by path prefix, so the same rules run unchanged on
//! fixture files in tests (they just get synthetic paths).

use crate::lexer::{Kind, Tok};
use crate::Finding;
use std::collections::{BTreeMap, BTreeSet};

/// Modules that must advance on chip time only and replay byte-identically.
pub const SIM_PATHS: &[&str] = &[
    "rust/src/asic/",
    "rust/src/fpga/",
    "rust/src/nn/",
    "rust/src/calib/",
    "rust/src/fault/",
    "rust/src/train/",
];

/// Server paths where a panic tears down a worker or a connection.
pub const PANIC_PATHS: &[&str] = &[
    "rust/src/coordinator/service/",
    "rust/src/fleet/",
    "crates/bss2-proto/src/",
];

/// The wire crate: every `MAX_*` limit must be checked before the
/// allocation it bounds.
pub const WIRE_PATHS: &[&str] = &["crates/bss2-proto/src/"];

/// libm-backed float intrinsics whose results are not guaranteed
/// bit-identical across platforms (`sqrt` is IEEE-correctly-rounded and
/// `powi` lowers to multiplies, so both stay legal).
const BANNED_FLOAT: &[&str] = &[
    "powf", "exp", "exp2", "exp_m1", "ln", "ln_1p", "log", "log2", "log10", "sin", "cos", "tan",
    "asin", "acos", "atan", "atan2", "sinh", "cosh", "tanh",
];

const BANNED_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Idents that can legally precede `[` without it being an index
/// expression (slice patterns, array types, ...).
const KEYWORDS: &[&str] = &[
    "as", "box", "break", "const", "continue", "crate", "dyn", "else", "enum", "extern", "fn",
    "for", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut", "pub", "ref",
    "return", "static", "struct", "super", "trait", "type", "unsafe", "use", "where", "while",
];

/// Method names that never acquire repo locks; calls to them while a guard
/// is held are not worth tracking in the acquisition graph.
const CALL_NOISE: &[&str] = &[
    "lock", "unwrap", "expect", "unwrap_or", "unwrap_or_else", "unwrap_or_default", "map",
    "map_err", "and_then", "ok", "err", "ok_or", "ok_or_else", "iter", "into_iter", "drain",
    "push", "pop", "insert", "remove", "get", "get_mut", "len", "is_empty", "clone",
    "to_string", "as_ref", "as_mut", "as_str", "as_bytes", "take", "replace", "store", "load",
    "compare_exchange", "send", "recv", "try_send", "try_recv", "contains", "contains_key",
    "min", "max", "clamp", "collect", "filter", "rev", "enumerate", "extend", "entry",
    "or_default", "or_insert", "or_insert_with", "values", "keys", "join", "wait", "notify_all",
    "notify_one", "new", "drop", "format", "write", "writeln", "into", "from", "retain",
    "position", "any", "all", "find", "count", "copied", "cloned", "chars", "next", "fmt",
    "flush", "shutdown", "set_nodelay", "set_nonblocking", "to_vec", "starts_with", "ends_with",
];

pub fn in_any(path: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| path.starts_with(p))
}

fn finding(rule: &'static str, family: &'static str, file: &str, line: u32, snippet: String) -> Finding {
    Finding { rule, family, file: file.to_string(), line, snippet, allow: None }
}

/// Determinism + panic-safety rules (path-scoped, single pass).
pub fn file_findings(path: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    let sim = in_any(path, SIM_PATHS);
    let panicky = in_any(path, PANIC_PATHS);
    if !sim && !panicky {
        return;
    }
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != Kind::Ident {
            if panicky && t.is_punct('[') {
                if let Some(s) = index_snippet(toks, i) {
                    out.push(finding("panic-index", "panic-safety", path, t.line, s));
                }
            }
            continue;
        }
        let prev_dot = i > 0 && toks[i - 1].is_punct('.');
        let next_paren = toks.get(i + 1).map_or(false, |n| n.is_punct('('));
        let next_bang = toks.get(i + 1).map_or(false, |n| n.is_punct('!'));
        if sim {
            if t.text == "Instant" || t.text == "SystemTime" {
                out.push(finding("det-wallclock", "determinism", path, t.line, t.text.clone()));
            }
            if t.text == "HashMap" || t.text == "HashSet" {
                out.push(finding("det-unordered-map", "determinism", path, t.line, t.text.clone()));
            }
            if prev_dot && next_paren && BANNED_FLOAT.contains(&t.text.as_str()) {
                out.push(finding(
                    "det-float-intrinsic",
                    "determinism",
                    path,
                    t.line,
                    format!(".{}()", t.text),
                ));
            }
        }
        if panicky {
            if prev_dot && next_paren && (t.text == "unwrap" || t.text == "expect") {
                out.push(finding(
                    "panic-unwrap",
                    "panic-safety",
                    path,
                    t.line,
                    format!(".{}()", t.text),
                ));
            }
            if next_bang && BANNED_MACROS.contains(&t.text.as_str()) {
                out.push(finding(
                    "panic-macro",
                    "panic-safety",
                    path,
                    t.line,
                    format!("{}!", t.text),
                ));
            }
        }
    }
}

/// `Some(snippet)` when `toks[open]` (a `[`) is a fallible index expression.
///
/// Single integer literals (`buf[0]`) and full ranges (`buf[..]`) are
/// considered benign: the former is the fixed-layout style the handshake
/// and header parsers use and cannot be wrong twice, the latter cannot
/// panic at all.  Everything computed (`buf[i]`, `buf[n..m]`) is flagged.
fn index_snippet(toks: &[Tok], open: usize) -> Option<String> {
    if open == 0 {
        return None;
    }
    let prev = &toks[open - 1];
    let indexable = match prev.kind {
        Kind::Ident => !KEYWORDS.contains(&prev.text.as_str()),
        Kind::Punct => prev.is_punct(')') || prev.is_punct(']'),
        _ => false,
    };
    if !indexable {
        return None;
    }
    let mut depth = 1usize;
    let mut k = open + 1;
    let mut inner: Vec<&Tok> = Vec::new();
    while k < toks.len() && depth > 0 {
        if toks[k].is_punct('[') {
            depth += 1;
        } else if toks[k].is_punct(']') {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        inner.push(&toks[k]);
        k += 1;
    }
    if inner.len() == 1 && inner[0].kind == Kind::Num {
        return None;
    }
    if inner.len() == 2 && inner[0].is_punct('.') && inner[1].is_punct('.') {
        return None;
    }
    let mut s = String::new();
    if prev.kind == Kind::Ident {
        s.push_str(&prev.text);
    }
    s.push('[');
    for (n, t) in inner.iter().take(6).enumerate() {
        if n > 0 && t.kind != Kind::Punct && inner[n - 1].kind != Kind::Punct {
            s.push(' ');
        }
        s.push_str(if t.kind == Kind::Str { "\u{201c}\u{201d}" } else { &t.text });
    }
    if inner.len() > 6 {
        s.push('\u{2026}');
    }
    s.push(']');
    Some(s)
}

/// Wire hygiene, part 1: allocations sized by a runtime value must follow a
/// limit check (`MAX_*`, or the `count`/`take`/`min` pre-validation
/// helpers) earlier in the same function.
pub fn wire_alloc_findings(path: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if !in_any(path, WIRE_PATHS) {
        return;
    }
    for_each_fn(toks, |_name, body| {
        for i in 0..body.len() {
            let t = &body[i];
            if t.kind != Kind::Ident {
                continue;
            }
            let size: Option<Vec<&Tok>> = if (t.text == "with_capacity" || t.text == "reserve")
                && body.get(i + 1).map_or(false, |n| n.is_punct('('))
            {
                Some(group_contents(body, i + 1, '(', ')'))
            } else if t.text == "vec" && body.get(i + 1).map_or(false, |n| n.is_punct('!')) {
                body.get(i + 2).filter(|n| n.is_punct('[')).map(|_| {
                    let inner = group_contents(body, i + 2, '[', ']');
                    match inner.iter().position(|t| t.is_punct(';')) {
                        Some(p) => inner[p + 1..].to_vec(),
                        None => Vec::new(),
                    }
                })
            } else {
                None
            };
            let Some(size) = size else { continue };
            let runtime_sized = size
                .iter()
                .any(|s| s.kind == Kind::Ident && s.text.chars().any(|c| c.is_lowercase()));
            if !runtime_sized {
                continue;
            }
            let guarded = body[..i].iter().any(|g| {
                g.kind == Kind::Ident
                    && (g.text.starts_with("MAX_")
                        || g.text == "count"
                        || g.text == "take"
                        || g.text == "min")
            });
            if !guarded {
                out.push(finding(
                    "wire-unchecked-alloc",
                    "wire-hygiene",
                    path,
                    t.line,
                    format!("{}(..)", t.text),
                ));
            }
        }
    });
}

/// Wire hygiene, part 2 (global): every `MAX_*` constant declared in the
/// wire crate must appear in at least one comparison / range / clamp
/// somewhere in the workspace.
#[derive(Debug, Clone)]
pub struct LimitDecl {
    pub name: String,
    pub file: String,
    pub line: u32,
}

pub fn limit_decls(path: &str, toks: &[Tok], out: &mut Vec<LimitDecl>) {
    if !in_any(path, WIRE_PATHS) {
        return;
    }
    for i in 0..toks.len() {
        if toks[i].is_ident("const")
            && toks.get(i + 1).map_or(false, |n| n.kind == Kind::Ident && n.text.starts_with("MAX_"))
        {
            out.push(LimitDecl {
                name: toks[i + 1].text.clone(),
                file: path.to_string(),
                line: toks[i + 1].line,
            });
        }
    }
}

pub fn guarded_limit_uses(toks: &[Tok], out: &mut BTreeSet<String>) {
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != Kind::Ident || !t.text.starts_with("MAX_") {
            continue;
        }
        if i >= 1 && toks[i - 1].is_ident("const") {
            continue; // the declaration itself
        }
        let lo = i.saturating_sub(3);
        let hi = (i + 4).min(toks.len());
        let win = &toks[lo..hi];
        let relational = win.iter().any(|w| w.is_punct('<') || w.is_punct('>'));
        let helper = win
            .iter()
            .any(|w| w.is_ident("min") || w.is_ident("max") || w.is_ident("contains") || w.is_ident("clamp"));
        let range = win.windows(2).any(|p| p[0].is_punct('.') && p[1].is_punct('.'));
        if relational || helper || range {
            out.insert(t.text.clone());
        }
    }
}

/// Tokens inside the bracket group opening at `body[open]` (exclusive).
fn group_contents<'a>(body: &'a [Tok], open: usize, oc: char, cc: char) -> Vec<&'a Tok> {
    let mut depth = 1usize;
    let mut k = open + 1;
    let mut inner = Vec::new();
    while k < body.len() && depth > 0 {
        if body[k].is_punct(oc) {
            depth += 1;
        } else if body[k].is_punct(cc) {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        inner.push(&body[k]);
        k += 1;
    }
    inner
}

/// Call `f(name, body)` for every `fn name(..) { body }` in the stream
/// (bodies include their outer braces; nested fns are visited too).
pub fn for_each_fn<'a>(toks: &'a [Tok], mut f: impl FnMut(&str, &'a [Tok])) {
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].is_ident("fn") && toks.get(i + 1).map_or(false, |n| n.kind == Kind::Ident) {
            let name = &toks[i + 1].text;
            let mut j = i + 2;
            let mut body_start = None;
            while j < toks.len() {
                if toks[j].is_punct('{') {
                    body_start = Some(j);
                    break;
                }
                if toks[j].is_punct(';') {
                    break; // trait method declaration, no body
                }
                j += 1;
            }
            if let Some(s) = body_start {
                let mut depth = 0i32;
                let mut k = s;
                while k < toks.len() {
                    if toks[k].is_punct('{') {
                        depth += 1;
                    } else if toks[k].is_punct('}') {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    k += 1;
                }
                f(name, &toks[s..k.min(toks.len())]);
                i = s + 1;
                continue;
            }
        }
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Lock discipline
// ---------------------------------------------------------------------------

/// One observed "A held while acquiring B" site.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Edge {
    pub from: String,
    pub to: String,
    pub file: String,
    pub line: u32,
}

/// Per-function lock facts extracted from one file.
#[derive(Debug, Default)]
pub struct FnFacts {
    pub name: String,
    pub file: String,
    /// Lock names this function acquires directly.
    pub locks: BTreeSet<String>,
    /// Snake-case callees (for one-level summary propagation).
    pub calls: BTreeSet<String>,
    /// Direct nested acquisitions: guard of `from` live while `to` is taken.
    pub direct_edges: Vec<Edge>,
    /// (held lock, callee) pairs for the informational graph.
    pub calls_while_holding: Vec<(String, String, u32)>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Until {
    /// Guard is `let`-bound (or a loop/match scrutinee temporary): lives to
    /// the end of the enclosing block, i.e. while depth >= this value.
    Scope(i32),
    /// Plain expression statement temporary: dies at the next `;` at or
    /// below this depth.
    Stmt(i32),
    /// A `compare_exchange(false, true)` latch: released by
    /// `.store(false)` on the same name, or at function end.
    Latch,
}

#[derive(Debug, Clone)]
struct Hold {
    name: String,
    var: Option<String>,
    until: Until,
}

pub fn lock_facts(path: &str, toks: &[Tok], out: &mut Vec<FnFacts>) {
    for_each_fn(toks, |name, body| {
        let mut facts = FnFacts {
            name: name.to_string(),
            file: path.to_string(),
            ..FnFacts::default()
        };
        walk_fn_body(path, body, &mut facts);
        if !facts.locks.is_empty() || !facts.calls.is_empty() {
            out.push(facts);
        }
    });
}

fn walk_fn_body(path: &str, body: &[Tok], facts: &mut FnFacts) {
    let mut held: Vec<Hold> = Vec::new();
    let mut depth = 0i32;
    let mut k = 0usize;
    while k < body.len() {
        let t = &body[k];
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            held.retain(|h| match h.until {
                Until::Scope(d) => depth >= d,
                Until::Stmt(d) => depth >= d,
                Until::Latch => true,
            });
        } else if t.is_punct(';') {
            held.retain(|h| match h.until {
                Until::Stmt(d) => depth > d,
                _ => true,
            });
        } else if t.is_ident("fn") && body.get(k + 1).map_or(false, |n| n.kind == Kind::Ident) {
            // Nested fn item: analysed separately by for_each_fn; skip its
            // body here so its acquisitions are not charged to us.
            let mut j = k + 2;
            while j < body.len() && !body[j].is_punct('{') && !body[j].is_punct(';') {
                j += 1;
            }
            if j < body.len() && body[j].is_punct('{') {
                let mut d = 0i32;
                while j < body.len() {
                    if body[j].is_punct('{') {
                        d += 1;
                    } else if body[j].is_punct('}') {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    }
                    j += 1;
                }
            }
            k = j + 1;
            continue;
        } else if t.is_ident("drop")
            && body.get(k + 1).map_or(false, |n| n.is_punct('('))
            && body.get(k + 3).map_or(false, |n| n.is_punct(')'))
        {
            if let Some(v) = body.get(k + 2).filter(|v| v.kind == Kind::Ident) {
                held.retain(|h| h.var.as_deref() != Some(v.text.as_str()));
            }
        } else if t.is_punct('.') {
            let meth = body.get(k + 1);
            let paren = body.get(k + 2).map_or(false, |n| n.is_punct('('));
            if let (Some(m), true) = (meth, paren) {
                if m.is_ident("lock") {
                    let name = receiver_name(body, k);
                    acquire(path, body, k, m.line, name, depth, &mut held, facts, false);
                    k += 2;
                    continue;
                }
                if m.is_ident("compare_exchange")
                    && body.get(k + 3).map_or(false, |n| n.is_ident("false"))
                    && body.get(k + 4).map_or(false, |n| n.is_punct(','))
                    && body.get(k + 5).map_or(false, |n| n.is_ident("true"))
                {
                    let name = receiver_name(body, k);
                    acquire(path, body, k, m.line, name, depth, &mut held, facts, true);
                    k += 2;
                    continue;
                }
                if m.is_ident("store") && body.get(k + 3).map_or(false, |n| n.is_ident("false")) {
                    let name = receiver_name(body, k);
                    held.retain(|h| !(h.until == Until::Latch && h.name == name));
                }
            }
        } else if t.kind == Kind::Ident
            && body.get(k + 1).map_or(false, |n| n.is_punct('('))
            && !CALL_NOISE.contains(&t.text.as_str())
            && t.text.chars().next().map_or(false, |c| c.is_lowercase())
        {
            facts.calls.insert(t.text.clone());
            for h in &held {
                facts.calls_while_holding.push((h.name.clone(), t.text.clone(), t.line));
            }
        }
        k += 1;
    }
}

#[allow(clippy::too_many_arguments)]
fn acquire(
    path: &str,
    body: &[Tok],
    dot: usize,
    line: u32,
    name: String,
    depth: i32,
    held: &mut Vec<Hold>,
    facts: &mut FnFacts,
    latch: bool,
) {
    for h in held.iter() {
        if h.name != name {
            facts.direct_edges.push(Edge {
                from: h.name.clone(),
                to: name.clone(),
                file: path.to_string(),
                line,
            });
        }
    }
    facts.locks.insert(name.clone());
    let (until, var) = if latch {
        (Until::Latch, None)
    } else {
        statement_binding(body, dot, depth)
    };
    held.push(Hold { name, var, until });
}

/// Look back to the start of the statement containing `dot` to decide how
/// long the guard lives, and capture a `let`-bound variable name if any.
fn statement_binding(body: &[Tok], dot: usize, depth: i32) -> (Until, Option<String>) {
    let mut s = dot;
    while s > 0 {
        let p = &body[s - 1];
        if p.is_punct(';') || p.is_punct('{') || p.is_punct('}') {
            break;
        }
        s -= 1;
    }
    let head: Vec<&Tok> = body[s..(s + 3).min(body.len())].iter().collect();
    let has_let = head.iter().any(|t| t.is_ident("let"));
    let scoped = has_let
        || head.first().map_or(false, |t| {
            t.is_ident("for") || t.is_ident("while") || t.is_ident("match") || t.is_ident("if")
        });
    let var = if has_let {
        body[s..dot]
            .iter()
            .skip_while(|t| !t.is_ident("let"))
            .skip(1)
            .find(|t| t.kind == Kind::Ident && t.text != "mut")
            .map(|t| t.text.clone())
    } else {
        None
    };
    if scoped {
        (Until::Scope(depth), var)
    } else {
        (Until::Stmt(depth), var)
    }
}

/// Last path segment of the receiver chain ending just before `body[dot]`.
fn receiver_name(body: &[Tok], dot: usize) -> String {
    if dot == 0 {
        return "<expr>".to_string();
    }
    let p = &body[dot - 1];
    match p.kind {
        Kind::Ident => p.text.clone(),
        Kind::Punct if p.is_punct(')') || p.is_punct(']') => {
            let (oc, cc) = if p.is_punct(')') { ('(', ')') } else { ('[', ']') };
            let mut d = 0i32;
            let mut k = dot - 1;
            loop {
                if body[k].is_punct(cc) {
                    d += 1;
                } else if body[k].is_punct(oc) {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                if k == 0 {
                    break;
                }
                k -= 1;
            }
            if k > 0 && body[k - 1].kind == Kind::Ident {
                receiver_name(body, k) // ident before the group, e.g. `handles[i].tx`
            } else {
                "<expr>".to_string()
            }
        }
        _ => "<expr>".to_string(),
    }
}

/// Global lock-order analysis over all collected facts.
pub struct LockReport {
    /// Gate-relevant findings: cycles in the *direct* acquisition graph.
    pub cycles: Vec<Finding>,
    /// Deduplicated direct edges (for the report / JSON output).
    pub edges: Vec<Edge>,
    /// Informational held-across-call edges via one-level fn summaries.
    pub info_edges: Vec<Edge>,
}

pub fn analyze_locks(facts: &[FnFacts]) -> LockReport {
    let mut edges: Vec<Edge> = Vec::new();
    for f in facts {
        for e in &f.direct_edges {
            if !edges.iter().any(|x| x.from == e.from && x.to == e.to) {
                edges.push(e.clone());
            }
        }
    }
    edges.sort();

    // Transitive lock summaries: fn name -> locks reachable through calls.
    let mut summary: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for f in facts {
        summary.entry(f.name.clone()).or_default().extend(f.locks.iter().cloned());
    }
    for _ in 0..8 {
        let mut changed = false;
        for f in facts {
            let mut add: BTreeSet<String> = BTreeSet::new();
            for callee in &f.calls {
                if let Some(s) = summary.get(callee) {
                    add.extend(s.iter().cloned());
                }
            }
            let own = summary.entry(f.name.clone()).or_default();
            for l in add {
                changed |= own.insert(l);
            }
        }
        if !changed {
            break;
        }
    }

    let mut info_edges: Vec<Edge> = Vec::new();
    for f in facts {
        for (held, callee, line) in &f.calls_while_holding {
            if let Some(locks) = summary.get(callee) {
                for l in locks {
                    if l != held
                        && !info_edges.iter().any(|x| &x.from == held && &x.to == l)
                        && !edges.iter().any(|x| &x.from == held && &x.to == l)
                    {
                        info_edges.push(Edge {
                            from: held.clone(),
                            to: l.clone(),
                            file: f.file.clone(),
                            line: *line,
                        });
                    }
                }
            }
        }
    }
    info_edges.sort();

    let cycles = find_cycles(&edges)
        .into_iter()
        .map(|cyc| {
            let first = edges
                .iter()
                .find(|e| e.from == cyc[0] && e.to == cyc[1])
                .cloned()
                .unwrap_or_else(|| Edge {
                    from: cyc[0].clone(),
                    to: cyc[1].clone(),
                    file: String::new(),
                    line: 0,
                });
            Finding {
                rule: "lock-order-cycle",
                family: "lock-discipline",
                file: first.file,
                line: first.line,
                snippet: cyc.join(" -> "),
                allow: None,
            }
        })
        .collect();

    LockReport { cycles, edges, info_edges }
}

/// All elementary cycles, canonicalised (rotated to start at the smallest
/// node, closed with the starting node repeated at the end).
fn find_cycles(edges: &[Edge]) -> Vec<Vec<String>> {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for e in edges {
        adj.entry(e.from.as_str()).or_default().push(e.to.as_str());
    }
    let mut cycles: BTreeSet<Vec<String>> = BTreeSet::new();
    let nodes: Vec<&str> = adj.keys().copied().collect();
    for start in nodes {
        let mut path: Vec<&str> = vec![start];
        dfs(start, &adj, &mut path, &mut cycles);
    }
    cycles.into_iter().collect()
}

fn dfs<'a>(
    node: &'a str,
    adj: &BTreeMap<&'a str, Vec<&'a str>>,
    path: &mut Vec<&'a str>,
    cycles: &mut BTreeSet<Vec<String>>,
) {
    if path.len() > 16 {
        return; // degenerate graphs: bail rather than blow the stack
    }
    let Some(nexts) = adj.get(node) else { return };
    for &n in nexts {
        if let Some(pos) = path.iter().position(|p| *p == n) {
            let cyc = &path[pos..];
            // canonical rotation: start at the lexicographically smallest
            let min_i = cyc
                .iter()
                .enumerate()
                .min_by_key(|&(_, s)| *s)
                .map(|(i, _)| i)
                .unwrap_or(0);
            let mut canon: Vec<String> =
                cyc.iter().cycle().skip(min_i).take(cyc.len()).map(|s| s.to_string()).collect();
            canon.push(canon[0].clone());
            cycles.insert(canon);
            continue;
        }
        path.push(n);
        dfs(n, adj, path, cycles);
        path.pop();
    }
}
