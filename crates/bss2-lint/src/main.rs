//! `cargo run -p bss2-lint -- [--root DIR] [--json] [--gate FILE]
//! [--write-baseline FILE]` — see DESIGN.md §16.
//!
//! With no flags: gates against `LINT_BASELINE.json` at the workspace root
//! when it exists, otherwise prints the full report.  Exit codes: 0 clean,
//! 1 gate failures, 2 usage/IO errors.

use std::path::PathBuf;

const USAGE: &str = "\
bss2-lint — workspace determinism & concurrency static analysis

USAGE: bss2-lint [--root DIR] [--json] [--gate FILE] [--write-baseline FILE]

  --root DIR             workspace root (default: discovered upward from CWD)
  --json                 print the machine-readable findings report
  --gate FILE            fail (exit 1) on findings not covered by FILE
  --write-baseline FILE  regenerate the baseline from the current findings
";

fn main() {
    let mut opts = bss2_lint::Options::default();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => opts.json = true,
            "--root" | "--gate" | "--write-baseline" => {
                let Some(v) = args.next() else {
                    eprintln!("error: {a} needs a value\n{USAGE}");
                    std::process::exit(2);
                };
                let p = PathBuf::from(v);
                match a.as_str() {
                    "--root" => opts.root = Some(p),
                    "--gate" => opts.gate = Some(p),
                    _ => opts.write_baseline = Some(p),
                }
            }
            "--help" | "-h" => {
                print!("{USAGE}");
                return;
            }
            other => {
                eprintln!("error: unknown argument `{other}`\n{USAGE}");
                std::process::exit(2);
            }
        }
    }
    match bss2_lint::run(&opts) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("bss2-lint error: {e}");
            std::process::exit(2);
        }
    }
}
