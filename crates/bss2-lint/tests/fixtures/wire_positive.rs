// Fixture: wire-hygiene violations — a runtime-sized allocation with no
// preceding limit check, and a declared MAX_* constant nothing ever
// enforces (tests feed it in as `crates/bss2-proto/src/fixture.rs`).
pub const MAX_ORPHAN_ITEMS: usize = 64;

pub fn decode_items(n: usize) -> Vec<u32> {
    let mut items = Vec::with_capacity(n);
    items.push(0);
    items
}
