// Fixture: the deterministic idioms the rules must NOT flag, plus one
// intentionally banned call carrying a lint:allow annotation.
use std::collections::BTreeMap;

pub fn clean(xs: &[f64]) -> f64 {
    let mut m: BTreeMap<u32, f64> = BTreeMap::new();
    // sqrt is IEEE-correctly-rounded, powi is compile-time multiplies:
    // both are bit-exact across hosts and stay legal.
    m.insert(0, xs[0].sqrt() + xs[0].powi(2));
    // lint:allow(det-float-intrinsic: fixture demonstrates an annotated site)
    m.insert(1, xs[0].exp());
    m.len() as f64
}
