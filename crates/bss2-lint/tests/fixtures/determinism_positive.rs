// Fixture: every determinism rule fires when this text is scanned under a
// simulation path (tests feed it in as `rust/src/asic/fixture.rs`).  It is
// never compiled — `tests/fixtures/` is data, not a test target.
use std::collections::HashMap;
use std::time::Instant;

pub fn tainted(xs: &[f64]) -> f64 {
    let t = Instant::now();
    let mut m: HashMap<u32, f64> = HashMap::new();
    m.insert(0, xs[0].powf(2.0));
    let _ = t;
    m.len() as f64
}
