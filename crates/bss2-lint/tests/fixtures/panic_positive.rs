// Fixture: every panic-safety rule fires when scanned under a server path
// (tests feed it in as `rust/src/fleet/fixture.rs`).
pub fn brittle(xs: &[u32], i: usize) -> u32 {
    let first = xs.first().unwrap();
    if *first > 9000 {
        panic!("impossible reading");
    }
    xs[i] + first
}
