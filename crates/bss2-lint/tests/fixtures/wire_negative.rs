// Fixture: the compliant wire idiom — the count is validated against its
// MAX_* limit before the allocation, so both wire rules stay quiet.
pub const MAX_ITEMS: usize = 64;

pub fn decode_items(n: usize) -> Option<Vec<u32>> {
    if n > MAX_ITEMS {
        return None;
    }
    let mut items = Vec::with_capacity(n);
    items.push(0);
    Some(items)
}
