// Fixture: a three-lock acquisition-order cycle — alpha→beta, beta→gamma,
// gamma→alpha via direct nesting.  The lock-discipline pass must report
// exactly one canonical cycle.
pub struct Trio {
    alpha: std::sync::Mutex<u32>,
    beta: std::sync::Mutex<u32>,
    gamma: std::sync::Mutex<u32>,
}

impl Trio {
    pub fn ab(&self) {
        let a = self.alpha.lock();
        let _b = self.beta.lock();
        drop(a);
    }

    pub fn bc(&self) {
        let b = self.beta.lock();
        let _c = self.gamma.lock();
        drop(b);
    }

    pub fn ca(&self) {
        let c = self.gamma.lock();
        let _a = self.alpha.lock();
        drop(c);
    }
}
