// Fixture: panic-free server-path idioms — typed errors, `.get()`, benign
// literal indexing — plus an annotated invariant `unreachable!`.
pub fn sturdy(xs: &[u32], i: usize) -> Result<u32, String> {
    let first = xs.first().ok_or("empty batch")?;
    let probe = xs[0];
    match xs.get(i) {
        Some(v) => Ok(v + first + probe),
        // lint:allow(panic-macro: fixture demonstrates an annotated invariant)
        None if i == usize::MAX => unreachable!("caller clamps i"),
        None => Err(format!("index {i} out of range")),
    }
}
