//! Rule-family self-tests: each fixture under `tests/fixtures/` is fed to
//! [`bss2_lint::scan_sources`] under a synthetic workspace path (scoping is
//! path-driven), so positives, negatives, and `lint:allow` budgeting are
//! pinned without compiling the fixtures.  The final test gates the real
//! workspace against the committed `LINT_BASELINE.json` — the same check
//! CI runs — so the baseline can never silently rot.

use std::path::Path;

use bss2_lint::{baseline_from, gate, parse_baseline, scan_sources, Report};

const DET_POS: &str = include_str!("fixtures/determinism_positive.rs");
const DET_NEG: &str = include_str!("fixtures/determinism_negative.rs");
const PANIC_POS: &str = include_str!("fixtures/panic_positive.rs");
const PANIC_NEG: &str = include_str!("fixtures/panic_negative.rs");
const LOCK_CYCLE: &str = include_str!("fixtures/lock_cycle.rs");
const WIRE_POS: &str = include_str!("fixtures/wire_positive.rs");
const WIRE_NEG: &str = include_str!("fixtures/wire_negative.rs");

fn scan(path: &str, src: &str) -> Report {
    scan_sources(&[(path.to_string(), src.to_string())])
}

fn rules(report: &Report) -> Vec<&'static str> {
    report.findings.iter().map(|f| f.rule).collect()
}

#[test]
fn determinism_positives_fire_in_sim_paths() {
    let r = scan("rust/src/asic/fixture.rs", DET_POS);
    let rs = rules(&r);
    assert!(rs.contains(&"det-wallclock"), "Instant must be flagged: {rs:?}");
    assert!(rs.contains(&"det-unordered-map"), "HashMap must be flagged: {rs:?}");
    assert!(rs.contains(&"det-float-intrinsic"), "powf must be flagged: {rs:?}");
    assert!(r.findings.iter().all(|f| f.allow.is_none()));
}

#[test]
fn determinism_rules_are_scoped_to_sim_paths() {
    // The identical source outside the simulation tree is none of the
    // determinism family's business (wall-clock in telemetry is fine).
    let r = scan("rust/src/obs/fixture.rs", DET_POS);
    assert!(
        r.findings.is_empty(),
        "non-sim path must not be flagged: {:?}",
        rules(&r)
    );
}

#[test]
fn determinism_negative_fixture_is_clean_modulo_allowed() {
    let r = scan("rust/src/asic/fixture.rs", DET_NEG);
    // sqrt/powi/BTreeMap are legal; the one banned call is annotated.
    let un: Vec<_> = r.findings.iter().filter(|f| f.allow.is_none()).collect();
    assert!(un.is_empty(), "unexpected un-annotated findings: {un:?}");
    let allowed: Vec<_> = r.findings.iter().filter(|f| f.allow.is_some()).collect();
    assert_eq!(allowed.len(), 1, "exactly the annotated exp() site");
    assert_eq!(allowed[0].rule, "det-float-intrinsic");
    // Annotated findings never enter a regenerated baseline.
    assert!(baseline_from(&r).is_empty());
}

#[test]
fn panic_positives_fire_in_server_paths() {
    let r = scan("rust/src/fleet/fixture.rs", PANIC_POS);
    let rs = rules(&r);
    assert!(rs.contains(&"panic-unwrap"), "unwrap must be flagged: {rs:?}");
    assert!(rs.contains(&"panic-macro"), "panic! must be flagged: {rs:?}");
    assert!(rs.contains(&"panic-index"), "xs[i] must be flagged: {rs:?}");
    // Same source in a non-server path: no panic-safety findings.
    let elsewhere = scan("rust/src/asic/fixture.rs", PANIC_POS);
    assert!(!rules(&elsewhere).contains(&"panic-unwrap"));
}

#[test]
fn panic_negative_fixture_is_clean_modulo_allowed() {
    let r = scan("rust/src/fleet/fixture.rs", PANIC_NEG);
    let un: Vec<_> = r.findings.iter().filter(|f| f.allow.is_none()).collect();
    assert!(un.is_empty(), "typed errors, .get(), and literal indices are legal: {un:?}");
    let allowed: Vec<_> = r.findings.iter().filter(|f| f.allow.is_some()).collect();
    assert_eq!(allowed.len(), 1, "exactly the annotated unreachable!");
    assert_eq!(allowed[0].rule, "panic-macro");
}

#[test]
fn three_lock_cycle_is_detected() {
    let r = scan("rust/src/fleet/fixture.rs", LOCK_CYCLE);
    let cycles: Vec<_> =
        r.findings.iter().filter(|f| f.rule == "lock-order-cycle").collect();
    assert_eq!(cycles.len(), 1, "one canonical cycle: {:?}", r.findings);
    let s = &cycles[0].snippet;
    for lock in ["alpha", "beta", "gamma"] {
        assert!(s.contains(lock), "cycle {s:?} must name {lock}");
    }
    assert_eq!(r.lock_edges.len(), 3, "three direct-nesting edges");
}

#[test]
fn consistent_lock_order_has_no_cycle() {
    // Drop the closing fn: alpha→beta→gamma alone is a clean partial order.
    let consistent = LOCK_CYCLE
        .replace("self.gamma.lock();\n        let _a = self.alpha.lock()", "self.gamma.lock()");
    let r = scan("rust/src/fleet/fixture.rs", &consistent);
    assert!(
        !rules(&r).contains(&"lock-order-cycle"),
        "acyclic order must pass: {:?}",
        r.findings
    );
}

#[test]
fn wire_rules_catch_unchecked_allocs_and_orphan_limits() {
    let r = scan("crates/bss2-proto/src/fixture.rs", WIRE_POS);
    let rs = rules(&r);
    assert!(rs.contains(&"wire-unchecked-alloc"), "with_capacity(n): {rs:?}");
    assert!(rs.contains(&"wire-unguarded-limit"), "MAX_ORPHAN_ITEMS: {rs:?}");

    let clean = scan("crates/bss2-proto/src/fixture.rs", WIRE_NEG);
    assert!(
        clean.findings.is_empty(),
        "limit-checked alloc must pass: {:?}",
        rules(&clean)
    );
}

#[test]
fn committed_baseline_gates_the_real_workspace() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let files = bss2_lint::collect_workspace(&root).expect("collect workspace");
    let report = scan_sources(&files);

    // The ISSUE-level invariant: determinism and lock-discipline are
    // hard-clean — every banned construct is either fixed or carries a
    // reviewed lint:allow reason.
    let hard: Vec<_> = report
        .findings
        .iter()
        .filter(|f| {
            bss2_lint::HARD_FAMILIES.contains(&f.family) && f.allow.is_none()
        })
        .collect();
    assert!(hard.is_empty(), "hard-family findings must be fixed or annotated: {hard:?}");

    // The committed ratchet budget parses and the gate passes against it —
    // the same check `repro audit` and the CI lint job run.
    let text = std::fs::read_to_string(root.join("LINT_BASELINE.json"))
        .expect("read LINT_BASELINE.json");
    let baseline = parse_baseline(&text).expect("parse committed baseline");
    assert!(
        baseline.iter().all(|e| e.rule.starts_with("panic-")),
        "only panic-safety budget entries belong in the baseline"
    );
    let outcome = gate(&report, &baseline);
    assert!(outcome.passed(), "gate failures: {:?}", outcome.failures);
}
