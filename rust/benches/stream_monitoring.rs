//! Bench: continuous ECG stream monitoring.
//!
//! Three views:
//! * **frontend cost vs hop** — the incremental windower's per-window
//!   cost is O(hop) (exact, via its deterministic op counter — asserted),
//!   not O(2048) like re-running the batch chain per window; wall-clock
//!   per window is reported for both.
//! * **sustained windows/s vs chips** — episode-labeled stream fanned
//!   through `Fleet::dispatch_acts` at hop 512 for 1/2/4 replicas.
//! * **afib detection latency** — windows from episode onset to the
//!   first positive window, with the untrained energy-detector model
//!   thresholded against the sinus lead-in.

use std::collections::VecDeque;
use std::time::Instant;

use bss2::asic::consts as c;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::stream::{ContinuousEcg, EpisodeConfig};
use bss2::fleet::{DispatchOutcome, Fleet, FleetConfig};
use bss2::fpga::preprocess::{preprocess, IncrementalWindower};
use bss2::nn::weights::TrainedModel;
use bss2::util::benchkit::section;
use bss2::util::stats::Summary;

fn short_cfg() -> EpisodeConfig {
    EpisodeConfig {
        lead_in_s: 30.0,
        sinus_s: (18.0, 35.0),
        afib_s: (12.0, 25.0),
    }
}

fn main() -> anyhow::Result<()> {
    frontend_cost()?;
    fleet_throughput()?;
    detection_latency()?;
    Ok(())
}

/// Per-window frontend cost: incremental O(hop) vs batch O(2048).
fn frontend_cost() -> anyhow::Result<()> {
    section("incremental frontend: per-window cost vs hop");
    // 3 simulated minutes of continuous signal, synthesized once.
    let total = (180.0 * c::ECG_FS_HZ) as usize;
    let raw = ContinuousEcg::new(42, 1.0, short_cfg()).next_chunk(total);

    for &hop in &[32usize, 128, 512, 2048] {
        // Incremental: feed the whole stream, count windows + exact ops.
        let mut w = IncrementalWindower::new(hop)?;
        let t0 = Instant::now();
        let mut ops_marks = Vec::new();
        for i in 0..total {
            if w.push([raw[0][i], raw[1][i]]).is_some() {
                ops_marks.push(w.work_ops);
            }
        }
        let inc_ns = t0.elapsed().as_nanos() as f64 / ops_marks.len() as f64;

        // The marginal op count between consecutive windows is *exactly*
        // 2·(hop + hop/32): O(hop), independent of the 2048 window.
        let per_window_ops =
            (c::ECG_CHANNELS * (hop + hop / c::POOL_WINDOW)) as u64;
        for pair in ops_marks.windows(2) {
            assert_eq!(
                pair[1] - pair[0],
                per_window_ops,
                "marginal frontend work must be O(hop), hop {hop}"
            );
        }

        // Batch reference: re-run the full chain per window.
        let n_windows = ops_marks.len();
        let t0 = Instant::now();
        for k in 0..n_windows {
            let s = k * hop;
            let win: Vec<Vec<u16>> = (0..2)
                .map(|ch| raw[ch][s..s + c::ECG_WINDOW].to_vec())
                .collect();
            let acts = preprocess(&win);
            assert_eq!(acts.len(), c::MODEL_IN);
        }
        let batch_ns = t0.elapsed().as_nanos() as f64 / n_windows as f64;

        println!(
            "  hop {hop:>4}: {n_windows:>4} windows  marginal ops \
             {per_window_ops:>5} (batch chain: {})  wall {:>8.0} ns/window \
             (batch: {:>8.0} ns/window)",
            c::ECG_CHANNELS * (c::ECG_WINDOW + c::ECG_WINDOW / c::POOL_WINDOW),
            inc_ns,
            batch_ns
        );
    }
    println!(
        "\n  per-window frontend cost scales with the hop, not with the \
         {}-sample window (op counts asserted above)",
        c::ECG_WINDOW
    );
    Ok(())
}

/// Sustained windows/s through the fleet at hop 512 for 1/2/4 chips.
fn fleet_throughput() -> anyhow::Result<()> {
    section("sustained stream throughput vs chips (hop 512)");
    let hop = 512usize;
    let stream_s = 60.0;
    let total = (stream_s * c::ECG_FS_HZ) as usize;
    let raw = ContinuousEcg::new(77, 1.0, short_cfg()).next_chunk(total);

    let mut base = None;
    for &chips in &[1usize, 2, 4] {
        let fleet = Fleet::start(
            FleetConfig { chips, queue_depth: 32, ..Default::default() },
            |chip| {
                Ok(Engine::native(
                    TrainedModel::energy_detector(),
                    EngineConfig { use_pjrt: false, ..Default::default() }
                        .for_chip(chip),
                ))
            },
        )?;
        let mut w = IncrementalWindower::new(hop)?;
        let mut pending = VecDeque::new();
        let (mut served, mut shed) = (0u64, 0u64);
        let t0 = Instant::now();
        for i in 0..total {
            let Some(frame) = w.push([raw[0][i], raw[1][i]]) else {
                continue;
            };
            let acts: Vec<i32> =
                frame.acts.iter().map(|&a| a as i32).collect();
            match fleet.dispatch_acts(acts) {
                DispatchOutcome::Enqueued { resp, .. } => pending.push_back(resp),
                DispatchOutcome::Shed { .. } => shed += 1,
            }
            // Bounded outstanding work: drain like a real monitor would,
            // so memory and admission stay flat.
            while pending.len() > 16 {
                let resp: std::sync::mpsc::Receiver<_> =
                    pending.pop_front().unwrap();
                if resp.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
                    served += 1;
                }
            }
        }
        while let Some(resp) = pending.pop_front() {
            if resp.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
                served += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let rate = served as f64 / wall;
        println!(
            "  chips {chips}: {served:>4} windows in {wall:>6.2} s -> \
             {rate:>7.1} windows/s ({shed} shed)  [stream real-time rate: \
             {:.2} windows/s]",
            c::ECG_FS_HZ / hop as f64
        );
        if chips == 1 {
            base = Some(rate);
        }
        fleet.shutdown();
    }
    if let Some(b) = base {
        println!(
            "\n  (single-chip baseline {b:.1} windows/s; scaling with chips \
             is measured precisely by benches/fleet_throughput.rs)"
        );
    }
    Ok(())
}

/// Afib detection latency: windows from episode onset to first positive.
fn detection_latency() -> anyhow::Result<()> {
    section("afib detection latency (energy detector, hop 512)");
    let hop = 512usize;
    let lead_in_s = 30.0;
    let minutes = 4.0;
    let total = (minutes * 60.0 * c::ECG_FS_HZ) as usize;
    let mut ecg = ContinuousEcg::new(99, 1.0, short_cfg());
    let raw = ecg.next_chunk(total);

    let mut engine = Engine::native(
        TrainedModel::energy_detector(),
        EngineConfig { use_pjrt: false, ..Default::default() },
    );
    let mut w = IncrementalWindower::new(hop)?;
    let mut wins: Vec<(u64, f64)> = Vec::new(); // (start_sample, score sum)
    for i in 0..total {
        let Some(frame) = w.push([raw[0][i], raw[1][i]]) else {
            continue;
        };
        let acts: Vec<i32> = frame.acts.iter().map(|&a| a as i32).collect();
        let inf = engine.classify_acts(&acts)?;
        wins.push((
            frame.start_sample,
            (inf.scores[0] + inf.scores[1]) as f64,
        ));
    }
    assert!(wins.len() > 20, "stream produced {} windows", wins.len());

    let win_len = c::ECG_WINDOW as u64;
    let lead_end = (lead_in_s * c::ECG_FS_HZ) as u64;
    let lead: Vec<f64> = wins
        .iter()
        .filter(|(s, _)| s + win_len <= lead_end)
        .map(|&(_, e)| e)
        .collect();
    assert!(lead.len() >= 2, "lead-in too short");
    let s = Summary::from(&lead);
    let thr = s.mean + 4.0 * s.std.max(0.5);
    println!(
        "  lead-in score sum {:.1} ± {:.1} LSB -> threshold {thr:.1}",
        s.mean, s.std
    );

    let episodes: Vec<_> = ecg
        .episodes()
        .into_iter()
        .filter(|e| e.afib && e.start + win_len <= total as u64)
        .collect();
    assert!(!episodes.is_empty(), "no afib episodes in {minutes} minutes");
    let mut detected = 0usize;
    for ep in &episodes {
        let onset_win = wins
            .iter()
            .position(|&(st, _)| st + win_len > ep.start)
            .expect("windows cover the episode");
        let det = wins
            .iter()
            .enumerate()
            .find(|&(_, &(st, e))| {
                st + win_len > ep.start && st < ep.end && e > thr
            });
        match det {
            Some((di, &(st, _))) => {
                detected += 1;
                println!(
                    "  episode at {:>6.1} s ({:>5.1} s): detected after \
                     {} windows ({:.1} s of signal past onset)",
                    ep.start as f64 / c::ECG_FS_HZ,
                    ep.len() as f64 / c::ECG_FS_HZ,
                    di - onset_win,
                    (st + win_len - ep.start) as f64 / c::ECG_FS_HZ
                );
            }
            None => println!(
                "  episode at {:>6.1} s ({:>5.1} s): missed",
                ep.start as f64 / c::ECG_FS_HZ,
                ep.len() as f64 / c::ECG_FS_HZ
            ),
        }
    }
    println!(
        "\n  {detected}/{} episodes detected (untrained energy threshold; \
         trained artifacts use the wire `pred` — see `repro monitor`)",
        episodes.len()
    );
    Ok(())
}
