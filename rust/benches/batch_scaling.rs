//! Bench: batched inference — per-sample cost vs batch size B on the
//! Native backend.
//!
//! Three views per B (1 → 32):
//! * cost model      — `nn::executor::cost_of_batch` on the ECG pass shapes
//! * simulated time  — `Engine::classify_batch` per-sample µs (the paper's
//!                     time base; 276 µs at B=1)
//! * host wall clock — best-of-N measured µs/sample on this machine
//!
//! The cost-model and simulated per-sample figures must decrease strictly
//! monotonically (asserted — they are deterministic); the wall clock is
//! reported and soft-checked, since it only saves the host-side weight
//! reloads and is subject to scheduler noise.

use std::time::Instant;

use bss2::asic::consts as c;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::gen::{generate_trace, Trace};
use bss2::nn::executor::cost_of_batch;
use bss2::nn::weights::TrainedModel;
use bss2::util::benchkit::section;

const BATCHES: [usize; 6] = [1, 2, 4, 8, 16, 32];

fn main() -> anyhow::Result<()> {
    // The three ECG passes as partitioned layer shapes (conv runs as its
    // Toeplitz matrix, paper Fig 6).
    let shapes = [
        (c::K_LOGICAL, c::CONV_OUT),
        (c::CONV_OUT, c::FC1_OUT),
        (c::FC1_OUT, c::FC2_OUT),
    ];
    let traces: Vec<Trace> = (0..32)
        .map(|i| generate_trace(500 + i, i % 2 == 0, 1.0))
        .collect();

    section("cost model (per-sample µs, ECG pass shapes)");
    let mut model_prev = f64::INFINITY;
    for b in BATCHES {
        let cost = cost_of_batch(&shapes, b);
        let per = cost.per_sample_us();
        println!(
            "  B={b:>2}: {per:>7.2} µs/sample  ({} integrations, {} weight \
             loads per batch)",
            cost.passes, cost.weight_loads
        );
        assert!(
            per < model_prev,
            "cost model must decrease monotonically (B={b})"
        );
        model_prev = per;
    }

    section("native engine (simulated µs/sample + host wall clock)");
    let mut eng = Engine::native(
        TrainedModel::synthetic(0xBA7C),
        EngineConfig { use_pjrt: false, ..Default::default() },
    );
    let mut sim_prev = f64::INFINITY;
    let mut wall = Vec::new();
    for b in BATCHES {
        let infs = eng.classify_batch(&traces[..b])?;
        let sim_us = infs[0].sim_time_s * 1e6;
        // Best-of-5 wall clock, robust against host scheduler noise.
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t0 = Instant::now();
            let _ = eng.classify_batch(&traces[..b])?;
            best = best.min(t0.elapsed().as_secs_f64() * 1e6 / b as f64);
        }
        println!(
            "  B={b:>2}: sim {sim_us:>7.2} µs/sample   wall {best:>9.2} \
             µs/sample"
        );
        assert!(
            sim_us < sim_prev,
            "simulated per-sample time must decrease monotonically (B={b})"
        );
        sim_prev = sim_us;
        wall.push(best);
    }

    let (w1, w32) = (wall[0], wall[wall.len() - 1]);
    println!(
        "\n  wall-clock amortisation B=1 -> B=32: {w1:.1} -> {w32:.1} \
         µs/sample ({:.2}x)",
        w1 / w32
    );
    if !wall.windows(2).all(|w| w[1] <= w[0] * 1.10) {
        println!(
            "  note: wall clock not strictly monotone on this host \
             (scheduler noise); sim + cost model are the deterministic views"
        );
    }
    println!(
        "\n[batch_scaling] paper single-sample latency: 276 µs at B=1; \
         batching trades latency for throughput by amortising weight \
         reconfiguration + per-program control overhead (DESIGN.md §9)"
    );
    Ok(())
}
