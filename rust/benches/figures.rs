//! Bench: regenerate the data behind the paper's figures.
//!
//! * Fig 4 — membrane-integration trace of one neuron column.
//! * Fig 7 — preprocessing-chain stages on one synthetic trace.
//! * Fig 8 — training/validation curve (from the python artifact, since
//!   training is a build-time activity; this harness re-evaluates the final
//!   model on the held-out set to confirm the curve's endpoint).
//!
//! Each section prints the series the figure plots (CSV-ish rows).

use bss2::asic::array::{AnalogArray, ColumnCalib};
use bss2::asic::consts as c;
use bss2::coordinator::batch::run_block;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::dataset::Dataset;
use bss2::ecg::gen::generate_trace;
use bss2::fpga::preprocess;
use bss2::runtime::ArtifactDir;
use bss2::util::benchkit::section;

fn fig4() {
    section("Fig 4: membrane voltage during one integration cycle");
    let mut array = AnalogArray::new(16, 1, ColumnCalib::nominal(1));
    let w: Vec<i8> = (0..16).map(|r| if r % 3 == 2 { -20 } else { 30 }).collect();
    array.load_weights(&w);
    let batches: Vec<Vec<u8>> = (0..16)
        .map(|r| {
            let mut b = vec![0u8; 16];
            b[r] = (5 + 2 * (r % 13)) as u8;
            b
        })
        .collect();
    let trace = array.membrane_trace(&batches, 0, 0.012);
    println!("t_ns,v_membrane_lsb");
    for (i, v) in trace.iter().enumerate() {
        println!("{},{:.2}", (i + 1) * 8, v);
    }
    println!("-> V_out = {:.1} LSB after {} events (paper Fig 4: the final \
              voltage represents the analog VMM result)", trace.last().unwrap(), 16);
}

fn fig7() {
    section("Fig 7: preprocessing stages (sinus example, first 8 pooled bins)");
    let trace = generate_trace(42, false, 1.0);
    let st = preprocess::fig7_trace(&trace.samples[0]);
    println!("bin,raw_first_sample,pooled_maxmin,act_u5");
    for bin in 0..8 {
        println!(
            "{},{},{},{}",
            bin,
            st.raw[bin * c::POOL_WINDOW],
            st.pooled[bin],
            st.activations[bin]
        );
    }
    let nz = st.activations.iter().filter(|&&a| a > 0).count();
    println!(
        "-> {} of {} bins active; activation range 0..{}",
        nz,
        st.activations.len(),
        st.activations.iter().max().unwrap()
    );
}

fn fig8(dir: &ArtifactDir) -> anyhow::Result<()> {
    section("Fig 8: training / validation metrics (build-time artifact)");
    let csv = std::fs::read_to_string(dir.path("fig8_training.csv"))?;
    print!("{csv}");
    // Endpoint check: re-evaluate the shipped model on the held-out set.
    let ds = Dataset::load(&dir.ecg_test())?;
    let traces: Vec<_> = ds.traces.iter().map(|t| (t.clone(), t.label)).collect();
    let mut engine = Engine::from_artifacts(dir, EngineConfig::default())?;
    let rep = run_block(&mut engine, &traces)?;
    println!(
        "-> shipped model on held-out set: det {:.3} fp {:.3} acc {:.3} \
         (paper endpoint: det 0.937, fp 0.140)",
        rep.confusion.detection_rate(),
        rep.confusion.false_positive_rate(),
        rep.confusion.accuracy()
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    fig4();
    fig7();
    let dir = ArtifactDir::default_location();
    if dir.exists() {
        fig8(&dir)?;
    } else {
        println!("\n[figures] artifacts missing — Fig 8 section skipped");
    }
    Ok(())
}
