//! §Perf harness: measured before/after for the L3 hot-path optimisations
//! (EXPERIMENTS.md §Perf).  Each section isolates ONE change:
//!
//!  A. weights re-uploaded every pass (the naive baseline)  vs
//!  B. weights staged once as device buffers (`execute_b`)  — the deployed
//!     configuration, mirroring the chip's "synapse matrix is filled once".
//!  C. end-to-end classify_acts (3 passes + SIMD interpretation).
//!  D. noise-sampling cost in the hot loop.

use bss2::asic::consts as c;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::dataset::Dataset;
use bss2::fpga::preprocess;
use bss2::nn::weights::TrainedModel;
use bss2::runtime::{ArtifactDir, Runtime};
use bss2::util::benchkit::{section, Bench};
use bss2::util::rng::SplitMix64;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactDir::default_location();
    if !dir.exists() {
        println!("[perf] artifacts missing — run `make artifacts`; skipping");
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let vmm = rt.load_vmm(&dir.vmm_hlo())?;
    let model = TrainedModel::load(&dir.weights())?;
    let w = &model.pass_weights[0];
    let gain = &model.gain[0];
    let offset = &model.offset[0];
    let scale = model.scales[0];
    let mut rng = SplitMix64::new(3);
    let x: Vec<f32> = (0..c::K_LOGICAL).map(|_| rng.below(32) as f32).collect();
    let noise = vec![0.0f32; c::N_COLS];

    section("A. naive: re-stage weights every pass (baseline)");
    let r_naive = Bench::new("vmm pass, weights re-uploaded")
        .iters(30, 20_000)
        .target(Duration::from_secs(2))
        .run(|| {
            let staged = vmm.stage_pass(w, gain, offset, scale).unwrap();
            std::hint::black_box(vmm.run_pass(&staged, &x, &noise).unwrap());
        });
    r_naive.print();

    section("B. deployed: weights staged once (execute_b)");
    let staged = vmm.stage_pass(w, gain, offset, scale)?;
    let r_staged = Bench::new("vmm pass, staged weights")
        .iters(30, 20_000)
        .target(Duration::from_secs(2))
        .run(|| {
            std::hint::black_box(vmm.run_pass(&staged, &x, &noise).unwrap());
        });
    r_staged.print();
    println!(
        "  staging speedup: {:.2}x ({} -> {})",
        r_naive.summary.mean / r_staged.summary.mean,
        bss2::util::benchkit::fmt_time(r_naive.summary.mean),
        bss2::util::benchkit::fmt_time(r_staged.summary.mean)
    );

    section("C. end-to-end classify_acts (3 passes + SIMD)");
    let ds = Dataset::load(&dir.ecg_test())?;
    let acts: Vec<i32> = preprocess::preprocess(&ds.traces[0].samples)
        .iter()
        .map(|&a| a as i32)
        .collect();
    let mut engine = Engine::from_artifacts(&dir, EngineConfig::default())?;
    Bench::new("classify_acts (PJRT, noise on)")
        .iters(30, 20_000)
        .target(Duration::from_secs(2))
        .run(|| {
            std::hint::black_box(engine.classify_acts(&acts).unwrap());
        })
        .print();
    let mut engine_n = Engine::from_artifacts(
        &dir,
        EngineConfig { use_pjrt: false, ..Default::default() },
    )?;
    Bench::new("classify_acts (native, noise on)")
        .iters(30, 20_000)
        .target(Duration::from_secs(2))
        .run(|| {
            std::hint::black_box(engine_n.classify_acts(&acts).unwrap());
        })
        .print();

    section("D. noise-sampling cost (256 gaussians/pass)");
    let mut nrng = SplitMix64::new(7);
    Bench::new("sample 256 gaussians")
        .iters(1000, 2_000_000)
        .target(Duration::from_secs(1))
        .run(|| {
            let v: Vec<f32> =
                (0..c::N_COLS).map(|_| (2.0 * nrng.gauss()) as f32).collect();
            std::hint::black_box(v);
        })
        .print();
    Ok(())
}
