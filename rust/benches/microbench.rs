//! Microbenchmarks of the L3 substrate hot paths (profiling targets for the
//! §Perf pass): preprocessing chain, event generation + routing, SIMD
//! interpreter, native array integration, JSON parsing, ECG generation.

use bss2::asic::array::{AnalogArray, ColumnCalib};
use bss2::asic::consts as c;
use bss2::asic::router::EventRouter;
use bss2::asic::simd::{ChipOps, SimdCpu};
use bss2::ecg::gen::generate_trace;
use bss2::fpga::eventgen::{generate, EventLut};
use bss2::fpga::preprocess::{self, StreamingPreprocessor};
use bss2::nn::graph;
use bss2::util::benchkit::{section, Bench};
use bss2::util::json::Json;
use bss2::util::rng::SplitMix64;
use std::time::Duration;

struct NopChip;
impl ChipOps for NopChip {
    fn send_events(&mut self, _: u8, _: &[i32]) {}
    fn run_vmm(&mut self, _: u8) -> anyhow::Result<()> {
        Ok(())
    }
    fn read_adc(&mut self, _: u8) -> Vec<i32> {
        vec![1; c::N_COLS]
    }
    fn load_slot(&mut self, _: u8) -> Vec<i32> {
        vec![3; c::MODEL_IN]
    }
    fn store_slot(&mut self, _: u8, _: &[i32]) {}
}

fn main() {
    let mut rng = SplitMix64::new(1);
    let trace = generate_trace(1, true, 1.0);

    section("FPGA preprocessing");
    Bench::new("batch chain (2 ch x 2048 samples)")
        .iters(100, 1_000_000)
        .target(Duration::from_secs(1))
        .run(|| {
            std::hint::black_box(preprocess::preprocess(&trace.samples));
        })
        .print();
    Bench::new("streaming chain (1 ch x 2048 samples)")
        .iters(100, 1_000_000)
        .target(Duration::from_secs(1))
        .run(|| {
            let mut sp = StreamingPreprocessor::new();
            sp.push_channel(&trace.samples[0]);
            std::hint::black_box(sp.out);
        })
        .print();

    section("event generation + routing");
    let acts: Vec<u8> = (0..c::K_LOGICAL).map(|_| rng.below(32) as u8).collect();
    let lut = EventLut::identity(0, c::K_LOGICAL);
    Bench::new("eventgen (256 elements)")
        .iters(1000, 5_000_000)
        .target(Duration::from_secs(1))
        .run(|| {
            std::hint::black_box(generate(&acts, &lut, 0));
        })
        .print();
    let mut router = EventRouter::identity();
    let (events, _) = generate(&acts, &lut, 0);
    Bench::new("router assemble (one burst)")
        .iters(1000, 5_000_000)
        .target(Duration::from_secs(1))
        .run(|| {
            std::hint::black_box(router.assemble(&events));
        })
        .print();

    section("SIMD instruction stream (chip ops stubbed)");
    let stream = graph::ecg_network().lower();
    let mut cpu = SimdCpu::new();
    let mut env = NopChip;
    Bench::new("full ECG stream interpret")
        .iters(1000, 5_000_000)
        .target(Duration::from_secs(1))
        .run(|| {
            std::hint::black_box(cpu.execute(&stream, &mut env).unwrap());
        })
        .print();

    section("native analog array");
    let mut array = AnalogArray::new(
        c::K_LOGICAL,
        c::N_COLS,
        ColumnCalib::fixed_pattern(c::N_COLS, &mut rng),
    );
    let w: Vec<i8> = (0..c::K_LOGICAL * c::N_COLS)
        .map(|_| (rng.below(127) as i32 - 63) as i8)
        .collect();
    array.load_weights(&w);
    let x: Vec<u8> = (0..c::K_LOGICAL).map(|_| rng.below(32) as u8).collect();
    let noise = vec![0.5f32; c::N_COLS];
    Bench::new("integrate 256x256")
        .iters(100, 1_000_000)
        .target(Duration::from_secs(1))
        .run(|| {
            std::hint::black_box(array.integrate(&x, 0.01, &noise, false));
        })
        .print();

    section("substrate utilities");
    let weights_like = {
        let vals: Vec<String> = (0..10_000).map(|i| (i % 127 - 63).to_string()).collect();
        format!("{{\"w\":[{}]}}", vals.join(","))
    };
    Bench::new("json parse (10k-int array)")
        .iters(20, 100_000)
        .target(Duration::from_secs(1))
        .run(|| {
            std::hint::black_box(Json::parse(&weights_like).unwrap());
        })
        .print();
    let mut seed = 0u64;
    Bench::new("ECG trace generation (2 ch x 2048)")
        .iters(20, 100_000)
        .target(Duration::from_secs(1))
        .run(|| {
            seed += 1;
            std::hint::black_box(generate_trace(seed, seed % 2 == 0, 1.0));
        })
        .print();
}

// NOTE: the PJRT perf comparison (staged weights vs re-uploaded weights)
// lives in benches/perf_pass.rs — see EXPERIMENTS.md §Perf.
