//! Bench: ablations of the design choices DESIGN.md §5 calls out.
//!
//! 1. temporal noise on/off               (why train with noise?)
//! 2. fixed-pattern calibration vs ideal  (what do the analog non-idealities cost?)
//! 3. output average-pooling 10->2 vs single neurons per class
//!    (the paper's noise-averaging trick, Fig 6 caption)
//! 4. fused L2 graph vs 3-pass engine     (XLA fusion value, host wall-clock)
//! 5. batch-1 edge constraint vs host batching of the fused graph

use bss2::coordinator::batch::run_block;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::dataset::Dataset;
use bss2::fpga::preprocess;
use bss2::nn::weights::TrainedModel;
use bss2::runtime::{ArtifactDir, Runtime};
use bss2::util::benchkit::{section, Bench};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactDir::default_location();
    if !dir.exists() {
        println!("[ablations] artifacts missing — run `make artifacts`; skipping");
        return Ok(());
    }
    let ds = Dataset::load(&dir.ecg_test())?;
    let traces: Vec<_> = ds
        .traces
        .iter()
        .map(|t| (t.clone(), t.label))
        .collect();

    section("ablation 1+2: analog non-idealities vs accuracy (500 traces)");
    println!(
        "{:<34} {:>10} {:>10} {:>9}",
        "configuration", "detection", "false-pos", "accuracy"
    );
    for (name, noise_off, nominal) in [
        ("full analog model (as deployed)", false, false),
        ("noise off", true, false),
        ("noise off + ideal fixed pattern", true, true),
    ] {
        let mut engine = Engine::from_artifacts(
            &dir,
            EngineConfig {
                use_pjrt: false, // native backend: ablations are model-level
                noise_off,
                nominal_calib: nominal,
                ..Default::default()
            },
        )?;
        let rep = run_block(&mut engine, &traces)?;
        println!(
            "{:<34} {:>9.1}% {:>9.1}% {:>8.1}%",
            name,
            rep.confusion.detection_rate() * 100.0,
            rep.confusion.false_positive_rate() * 100.0,
            rep.confusion.accuracy() * 100.0
        );
    }

    section("ablation 3: output pooling (noise averaging, Fig 6)");
    // Compare avg-pool of 5 outputs per class vs using single output
    // neurons: run the engine with noise, score both readouts per window.
    let model = TrainedModel::load(&dir.weights())?;
    let mut engine = Engine::from_artifacts(
        &dir,
        EngineConfig { use_pjrt: false, ..Default::default() },
    )?;
    let _ = &model;
    let mut pooled_conf = bss2::coordinator::metrics::Confusion::default();
    // Single-neuron readout needs the raw fc2 ADC values; approximate by
    // re-running with a "pool group of 1" via scores: the engine's pooled
    // scores ARE the avg; single-neuron = re-classify using only the first
    // output of each group.  We emulate by classifying twice with different
    // noise seeds and measuring prediction *stability* instead.
    let mut engine_b = Engine::from_artifacts(
        &dir,
        EngineConfig { use_pjrt: false, noise_seed: 0x0DD, ..Default::default() },
    )?;
    let mut stable = 0;
    for (t, l) in traces.iter().take(200) {
        let a = engine.classify(t)?;
        let b = engine_b.classify(t)?;
        pooled_conf.add(a.pred, *l);
        stable += (a.pred == b.pred) as usize;
    }
    println!(
        "avg-pooled readout: det {:.1}% fp {:.1}%; prediction stability under \
         independent noise: {}/200 (pooling averages ~sqrt(5) of the ADC noise)",
        pooled_conf.detection_rate() * 100.0,
        pooled_conf.false_positive_rate() * 100.0,
        stable
    );

    section("ablation 4: fused L2 graph vs 3-pass engine (host wall-clock)");
    let rt = Runtime::cpu()?;
    let fused = rt.load_model(&dir.model_hlo())?;
    fused.stage(&model)?;
    let acts: Vec<i32> = preprocess::preprocess(&ds.traces[0].samples)
        .iter()
        .map(|&a| a as i32)
        .collect();
    let actf: Vec<f32> = acts.iter().map(|&a| a as f32).collect();
    let r_fused = Bench::new("fused model.hlo (1 PJRT call)")
        .iters(50, 50_000)
        .target(Duration::from_secs(2))
        .run(|| {
            std::hint::black_box(fused.run(&actf).unwrap());
        });
    r_fused.print();
    let mut engine3 = Engine::from_artifacts(
        &dir,
        EngineConfig { noise_off: true, ..Default::default() },
    )?;
    let r_3pass = Bench::new("3-pass engine (vmm.hlo x3 + SIMD)")
        .iters(50, 50_000)
        .target(Duration::from_secs(2))
        .run(|| {
            std::hint::black_box(engine3.classify_acts(&acts).unwrap());
        });
    r_3pass.print();
    println!(
        "  fusion speedup on host: {:.2}x (the chip cannot fuse: passes are \
         physical integration cycles)",
        r_3pass.summary.mean / r_fused.summary.mean
    );

    section("ablation 5: batch-1 constraint (paper §III-A)");
    println!(
        "simulated chip time is batch-independent (one integration cycle per \
         pass); host-side batching of the fused graph amortises dispatch:"
    );
    for batch in [1usize, 8, 64] {
        let r = Bench::new(&format!("fused x{batch} sequential"))
            .iters(10, 10_000)
            .target(Duration::from_millis(800))
            .run(|| {
                for _ in 0..batch {
                    std::hint::black_box(fused.run(&actf).unwrap());
                }
            });
        println!(
            "  batch {batch:>3}: {:>10.1} µs/inference",
            r.summary.mean * 1e6 / batch as f64
        );
    }
    Ok(())
}
