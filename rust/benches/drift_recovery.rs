//! Bench: accuracy over a long drifting run — auto-recalibration on vs off.
//!
//! Three engines share one piece of (simulated) silicon — the same
//! fixed-pattern seed — and the two serving arms share the same drift
//! field and traffic:
//! * **baseline**  — frozen pattern (no drift), freshly calibrated: its
//!   predictions on the eval set define 100 % "accuracy" (the
//!   fresh-calibration reference of the acceptance criterion);
//! * **no-recal**  — drift on, one day-0 profile, never refreshed;
//! * **auto-recal** — drift on, the `calib::scheduler` policy re-measures
//!   the profile whenever it ages out (or the logit margin degrades).
//!
//! Metric: *stable-decision agreement* with the baseline — the fraction
//! of eval traces (pre-filtered to a baseline logit margin ≥ 4 LSB, i.e.
//! decisions that are meaningful to hold) predicted identically.  The
//! run alternates serving bursts with idle aging so the chip covers
//! several drift relaxation times in seconds of wall clock.
//!
//! Expected shape (asserted): the auto-recal arm stays within 1 pp of
//! the baseline while the no-recal arm measurably degrades below it.

use bss2::calib::{DriftParams, RecalibPolicy};
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::gen::{Trace, TraceStream};
use bss2::nn::weights::TrainedModel;
use bss2::util::benchkit::section;

const FPN_SEED: u64 = 0xD81F7;
const MODEL_SEED: u64 = 0xF1EE7;
/// Serving bursts between evaluations.
const STEPS_PER_EVAL: usize = 100;
const EVALS: usize = 5;
/// Traces served per burst.
const BURST: usize = 8;
/// Idle chip time between bursts [µs].
const IDLE_US: u64 = 20_000;
/// Eval traces kept (after the margin filter).
const EVAL_N: usize = 200;
/// Baseline margin below which a decision is too marginal to score.
const MARGIN_FLOOR: f32 = 4.0;

fn drift() -> DriftParams {
    DriftParams {
        tau_us: 2.0e6,
        sigma_gain: 0.05,
        sigma_offset: 8.0,
        ..Default::default()
    }
}

fn engine(drift: Option<DriftParams>) -> Engine {
    Engine::native(
        TrainedModel::synthetic(MODEL_SEED),
        EngineConfig {
            use_pjrt: false,
            noise_off: true,
            fpn_seed: Some(FPN_SEED),
            drift,
            ..Default::default()
        },
    )
}

/// Fraction of eval traces predicted identically to the baseline.
fn agreement(
    eng: &mut Engine,
    eval: &[Trace],
    reference: &[u8],
) -> anyhow::Result<f64> {
    let mut same = 0usize;
    for (t, &want) in eval.iter().zip(reference) {
        if eng.classify(t)?.pred == want {
            same += 1;
        }
    }
    Ok(same as f64 / eval.len() as f64)
}

fn main() -> anyhow::Result<()> {
    let policy = RecalibPolicy {
        max_age_us: 100_000, // tau/20: wander stays ~2-3 LSB between runs
        margin_degrade_ratio: 0.7,
        reps: 32,
        min_serving: 0,
    };

    // Freshly calibrated frozen silicon defines the reference decisions;
    // keep only traces whose decision margin is meaningful to hold.
    let mut baseline = engine(None);
    baseline.recalibrate(64)?;
    let mut eval: Vec<Trace> = Vec::with_capacity(EVAL_N);
    let mut reference: Vec<u8> = Vec::with_capacity(EVAL_N);
    for trace in TraceStream::new(4242, 1.0).take(3 * EVAL_N) {
        let inf = baseline.classify(&trace)?;
        if (inf.scores[0] - inf.scores[1]).abs() >= MARGIN_FLOOR {
            eval.push(trace);
            reference.push(inf.pred);
            if eval.len() == EVAL_N {
                break;
            }
        }
    }
    println!(
        "eval set: {} stable-decision traces (baseline margin >= {} LSB)",
        eval.len(),
        MARGIN_FLOOR
    );

    // Two identical drifted chips; both get a day-0 profile.
    let mut norecal = engine(Some(drift()));
    norecal.recalibrate(policy.reps)?;
    let mut recal = engine(Some(drift()));
    recal.recalibrate(policy.reps)?;
    let mut recals = 0usize;

    section("drift run: agreement with the fresh-calibration baseline");
    println!(
        "{:>10} {:>12} {:>12} {:>8} {:>12}",
        "t [s]", "no-recal", "auto-recal", "recals", "residual"
    );
    let mut serve_stream = TraceStream::new(99, 1.0);
    let (mut final_no, mut final_auto) = (1.0f64, 1.0f64);
    for _ in 0..EVALS {
        for _ in 0..STEPS_PER_EVAL {
            // Identical traffic + idle aging on both arms.
            let burst: Vec<Trace> =
                serve_stream.by_ref().take(BURST).collect();
            norecal.classify_batch(&burst)?;
            recal.classify_batch(&burst)?;
            norecal.advance_idle_us(IDLE_US);
            recal.advance_idle_us(IDLE_US);
            // The auto-recal arm runs the fleet policy (age/margin).
            if policy
                .should_recalibrate(recal.calib_age_us(), None)
                .is_some()
            {
                recal.recalibrate(policy.reps)?;
                recals += 1;
            }
        }
        final_no = agreement(&mut norecal, &eval, &reference)?;
        final_auto = agreement(&mut recal, &eval, &reference)?;
        let residual = recal
            .calib_profile()
            .map(|p| p.worst_residual())
            .unwrap_or(0.0);
        println!(
            "{:>10.2} {:>11.1}% {:>11.1}% {:>8} {:>9.3} LSB",
            recal.chip_time_us() as f64 / 1e6,
            100.0 * final_no,
            100.0 * final_auto,
            recals,
            residual
        );
    }

    println!(
        "\n[drift_recovery] auto-recalibration held {:.1}% agreement \
         (baseline 100%) over {:.1} s of chip time and {recals} \
         recalibrations; without recalibration the day-0 profile decayed \
         to {:.1}%",
        100.0 * final_auto,
        recal.chip_time_us() as f64 / 1e6,
        100.0 * final_no,
    );
    assert!(
        final_auto >= 0.99,
        "auto-recal arm must stay within 1 pp of the fresh-calibration \
         baseline, got {:.3}",
        final_auto
    );
    assert!(
        final_no < final_auto,
        "the no-recalibration arm must measurably degrade \
         ({final_no:.3} !< {final_auto:.3})"
    );
    Ok(())
}
