//! Bench: paper Table 1 — the end-to-end 500-trace block measurement.
//!
//! Reports every row of the table (simulated time/energy/accuracy via the
//! §IV procedure) plus host wall-clock throughput of the two backends.
//! Absolute numbers are expected to match the paper's *shape* (who costs
//! what, ratios); see EXPERIMENTS.md.

use bss2::coordinator::batch::run_block;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::dataset::Dataset;
use bss2::runtime::ArtifactDir;
use bss2::util::benchkit::{fmt_time, section, Bench};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactDir::default_location();
    if !dir.exists() {
        println!("[table1] artifacts missing — run `make artifacts` first; skipping");
        return Ok(());
    }
    let ds = Dataset::load(&dir.ecg_test())?;
    let traces: Vec<_> = ds
        .traces
        .iter()
        .map(|t| (t.clone(), t.label))
        .collect();

    section("Table 1: full 500-trace block (PJRT artifact backend)");
    let mut engine = Engine::from_artifacts(&dir, EngineConfig::default())?;
    let t0 = std::time::Instant::now();
    let rep = run_block(&mut engine, &traces)?;
    let wall = t0.elapsed().as_secs_f64();
    println!("{}", rep.table1());
    println!(
        "host wall-clock: {} for {} traces ({} each)",
        fmt_time(wall),
        traces.len(),
        fmt_time(wall / traces.len() as f64)
    );

    section("Table 1: native array-model backend (cross-check)");
    let mut engine_n = Engine::from_artifacts(
        &dir,
        EngineConfig { use_pjrt: false, ..Default::default() },
    )?;
    let t0 = std::time::Instant::now();
    let rep_n = run_block(&mut engine_n, &traces)?;
    let wall_n = t0.elapsed().as_secs_f64();
    println!(
        "native backend: det {:.1} % fp {:.1} % (PJRT: det {:.1} % fp {:.1} %)",
        rep_n.confusion.detection_rate() * 100.0,
        rep_n.confusion.false_positive_rate() * 100.0,
        rep.confusion.detection_rate() * 100.0,
        rep.confusion.false_positive_rate() * 100.0,
    );
    println!(
        "host wall-clock: {} ({} each)",
        fmt_time(wall_n),
        fmt_time(wall_n / traces.len() as f64)
    );

    section("single-inference host latency (PJRT backend)");
    let one = vec![traces[0].clone()];
    let r = Bench::new("classify one trace (end-to-end)")
        .warmup(3)
        .iters(20, 2000)
        .target(Duration::from_secs(3))
        .run(|| {
            let _ = run_block(&mut engine, &one).unwrap();
        });
    r.print();
    println!(
        "simulated: {} per inference (paper: 276 µs)",
        fmt_time(rep.time_per_inference_s)
    );
    Ok(())
}
