//! Bench: paper Eq. 1–3 — synapse-array rates and area efficiency, plus the
//! measured VMM-pass rate of both backends (how fast our substrate actually
//! executes integration cycles, host wall-clock).

use bss2::asic::array::{AnalogArray, ColumnCalib};
use bss2::asic::consts as c;
use bss2::runtime::{ArtifactDir, Runtime};
use bss2::util::benchkit::{section, Bench};
use bss2::util::rng::SplitMix64;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    section("paper equations (architecture model)");
    println!(
        "Eq. 1  peak synapse-array rate:   {:7.2} TOp/s   (paper: 32.8)",
        c::peak_ops_per_s() / 1e12
    );
    println!(
        "Eq. 2  effective VMM rate:        {:7.2} GOp/s   (paper: ~52)",
        c::effective_ops_per_s() / 1e9
    );
    println!(
        "Eq. 3  MAC area efficiency:       {:7.2} TOp/(s mm^2) (paper: 2.6)",
        c::area_efficiency_tops_mm2()
    );
    println!(
        "       full-die efficiency goal:  {:7.2} TOp/(s mm^2) (paper target: >1)",
        c::peak_ops_per_s() / 1e12 / c::DIE_MM2
    );

    section("native array model: integration-cycle rate (host)");
    let mut rng = SplitMix64::new(5);
    let mut array = AnalogArray::new(
        c::K_LOGICAL,
        c::N_COLS,
        ColumnCalib::fixed_pattern(c::N_COLS, &mut rng),
    );
    let w: Vec<i8> = (0..c::K_LOGICAL * c::N_COLS)
        .map(|_| (rng.below(127) as i32 - 63) as i8)
        .collect();
    array.load_weights(&w);
    let x: Vec<u8> = (0..c::K_LOGICAL).map(|_| rng.below(32) as u8).collect();
    let noise = vec![0.0f32; c::N_COLS];
    let r = Bench::new("native integrate (256x256 pass)")
        .iters(50, 100_000)
        .target(Duration::from_secs(2))
        .run(|| {
            std::hint::black_box(array.integrate(&x, 0.01, &noise, false));
        });
    r.print();
    let macs = (c::K_LOGICAL * c::N_COLS) as f64;
    println!(
        "  -> {:.2} GOp/s host-equivalent (2 Op/synapse; chip Eq. 2: {:.1} GOp/s)",
        r.per_second(2.0 * macs) / 1e9,
        c::effective_ops_per_s() / 1e9
    );

    let dir = ArtifactDir::default_location();
    if dir.exists() {
        section("PJRT artifact: integration-cycle rate (host)");
        let rt = Runtime::cpu()?;
        let vmm = rt.load_vmm(&dir.vmm_hlo())?;
        let wf: Vec<f32> = w.iter().map(|&v| v as f32).collect();
        let gain = vec![1.0f32; c::N_COLS];
        let offset = vec![0.0f32; c::N_COLS];
        let staged = vmm.stage_pass(&wf, &gain, &offset, 0.01)?;
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let r = Bench::new("pjrt vmm pass (256x256)")
            .iters(50, 100_000)
            .target(Duration::from_secs(2))
            .run(|| {
                std::hint::black_box(vmm.run_pass(&staged, &xf, &noise).unwrap());
            });
        r.print();
        println!(
            "  -> {:.2} GOp/s host-equivalent",
            r.per_second(2.0 * macs) / 1e9
        );
    } else {
        println!("\n[throughput] artifacts missing — PJRT section skipped");
    }
    Ok(())
}
