//! Bench: fleet scaling — aggregate inferences/s vs chip count on the
//! Native backend (acceptance: ≥3× the single-chip rate at 4 chips).
//!
//! Each chip is a full single-unit engine (276 µs simulated per
//! inference, batch size 1); the fleet scales throughput *out* by adding
//! replicas, not by batching — so the rate should grow near-linearly
//! until the host runs out of cores.

use std::sync::Arc;
use std::time::Instant;

use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::gen::{generate_trace, Trace};
use bss2::fleet::{Fleet, FleetConfig};
use bss2::nn::weights::TrainedModel;
use bss2::util::benchkit::section;

const MODEL_SEED: u64 = 0xBEEF;

fn start_fleet(chips: usize) -> Fleet {
    Fleet::start(
        FleetConfig { chips, queue_depth: 64, ..Default::default() },
        |chip| {
            Ok(Engine::native(
                TrainedModel::synthetic(MODEL_SEED),
                EngineConfig { use_pjrt: false, ..Default::default() }
                    .for_chip(chip),
            ))
        },
    )
    .expect("native fleet must start")
}

/// Pump `jobs_per_client` traces from `2 * chips` concurrent clients and
/// return aggregate completed inferences per second.
fn fleet_rate(chips: usize, jobs_per_client: usize) -> anyhow::Result<f64> {
    let fleet = Arc::new(start_fleet(chips));
    let traces: Arc<Vec<Trace>> = Arc::new(
        (0..32).map(|i| generate_trace(1000 + i, i % 2 == 0, 1.0)).collect(),
    );

    // Warm up every replica once (first-classify allocations).
    for _ in 0..chips {
        fleet.classify_blocking(&traces[0])?;
    }

    let n_clients = 2 * chips;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for client in 0..n_clients {
        let fleet = fleet.clone();
        let traces = traces.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
            for i in 0..jobs_per_client {
                let trace = &traces[(client + i) % traces.len()];
                // Queue depth 64 with 2 clients/chip never saturates.
                fleet.classify_blocking(trace)?;
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("client thread panicked")?;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let total = (n_clients * jobs_per_client) as f64;

    let snaps = Arc::try_unwrap(fleet)
        .unwrap_or_else(|_| panic!("fleet still shared"));
    let per_chip: Vec<u64> =
        snaps.chip_snapshots().iter().map(|s| s.served).collect();
    println!("    per-chip served: {per_chip:?}");
    snaps.shutdown();
    Ok(total / elapsed)
}

fn main() -> anyhow::Result<()> {
    section("paper single-unit reference");
    println!(
        "  one BSS-2 mobile unit: 276 µs/inference => {:.0} inf/s simulated ceiling",
        1e6 / 276.0
    );

    section("fleet scaling: aggregate inferences/s (native backend, host)");
    let jobs_per_client = 96;
    let base = fleet_rate(1, jobs_per_client)?;
    println!("  1 chip : {base:8.0} inf/s   (1.00x)");
    let mut at4 = None;
    for chips in [2usize, 4, 8] {
        let rate = fleet_rate(chips, jobs_per_client)?;
        let scale = rate / base;
        println!("  {chips} chips: {rate:8.0} inf/s   ({scale:.2}x)");
        if chips == 4 {
            at4 = Some(scale);
        }
    }
    if let Some(s) = at4 {
        println!(
            "\n  4-chip scaling: {s:.2}x vs single chip (acceptance: >= 3x \
             on a >=4-core host)"
        );
    }
    Ok(())
}
