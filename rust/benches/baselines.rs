//! Bench: paper §V platform comparison + battery projection.
//!
//! Reproduces the discussion's energy table (BSS-2 vs Galileo vs Jetson
//! Nano vs the sub-Vt dedicated ASIC), using *our measured* per-inference
//! energy for BSS-2, and times the float CPU baseline on this host for a
//! software reference point.

use bss2::baselines::{comparison_table, CpuFloatBaseline};
use bss2::coordinator::batch::run_block;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::dataset::Dataset;
use bss2::fpga::preprocess;
use bss2::nn::weights::TrainedModel;
use bss2::power::energy::cr2032_years;
use bss2::runtime::ArtifactDir;
use bss2::util::benchkit::{section, Bench};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactDir::default_location();
    if !dir.exists() {
        println!("[baselines] artifacts missing — run `make artifacts`; skipping");
        return Ok(());
    }

    // Measure our system's per-inference energy on a 100-trace block.
    let ds = Dataset::load(&dir.ecg_test())?;
    let traces: Vec<_> = ds
        .traces
        .iter()
        .take(100)
        .map(|t| (t.clone(), t.label))
        .collect();
    let mut engine = Engine::from_artifacts(&dir, EngineConfig::default())?;
    let rep = run_block(&mut engine, &traces)?;

    section("§V energy comparison (per classification)");
    println!("{:<40} {:>12} {:>10}", "platform", "energy [mJ]", "vs BSS-2");
    for (name, j, ratio) in comparison_table(rep.energy_total_j) {
        println!("{:<40} {:>12.4} {:>9.1}x", name, j * 1e3, ratio);
    }
    println!(
        "\npaper: 220 mJ (Galileo) / 7.4 mJ (Jetson) vs 1.56 mJ (BSS-2) — \
         ratios ~141x / ~4.7x; ours {:.0}x / {:.1}x",
        0.220 / rep.energy_total_j,
        7.4e-3 / rep.energy_total_j
    );

    section("CR2032 battery projection (paper §V)");
    for interval in [60.0, 120.0, 300.0] {
        println!(
            "  every {:>3.0} s: {:>5.1} years",
            interval,
            cr2032_years(rep.energy_total_j, interval)
        );
    }

    section("float CPU baseline (this host)");
    let model = TrainedModel::load(&dir.weights())?;
    let cpu = CpuFloatBaseline::new(model);
    let act: Vec<f32> = preprocess::preprocess(&ds.traces[0].samples)
        .iter()
        .map(|&a| a as f32)
        .collect();
    let r = Bench::new("cpu float forward (full network)")
        .iters(100, 100_000)
        .target(Duration::from_secs(2))
        .run(|| {
            std::hint::black_box(cpu.forward(&act));
        });
    r.print();
    // Agreement with the analog path (both argmax the same windows?).
    let mut agree = 0;
    let mut engine2 = Engine::from_artifacts(
        &dir,
        EngineConfig { noise_off: true, ..Default::default() },
    )?;
    for t in ds.traces.iter().take(100) {
        let acts: Vec<i32> = preprocess::preprocess(&t.samples)
            .iter()
            .map(|&a| a as i32)
            .collect();
        let actf: Vec<f32> = acts.iter().map(|&a| a as f32).collect();
        let hw = engine2.classify_acts(&acts)?.pred;
        let sw = cpu.classify(&actf);
        agree += (hw == sw) as usize;
    }
    println!("  float-CPU vs analog-path agreement: {agree}/100 windows");
    Ok(())
}
