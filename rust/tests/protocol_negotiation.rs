//! Protocol-negotiation integration tests (DESIGN.md §14): the framed
//! binary client, the framed-JSON fallback, and the legacy line protocol
//! against one server; version-mismatch rejection; cross-encoding reply
//! equivalence; pipelined ordering and stream sessions over binary
//! framing; the typed client read timeout; and the backoff hints on
//! shed replies.

use std::io::{Read, Write};
use std::time::Duration;

use bss2::asic::consts as c;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::coordinator::service::{self, ServeModel, Service};
use bss2::ecg::gen::{generate_trace, Trace};
use bss2::fleet::FleetConfig;
use bss2::nn::weights::TrainedModel;
use bss2_client::{Client, ClientError, Encoding, Json, Options};
use bss2_proto::handshake;

/// Deterministic native engine; identical on every chip, so the server's
/// replies equal a local reference engine's bit for bit.
fn test_engine() -> Engine {
    Engine::native(
        TrainedModel::synthetic(0x57AB1E),
        EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
    )
}

fn start_service(cfg: FleetConfig) -> Service {
    Service::start_fleet("127.0.0.1:0", cfg, |_chip| Ok(test_engine())).unwrap()
}

fn small_fleet() -> FleetConfig {
    FleetConfig { chips: 1, queue_depth: 64, ..Default::default() }
}

fn assert_ok(reply: &Json) {
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
}

#[test]
fn both_framed_encodings_negotiate_and_serve() {
    let svc = start_service(small_fleet());
    for (opts, want) in [
        (Options::default(), Encoding::Binary),
        (Options::json(), Encoding::Json),
    ] {
        let mut cl = Client::connect(svc.addr, opts).unwrap();
        assert_eq!(cl.encoding(), want);
        assert_ok(&cl.ping().unwrap());
        let trace = generate_trace(3, false, 1.0);
        let reply = cl.classify(&trace.samples).unwrap();
        assert_ok(&reply);
        assert!(reply.get("pred").is_some(), "{reply}");
    }
    svc.stop();
}

#[test]
fn legacy_line_clients_coexist_with_framed_clients() {
    let svc = start_service(small_fleet());
    let trace = generate_trace(11, true, 1.0);
    // Line-protocol client (no handshake) and a binary client, same
    // server, same trace: byte-identical reply content.
    let mut legacy = service::Client::connect(&svc.addr).unwrap();
    let from_lines = legacy.classify(&trace).unwrap();
    let mut framed = Client::connect(svc.addr, Options::default()).unwrap();
    let from_frames = framed.classify(&trace.samples).unwrap();
    assert_ok(&from_lines);
    assert_eq!(from_lines, from_frames);
    svc.stop();
}

#[test]
fn version_mismatch_is_rejected_with_server_version() {
    let svc = start_service(small_fleet());
    let opts = Options {
        protocol_version: bss2_client::PROTO_VERSION + 7,
        ..Options::default()
    };
    match Client::connect(svc.addr, opts) {
        Err(ClientError::VersionMismatch { server_version }) => {
            assert_eq!(server_version, bss2_client::PROTO_VERSION);
        }
        other => panic!("expected VersionMismatch, got {other:?}"),
    }
    // The rejection must not poison the acceptor: the next client is
    // served normally.
    let mut cl = Client::connect(svc.addr, Options::default()).unwrap();
    assert_ok(&cl.ping().unwrap());
    svc.stop();
}

#[test]
fn unknown_encoding_is_rejected_at_the_socket() {
    let svc = start_service(small_fleet());
    let mut raw = std::net::TcpStream::connect(svc.addr).unwrap();
    let mut hello = handshake::hello_bytes(
        bss2_client::PROTO_VERSION,
        Encoding::Binary,
    );
    hello[4] = 0x7f; // an encoding this server has never heard of
    raw.write_all(&hello).unwrap();
    let mut ack = [0u8; handshake::LEN];
    raw.read_exact(&mut ack).unwrap();
    assert_eq!(
        handshake::evaluate_ack(&ack),
        Err(handshake::AckError::Rejected {
            server_version: bss2_client::PROTO_VERSION,
            reason: handshake::REJECT_ENCODING,
        })
    );
    // Reject closes the connection.
    assert_eq!(raw.read(&mut [0u8; 1]).unwrap(), 0);
    svc.stop();
}

#[test]
fn replies_are_equivalent_across_all_three_encodings() {
    let svc = start_service(small_fleet());
    let trace = generate_trace(29, false, 1.0);
    let mut replies = Vec::new();
    for opts in [Options::default(), Options::json()] {
        let mut cl = Client::connect(svc.addr, opts).unwrap();
        replies.push(cl.classify(&trace.samples).unwrap());
    }
    let mut legacy = service::Client::connect(&svc.addr).unwrap();
    replies.push(legacy.classify(&trace).unwrap());
    assert_ok(&replies[0]);
    assert_eq!(replies[0], replies[1], "binary vs framed-JSON");
    assert_eq!(replies[0], replies[2], "binary vs legacy lines");

    // And the values agree with a local reference engine.
    let mut reference = test_engine();
    let infs =
        reference.classify_batch(std::slice::from_ref(&trace)).unwrap();
    let inf = &infs[0];
    assert_eq!(
        replies[0].get("pred").and_then(|v| v.as_uint()),
        Some(u64::from(inf.pred))
    );
    let scores = replies[0].get("scores").and_then(|v| v.as_arr()).unwrap();
    for (got, want) in scores.iter().zip(inf.scores) {
        assert!(
            (got.as_f64().unwrap() - f64::from(want)).abs() < 1e-3,
            "server scores {scores:?} vs local {:?}",
            inf.scores
        );
    }
    svc.stop();
}

#[test]
fn pipelined_replies_stay_ordered_over_binary_framing() {
    let svc = start_service(small_fleet());
    let traces: Vec<Trace> =
        (0..6).map(|i| generate_trace(100 + i, i % 2 == 1, 1.0)).collect();
    let mut reference = test_engine();
    let expected: Vec<u64> = traces
        .iter()
        .map(|t| {
            u64::from(
                reference.classify_batch(std::slice::from_ref(t)).unwrap()[0]
                    .pred,
            )
        })
        .collect();

    // Interleave slow (classify) and instant (ping) requests without
    // reading a single reply; the reply sequence must match the request
    // sequence exactly — a ping answered before the classify sent ahead
    // of it is an ordering bug.
    let mut cl = Client::connect(svc.addr, Options::default()).unwrap();
    let ping = Json::parse("{\"cmd\":\"ping\"}").unwrap();
    for t in &traces {
        cl.send_classify(&t.samples).unwrap();
        cl.send(&ping).unwrap();
    }
    for pred in &expected {
        let classify = cl.read_reply().unwrap();
        assert_ok(&classify);
        assert_eq!(
            classify.get("pred").and_then(|v| v.as_uint()).as_ref(),
            Some(pred),
            "{classify}"
        );
        let pong = cl.read_reply().unwrap();
        assert_eq!(pong.get("pong"), Some(&Json::Bool(true)), "{pong}");
    }
    svc.stop();
}

#[test]
fn stream_session_works_over_binary_framing() {
    let svc = start_service(small_fleet());
    let mut cl = Client::connect(svc.addr, Options::default()).unwrap();
    let hop = c::ECG_WINDOW;
    assert_eq!(
        cl.stream_open(Some(hop)).unwrap().get("stream").and_then(|v| v.as_str()),
        Some("open")
    );
    // Three full windows, pushed in chunks that straddle the window
    // boundary so the server-side windower does the reassembly.
    let windows = 3;
    let long = generate_trace(77, false, 1.0);
    let total = hop * windows;
    let mut sent = 0usize;
    while sent < total {
        let n = 700.min(total - sent);
        let chunk: Vec<Vec<u16>> = long
            .samples
            .iter()
            .map(|ch| {
                (0..n).map(|i| ch[(sent + i) % ch.len()]).collect()
            })
            .collect();
        cl.stream_push(&chunk).unwrap();
        sent += n;
    }
    cl.stream_close().unwrap();

    let mut results = Vec::new();
    loop {
        let line = cl.read_reply().unwrap();
        if line.get("stream").and_then(|v| v.as_str()) == Some("closed") {
            break;
        }
        results.push(line);
    }
    assert_eq!(results.len(), windows, "{results:?}");
    for (i, line) in results.iter().enumerate() {
        assert_eq!(
            line.get("window").and_then(|v| v.as_uint()),
            Some(i as u64),
            "{line}"
        );
        assert_eq!(
            line.get("start_sample").and_then(|v| v.as_uint()),
            Some((i * hop) as u64),
            "{line}"
        );
        assert_ok(line);
        assert!(line.get("scores").is_some(), "{line}");
    }
    svc.stop();
}

#[test]
fn read_timeout_is_typed_and_recoverable() {
    let svc = start_service(small_fleet());
    let opts = Options {
        read_timeout: Some(Duration::from_millis(150)),
        ..Options::default()
    };
    let mut cl = Client::connect(svc.addr, opts).unwrap();
    // Nothing was requested, so nothing ever arrives: the wait must end
    // in the typed timeout, not block forever or surface a raw io error.
    match cl.read_reply() {
        Err(ClientError::Timeout) => {}
        other => panic!("expected ClientError::Timeout, got {other:?}"),
    }
    // A timeout consumes no bytes — the connection stays usable.
    assert_ok(&cl.ping().unwrap());
    // And the timeout is adjustable on a live connection.
    cl.set_read_timeout(None).unwrap();
    assert_ok(&cl.ping().unwrap());
    svc.stop();
}

#[test]
fn shed_replies_carry_backoff_hints() {
    // Admission queue of one sample: a pipelined burst must shed, and
    // every shed reply must tell the client how loaded the fleet is
    // (queue_depth) and when to come back (retry_after_us).
    let svc = start_service(FleetConfig {
        chips: 1,
        queue_depth: 1,
        ..Default::default()
    });
    let mut cl = Client::connect(svc.addr, Options::default()).unwrap();
    let trace = generate_trace(5, false, 1.0);
    let burst = 8;
    for _ in 0..burst {
        cl.send_classify(&trace.samples).unwrap();
    }
    let (mut ok, mut shed) = (0, 0);
    for _ in 0..burst {
        let reply = cl.read_reply().unwrap();
        if reply.get("ok") == Some(&Json::Bool(true)) {
            ok += 1;
        } else {
            assert_eq!(reply.get("shed"), Some(&Json::Bool(true)), "{reply}");
            assert!(
                reply.get("queue_depth").and_then(|v| v.as_uint()).is_some(),
                "shed reply without queue_depth hint: {reply}"
            );
            assert!(
                reply
                    .get("retry_after_us")
                    .and_then(|v| v.as_uint())
                    .map(|us| us > 0)
                    .unwrap_or(false),
                "shed reply without retry_after_us hint: {reply}"
            );
            shed += 1;
        }
    }
    assert!(ok >= 1, "the first sample of the burst must be admitted");
    assert!(shed >= 1, "a queue depth of 1 must shed under a burst of 8");

    // Accept-time sheds carry the same kind of hint, counted in
    // connections: hold the only slot, then read the refusal line.
    let tight = start_service(FleetConfig {
        chips: 1,
        queue_depth: 8,
        max_connections: 1,
        ..Default::default()
    });
    let mut held =
        Client::connect(tight.addr, Options::default()).unwrap();
    assert_ok(&held.ping().unwrap());
    let mut refused = service::Client::connect(&tight.addr).unwrap();
    let line = refused.read_reply().unwrap();
    assert_eq!(line.get("shed"), Some(&Json::Bool(true)), "{line}");
    assert_eq!(line.get("queue_depth").and_then(|v| v.as_uint()), Some(1));
    assert_eq!(
        line.get("max_connections").and_then(|v| v.as_uint()),
        Some(1)
    );
    tight.stop();
    svc.stop();
}

#[test]
fn binary_client_works_against_the_threaded_model() {
    let svc = Service::start_fleet_with(
        "127.0.0.1:0",
        small_fleet(),
        ServeModel::Threaded,
        |_chip| Ok(test_engine()),
    )
    .unwrap();
    let mut cl = Client::connect(svc.addr, Options::default()).unwrap();
    assert_ok(&cl.ping().unwrap());
    let trace = generate_trace(42, false, 1.0);
    let a = cl.classify(&trace.samples).unwrap();
    assert_ok(&a);
    // Same request against the default model: identical reply — the two
    // connection models are wire-indistinguishable.
    let dfl = start_service(small_fleet());
    let mut dcl = Client::connect(dfl.addr, Options::default()).unwrap();
    assert_eq!(a, dcl.classify(&trace.samples).unwrap());
    dfl.stop();
    svc.stop();
}
