//! Observability integration: the `metrics` / `trace` / `journal` wire
//! surface and the per-stage latency stats over a live fleet (ISSUE 6,
//! DESIGN.md §13).
//!
//! The contract under test:
//!   * `metrics` answers one unified snapshot — registry metrics plus the
//!     scattered fleet stats — in JSON and Prometheus text, and the two
//!     formats agree because they render the same sample vector;
//!   * every completed job leaves a span whose host stages sum to its
//!     end-to-end latency and whose simulated stages sum to its chip
//!     time, for arbitrary batch sizes (property test);
//!   * `fleet_stats` reports per-stage p50/p95/p99 in both time bases
//!     even with the trace ring disabled (histograms always record).

use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::coordinator::service::{Client, Service};
use bss2::ecg::gen::{Trace, TraceStream};
use bss2::fleet::FleetConfig;
use bss2::nn::weights::TrainedModel;
use bss2::prop_assert;
use bss2::util::json::Json;
use bss2::util::propcheck;

const MODEL_SEED: u64 = 0x0B5E;

fn start_fleet(chips: usize, trace_sample: u64) -> Service {
    Service::start_fleet(
        "127.0.0.1:0",
        FleetConfig { chips, queue_depth: 64, trace_sample, ..Default::default() },
        |chip| {
            Ok(Engine::native(
                TrainedModel::synthetic(MODEL_SEED),
                EngineConfig {
                    use_pjrt: false,
                    noise_off: true,
                    ..Default::default()
                }
                .for_chip(chip),
            ))
        },
    )
    .unwrap()
}

#[test]
fn metrics_track_served_work_in_both_formats() {
    let svc = start_fleet(2, 16);
    let mut cl = Client::connect(&svc.addr).unwrap();
    let mut traces = TraceStream::new(31, 1.0);
    for _ in 0..3 {
        let t = traces.next().unwrap();
        let r = cl.classify(&t).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    }
    let batch: Vec<Trace> = (&mut traces).take(4).collect();
    let r = cl.classify_batch(&batch).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");

    // The unified snapshot agrees with the fleet's own accounting.
    let served = cl
        .call("{\"cmd\":\"stats\"}")
        .unwrap()
        .get("served")
        .and_then(|v| v.as_f64())
        .unwrap();
    let m = cl.call("{\"cmd\":\"metrics\"}").unwrap();
    assert_eq!(m.get("ok"), Some(&Json::Bool(true)), "{m}");
    let arr = m.get("metrics").and_then(|v| v.as_arr()).unwrap();
    let sum_of = |name: &str| -> f64 {
        arr.iter()
            .filter(|s| s.get("name").and_then(|n| n.as_str()) == Some(name))
            .map(|s| s.get("value").and_then(|v| v.as_f64()).unwrap())
            .sum()
    };
    assert!(served >= 7.0, "3 singles + a 4-batch served: {served}");
    assert_eq!(sum_of("bss2_fleet_served_total"), served, "{m}");
    assert_eq!(
        sum_of("bss2_chip_served_total"),
        served,
        "per-chip counters must sum to the fleet total: {m}"
    );
    assert_eq!(sum_of("bss2_fleet_healthy_chips"), 2.0);
    assert!(
        sum_of("bss2_trace_spans_total") >= 4.0,
        "one span per completed job: {m}"
    );
    let sim_mean = arr
        .iter()
        .find(|s| {
            s.get("name").and_then(|n| n.as_str())
                == Some("bss2_sim_time_mean_us")
        })
        .and_then(|s| s.get("value"))
        .and_then(|v| v.as_f64())
        .unwrap();
    assert!(
        sim_mean > 50.0,
        "mean simulated time must be paper-scale: {sim_mean}"
    );

    // Prometheus text: one HELP/TYPE pair per family even with per-chip
    // samples, and both chips labelled.
    let t = cl.call("{\"cmd\":\"metrics\",\"format\":\"text\"}").unwrap();
    assert_eq!(t.get("ok"), Some(&Json::Bool(true)), "{t}");
    let body =
        t.get("body").and_then(|b| b.as_str()).unwrap().to_string();
    let helps = body
        .lines()
        .filter(|l| l.starts_with("# HELP bss2_chip_served_total "))
        .count();
    assert_eq!(helps, 1, "HELP once per family:\n{body}");
    assert!(body.contains("bss2_chip_served_total{chip=\"0\"}"), "{body}");
    assert!(body.contains("bss2_chip_served_total{chip=\"1\"}"), "{body}");
    assert!(
        body.contains("# TYPE bss2_host_latency_us gauge"),
        "{body}"
    );
    svc.stop();
}

#[test]
fn trace_spans_are_internally_consistent_over_random_batches() {
    // sample_every = 1: every completed span lands in the ring.
    let svc = start_fleet(2, 1);
    let addr = svc.addr;
    propcheck::check("trace_span_sums", 8, 0x7CE5, |g| {
        let mut cl = Client::connect(&addr).map_err(|e| e.to_string())?;
        let b = g.usize_in(1, 5);
        let traces: Vec<Trace> =
            TraceStream::new(g.rng.next_u64() % 50_000, 1.0)
                .take(b)
                .collect();
        let r = if b == 1 {
            cl.classify(&traces[0])
        } else {
            cl.classify_batch(&traces)
        }
        .map_err(|e| e.to_string())?;
        prop_assert!(r.get("ok") == Some(&Json::Bool(true)), "{}", r);
        let tr = cl
            .call("{\"cmd\":\"trace\",\"n\":64}")
            .map_err(|e| e.to_string())?;
        let recs =
            tr.get("traces").and_then(|v| v.as_arr()).ok_or("no traces")?;
        prop_assert!(!recs.is_empty(), "sample_every=1 keeps every span");
        for rec in recs {
            let host = rec.get("host_us").ok_or("no host_us")?;
            let total =
                host.get("total").and_then(|v| v.as_f64()).ok_or("no total")?;
            let sum: f64 = ["queue", "execute", "retry"]
                .iter()
                .map(|k| {
                    host.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN)
                })
                .sum();
            prop_assert!(
                (sum - total).abs() < 0.01,
                "host stages {} != e2e {}: {}",
                sum,
                total,
                rec
            );
            let sim = rec.get("sim_us").ok_or("no sim_us")?;
            let stotal =
                sim.get("total").and_then(|v| v.as_f64()).ok_or("no sim")?;
            let ssum: f64 = [
                "dma", "events", "weight_write", "vmm", "adc", "simd",
                "wait", "control",
            ]
            .iter()
            .map(|k| sim.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN))
            .sum();
            prop_assert!(
                (ssum - stotal).abs() < 0.05,
                "sim stages {} != chip time {}: {}",
                ssum,
                stotal,
                rec
            );
            prop_assert!(
                stotal > 50.0,
                "per-sample chip time must be paper-scale: {}",
                rec
            );
        }
        Ok(())
    });
    svc.stop();
}

#[test]
fn fleet_stats_exposes_stage_quantiles_with_ring_disabled() {
    // trace_sample = 0: the full-span ring is off, but the per-stage
    // histograms (and therefore `fleet_stats` quantiles) always record.
    let svc = start_fleet(1, 0);
    let mut cl = Client::connect(&svc.addr).unwrap();
    let mut traces = TraceStream::new(3, 1.0);
    for _ in 0..5 {
        let t = traces.next().unwrap();
        let r = cl.classify(&t).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    }
    let fs = cl.call("{\"cmd\":\"fleet_stats\"}").unwrap();
    let stages = fs.get("stages").expect("stages block");
    let host = stages.get("host").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(host.len(), 3, "{fs}");
    let exec = host
        .iter()
        .find(|s| s.get("stage").and_then(|x| x.as_str()) == Some("execute"))
        .unwrap();
    assert_eq!(exec.get("count").and_then(|v| v.as_usize()), Some(5));
    let p50 = exec.get("p50_us").and_then(|v| v.as_f64()).unwrap();
    let p99 = exec.get("p99_us").and_then(|v| v.as_f64()).unwrap();
    assert!(p99 >= p50, "{fs}");
    assert!(
        exec.get("mean_us").and_then(|v| v.as_f64()).unwrap() > 0.0,
        "{fs}"
    );
    let sim = stages.get("sim").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(sim.len(), 8, "{fs}");
    let ww = sim
        .iter()
        .find(|s| {
            s.get("stage").and_then(|x| x.as_str()) == Some("weight_write")
        })
        .unwrap();
    // Per-pass weight reconfiguration dominates the paper's 276 µs:
    // multiple 40 µs half-array writes per single-sample program.
    assert!(
        ww.get("mean_us").and_then(|v| v.as_f64()).unwrap() > 50.0,
        "{fs}"
    );

    // The ring stayed empty while the histograms recorded.
    let tr = cl.call("{\"cmd\":\"trace\"}").unwrap();
    assert_eq!(tr.get("ok"), Some(&Json::Bool(true)), "{tr}");
    assert_eq!(tr.get("seen").and_then(|v| v.as_usize()), Some(5));
    assert_eq!(tr.get("recorded").and_then(|v| v.as_usize()), Some(0));
    assert_eq!(
        tr.get("traces").and_then(|v| v.as_arr()).map(|a| a.len()),
        Some(0)
    );
    svc.stop();
}
