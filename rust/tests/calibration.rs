//! Integration: the calibration & drift-compensation subsystem.
//!
//! (a) Property: profile-compensated inference on a *drifted* array
//!     recovers (within tolerance) the predictions of a freshly
//!     calibrated chip, and is never worse than serving on a stale
//!     day-0 profile.
//! (b) The fleet's drain → `Calibrating` → re-admit state machine holds
//!     under concurrent dispatch: every request completes, no request is
//!     lost to a draining chip, and recalibrated chips return to service.
//! (c) The age-triggered auto-recalibration policy fires during normal
//!     serving and the pool never stops serving while it does.

use std::sync::Arc;

use bss2::calib::{DriftParams, RecalibPolicy};
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::gen::TraceStream;
use bss2::fleet::{ChipState, Fleet, FleetConfig};
use bss2::nn::weights::TrainedModel;
use bss2::util::propcheck;

fn drifted_engine(
    fpn_seed: u64,
    noise_seed: u64,
    drift: Option<DriftParams>,
) -> Engine {
    Engine::native(
        TrainedModel::synthetic(0xF1EE7),
        EngineConfig {
            use_pjrt: false,
            noise_off: true,
            noise_seed,
            fpn_seed: Some(fpn_seed),
            drift,
            ..Default::default()
        },
    )
}

/// (a) The satellite property: compensation against a *fresh* profile
/// tracks the freshly calibrated chip; a stale profile does not get
/// better than that.
#[test]
fn compensated_drifted_chip_recovers_fresh_predictions() {
    propcheck::check("drift_recovery", 4, 0xCA11B, |g| {
        let fpn_seed = g.rng.next_u64();
        let noise_seed = g.rng.next_u64();
        let drift = DriftParams {
            tau_us: 100_000.0,
            sigma_gain: 0.05,
            sigma_offset: g.f64_in(6.0, 12.0),
            temp_amplitude_k: 0.0,
            ..Default::default()
        };
        let age_us = 300_000; // 3 relaxation times: near-stationary wander
        let traces: Vec<_> = TraceStream::new(g.rng.next_u64(), 1.0)
            .take(6)
            .collect();

        // Fresh reference: frozen pattern, compensated at measurement.
        let mut fresh = drifted_engine(fpn_seed, noise_seed, None);
        fresh.recalibrate(32).map_err(|e| e.to_string())?;
        let mut reference = Vec::new();
        for t in &traces {
            reference.push(fresh.classify(t).map_err(|e| e.to_string())?.scores);
        }
        let dev_of = |eng: &mut Engine| -> Result<f64, String> {
            let mut dev = 0.0;
            for (t, want) in traces.iter().zip(&reference) {
                let got =
                    eng.classify(t).map_err(|e| e.to_string())?.scores;
                dev += (got[0] - want[0]).abs() as f64
                    + (got[1] - want[1]).abs() as f64;
            }
            Ok(dev / (2.0 * traces.len() as f64))
        };

        // Stale arm: day-0 profile, then age_us of drift.
        let mut stale = drifted_engine(fpn_seed, noise_seed, Some(drift));
        stale.recalibrate(32).map_err(|e| e.to_string())?;
        stale.advance_idle_us(age_us);
        let dev_stale = dev_of(&mut stale)?;

        // Recalibrated arm: identical silicon + drift path, profile
        // re-measured after the wander.
        let mut recal = drifted_engine(fpn_seed, noise_seed, Some(drift));
        recal.recalibrate(32).map_err(|e| e.to_string())?;
        recal.advance_idle_us(age_us);
        recal.recalibrate(32).map_err(|e| e.to_string())?;
        let dev_recal = dev_of(&mut recal)?;

        bss2::prop_assert!(
            dev_recal <= 8.0,
            "fresh profile must track the freshly calibrated chip \
             (mean |score delta| {dev_recal})"
        );
        bss2::prop_assert!(
            dev_recal <= dev_stale + 0.5,
            "recalibration must not lose to the stale profile \
             ({dev_recal} vs {dev_stale})"
        );
        Ok(())
    });
}

/// (b) Drain -> Calibrating -> re-admit under concurrent dispatch.
#[test]
fn recalibration_state_machine_under_concurrent_dispatch() {
    let drift = DriftParams::default();
    let fleet = Arc::new(
        Fleet::start(
            FleetConfig { chips: 3, queue_depth: 64, ..Default::default() },
            move |chip| {
                Ok(Engine::native(
                    TrainedModel::synthetic(0xF1EE7),
                    EngineConfig {
                        use_pjrt: false,
                        noise_off: true,
                        fpn_seed: Some(0xD81F7),
                        drift: Some(drift),
                        ..Default::default()
                    }
                    .for_chip(chip),
                ))
            },
        )
        .unwrap(),
    );

    // Concurrent traffic across the pool while two chips recalibrate.
    let mut clients = Vec::new();
    for c in 0..4u64 {
        let fleet = fleet.clone();
        clients.push(std::thread::spawn(move || {
            for trace in TraceStream::new(500 + c, 1.0).take(20) {
                let (chip, inf) = fleet
                    .classify_blocking(&trace)
                    .expect("pool must keep serving during recalibration");
                assert!(chip < 3);
                assert!(inf.pred <= 1);
            }
        }));
    }
    for chip in [0usize, 1] {
        let rx = fleet.recalibrate_chip(chip, 32).unwrap();
        let reply = rx.recv().expect("worker reply");
        assert_eq!(reply.chip, chip);
        let (stamp, residual) = reply.result.expect("calibration succeeds");
        assert!(stamp > 0);
        assert!(residual < 3.0, "implausible residual {residual}");
    }
    for cl in clients {
        cl.join().unwrap();
    }
    assert_eq!(fleet.recalibration_count(), 2);
    assert_eq!(fleet.calibrating_count(), 0, "everyone re-admitted");
    assert_eq!(fleet.telemetry().served(), 80, "no request lost");
    for snap in fleet.chip_snapshots() {
        assert_eq!(snap.state, ChipState::Healthy);
    }
    // The served chip time and profile ages are visible in fleet stats.
    let j = bss2::util::json::Json::parse(&fleet.stats_json()).unwrap();
    assert_eq!(j.get("recalibrations").and_then(|v| v.as_usize()), Some(2));
    Arc::try_unwrap(fleet).ok().expect("all clients joined").shutdown();
}

/// (c) Age-triggered auto-recalibration during normal serving: the policy
/// drains chips on its own, one at a time, and the pool keeps serving.
#[test]
fn auto_recalibration_fires_while_pool_serves() {
    let policy = RecalibPolicy {
        max_age_us: 1_000, // a few inferences of chip time
        margin_degrade_ratio: 0.0,
        reps: 8,
        min_serving: 1,
    };
    let fleet = Fleet::start(
        FleetConfig {
            chips: 2,
            queue_depth: 64,
            recalib: Some(policy),
            ..Default::default()
        },
        |chip| {
            Ok(Engine::native(
                TrainedModel::synthetic(0xF1EE7),
                EngineConfig {
                    use_pjrt: false,
                    noise_off: true,
                    fpn_seed: Some(0xD81F7),
                    drift: Some(DriftParams::default()),
                    ..Default::default()
                }
                .for_chip(chip),
            ))
        },
    )
    .unwrap();

    for trace in TraceStream::new(900, 1.0).take(40) {
        // Never drains below min_serving, so blocking classify always
        // finds a healthy chip.
        let (chip, _) = fleet
            .classify_blocking(&trace)
            .expect("pool must keep serving under auto-recalibration");
        assert!(chip < 2);
        assert!(fleet.calibrating_count() <= 1, "one drain at a time");
    }
    assert!(
        fleet.recalibration_count() >= 1,
        "the age trigger must have fired during 40 served inferences"
    );
    fleet.shutdown();
}
