//! Integration: the two engine backends (PJRT artifact vs native array
//! model) and the fused L2 graph all compute identical classifications, and
//! the end-to-end system reproduces the paper's headline metrics.

use bss2::coordinator::batch::run_block;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::dataset::Dataset;
use bss2::ecg::gen::TraceStream;
use bss2::runtime::{ArtifactDir, Runtime};

fn artifacts() -> Option<ArtifactDir> {
    let dir = ArtifactDir::default_location();
    if dir.exists() {
        Some(dir)
    } else {
        eprintln!("[skip] artifacts missing — run `make artifacts`");
        None
    }
}

#[test]
fn pjrt_and_native_backends_agree_bit_exactly() {
    let Some(dir) = artifacts() else { return };
    // Same noise seed => same noise stream => identical ADC counts.
    let mut pjrt = Engine::from_artifacts(
        &dir,
        EngineConfig { use_pjrt: true, noise_seed: 42, ..Default::default() },
    )
    .unwrap();
    let mut native = Engine::from_artifacts(
        &dir,
        EngineConfig { use_pjrt: false, noise_seed: 42, ..Default::default() },
    )
    .unwrap();
    for trace in TraceStream::new(17, 1.0).take(12) {
        let a = pjrt.classify(&trace).unwrap();
        let b = native.classify(&trace).unwrap();
        assert_eq!(a.scores, b.scores, "backends disagree");
        assert_eq!(a.pred, b.pred);
    }
}

#[test]
fn fused_graph_matches_three_pass_engine() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let fused = rt.load_model(&dir.model_hlo()).unwrap();
    let model = bss2::nn::weights::TrainedModel::load(&dir.weights()).unwrap();
    fused.stage(&model).unwrap();
    let mut engine = Engine::from_artifacts(
        &dir,
        EngineConfig { noise_off: true, ..Default::default() },
    )
    .unwrap();
    for trace in TraceStream::new(23, 1.0).take(8) {
        let acts: Vec<i32> = bss2::fpga::preprocess::preprocess(&trace.samples)
            .iter()
            .map(|&a| a as i32)
            .collect();
        let actf: Vec<f32> = acts.iter().map(|&a| a as f32).collect();
        let f = fused.run(&actf).unwrap();
        let e = engine.classify_acts(&acts).unwrap();
        // Engine pools in integer arithmetic (SIMD CPU); fused pools in f32.
        assert!(
            (f[0] - e.scores[0]).abs() <= 1.0 && (f[1] - e.scores[1]).abs() <= 1.0,
            "fused {f:?} vs engine {:?}",
            e.scores
        );
    }
}

#[test]
fn headline_metrics_reproduce_table1_shape() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load(&dir.ecg_test()).unwrap();
    let traces: Vec<_> = ds
        .traces
        .iter()
        .map(|t| (t.clone(), t.label))
        .collect();
    let mut engine =
        Engine::from_artifacts(&dir, EngineConfig::default()).unwrap();
    let rep = run_block(&mut engine, &traces).unwrap();

    // Timing: 276 µs per inference, 138 ms per 500-block.
    let us = rep.time_per_inference_s * 1e6;
    assert!((us - 276.0).abs() < 25.0, "time/inference {us} µs");
    // Power: 5.6 W system, 0.69 W ASIC.
    assert!((rep.system_power_w - 5.6).abs() < 0.4, "{} W", rep.system_power_w);
    assert!((rep.asic_power_w - 0.69).abs() < 0.15, "{} W", rep.asic_power_w);
    // Energy: 1.56 mJ total.
    assert!(
        (rep.energy_total_j * 1e3 - 1.56).abs() < 0.15,
        "{} mJ",
        rep.energy_total_j * 1e3
    );
    // Accuracy: high-sensitivity regime (paper 93.7 % det at 14.0 % fp).
    let det = rep.confusion.detection_rate();
    let fp = rep.confusion.false_positive_rate();
    assert!(det > 0.90, "detection {det}");
    assert!(fp < 0.20, "false positives {fp}");
}

#[test]
fn noise_ablation_changes_individual_scores() {
    let Some(dir) = artifacts() else { return };
    let mut noisy = Engine::from_artifacts(
        &dir,
        EngineConfig { use_pjrt: false, ..Default::default() },
    )
    .unwrap();
    let mut clean = Engine::from_artifacts(
        &dir,
        EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
    )
    .unwrap();
    let mut diffs = 0;
    for trace in TraceStream::new(31, 1.0).take(10) {
        let a = noisy.classify(&trace).unwrap();
        let b = clean.classify(&trace).unwrap();
        if a.scores != b.scores {
            diffs += 1;
        }
    }
    assert!(diffs >= 5, "noise should perturb most scores, got {diffs}/10");
}

#[test]
fn service_end_to_end_over_tcp() {
    let Some(dir) = artifacts() else { return };
    let svc = bss2::coordinator::service::Service::start("127.0.0.1:0", move || {
        Engine::from_artifacts(
            &dir,
            EngineConfig { use_pjrt: false, ..Default::default() },
        )
    })
    .unwrap();
    let mut client =
        bss2::coordinator::service::Client::connect(&svc.addr).unwrap();
    let trace = TraceStream::new(3, 1.0).next().unwrap();
    let reply = client.classify(&trace).unwrap();
    assert_eq!(
        reply.get("ok"),
        Some(&bss2::util::json::Json::Bool(true)),
        "{reply}"
    );
    let t = reply.get("time_us").and_then(|v| v.as_f64()).unwrap();
    assert!((t - 276.0).abs() < 40.0, "served time {t} µs");
    svc.stop();
}
