//! Chaos/soak integration: deterministic fault injection + transparent
//! fleet failover, end to end.
//!
//! The contract under test (ISSUE 5, DESIGN.md §12):
//!   * every accepted request gets **exactly one, in-order** reply —
//!     success, shed, or terminal error; never silence — even while
//!     injected faults kill chips mid-traffic;
//!   * stream sessions re-dispatch in-flight windows instead of dropping
//!     them (window result lines keep arriving, in window order);
//!   * failover is numerically invisible: results are bit-identical to a
//!     fault-free fleet without the faulted replica;
//!   * the fleet ends with at least the plan's serving floor intact;
//!   * `repro chaos` prints a byte-identical survival report per seed
//!     (CLI-level determinism lives in the bss2-cli crate's
//!     `cli_determinism` suite — `CARGO_BIN_EXE_repro` is only defined
//!     for the package that owns the binary).
//!
//! The short churn soak runs in the default suite; the heavy randomized
//! soak is `#[ignore]`d for the nightly `cargo test --release -- --ignored`
//! job.

use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::coordinator::service::{Client, Service};
use bss2::ecg::gen::{Trace, TraceStream};
use bss2::fault::{FaultKind, FaultPlan, FaultSpec};
use bss2::fleet::{Fleet, FleetConfig};
use bss2::nn::weights::TrainedModel;
use bss2::util::json::Json;
use bss2::util::propcheck;
use bss2::{prop_assert, prop_assert_eq};

const MODEL_SEED: u64 = 0xC4A05;

fn engine_cfg(chip: usize) -> EngineConfig {
    EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() }
        .for_chip(chip)
}

fn spec(
    chip: usize,
    at_us: u64,
    duration_us: Option<u64>,
    kind: FaultKind,
) -> FaultSpec {
    FaultSpec { chip, at_us, duration_us, kind }
}

/// One soak client: pipelines bursts of `classify_batch` requests with
/// cycling batch sizes, then collects the replies and checks that each
/// arrives in request order (every reply — ok, shed, or terminal error —
/// echoes the `batch` field, which cycles deterministically).  Returns
/// (ok, shed, failed) reply counts; panics on silence, disorder, or a
/// malformed reply.
fn churn_client(
    addr: std::net::SocketAddr,
    client_seed: u64,
    bursts: usize,
    burst_len: usize,
) -> (u64, u64, u64) {
    let mut cl = Client::connect(&addr).unwrap();
    let mut traces = TraceStream::new(9_000 + client_seed, 1.0);
    let (mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64);
    let mut req = 0usize;
    for _ in 0..bursts {
        // Pipeline the whole burst before reading any reply.
        let mut expect = Vec::with_capacity(burst_len);
        for _ in 0..burst_len {
            let b = 1 + (req % 3);
            req += 1;
            let batch: Vec<Trace> = (&mut traces).take(b).collect();
            cl.send_classify_batch(&batch).unwrap();
            expect.push(b);
        }
        // Exactly one reply per request, in request order.
        for (slot, want_b) in expect.iter().enumerate() {
            let reply = cl
                .read_reply()
                .unwrap_or_else(|e| panic!("reply {slot} missing: {e}"));
            let got_b = reply
                .get("batch")
                .and_then(|v| v.as_usize())
                .unwrap_or_else(|| panic!("reply without batch echo: {reply}"));
            assert_eq!(
                got_b, *want_b,
                "reply {slot} out of order (client {client_seed}): {reply}"
            );
            if reply.get("ok") == Some(&Json::Bool(true)) {
                let n = reply
                    .get("results")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.len())
                    .unwrap_or(0);
                let accepted = reply
                    .get("accepted")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(0);
                assert_eq!(n, accepted, "one inference per accepted sample");
                ok += 1;
            } else if reply.get("shed") == Some(&Json::Bool(true)) {
                shed += 1;
            } else {
                assert!(
                    reply.get("error").is_some(),
                    "failure without an error field: {reply}"
                );
                failed += 1;
            }
        }
    }
    (ok, shed, failed)
}

/// The short deterministic churn soak (default suite): chip 1 is
/// permanently dead from t = 0 (erroring fault), chip 0 carries silent
/// link corruption, chip 2 a permanent latency spike.  Concurrent
/// pipelining clients plus one streaming session; every request must be
/// answered in order and the fleet must end at the serving floor.
#[test]
fn chaos_soak_short_every_request_answered_in_order() {
    let chips = 3;
    let plan = FaultPlan {
        seed: 11,
        faults: vec![
            spec(1, 0, None, FaultKind::ChipDeath),
            spec(0, 0, None, FaultKind::LinkCorruption { ber: 0.002 }),
            spec(2, 0, None, FaultKind::LatencySpike { extra_us: 1_500 }),
        ],
    };
    let floor = chips - plan.erroring_chips(chips);
    assert_eq!(floor, 2);
    let svc = Service::start_fleet(
        "127.0.0.1:0",
        FleetConfig {
            chips,
            queue_depth: 256,
            error_threshold: 3,
            probe_period: 8,
            redirects: 4,
            fault_plan: Some(plan),
            ..Default::default()
        },
        |chip| {
            Ok(Engine::native(
                TrainedModel::synthetic(MODEL_SEED),
                engine_cfg(chip),
            ))
        },
    )
    .unwrap();
    let addr = svc.addr;

    // Streaming session alongside the classify churn: in-flight windows
    // must be re-dispatched (never dropped) when they land on the dead
    // chip, and their result lines must arrive in window order.
    let stream_handle = std::thread::spawn(move || {
        let mut cl = Client::connect(&addr).unwrap();
        let hop = 512usize;
        let open = cl.stream_open(hop).unwrap();
        assert_eq!(
            open.get("stream").and_then(|s| s.as_str()),
            Some("open"),
            "{open}"
        );
        // 6000 samples/channel at hop 512, window 2048 -> several
        // windows, pushed in uneven chunks.
        let total = 6_000usize;
        let mut pushed = 0usize;
        let mut ecg = bss2::ecg::stream::ContinuousEcg::new(
            77,
            1.0,
            Default::default(),
        );
        while pushed < total {
            let n = (total - pushed).min(700);
            let chunk = ecg.next_chunk(n);
            cl.stream_push(&chunk).unwrap();
            pushed += n;
        }
        cl.stream_close().unwrap();
        // Collect every line up to the close ack; windows must be
        // strictly increasing across ok/shed/error lines alike.
        let mut lines = 0u64;
        let mut last_window: Option<u64> = None;
        loop {
            let line = cl.read_reply().unwrap();
            if line.get("stream").and_then(|s| s.as_str()) == Some("closed") {
                let windows =
                    line.get("windows").and_then(|v| v.as_uint()).unwrap();
                assert_eq!(
                    lines, windows,
                    "every produced window needs exactly one line: {line}"
                );
                break;
            }
            let w = line
                .get("window")
                .and_then(|v| v.as_uint())
                .unwrap_or_else(|| panic!("stream line without window: {line}"));
            if let Some(prev) = last_window {
                assert!(w > prev, "stream out of order: {w} after {prev}");
            }
            last_window = Some(w);
            lines += 1;
        }
    });

    let mut handles = Vec::new();
    for client in 0..3u64 {
        handles.push(std::thread::spawn(move || {
            churn_client(addr, client, 6, 5)
        }));
    }
    let (mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64);
    for h in handles {
        let (o, s, f) = h.join().unwrap();
        ok += o;
        shed += s;
        failed += f;
    }
    stream_handle.join().unwrap();

    // 3 clients x 6 bursts x 5 requests: every single one was answered
    // (the churn clients panic on silence), and the healthy majority
    // actually served.
    assert_eq!(ok + shed + failed, 3 * 6 * 5);
    assert!(ok > 0, "a 2-healthy-chip fleet must serve most requests");
    assert_eq!(
        failed, 0,
        "budget 4 with 2 permanently healthy chips must absorb every \
         failure transparently"
    );

    // The dead chip was hit and failed over; the fleet holds the floor.
    let mut cl = Client::connect(&addr).unwrap();
    let fs = cl.call("{\"cmd\":\"fleet_stats\"}").unwrap();
    assert!(
        fs.get("redirects").and_then(|v| v.as_uint()).unwrap() >= 1,
        "chip 1 must have been picked and failed over: {fs}"
    );
    assert!(
        fs.get("fault_errors").and_then(|v| v.as_uint()).unwrap() >= 1,
        "{fs}"
    );
    let healthy = fs.get("healthy").and_then(|v| v.as_usize()).unwrap();
    assert!(
        healthy >= floor,
        "fleet ended below the serving floor: {healthy} < {floor}: {fs}"
    );
    svc.stop();
}

/// Failover must not change numerics: with a permanently dead chip K and
/// retry enabled, batch results are bit-identical to a fault-free fleet
/// with chip K removed (replicas share silicon and noise is off, so the
/// *only* way to differ is serving a corrupted result from K or breaking
/// batch composition during the redirect).
#[test]
fn failover_is_numerically_invisible() {
    propcheck::check("failover_numerics", 4, 0xFA11, |g| {
        let chips = g.usize_in(2, 4);
        let k = g.usize_in(0, chips - 1);
        let plan = FaultPlan {
            seed: 1,
            faults: vec![spec(k, 0, None, FaultKind::ChipDeath)],
        };
        let mk = |fault_plan: Option<FaultPlan>, removed: Option<usize>| {
            Fleet::start(
                FleetConfig {
                    chips,
                    queue_depth: 64,
                    error_threshold: 2,
                    probe_period: 4,
                    redirects: 2,
                    fault_plan,
                    ..Default::default()
                },
                move |chip| {
                    anyhow::ensure!(
                        Some(chip) != removed,
                        "chip removed for the reference fleet"
                    );
                    Ok(Engine::native(
                        TrainedModel::synthetic(MODEL_SEED),
                        engine_cfg(chip),
                    ))
                },
            )
        };
        let faulty = mk(Some(plan), None).map_err(|e| e.to_string())?;
        let reference = mk(None, Some(k)).map_err(|e| e.to_string())?;
        for round in 0..4 {
            let b = g.usize_in(1, 5);
            let traces: Vec<Trace> =
                TraceStream::new(g.rng.next_u64() % 100_000, 1.0)
                    .take(b)
                    .collect();
            let (chip_a, got, rej_a) = faulty
                .classify_batch_blocking(&traces)
                .map_err(|e| format!("faulty fleet round {round}: {e}"))?;
            let (_chip_b, want, rej_b) = reference
                .classify_batch_blocking(&traces)
                .map_err(|e| format!("reference fleet round {round}: {e}"))?;
            prop_assert!(
                chip_a != k,
                "round {round}: the dead chip {k} produced a reply"
            );
            prop_assert_eq!(rej_a, rej_b);
            prop_assert!(
                got.len() == want.len(),
                "round {round}: {} vs {} results",
                got.len(),
                want.len()
            );
            for (i, (a, w)) in got.iter().zip(&want).enumerate() {
                prop_assert!(
                    a.pred == w.pred && a.scores == w.scores,
                    "round {round} sample {i}: failover changed numerics \
                     ({}, {:?}) != ({}, {:?})",
                    a.pred,
                    a.scores,
                    w.pred,
                    w.scores
                );
            }
        }
        prop_assert!(
            faulty.redirect_count() >= 1,
            "4 rotation rounds over ≤ 4 chips must have hit chip {k}"
        );
        faulty.shutdown();
        reference.shutdown();
        Ok(())
    });
}

/// The event journal keeps the fleet's lifecycle transitions in causal
/// order under chaos: a chip's quarantine entry comes after the fault
/// that earned it, a recalibration's drain entry comes before its
/// readmit, sequence numbers are strictly increasing, and a `since`
/// cursor returns exactly the suffix.
#[test]
fn journal_orders_fleet_transitions_under_chaos() {
    let chips = 3;
    let plan = FaultPlan {
        seed: 7,
        faults: vec![spec(1, 0, None, FaultKind::ChipDeath)],
    };
    let svc = Service::start_fleet(
        "127.0.0.1:0",
        FleetConfig {
            chips,
            queue_depth: 64,
            error_threshold: 3,
            probe_period: 64,
            redirects: 4,
            fault_plan: Some(plan),
            ..Default::default()
        },
        |chip| {
            Ok(Engine::native(
                TrainedModel::synthetic(MODEL_SEED),
                engine_cfg(chip),
            ))
        },
    )
    .unwrap();
    let mut cl = Client::connect(&svc.addr).unwrap();
    // Sequential singles: round-robin over 3 chips lands on the dead
    // chip 1 every third admission, so 24 requests push it well past
    // error_threshold 3 — and with budget 4 every reply is still ok.
    let mut traces = TraceStream::new(41, 1.0);
    for i in 0..24 {
        let t = traces.next().unwrap();
        let r = cl.classify(&t).unwrap();
        assert_eq!(
            r.get("ok"),
            Some(&Json::Bool(true)),
            "request {i}: {r}"
        );
    }
    // A manual drain of a *healthy* chip while chip 1 sits quarantined;
    // the reply only comes back after the worker journals the readmit.
    let r = cl.call("{\"cmd\":\"recalibrate\",\"chip\":0,\"reps\":8}").unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");

    let j = cl.call("{\"cmd\":\"journal\"}").unwrap();
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{j}");
    let events = j.get("events").and_then(|v| v.as_arr()).unwrap();
    let seqs: Vec<u64> = events
        .iter()
        .map(|e| e.get("seq").and_then(|v| v.as_uint()).unwrap())
        .collect();
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "seqs not strictly increasing: {j}");
    let first = |kind: &str, chip: usize| -> Option<usize> {
        events.iter().position(|e| {
            e.get("kind").and_then(|k| k.as_str()) == Some(kind)
                && e.get("chip").and_then(|c| c.as_usize()) == Some(chip)
        })
    };
    let fired = first("fault_fired", 1).expect("chip 1's fault journaled");
    let quarantined =
        first("chip_quarantined", 1).expect("chip 1 quarantined");
    assert!(
        fired < quarantined,
        "quarantine must follow the fault that earned it: {j}"
    );
    let drain = first("calib_drain", 0).expect("chip 0 drained");
    let readmit = first("calib_readmit", 0).expect("chip 0 readmitted");
    assert!(drain < readmit, "drain must precede readmit: {j}");

    // Cursor semantics: `since` mid-stream returns exactly the suffix.
    let mid = seqs[seqs.len() / 2];
    let tail = cl
        .call(&format!("{{\"cmd\":\"journal\",\"since\":{mid}}}"))
        .unwrap();
    let tail_seqs: Vec<u64> = tail
        .get("events")
        .and_then(|v| v.as_arr())
        .unwrap()
        .iter()
        .map(|e| e.get("seq").and_then(|v| v.as_uint()).unwrap())
        .collect();
    let want: Vec<u64> =
        seqs.iter().copied().filter(|&s| s >= mid).collect();
    assert_eq!(tail_seqs, want, "{tail}");
    svc.stop();
}

/// The heavy randomized soak (nightly: `cargo test --release -- --ignored`):
/// a bigger fleet under a randomly drawn fault plan and much more
/// concurrent traffic.  Invariants only — every request answered in
/// order, and the fleet never ends below what the plan's erroring faults
/// can explain.
#[test]
#[ignore = "long soak; run via `cargo test --release -- --ignored`"]
fn chaos_soak_long_randomized() {
    let chips = 4;
    for seed in [1u64, 2, 3] {
        let plan = FaultPlan::random(seed, chips, 60_000);
        let floor = chips - plan.erroring_chips(chips);
        let svc = Service::start_fleet(
            "127.0.0.1:0",
            FleetConfig {
                chips,
                queue_depth: 512,
                error_threshold: 3,
                probe_period: 8,
                redirects: 6,
                fault_plan: Some(plan),
                ..Default::default()
            },
            |chip| {
                Ok(Engine::native(
                    TrainedModel::synthetic(MODEL_SEED),
                    engine_cfg(chip),
                ))
            },
        )
        .unwrap();
        let addr = svc.addr;
        let mut handles = Vec::new();
        for client in 0..6u64 {
            handles.push(std::thread::spawn(move || {
                churn_client(addr, 100 * client + 7, 20, 8)
            }));
        }
        let (mut answered, mut failed) = (0u64, 0u64);
        for h in handles {
            let (o, s, f) = h.join().unwrap();
            answered += o + s + f;
            failed += f;
        }
        assert_eq!(answered, 6 * 20 * 8, "seed {seed}: silence detected");
        let mut cl = Client::connect(&addr).unwrap();
        let fs = cl.call("{\"cmd\":\"fleet_stats\"}").unwrap();
        let healthy = fs.get("healthy").and_then(|v| v.as_usize()).unwrap();
        assert!(
            healthy >= floor,
            "seed {seed}: fleet ended below the erroring-fault floor \
             ({healthy} < {floor}): {fs}"
        );
        // Terminal failures are only legitimate when the erroring faults
        // could momentarily exhaust every candidate; with at least one
        // never-erroring chip and budget 6 they should stay rare.
        if floor >= 1 {
            assert!(
                failed <= 6 * 20 * 8 / 10,
                "seed {seed}: too many terminal failures ({failed})"
            );
        }
        svc.stop();
    }
}
