//! Training-loop acceptance tests (hardware-in-the-loop subsystem):
//!
//! * finite-difference checks of the straight-through estimator against
//!   the simulated forward pass — end-to-end on the score margin for the
//!   output layer, and at the ADC-tap level for the hidden layers (where
//!   per-weight effects on the integer scores drown in AvgPool rounding,
//!   but the linear surrogate `Δadc ≈ scale·ΣΔw·x` is directly
//!   measurable);
//! * in-process training determinism (two `Trainer::run`s with the same
//!   config produce byte-identical artifacts);
//! * save→load roundtrip properties for the model and its artifact.
//!
//! All FD checks run on the ideal substrate (`noise_off`, no FPN, no
//! drift): the forward is then exactly `round(scale·Wx)` per column up
//! to clip, so the surrogate's error budget is integer rounding only.

use bss2::asic::consts as c;
use bss2::coordinator::engine::{Engine, EngineConfig, PassTap};
use bss2::ecg::gen::generate_trace;
use bss2::nn::weights::TrainedModel;
use bss2::train::artifact::ModelArtifact;
use bss2::train::shadow::ShadowWeights;
use bss2::train::ste::{backward_scores, Grads};
use bss2::train::{TrainConfig, Trainer};
use bss2::util::propcheck;
use bss2::{prop_assert, prop_assert_eq};

const SCALES: [f32; 3] = [0.2, 0.08, 0.1];

/// Ideal, frozen, noiseless substrate: finite differences see only the
/// deterministic analog chain.
fn ideal_cfg() -> EngineConfig {
    EngineConfig {
        use_pjrt: false,
        noise_off: true,
        ..Default::default()
    }
}

fn acts_for(seed: u64, afib: bool) -> Vec<i32> {
    let trace = generate_trace(seed, afib, 1.0);
    bss2::fpga::preprocess::preprocess(&trace.samples)
        .iter()
        .map(|&a| a as i32)
        .collect()
}

/// One tapped forward pass; returns the score margin `s1 − s0` and the
/// per-pass gradient taps.
fn forward(shadow: &ShadowWeights, acts: &[i32]) -> (f64, [PassTap; 3]) {
    let mut engine = Engine::native(shadow.to_model(SCALES), ideal_cfg());
    let (inf, tap) = engine.classify_acts_taps(acts).unwrap();
    ((inf.scores[1] - inf.scores[0]) as f64, tap)
}

/// End-to-end finite-difference check on the output layer: perturb a
/// handful of fc2 shadow weights feeding one safely-off-rail column and
/// compare the measured change of the score margin against the STE
/// prediction `ΔJ ≈ h·Σ ∂J/∂w` from [`backward_scores`].
///
/// The scores are integer-valued (AvgPool rounds to nearest), so the
/// measurement carries up to ±1 LSB of rounding per score — the step
/// size is chosen so the predicted ΔJ clears that noise floor.
#[test]
fn ste_fc2_gradient_matches_end_to_end_finite_difference() {
    let shadow = ShadowWeights::init(31, 4.0);
    let acts = acts_for(123, true);
    let (j0, tap) = forward(&shadow, &acts);

    // With g_scores = [-1, +1], `grads` is exactly ∂J/∂w for J = s1−s0.
    let mut grads = Grads::zero();
    backward_scores(&tap, &shadow.quantised(), SCALES, [-1.0, 1.0], &mut grads);
    assert!(
        grads.w2.iter().any(|&g| g != 0.0),
        "gradient must reach the output layer"
    );

    // Pick an output column whose base ADC sits well inside the rails,
    // then the rows with the largest activations feeding it (largest
    // |∂J/∂w|, strongest finite-difference signal).
    let x2 = &tap[2].x[..c::FC1_OUT];
    let col = (0..c::FC2_OUT)
        .find(|&j| tap[2].adc[2 * c::FC1_OUT + j].abs() < 60)
        .expect("some fc2 column off the rails");
    let mut rows: Vec<usize> = (0..c::FC1_OUT).collect();
    rows.sort_by_key(|&r| std::cmp::Reverse(x2[r]));
    rows.truncate(6);
    let x_sum: f32 = rows.iter().map(|&r| x2[r] as f32).sum();
    assert!(x_sum > 0.0, "no fc1 activation reached the output pass");

    // Step size targeting ~15 ADC LSB on the perturbed column: far above
    // the score-rounding floor, far below the rail from |adc| < 60.
    let h = (15.0 / (SCALES[2] * x_sum)).ceil().clamp(1.0, 16.0);
    let mut pert = shadow.clone();
    let mut predicted = 0.0f64;
    for &r in &rows {
        pert.w2[r * c::FC2_OUT + col] += h;
        predicted += (h * grads.w2[r * c::FC2_OUT + col]) as f64;
    }
    let (j1, _) = forward(&pert, &acts);
    let actual = j1 - j0;
    // predicted = ±h·scale2·Σx2/5: at least 3/5 of the targeted 15 LSB
    // (h is clamped, the column average divides by 5).
    assert!(
        predicted.abs() >= 0.6,
        "predicted step too small to resolve: {predicted}"
    );
    if predicted.abs() >= 2.0 {
        // Well above the ±1 LSB rounding floor: direction must match.
        assert_eq!(
            actual.signum(),
            predicted.signum(),
            "FD and STE must agree on direction: {actual} vs {predicted}"
        );
    }
    // AvgPool rounds each score to an integer: ±1 LSB of margin noise,
    // plus a surrogate slack for the (identity-assumed) rounding chain.
    let tol = 1.25 + 0.35 * predicted.abs();
    assert!(
        (actual - predicted).abs() <= tol,
        "FD mismatch: measured {actual:.2}, predicted {predicted:.2}"
    );
}

/// Tap-level finite-difference check for fc1: perturbing `w1[r][j]`
/// must move ADC column `j` (input row in the signed block) or
/// `123 + j` (unsigned block) by `scale1·h·x1[r]`, and leave untouched
/// columns bit-identical.  This validates the surrogate slope and the
/// two-block column mapping the STE's fc1 loop encodes.
#[test]
fn ste_fc1_surrogate_matches_tap_deltas() {
    let shadow = ShadowWeights::init(32, 4.0);
    let acts = acts_for(124, false);
    let (_, tap) = forward(&shadow, &acts);
    let x1 = &tap[1].x;

    // A column comfortably off the rails in both blocks.
    let col = (0..c::FC1_OUT)
        .find(|&j| {
            tap[1].adc[j].abs() < 60 && tap[1].adc[c::FC1_OUT + j].abs() < 60
        })
        .expect("some fc1 column off the rails");
    // The strongest input row of each block.
    let r_a = (0..c::K_SIGNED).max_by_key(|&r| x1[r]).unwrap();
    let r_b = (c::K_SIGNED..c::K_LOGICAL).max_by_key(|&r| x1[r]).unwrap();
    assert!(x1[r_a] > 0, "signed block saw no activation");

    let h = 4.0f32;
    let mut pert = shadow.clone();
    pert.w1[r_a * c::FC1_OUT + col] += h;
    pert.w1[r_b * c::FC1_OUT + col] += h;
    let (_, tap2) = forward(&pert, &acts);

    // Inputs to the pass are untouched by an fc1-weight change.
    assert_eq!(tap[1].x, tap2[1].x, "pass-1 inputs must not move");
    for (block, r) in [(0, r_a), (c::FC1_OUT, r_b)] {
        let want = SCALES[1] * h * x1[r] as f32;
        let got = (tap2[1].adc[block + col] - tap[1].adc[block + col]) as f32;
        assert!(
            (got - want).abs() <= 1.5 + 0.05 * want,
            "block at {block}: Δadc {got} vs surrogate {want}"
        );
    }
    // A neighbouring column's weights are untouched: bit-identical ADC.
    let other = (col + 1) % c::FC1_OUT;
    assert_eq!(tap[1].adc[other], tap2[1].adc[other]);
    assert_eq!(
        tap[1].adc[c::FC1_OUT + other],
        tap2[1].adc[c::FC1_OUT + other]
    );
}

/// Tap-level finite-difference check for the conv layer: one logical
/// tap `(o, ch, t)` is replicated across all valid Toeplitz positions,
/// so perturbing it must move ADC column `p·8 + o` by
/// `scale0·h·x0[ch·64 + p·2 − 3 + t]` at every interior position and
/// leave positions where the tap falls off the window — and every other
/// output channel — bit-identical.  Mirrors `pack_conv` exactly; this
/// is the indexing the STE's conv loop folds gradients back through.
#[test]
fn ste_conv_toeplitz_surrogate_matches_tap_deltas() {
    let shadow = ShadowWeights::init(33, 4.0);
    let acts = acts_for(125, true);
    let (_, tap) = forward(&shadow, &acts);
    let x0 = &tap[0].x;

    let (o, ch, t) = (2usize, 0usize, 0usize);
    let h = 4.0f32;
    let mut pert = shadow.clone();
    pert.wc[(o * c::ECG_CHANNELS + ch) * c::CONV_KERNEL + t] += h;
    let (_, tap2) = forward(&pert, &acts);
    assert_eq!(tap[0].x, tap2[0].x, "pass-0 inputs must not move");

    let mut checked = 0;
    for p in 0..c::CONV_POSITIONS {
        let colv = p * c::CONV_CHANNELS + o;
        let ti = p as isize * c::CONV_STRIDE as isize
            - c::CONV_PAD as isize
            + t as isize;
        if ti < 0 || ti as usize >= c::POOLED_LEN {
            // Tap off the padded window: the placed column never held
            // this cell, so its ADC must not move at all.
            assert_eq!(tap[0].adc[colv], tap2[0].adc[colv], "pad at p={p}");
            continue;
        }
        if tap[0].adc[colv].abs() >= 80 {
            continue; // too close to a rail for a linear check
        }
        let want = SCALES[0] * h * x0[ch * c::POOLED_LEN + ti as usize] as f32;
        let got = (tap2[0].adc[colv] - tap[0].adc[colv]) as f32;
        assert!(
            (got - want).abs() <= 1.5 + 0.05 * want,
            "p={p}: Δadc {got} vs surrogate {want}"
        );
        checked += 1;
    }
    assert!(checked >= 5, "too few positions off the rails: {checked}");
    // Other output channels never share the perturbed tap.
    for p in 0..c::CONV_POSITIONS {
        let colv = p * c::CONV_CHANNELS + (o + 1);
        assert_eq!(tap[0].adc[colv], tap2[0].adc[colv]);
    }
}

/// ISSUE 8 acceptance: training is deterministic per seed — two runs
/// with the same config produce byte-identical `bss2-model-v1`
/// artifacts (FPN, drift, data order and init all derive from explicit
/// seeds), and a different seed trains different weights.
#[test]
fn training_is_deterministic_per_seed() {
    let cfg = TrainConfig {
        epochs: 2,
        batch: 4,
        windows: 12,
        val_per_class: 3,
        seed: 9,
        ..TrainConfig::default()
    };
    let a = Trainer::run(&cfg).unwrap();
    let b = Trainer::run(&cfg).unwrap();
    assert_eq!(
        a.artifact.to_json(),
        b.artifact.to_json(),
        "same config must produce byte-identical artifacts"
    );
    assert_eq!(a.report.epoch_loss, b.report.epoch_loss);
    assert_eq!(a.report.epoch_val, b.report.epoch_val);
    // The artifact is stamped with the substrate it trained against.
    assert_ne!(a.artifact.substrate, 0, "FPN substrate must be stamped");
    assert!(a.artifact.drift && a.artifact.fpn_seed.is_some());
    assert!(a.artifact.metrics.contains_key("val_det"));
    assert_eq!(a.report.steps, 2 * 3, "2 epochs × ⌈12/4⌉ batches");

    let c = Trainer::run(&TrainConfig { seed: 10, ..cfg }).unwrap();
    assert_ne!(
        a.artifact.to_json(),
        c.artifact.to_json(),
        "different seed, different artifact"
    );
}

/// Satellite: save→load roundtrip of the trained model and its artifact
/// reproduces weights, scales, calibration and metrics bit-identically.
#[test]
fn model_and_artifact_save_load_roundtrip_property() {
    propcheck::check("model artifact roundtrip", 6, 0x8A17, |g| {
        let mut model = TrainedModel::synthetic(g.rng.next_u64());
        model.scales = [
            g.f64_in(0.01, 0.5) as f32,
            g.f64_in(0.01, 0.5) as f32,
            g.f64_in(0.01, 0.5) as f32,
        ];
        model
            .train_metrics
            .insert("val_det".into(), g.f64_in(0.0, 1.0));
        let fpn = g.bool();
        let art = ModelArtifact {
            substrate: g.rng.next_u64(),
            chip: g.usize_in(0, 7),
            chip_time_us: g.rng.next_u64() >> 20,
            seed: g.rng.next_u64(),
            fpn_seed: if fpn { Some(g.rng.next_u64()) } else { None },
            drift: g.bool(),
            augmented: g.bool(),
            epochs: g.usize_in(1, 32),
            batch: g.usize_in(1, 64),
            lr: g.f64_in(0.01, 1.0),
            momentum: g.f64_in(0.0, 0.99),
            temperature: g.f64_in(1.0, 16.0),
            metrics: model.train_metrics.clone(),
            model,
        };
        let path = std::env::temp_dir()
            .join(format!("bss2_model_roundtrip_{:016x}.json", g.seed));
        art.save(&path).map_err(|e| e.to_string())?;
        let back = ModelArtifact::load(&path).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        prop_assert_eq!(back.substrate, art.substrate);
        prop_assert_eq!(back.fpn_seed, art.fpn_seed);
        prop_assert_eq!(back.model.scales, art.model.scales);
        prop_assert_eq!(back.model.gain, art.model.gain);
        prop_assert_eq!(back.model.offset, art.model.offset);
        prop_assert_eq!(back.metrics, art.metrics);
        for p in 0..3 {
            prop_assert!(
                back.model.pass_weights[p] == art.model.pass_weights[p],
                "pass {} weights drifted through the roundtrip",
                p
            );
        }
        // Byte-level fixpoint: serialising the reload reproduces the
        // file exactly (no float drift through the JSON layer).
        prop_assert_eq!(back.to_json(), art.to_json());
        Ok(())
    });
}
