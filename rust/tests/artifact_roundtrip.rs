//! Integration: the AOT artifacts round-trip bit-exactly through the PJRT
//! runtime and agree with (a) the python-exported golden vectors and (b)
//! the native rust array model.  These tests skip (with a message) when
//! `make artifacts` has not run.

use bss2::asic::array::{AnalogArray, ColumnCalib};
use bss2::asic::consts as c;
use bss2::runtime::{ArtifactDir, Runtime};
use bss2::util::json::Json;

fn artifacts() -> Option<ArtifactDir> {
    let dir = ArtifactDir::default_location();
    if dir.exists() {
        Some(dir)
    } else {
        eprintln!("[skip] artifacts missing — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_matches_rust_constants() {
    let Some(dir) = artifacts() else { return };
    let m = dir.load_manifest().expect("manifest parses + validates");
    assert_eq!(m.k_logical, c::K_LOGICAL);
    assert_eq!(m.n_cols, c::N_COLS);
    assert_eq!(m.macs_total, c::MACS_TOTAL);
    assert_eq!(m.ops_total, c::OPS_TOTAL);
    assert!((m.noise_sigma - c::NOISE_SIGMA).abs() < 1e-9);
}

#[test]
fn vmm_artifact_matches_python_goldens_bit_exact() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let vmm = rt.load_vmm(&dir.vmm_hlo()).unwrap();
    let tv = std::fs::read_to_string(dir.path("vmm_testvec.json")).unwrap();
    let tv = Json::parse(&tv).unwrap();
    for (i, case) in tv.req("cases").unwrap().as_arr().unwrap().iter().enumerate() {
        let x = case.req("x").unwrap().to_f32_vec().unwrap();
        let w = case.req("w").unwrap().to_f32_vec().unwrap();
        let gain = case.req("gain").unwrap().to_f32_vec().unwrap();
        let offset = case.req("offset").unwrap().to_f32_vec().unwrap();
        let noise = case.req("noise").unwrap().to_f32_vec().unwrap();
        let scale = case.req("scale").unwrap().as_f64().unwrap() as f32;
        let expected = case.req("expected").unwrap().to_f32_vec().unwrap();
        let staged = vmm.stage_pass(&w, &gain, &offset, scale).unwrap();
        let got = vmm.run_pass(&staged, &x, &noise).unwrap();
        assert_eq!(got, expected, "case {i} differs from the pallas kernel");
    }
}

#[test]
fn vmm_artifact_matches_native_array_model() {
    // The rust `AnalogArray` is the in-process twin of the L1 kernel: same
    // inputs must give identical ADC counts (round-half-even et al.).
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let vmm = rt.load_vmm(&dir.vmm_hlo()).unwrap();

    let mut rng = bss2::util::rng::SplitMix64::new(0xA5A5);
    for case in 0..3 {
        let w_i8: Vec<i8> = (0..c::K_LOGICAL * c::N_COLS)
            .map(|_| (rng.below(127) as i32 - 63) as i8)
            .collect();
        let x_u8: Vec<u8> = (0..c::K_LOGICAL).map(|_| rng.below(32) as u8).collect();
        let gain: Vec<f32> = (0..c::N_COLS)
            .map(|_| (1.0 + 0.06 * rng.gauss()) as f32)
            .collect();
        let offset: Vec<f32> =
            (0..c::N_COLS).map(|_| (2.0 * rng.gauss()) as f32).collect();
        let noise: Vec<f32> =
            (0..c::N_COLS).map(|_| (2.0 * rng.gauss()) as f32).collect();
        let scale = (0.002 + 0.02 * rng.unit()) as f32;

        let mut array = AnalogArray::new(
            c::K_LOGICAL,
            c::N_COLS,
            ColumnCalib { gain: gain.clone(), offset: offset.clone() },
        );
        array.load_weights(&w_i8);
        let native: Vec<f32> = array
            .integrate(&x_u8, scale, &noise, false)
            .iter()
            .map(|&v| v as f32)
            .collect();

        let wf: Vec<f32> = w_i8.iter().map(|&v| v as f32).collect();
        let xf: Vec<f32> = x_u8.iter().map(|&v| v as f32).collect();
        let staged = vmm.stage_pass(&wf, &gain, &offset, scale).unwrap();
        let pjrt = vmm.run_pass(&staged, &xf, &noise).unwrap();

        let diffs = native
            .iter()
            .zip(&pjrt)
            .filter(|(a, b)| a != b)
            .count();
        assert_eq!(diffs, 0, "case {case}: {diffs} columns differ");
    }
}

#[test]
fn weights_artifact_loads_and_is_on_grid() {
    let Some(dir) = artifacts() else { return };
    let model = bss2::nn::weights::TrainedModel::load(&dir.weights()).unwrap();
    for (p, m) in model.pass_weights.iter().enumerate() {
        assert_eq!(m.len(), c::K_LOGICAL * c::N_COLS);
        for &w in m.iter() {
            assert!(w == w.trunc() && w.abs() <= c::W_MAX as f32,
                    "pass {p}: weight {w} off the 6-bit grid");
        }
    }
    assert!(model.scales.iter().all(|&s| s > 0.0));
    // Recorded training metrics landed in the paper's regime.
    let det = model.train_metrics.get("test_detection_mean").copied().unwrap_or(0.0);
    let fp = model.train_metrics.get("test_fp_mean").copied().unwrap_or(1.0);
    assert!(det > 0.85, "detection {det} below the paper's regime");
    assert!(fp < 0.25, "false positives {fp} above the paper's regime");
}

#[test]
fn ecg_test_set_loads_with_expected_geometry() {
    let Some(dir) = artifacts() else { return };
    let ds = bss2::ecg::dataset::Dataset::load(&dir.ecg_test()).unwrap();
    assert_eq!(ds.len(), 500, "paper: test blocks of 500 records");
    let frac = ds.afib_fraction();
    assert!((frac - 0.5).abs() < 0.1, "afib fraction {frac}");
}
