//! Accuracy regression pin: a seeded end-to-end run whose detection /
//! false-positive rates must stay inside a stored tolerance band, so a
//! future PR cannot silently degrade classification (a wrong rounding
//! mode, a broken pass mapping, a mis-applied calibration correction —
//! all of those collapse the class separation long before they crash).
//!
//! Two layers:
//!
//! 1. **Always-on** (artifact-free): the untrained
//!    [`TrainedModel::energy_detector`] — the same model `repro monitor`
//!    serves — classifies a seeded synthetic ECG set end to end (DMA →
//!    preprocessing → three analog passes → pooled scores), with a
//!    score-sum threshold calibrated on a disjoint split.  Everything is
//!    seeded, so the measured rates are bit-stable; the band is the
//!    regression fence.
//! 2. **Artifact-gated**: with trained artifacts present, the paper's
//!    own operating point (det 93.7 %, fp 14.0 %, Table 1) is pinned on
//!    the held-out test set.  Skipped (with a note) when artifacts are
//!    absent, e.g. in CI.
//! 3. **Trained-model-gated** (the ratchet): with a `repro train`
//!    artifact present, the trained model must *beat* the hand-built
//!    energy detector by a fixed margin on the same held-out pin seeds,
//!    served on the exact substrate it was trained against.  This is
//!    the stricter pin ISSUE 8 adds — training that fails to improve on
//!    the untrained baseline is a regression, not a model.

use bss2::coordinator::batch;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::dataset::Dataset;
use bss2::ecg::gen::generate_trace;
use bss2::nn::weights::TrainedModel;
use bss2::runtime::ArtifactDir;
use bss2::train::artifact::ModelArtifact;

/// Stored operating band of the synthetic energy-detector pin.  The
/// fence is loose on purpose: it exists to catch *catastrophic* silent
/// regressions (class separation collapsing toward chance), not
/// single-point drift.  Baseline measured at introduction with the
/// bit-exact python mirror of the generator + preprocessing
/// (`python/compile/data.py`) on these exact seeds: activation-level
/// det 0.72, fp 0.26, margin 0.46 at the midpoint threshold — the
/// chip transform is near-linear in the detector's operating range, so
/// the served rates sit close to those.
const DET_FLOOR: f64 = 0.60;
const FP_CEIL: f64 = 0.40;
/// The detector must beat chance by a wide margin: at chance level
/// (indistinguishable classes) `det - fp` is ~0 for any threshold.
const MARGIN_FLOOR: f64 = 0.25;
/// Mean afib window energy must exceed sinus by at least this factor
/// (the physical signal: fibrillatory 4–9 Hz waves + elevated rate).
const MEAN_RATIO_FLOOR: f64 = 1.02;

/// Windows per class; even indices calibrate the threshold, odd ones
/// are the held-out evaluation split.
const N_PER_CLASS: u64 = 100;

fn score_sum(eng: &mut Engine, seed: u64, afib: bool) -> f64 {
    let trace = generate_trace(seed, afib, 1.0);
    let inf = eng.classify(&trace).expect("healthy engine must classify");
    inf.scores[0] as f64 + inf.scores[1] as f64
}

#[test]
fn synthetic_operating_point_stays_in_band() {
    let mut eng = Engine::native(
        TrainedModel::energy_detector(),
        EngineConfig { use_pjrt: false, ..Default::default() },
    );
    let (mut cal_sinus, mut cal_afib) = (Vec::new(), Vec::new());
    let (mut eval_sinus, mut eval_afib) = (Vec::new(), Vec::new());
    for i in 0..N_PER_CLASS {
        let s = score_sum(&mut eng, 10_000 + i, false);
        let a = score_sum(&mut eng, 20_000 + i, true);
        if i % 2 == 0 {
            cal_sinus.push(s);
            cal_afib.push(a);
        } else {
            eval_sinus.push(s);
            eval_afib.push(a);
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (ms, ma) = (mean(&cal_sinus), mean(&cal_afib));
    assert!(
        ma > ms * MEAN_RATIO_FLOOR,
        "afib window energy must exceed sinus: afib {ma:.1} vs sinus \
         {ms:.1} (ratio floor {MEAN_RATIO_FLOOR})"
    );
    // Midpoint threshold from the calibration split only.
    let thr = (ms + ma) / 2.0;
    let frac_above = |v: &[f64]| {
        v.iter().filter(|&&x| x > thr).count() as f64 / v.len() as f64
    };
    let det = frac_above(&eval_afib);
    let fp = frac_above(&eval_sinus);
    println!(
        "[accuracy_regression] synthetic pin: det {det:.3}, fp {fp:.3} \
         (threshold {thr:.1}; sinus mean {ms:.1}, afib mean {ma:.1})"
    );
    assert!(
        det >= DET_FLOOR,
        "detection rate {det:.3} fell out of the stored band (floor \
         {DET_FLOOR}) — classification silently degraded"
    );
    assert!(
        fp <= FP_CEIL,
        "false-positive rate {fp:.3} fell out of the stored band (ceiling \
         {FP_CEIL}) — classification silently degraded"
    );
    assert!(
        det - fp >= MARGIN_FLOOR,
        "operating margin det - fp = {:.3} below {MARGIN_FLOOR}: the \
         classes are collapsing toward indistinguishable",
        det - fp
    );
}

#[test]
fn paper_operating_point_with_artifacts() {
    // The paper pin proper: only runnable with trained artifacts (the
    // held-out test set + trained weights are build products, absent in
    // CI).  Table 1: det 93.7 ± 0.7 %, fp 14.0 ± 1.0 %.
    let dir = ArtifactDir::default_location();
    if !dir.exists() {
        println!(
            "[accuracy_regression] no artifacts under {} — paper pin \
             skipped (run `make artifacts` to enable)",
            dir.root.display()
        );
        return;
    }
    let ds = Dataset::load(&dir.ecg_test()).expect("test set loads");
    let traces: Vec<_> = ds
        .traces
        .iter()
        .take(200)
        .map(|t| (t.clone(), t.label))
        .collect();
    let mut engine = Engine::from_artifacts(
        &dir,
        EngineConfig { use_pjrt: false, ..Default::default() },
    )
    .expect("engine from artifacts");
    let rep = batch::run_block(&mut engine, &traces).expect("block runs");
    let det = rep.confusion.detection_rate();
    let fp = rep.confusion.false_positive_rate();
    println!(
        "[accuracy_regression] paper pin: det {det:.3}, fp {fp:.3} \
         (paper: 0.937 / 0.140)"
    );
    // Generous band around Table 1 (200-trace subsample + analog noise).
    assert!(
        (det - 0.937).abs() <= 0.05,
        "trained detection rate {det:.3} left the paper band 0.937 ± 0.05"
    );
    assert!(
        (fp - 0.140).abs() <= 0.08,
        "trained false-positive rate {fp:.3} left the paper band \
         0.140 ± 0.08"
    );
}

/// The trained model's operating margin `det − fp` must beat the
/// energy detector's by at least this much on the same eval seeds.
const TRAINED_MARGIN_OVER_BASELINE: f64 = 0.05;

/// Fraction of a seeded trace set flagged afib by a trained classifier
/// (argmax prediction, not the energy threshold).
fn flag_rate(eng: &mut Engine, base: u64, afib: bool) -> f64 {
    let mut hits = 0usize;
    let mut n = 0usize;
    for i in 0..N_PER_CLASS {
        if i % 2 == 0 {
            continue; // even seeds are the baseline's calibration split
        }
        let trace = generate_trace(base + i, afib, 1.0);
        let inf = eng.classify(&trace).expect("healthy engine classifies");
        hits += usize::from(inf.pred == 1);
        n += 1;
    }
    hits as f64 / n as f64
}

#[test]
fn trained_artifact_beats_energy_detector() {
    // The ratchet pin: gated on a `repro train` artifact (a build
    // product, absent in a fresh checkout; CI trains one before
    // running the gate).
    let dir = ArtifactDir::default_location();
    let path = dir.trained_model();
    if !path.exists() {
        println!(
            "[accuracy_regression] no trained model at {} — ratchet pin \
             skipped (run `repro train` to enable)",
            path.display()
        );
        return;
    }
    let art = ModelArtifact::load(&path).expect("trained artifact loads");
    // Serve on the exact substrate the model was trained against.
    let mut eng = Engine::native(art.model.clone(), art.engine_config());
    assert_eq!(
        eng.substrate_hash(),
        Some(art.substrate),
        "reconstructed substrate must match the artifact's stamp"
    );
    let det = flag_rate(&mut eng, 20_000, true);
    let fp = flag_rate(&mut eng, 10_000, false);

    // The energy detector's margin on the *same* eval seeds, with its
    // threshold calibrated on the even-seed split (as in the synthetic
    // pin above).
    let mut base_eng = Engine::native(
        TrainedModel::energy_detector(),
        EngineConfig { use_pjrt: false, ..Default::default() },
    );
    let (mut cal_sinus, mut cal_afib) = (Vec::new(), Vec::new());
    let (mut eval_sinus, mut eval_afib) = (Vec::new(), Vec::new());
    for i in 0..N_PER_CLASS {
        let s = score_sum(&mut base_eng, 10_000 + i, false);
        let a = score_sum(&mut base_eng, 20_000 + i, true);
        if i % 2 == 0 {
            cal_sinus.push(s);
            cal_afib.push(a);
        } else {
            eval_sinus.push(s);
            eval_afib.push(a);
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let thr = (mean(&cal_sinus) + mean(&cal_afib)) / 2.0;
    let frac_above = |v: &[f64]| {
        v.iter().filter(|&&x| x > thr).count() as f64 / v.len() as f64
    };
    let base_margin = frac_above(&eval_afib) - frac_above(&eval_sinus);

    println!(
        "[accuracy_regression] ratchet pin: trained det {det:.3} fp \
         {fp:.3} (margin {:.3}) vs energy-detector margin {base_margin:.3}",
        det - fp
    );
    assert!(
        det >= DET_FLOOR,
        "trained detection rate {det:.3} below the synthetic floor \
         {DET_FLOOR} — training made things worse"
    );
    assert!(
        fp <= FP_CEIL,
        "trained false-positive rate {fp:.3} above the synthetic ceiling \
         {FP_CEIL}"
    );
    assert!(
        det - fp >= base_margin + TRAINED_MARGIN_OVER_BASELINE,
        "trained margin {:.3} must beat the energy detector's \
         {base_margin:.3} by at least {TRAINED_MARGIN_OVER_BASELINE}",
        det - fp
    );
}
