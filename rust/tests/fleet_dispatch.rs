//! Integration: the multi-chip fleet scheduler.
//!
//! (a) Under concurrent load every chip replica receives work.
//! (b) A fleet of N chips produces exactly the same predictions as a
//!     single engine for the same traces and seed (per-chip semantics are
//!     bit-identical to the paper's single-unit setup).
//! (c) Saturating the admission queues yields well-formed shed
//!     (backpressure) responses instead of hangs or unbounded queueing.

use std::sync::Arc;

use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::coordinator::service::{Client, Service};
use bss2::ecg::gen::{Trace, TraceStream};
use bss2::fleet::{
    BatchDispatchOutcome, DispatchOutcome, Fleet, FleetConfig, ShedReason,
};
use bss2::nn::weights::TrainedModel;
use bss2::util::json::Json;

const MODEL_SEED: u64 = 0xF1EE7;

fn engine_config(chip: usize) -> EngineConfig {
    EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() }
        .for_chip(chip)
}

fn native_fleet(chips: usize, queue_depth: usize) -> Fleet {
    Fleet::start(
        FleetConfig { chips, queue_depth, ..Default::default() },
        |chip| Ok(Engine::native(TrainedModel::synthetic(MODEL_SEED), engine_config(chip))),
    )
    .unwrap()
}

#[test]
fn all_chips_receive_work_under_load() {
    let chips = 4;
    let fleet = Arc::new(native_fleet(chips, 16));
    let mut handles = Vec::new();
    for client in 0..8u64 {
        let fleet = fleet.clone();
        handles.push(std::thread::spawn(move || {
            for trace in TraceStream::new(100 + client, 1.0).take(12) {
                // Depth 16 with ≤8 concurrent requests never sheds; any
                // shed here is a scheduler bug.
                let (chip, inf) = fleet.classify_blocking(&trace).unwrap();
                assert!(chip < 4);
                assert!(inf.pred <= 1);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snaps = fleet.chip_snapshots();
    let served: Vec<u64> = snaps.iter().map(|s| s.served).collect();
    assert_eq!(served.iter().sum::<u64>(), 96);
    for (chip, &n) in served.iter().enumerate() {
        assert!(n > 0, "chip {chip} served nothing: {served:?}");
    }
    assert_eq!(fleet.telemetry().served(), 96);
    assert_eq!(fleet.shed_count(), 0, "no shed expected under this load");
    Arc::try_unwrap(fleet).ok().unwrap().shutdown();
}

#[test]
fn fleet_matches_single_engine_predictions() {
    // Noise is off, so classification is a pure function of the trace and
    // the replicas are exact clones of the single-unit engine.
    let mut single =
        Engine::native(TrainedModel::synthetic(MODEL_SEED), engine_config(0));
    let fleet = native_fleet(3, 8);
    for trace in TraceStream::new(55, 1.0).take(15) {
        let want = single.classify(&trace).unwrap();
        let (_chip, got) = fleet.classify_blocking(&trace).unwrap();
        assert_eq!(got.pred, want.pred);
        assert_eq!(got.scores, want.scores);
        assert_eq!(got.sim_time_s, want.sim_time_s, "timing accounting drifted");
        assert_eq!(
            got.energy.total_j(),
            want.energy.total_j(),
            "energy accounting drifted"
        );
    }
    fleet.shutdown();
}

#[test]
fn backpressure_sheds_instead_of_hanging() {
    // One chip, tiny admission bound, and a dispatch loop much faster
    // than one inference: the queue must fill and shed.
    let fleet = native_fleet(1, 2);
    let trace = TraceStream::new(9, 1.0).next().unwrap();
    let mut enqueued = Vec::new();
    let mut sheds = 0u64;
    for _ in 0..200 {
        match fleet.dispatch(trace.clone()) {
            DispatchOutcome::Enqueued { resp, .. } => enqueued.push(resp),
            DispatchOutcome::Shed { reason, retry_after_us } => {
                assert_eq!(reason, ShedReason::Saturated);
                assert!(retry_after_us > 0, "retry hint must be positive");
                sheds += 1;
            }
        }
    }
    assert!(sheds > 0, "200 instant dispatches into depth 2 must shed");
    assert_eq!(fleet.shed_count(), sheds);
    // Every admitted job still completes (drain, no loss).
    for resp in enqueued {
        let reply = resp.recv().expect("admitted job must be answered");
        assert!(reply.result.is_ok(), "{:?}", reply.result);
    }
    fleet.shutdown();
}

#[test]
fn batch_sheds_partially_when_it_only_partially_fits() {
    let fleet = native_fleet(1, 4);
    let traces: Vec<Trace> = TraceStream::new(77, 1.0).take(6).collect();
    // Idle fleet: a 6-batch only partially fits a depth-4 queue.
    let (accepted, rejected, resp) = match fleet.dispatch_batch(traces.clone())
    {
        BatchDispatchOutcome::Enqueued {
            accepted,
            rejected,
            resp,
            retry_after_us,
            ..
        } => {
            assert!(retry_after_us > 0, "partial fit must carry a retry hint");
            (accepted, rejected, resp)
        }
        BatchDispatchOutcome::Shed { .. } => {
            panic!("idle fleet must admit a prefix")
        }
    };
    assert_eq!((accepted, rejected), (4, 2));
    // Instant follow-up batches shed once the 4 slots are occupied.
    let mut sheds = 0u64;
    let mut held = Vec::new();
    for _ in 0..50 {
        match fleet.dispatch_batch(traces[..2].to_vec()) {
            BatchDispatchOutcome::Shed { reason, retry_after_us } => {
                assert_eq!(reason, ShedReason::Saturated);
                assert!(retry_after_us > 0);
                sheds += 1;
            }
            BatchDispatchOutcome::Enqueued { resp, .. } => held.push(resp),
        }
    }
    assert!(sheds > 0, "50 instant 2-batches into depth 4 must shed");
    // The admitted prefix is fully answered, one inference per sample.
    let infs = resp.recv().unwrap().result.unwrap();
    assert_eq!(infs.len(), 4);
    for r in held {
        assert!(r.recv().unwrap().result.is_ok());
    }
    fleet.shutdown();
}

#[test]
fn fleet_batch_matches_single_engine_predictions() {
    // Same parity guarantee as the single path, through classify_batch:
    // per-sample results must be bit-identical to a fresh single engine.
    let mut single =
        Engine::native(TrainedModel::synthetic(MODEL_SEED), engine_config(0));
    let fleet = native_fleet(2, 32);
    let traces: Vec<Trace> = TraceStream::new(91, 1.0).take(6).collect();
    let (_chip, infs, rejected) =
        fleet.classify_batch_blocking(&traces).unwrap();
    assert_eq!(rejected, 0);
    assert_eq!(infs.len(), 6);
    for (trace, got) in traces.iter().zip(&infs) {
        let want = single.classify(trace).unwrap();
        assert_eq!(got.pred, want.pred);
        assert_eq!(got.scores, want.scores);
        // Timing amortises: per-sample time beats the single-trace path.
        assert!(got.sim_time_s < want.sim_time_s);
    }
    fleet.shutdown();
}

#[test]
fn service_shed_response_is_well_formed() {
    // Same saturation scenario end-to-end over TCP: every reply is valid
    // line-delimited JSON, either a classification or a shed.
    let svc = Service::start_fleet(
        "127.0.0.1:0",
        FleetConfig { chips: 1, queue_depth: 1, ..Default::default() },
        |chip| {
            Ok(Engine::native(
                TrainedModel::synthetic(MODEL_SEED),
                engine_config(chip),
            ))
        },
    )
    .unwrap();
    let addr = svc.addr;
    let mut handles = Vec::new();
    for client in 0..6u64 {
        handles.push(std::thread::spawn(move || -> (usize, usize) {
            let mut cl = Client::connect(&addr).unwrap();
            let (mut served, mut shed) = (0, 0);
            for trace in TraceStream::new(700 + client, 1.0).take(8) {
                let reply = cl.classify(&trace).unwrap();
                if reply.get("ok") == Some(&Json::Bool(true)) {
                    assert!(reply.get("chip").is_some());
                    served += 1;
                } else {
                    // A rejection must be an explicit, well-formed shed.
                    assert_eq!(
                        reply.get("shed"),
                        Some(&Json::Bool(true)),
                        "non-shed failure: {reply}"
                    );
                    assert!(reply
                        .get("retry_after_us")
                        .and_then(|v| v.as_f64())
                        .unwrap() > 0.0);
                    assert!(reply.get("error").is_some());
                    shed += 1;
                }
            }
            (served, shed)
        }));
    }
    let mut total_served = 0;
    let mut total_shed = 0;
    for h in handles {
        let (s, d) = h.join().unwrap();
        total_served += s;
        total_shed += d;
    }
    assert_eq!(total_served + total_shed, 48, "every request got a reply");
    assert!(total_served > 0, "some requests must be served");
    let mut cl = Client::connect(&addr).unwrap();
    let stats = cl.call("{\"cmd\":\"stats\"}").unwrap();
    assert_eq!(
        stats.get("served").and_then(|v| v.as_usize()),
        Some(total_served)
    );
    svc.stop();
}

#[test]
fn fleet_stats_protocol_roundtrip() {
    let svc = Service::start_fleet(
        "127.0.0.1:0",
        FleetConfig { chips: 2, queue_depth: 8, ..Default::default() },
        |chip| {
            Ok(Engine::native(
                TrainedModel::synthetic(MODEL_SEED),
                engine_config(chip),
            ))
        },
    )
    .unwrap();
    let mut cl = Client::connect(&svc.addr).unwrap();
    for trace in TraceStream::new(31, 1.0).take(4) {
        let reply = cl.classify(&trace).unwrap();
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    }
    let fs = cl.call("{\"cmd\":\"fleet_stats\"}").unwrap();
    assert_eq!(fs.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(fs.get("chips").and_then(|v| v.as_usize()), Some(2));
    assert_eq!(fs.get("served").and_then(|v| v.as_usize()), Some(4));
    let per_chip = fs.get("per_chip").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(per_chip.len(), 2);
    let chip_served: usize = per_chip
        .iter()
        .map(|c| c.get("served").and_then(|v| v.as_usize()).unwrap())
        .sum();
    assert_eq!(chip_served, 4);
    for c in per_chip {
        assert_eq!(c.get("state").and_then(|v| v.as_str()), Some("healthy"));
    }
    // The round-robin tie-break spreads even a single sequential client.
    assert!(
        per_chip.iter().all(|c| {
            c.get("served").and_then(|v| v.as_usize()).unwrap() > 0
        }),
        "both chips serve a sequential client: {fs}"
    );
    svc.stop();
}
