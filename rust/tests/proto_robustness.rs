//! Framing-robustness suite (DESIGN.md §14): the binary decoder and the
//! server's protocol state machine against hostile bytes — truncations,
//! oversized length prefixes, random garbage, mid-frame splits.  The
//! server must answer with typed errors or close the connection; it must
//! never panic, and it must keep serving other clients afterwards.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::coordinator::service::Service;
use bss2::fleet::FleetConfig;
use bss2::nn::weights::TrainedModel;
use bss2::util::propcheck::{self, Gen};
use bss2_client::{Client, Json, Options};
use bss2_proto::handshake::{self, Encoding};
use bss2_proto::{bin, frame, PROTO_VERSION};

fn start_service() -> Service {
    Service::start_fleet(
        "127.0.0.1:0",
        FleetConfig { chips: 1, queue_depth: 16, ..Default::default() },
        |_chip| {
            Ok(Engine::native(
                TrainedModel::synthetic(0x57AB1E),
                EngineConfig {
                    use_pjrt: false,
                    noise_off: true,
                    ..Default::default()
                },
            ))
        },
    )
    .unwrap()
}

fn assert_still_serving(svc: &Service) {
    let mut cl = Client::connect(svc.addr, Options::default()).unwrap();
    assert_eq!(
        cl.ping().unwrap().get("ok"),
        Some(&Json::Bool(true)),
        "service stopped answering after hostile input"
    );
}

// --- pure decoder properties (no server) --------------------------------

fn arbitrary_json(g: &mut Gen, depth: usize) -> Json {
    let top = if depth >= 3 { 4 } else { 6 };
    match g.usize_in(0, top) {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num(g.f64_in(-1e6, 1e6)),
        // Integral 0..=65535 numbers steer arrays onto the packed-u16
        // wire representation.
        3 => Json::Num(f64::from(g.i32_in(0, 65535))),
        4 => {
            let len = g.usize_in(0, 12);
            Json::Str(
                (0..len)
                    .map(|_| g.i32_in(32, 126) as u8 as char)
                    .collect(),
            )
        }
        5 => Json::Arr(
            (0..g.usize_in(0, 6))
                .map(|_| arbitrary_json(g, depth + 1))
                .collect(),
        ),
        _ => Json::Obj(
            (0..g.usize_in(0, 5))
                .map(|i| {
                    (format!("k{i}"), arbitrary_json(g, depth + 1))
                })
                .collect(),
        ),
    }
}

#[test]
fn decoder_roundtrips_arbitrary_values() {
    propcheck::check("bin roundtrip", 300, 0xB17, |g| {
        let v = arbitrary_json(g, 0);
        let decoded = bin::decode(&bin::encode(&v))
            .map_err(|e| format!("decode failed on {v}: {e}"))?;
        if decoded != v {
            return Err(format!("roundtrip mismatch: {v} -> {decoded}"));
        }
        Ok(())
    });
}

#[test]
fn decoder_rejects_every_strict_prefix() {
    // The encoding is self-delimiting, so a cut-anywhere prefix can
    // never decode to a complete value — it must be a typed error.
    propcheck::check("bin truncation", 300, 0x7120, |g| {
        let v = arbitrary_json(g, 0);
        let bytes = bin::encode(&v);
        let cut = g.usize_in(0, bytes.len() - 1);
        if bin::decode(&bytes[..cut]).is_ok() {
            return Err(format!(
                "prefix of {cut}/{} bytes of {v} decoded Ok",
                bytes.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn decoder_never_panics_on_garbage() {
    propcheck::check("bin garbage", 500, 0xF00D, |g| {
        let len = g.usize_in(0, 128);
        let bytes: Vec<u8> =
            (0..len).map(|_| g.i32_in(0, 255) as u8).collect();
        let _ = bin::decode(&bytes); // any Result is fine; a panic is not
        let _ = frame::first_frame_len(&bytes);
        Ok(())
    });
    // Single-byte corruptions of valid encodings, same contract.
    propcheck::check("bin corruption", 300, 0xBADB17, |g| {
        let v = arbitrary_json(g, 0);
        let mut bytes = bin::encode(&v);
        let at = g.usize_in(0, bytes.len() - 1);
        bytes[at] ^= (1 + g.i32_in(0, 254)) as u8;
        let _ = bin::decode(&bytes);
        Ok(())
    });
}

#[test]
fn length_arithmetic_cannot_wrap() {
    // A u32::MAX payload length must be TooLarge on every pointer width.
    // Before first_frame_len did its math in u64, a 32-bit host computed
    // `HEADER_LEN + (u32::MAX as usize)`, wrapped to 3, and treated the
    // hostile prefix as a tiny complete frame.
    let hostile = u32::MAX.to_le_bytes();
    match frame::first_frame_len(&hostile) {
        Err(frame::FrameError::TooLarge { len, max }) => {
            assert_eq!(len, u64::from(u32::MAX) + frame::HEADER_LEN as u64);
            assert_eq!(max, bss2_proto::MAX_FRAME);
        }
        other => panic!("u32::MAX prefix must be TooLarge, got {other:?}"),
    }

    // Same idea inside the binary decoder: a string length of u32::MAX
    // with a few real bytes behind it must be a typed Truncated error,
    // not a wrapped in-bounds slice (bin::Reader::take uses checked_add).
    let mut s = vec![0x04]; // TAG_STR
    s.extend_from_slice(&u32::MAX.to_le_bytes());
    s.extend_from_slice(b"abc");
    assert_eq!(bin::decode(&s), Err(bin::BinError::Truncated));

    // Packed-u16 array claiming u32::MAX elements: count validation
    // (2 bytes/element minimum) rejects it before any allocation.
    let mut u16s = vec![0x07]; // TAG_U16S
    u16s.extend_from_slice(&u32::MAX.to_le_bytes());
    u16s.extend_from_slice(&[0u8; 8]);
    assert_eq!(bin::decode(&u16s), Err(bin::BinError::Truncated));

    // Nested object whose inner count also lies: still a typed error.
    let mut obj = vec![0x06]; // TAG_OBJ
    obj.extend_from_slice(&1u32.to_le_bytes());
    obj.extend_from_slice(&u32::MAX.to_le_bytes()); // key length
    obj.extend_from_slice(b"k");
    assert_eq!(bin::decode(&obj), Err(bin::BinError::Truncated));
}

// --- live-server robustness ----------------------------------------------

/// Raw framed connection with the handshake already done.
fn framed_conn(svc: &Service) -> TcpStream {
    let mut s = TcpStream::connect(svc.addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(&handshake::hello_bytes(PROTO_VERSION, Encoding::Binary))
        .unwrap();
    let mut ack = [0u8; handshake::LEN];
    s.read_exact(&mut ack).unwrap();
    assert_eq!(handshake::evaluate_ack(&ack), Ok(Encoding::Binary));
    s
}

fn read_raw_frame(s: &mut TcpStream) -> Vec<u8> {
    let mut hdr = [0u8; frame::HEADER_LEN];
    s.read_exact(&mut hdr).unwrap();
    let len = u32::from_le_bytes(hdr) as usize;
    let mut payload = vec![0u8; len];
    s.read_exact(&mut payload).unwrap();
    payload
}

fn ping_frame() -> Vec<u8> {
    let ping = Json::parse("{\"cmd\":\"ping\"}").unwrap();
    let mut out = Vec::new();
    frame::encode_into(&bin::encode(&ping), &mut out);
    out
}

#[test]
fn oversized_length_prefix_is_a_typed_error_then_close() {
    let svc = start_service();
    let mut s = framed_conn(&svc);
    // Four bytes claiming a 4 GiB frame: the server must refuse before
    // buffering anything, tell the client why, and hang up.
    s.write_all(&u32::MAX.to_le_bytes()).unwrap();
    let reply = bin::decode(&read_raw_frame(&mut s)).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply}");
    let msg = reply.get("error").and_then(|v| v.as_str()).unwrap();
    assert!(msg.contains("exceeds"), "unexpected error text: {msg}");
    let mut rest = Vec::new();
    assert_eq!(s.read_to_end(&mut rest).unwrap(), 0, "must close");
    assert_still_serving(&svc);
    svc.stop();
}

#[test]
fn truncated_frames_and_dead_connections_are_harmless() {
    let svc = start_service();
    // A header promising 100 bytes followed by 10 and a close.
    let mut s = framed_conn(&svc);
    s.write_all(&100u32.to_le_bytes()).unwrap();
    s.write_all(&[0u8; 10]).unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    let mut sink = Vec::new();
    let _ = s.read_to_end(&mut sink);
    // A hello cut off halfway.
    let mut s = TcpStream::connect(svc.addr).unwrap();
    s.write_all(&handshake::hello_bytes(PROTO_VERSION, Encoding::Binary)[..3])
        .unwrap();
    drop(s);
    assert_still_serving(&svc);
    svc.stop();
}

#[test]
fn garbage_inside_a_valid_frame_is_a_bad_request_not_a_hangup() {
    let svc = start_service();
    let mut s = framed_conn(&svc);
    // Well-framed payload that is not a valid binary value.
    let mut msg = Vec::new();
    frame::encode_into(&[0xff, 0x01, 0x02], &mut msg);
    s.write_all(&msg).unwrap();
    let reply = bin::decode(&read_raw_frame(&mut s)).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply}");
    // The connection survives a bad request: a valid ping still answers.
    s.write_all(&ping_frame()).unwrap();
    let pong = bin::decode(&read_raw_frame(&mut s)).unwrap();
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)), "{pong}");
    assert_still_serving(&svc);
    svc.stop();
}

#[test]
fn mid_frame_splits_reassemble() {
    let svc = start_service();
    let mut s = TcpStream::connect(svc.addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.set_nodelay(true).unwrap();
    let mut msg =
        handshake::hello_bytes(PROTO_VERSION, Encoding::Binary).to_vec();
    msg.extend(ping_frame());
    // One byte at a time across the hello boundary and the frame header,
    // then tiny chunks: the state machine sees every possible split.
    for b in &msg[..14] {
        s.write_all(std::slice::from_ref(b)).unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    for chunk in msg[14..].chunks(3) {
        s.write_all(chunk).unwrap();
        std::thread::yield_now();
    }
    let mut ack = [0u8; handshake::LEN];
    s.read_exact(&mut ack).unwrap();
    assert_eq!(handshake::evaluate_ack(&ack), Ok(Encoding::Binary));
    let pong = bin::decode(&read_raw_frame(&mut s)).unwrap();
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)), "{pong}");
    assert_still_serving(&svc);
    svc.stop();
}

#[test]
fn random_opening_bytes_never_kill_the_server() {
    let svc = start_service();
    propcheck::check("server vs garbage", 24, 0x5E12, |g| {
        let len = g.usize_in(1, 96);
        let bytes: Vec<u8> =
            (0..len).map(|_| g.i32_in(0, 255) as u8).collect();
        let mut s = TcpStream::connect(svc.addr)
            .map_err(|e| format!("connect: {e}"))?;
        s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        // The server may already have rejected and closed (e.g. a blob
        // starting 0xB5 with a bad version) — a write error is fine.
        let _ = s.write_all(&bytes);
        let _ = s.shutdown(Shutdown::Write);
        // Drain whatever the server says (reject bytes, error replies,
        // nothing); only a panic on the other side is a failure, and
        // that is caught by the liveness probe below.
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink);
        Ok(())
    });
    assert_still_serving(&svc);
    svc.stop();
}
