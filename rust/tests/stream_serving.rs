//! Serving-layer integration tests for the streaming/pipelining PR:
//! connection-churn soak (handler reaping), the max-connections cap,
//! pipelined request concurrency with ordered replies, stream-session
//! round-trips with chunk sizes that straddle window boundaries, and the
//! remote-shutdown gate.

use std::time::{Duration, Instant};

use bss2::asic::consts as c;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::coordinator::service::{Client, Service};
use bss2::ecg::gen::generate_trace;
use bss2::ecg::stream::{ContinuousEcg, EpisodeConfig};
use bss2::fleet::FleetConfig;
use bss2::fpga::preprocess::IncrementalWindower;
use bss2::nn::weights::TrainedModel;
use bss2::util::json::Json;

/// Deterministic native engine; every chip identical (no per-chip split),
/// so any replica's answer equals a local reference engine's.
fn test_engine() -> Engine {
    Engine::native(
        TrainedModel::synthetic(0x57AB1E),
        EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
    )
}

fn start_service(cfg: FleetConfig) -> Service {
    Service::start_fleet("127.0.0.1:0", cfg, |_chip| Ok(test_engine())).unwrap()
}

#[test]
fn connection_churn_does_not_grow_handlers() {
    let svc = start_service(FleetConfig {
        chips: 1,
        queue_depth: 8,
        ..Default::default()
    });
    // N connect/use/disconnect cycles: the handler registry must drain
    // back instead of accumulating finished connections forever.
    for i in 0..40 {
        let mut cl = Client::connect(&svc.addr).unwrap();
        let pong = cl.call("{\"cmd\":\"ping\"}").unwrap();
        assert_eq!(pong.get("ok"), Some(&Json::Bool(true)), "cycle {i}");
        drop(cl);
        assert!(
            svc.active_connections() <= 4,
            "handler growth under churn: {} live after cycle {i}",
            svc.active_connections()
        );
    }
    // After the last disconnect every handler unwinds (blocking read
    // returns 0) and deregisters.
    let t0 = Instant::now();
    while svc.active_connections() > 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "handlers never drained: {} still live",
            svc.active_connections()
        );
        std::thread::yield_now();
    }
    svc.stop();
}

#[test]
fn connection_cap_sheds_with_explicit_reply() {
    let svc = start_service(FleetConfig {
        chips: 1,
        queue_depth: 8,
        max_connections: 2,
        ..Default::default()
    });
    // Two held connections fill the cap (ping proves they're registered).
    let mut a = Client::connect(&svc.addr).unwrap();
    let mut b = Client::connect(&svc.addr).unwrap();
    assert_eq!(a.call("{\"cmd\":\"ping\"}").unwrap().get("ok"), Some(&Json::Bool(true)));
    assert_eq!(b.call("{\"cmd\":\"ping\"}").unwrap().get("ok"), Some(&Json::Bool(true)));
    // The third gets an accept-time shed reply, then the socket closes.
    let mut cl = Client::connect(&svc.addr).unwrap();
    let shed = cl.read_reply().unwrap();
    assert_eq!(shed.get("ok"), Some(&Json::Bool(false)), "{shed}");
    assert_eq!(shed.get("shed"), Some(&Json::Bool(true)), "{shed}");
    assert_eq!(shed.get("max_connections").and_then(|v| v.as_usize()), Some(2));
    assert!(cl.read_reply().is_err(), "shed connection must be closed");
    // Freeing a slot re-admits new clients.
    drop(a);
    let t0 = Instant::now();
    loop {
        let mut cl = Client::connect(&svc.addr).unwrap();
        let r = cl.read_reply_or_ping();
        if r {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "slot never freed after disconnect"
        );
        std::thread::yield_now();
    }
    drop(b);
    svc.stop();
}

/// Tiny helper: returns true when the connection accepts a ping (i.e. it
/// was admitted, not shed).
trait PingProbe {
    fn read_reply_or_ping(&mut self) -> bool;
}

impl PingProbe for Client {
    fn read_reply_or_ping(&mut self) -> bool {
        if self.send("{\"cmd\":\"ping\"}").is_err() {
            return false;
        }
        match self.read_reply() {
            Ok(r) => r.get("pong") == Some(&Json::Bool(true)),
            Err(_) => false,
        }
    }
}

#[test]
fn pipelined_requests_execute_concurrently_in_order() {
    let svc = start_service(FleetConfig {
        chips: 2,
        queue_depth: 128,
        ..Default::default()
    });
    let mut cl = Client::connect(&svc.addr).unwrap();

    // Two batches written back-to-back *before reading any reply*: the
    // reader dispatches both immediately, so both chips hold inflight
    // work at the same time — impossible under the old one-request-at-a-
    // time handler, which would not even parse the second request until
    // the first reply was written.
    let big: Vec<_> =
        (0..64).map(|i| generate_trace(300 + i, i % 2 == 0, 1.0)).collect();
    let small: Vec<_> =
        (0..3).map(|i| generate_trace(400 + i, i % 2 == 1, 1.0)).collect();
    cl.send_classify_batch(&big).unwrap();
    cl.send_classify_batch(&small).unwrap();

    // Observe the overlap: both chips must report inflight work
    // simultaneously at some point (inflight is set at admission and
    // cleared at completion, and the 64-batch runs for milliseconds).
    // If everything finished before this thread got scheduled at all,
    // the observation is inconclusive rather than failed — the
    // different-chips assertion below still proves both were dispatched
    // before either reply was read.
    let t0 = Instant::now();
    let mut overlapped = false;
    let mut conclusive = true;
    while t0.elapsed() < Duration::from_secs(5) {
        let snaps = svc.fleet.chip_snapshots();
        if snaps[0].inflight > 0 && snaps[1].inflight > 0 {
            overlapped = true;
            break;
        }
        if snaps.iter().map(|s| s.served).sum::<u64>() >= 67 {
            conclusive = false;
            break;
        }
        std::thread::yield_now();
    }

    // Replies come back in request order regardless of completion order.
    let r1 = cl.read_reply().unwrap();
    let r2 = cl.read_reply().unwrap();
    assert_eq!(r1.get("ok"), Some(&Json::Bool(true)), "{r1}");
    assert_eq!(r2.get("ok"), Some(&Json::Bool(true)), "{r2}");
    assert_eq!(r1.get("batch").and_then(|v| v.as_usize()), Some(64));
    assert_eq!(r2.get("batch").and_then(|v| v.as_usize()), Some(3));
    assert_ne!(
        r1.get("chip").and_then(|v| v.as_usize()),
        r2.get("chip").and_then(|v| v.as_usize()),
        "least-loaded dispatch must spread pipelined batches: {r1} / {r2}"
    );
    assert!(
        overlapped || !conclusive,
        "pipelined requests never held inflight work on both chips at once"
    );

    // Pipelined single classifies: replies arrive in request order and
    // each matches a local reference engine bit-for-bit (noise off, all
    // replicas identical).
    let traces: Vec<_> =
        (0..6).map(|i| generate_trace(500 + i, i % 2 == 0, 1.0)).collect();
    for t in &traces {
        cl.send_classify(t).unwrap();
    }
    let mut reference = test_engine();
    for (i, t) in traces.iter().enumerate() {
        let want = reference.classify(t).unwrap();
        let got = cl.read_reply().unwrap();
        assert_eq!(got.get("ok"), Some(&Json::Bool(true)), "req {i}: {got}");
        assert_eq!(
            got.get("pred").and_then(|v| v.as_usize()),
            Some(want.pred as usize),
            "reply order broken at request {i}: {got}"
        );
        let scores = got.get("scores").and_then(|v| v.as_arr()).unwrap();
        for k in 0..2 {
            let s = scores[k].as_f64().unwrap();
            assert!(
                (s - want.scores[k] as f64).abs() < 1e-3,
                "req {i} score {k}: wire {s} vs local {}",
                want.scores[k]
            );
        }
    }
    svc.stop();
}

#[test]
fn stream_session_roundtrip_straddles_window_boundaries() {
    let svc = start_service(FleetConfig {
        chips: 1,
        queue_depth: 64,
        ..Default::default()
    });
    let mut cl = Client::connect(&svc.addr).unwrap();
    let hop = 512usize;

    // Protocol guards: push before open, double open.
    let r = cl
        .call("{\"cmd\":\"stream_push\",\"samples\":[[1],[2]]}")
        .unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
    let open = cl.stream_open(hop).unwrap();
    assert_eq!(open.get("ok"), Some(&Json::Bool(true)), "{open}");
    assert_eq!(open.get("hop").and_then(|v| v.as_usize()), Some(hop));
    let again = cl.call(&format!("{{\"cmd\":\"stream_open\",\"hop\":{hop}}}")).unwrap();
    assert_eq!(again.get("ok"), Some(&Json::Bool(false)), "{again}");
    // A malformed chunk is rejected without killing the session.
    let r = cl
        .call("{\"cmd\":\"stream_push\",\"samples\":[[1,2],[3]]}")
        .unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "ragged: {r}");
    let r = cl
        .call("{\"cmd\":\"stream_push\",\"samples\":[[1.5],[2]]}")
        .unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "non-integer: {r}");

    // Stream 3684 samples in chunks that straddle both the 2048 window
    // boundary and every 512 hop boundary: 4 windows emerge.
    let total = c::ECG_WINDOW + 3 * hop + 100;
    let mut ecg = ContinuousEcg::new(
        5,
        1.0,
        EpisodeConfig { lead_in_s: 6.0, sinus_s: (5.0, 8.0), afib_s: (4.0, 7.0) },
    );
    let raw = ecg.next_chunk(total);
    let mut fed = 0usize;
    for n in [1usize, 700, 41, 1000, 613, 800, 529] {
        let chunk: Vec<Vec<u16>> =
            raw.iter().map(|ch| ch[fed..fed + n].to_vec()).collect();
        cl.stream_push(&chunk).unwrap();
        fed += n;
    }
    assert_eq!(fed, total);
    cl.stream_close().unwrap();

    // Results arrive in window order; the close ack arrives last, after
    // every pending result (ordered-reply FIFO).
    let mut reference = test_engine();
    let mut windower = IncrementalWindower::new(hop).unwrap();
    let frames = windower.push_chunk(&raw).unwrap();
    assert_eq!(frames.len(), 4);
    for (k, frame) in frames.iter().enumerate() {
        let line = cl.read_reply().unwrap();
        assert_eq!(line.get("ok"), Some(&Json::Bool(true)), "window {k}: {line}");
        assert_eq!(line.get("stream"), Some(&Json::Bool(true)), "{line}");
        assert_eq!(line.get("window").and_then(|v| v.as_usize()), Some(k));
        assert_eq!(
            line.get("start_sample").and_then(|v| v.as_usize()),
            Some(k * hop)
        );
        let acts: Vec<i32> = frame.acts.iter().map(|&a| a as i32).collect();
        let want = reference.classify_acts(&acts).unwrap();
        assert_eq!(
            line.get("pred").and_then(|v| v.as_usize()),
            Some(want.pred as usize),
            "window {k}: {line}"
        );
        let scores = line.get("scores").and_then(|v| v.as_arr()).unwrap();
        for i in 0..2 {
            let s = scores[i].as_f64().unwrap();
            assert!(
                (s - want.scores[i] as f64).abs() < 1e-3,
                "window {k} score {i}: wire {s} vs local {}",
                want.scores[i]
            );
        }
    }
    let closed = cl.read_reply().unwrap();
    assert_eq!(closed.get("stream").and_then(|v| v.as_str()), Some("closed"));
    assert_eq!(closed.get("windows").and_then(|v| v.as_usize()), Some(4));
    assert_eq!(closed.get("dispatched").and_then(|v| v.as_usize()), Some(4));
    assert_eq!(closed.get("shed").and_then(|v| v.as_usize()), Some(0));
    assert_eq!(
        closed.get("samples").and_then(|v| v.as_usize()),
        Some(total)
    );
    // The session is gone; a fresh one can be opened on the same
    // connection.
    let r = cl.call("{\"cmd\":\"stream_close\"}").unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
    let reopen = cl.stream_open(c::ECG_WINDOW).unwrap();
    assert_eq!(reopen.get("ok"), Some(&Json::Bool(true)), "{reopen}");
    svc.stop();
}

#[test]
fn remote_shutdown_is_gated() {
    // Default config: the wire shutdown command is refused and the
    // service keeps serving.
    let svc = start_service(FleetConfig {
        chips: 1,
        queue_depth: 8,
        ..Default::default()
    });
    let mut cl = Client::connect(&svc.addr).unwrap();
    let r = cl.call("{\"cmd\":\"shutdown\"}").unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
    assert!(
        r.get("error").and_then(|e| e.as_str()).unwrap().contains("disabled"),
        "{r}"
    );
    let pong = cl.call("{\"cmd\":\"ping\"}").unwrap();
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)), "still serving");
    svc.stop();

    // Opt-in config: shutdown answers bye and closes the connection.
    let svc = start_service(FleetConfig {
        chips: 1,
        queue_depth: 8,
        allow_remote_shutdown: true,
        ..Default::default()
    });
    let mut cl = Client::connect(&svc.addr).unwrap();
    let r = cl.call("{\"cmd\":\"shutdown\"}").unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(r.get("bye"), Some(&Json::Bool(true)), "{r}");
    assert!(cl.read_reply().is_err(), "connection closes after bye");
    svc.stop();
}
