//! The versioned trained-model artifact (`bss2-model-v1`).
//!
//! Wraps the `bss2-weights-v1` weight payload with the provenance the
//! serving path needs to decide whether the model is *applicable*: the
//! [`substrate_hash`](crate::calib::profile::substrate_hash) of the
//! silicon it was trained against, the chip ordinal, the chip-time age,
//! and the full training configuration (so a run is reproducible from
//! its artifact alone).  Policy mirrors `bss2-calib-v2`: a
//! different-format artifact is a *typed* error loaders may skip; a
//! foreign-substrate artifact is warn-skipped by `serve` rather than
//! silently served on silicon it was never trained for.

use std::collections::BTreeMap;
use std::path::Path;

use crate::calib::drift::DriftParams;
use crate::coordinator::engine::EngineConfig;
use crate::nn::weights::TrainedModel;
use crate::util::json::Json;

/// Artifact format tag (bump on layout changes).
pub const MODEL_FORMAT: &str = "bss2-model-v1";

/// [`ModelArtifact::parse`] error for a well-formed artifact of a
/// *different* format version — skippable, unlike corruption.
#[derive(Debug)]
pub struct UnsupportedFormat(pub String);

impl std::fmt::Display for UnsupportedFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unsupported model artifact format `{}` (expected {})",
            self.0, MODEL_FORMAT
        )
    }
}

impl std::error::Error for UnsupportedFormat {}

/// A trained model plus the provenance of its training run.
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    /// Substrate identity the model was trained against (0 = ideal).
    pub substrate: u64,
    /// Fleet ordinal of the training chip.
    pub chip: usize,
    /// Chip time the training run consumed [µs].
    pub chip_time_us: u64,
    /// Training seed (data order, init, validation draw).
    pub seed: u64,
    /// The *final* engine FPN seed (post `for_chip` split) — reusing it
    /// verbatim reconstructs the training silicon exactly.
    pub fpn_seed: Option<u64>,
    /// Whether drift advanced during training.
    pub drift: bool,
    /// Whether a fault plan was armed as augmentation.
    pub augmented: bool,
    pub epochs: usize,
    pub batch: usize,
    pub lr: f64,
    pub momentum: f64,
    pub temperature: f64,
    /// Final training metrics (validation rates, loss, step cost).
    pub metrics: BTreeMap<String, f64>,
    /// The trained weights themselves (`bss2-weights-v1` payload).
    pub model: TrainedModel,
}

impl ModelArtifact {
    pub fn to_json(&self) -> String {
        let hex = |v: u64| Json::Str(format!("{v:016x}"));
        let mut m = BTreeMap::new();
        m.insert("format".into(), Json::Str(MODEL_FORMAT.into()));
        // Hex strings, not numbers: u64 identities do not survive the
        // f64 round-trip a JSON number would impose.
        m.insert("substrate".into(), hex(self.substrate));
        m.insert("chip".into(), Json::Num(self.chip as f64));
        m.insert("chip_time_us".into(), Json::Num(self.chip_time_us as f64));
        m.insert("seed".into(), hex(self.seed));
        m.insert(
            "fpn_seed".into(),
            match self.fpn_seed {
                Some(s) => hex(s),
                None => Json::Null,
            },
        );
        m.insert("drift".into(), Json::Bool(self.drift));
        m.insert("augmented".into(), Json::Bool(self.augmented));
        m.insert("epochs".into(), Json::Num(self.epochs as f64));
        m.insert("batch".into(), Json::Num(self.batch as f64));
        m.insert("lr".into(), Json::Num(self.lr));
        m.insert("momentum".into(), Json::Num(self.momentum));
        m.insert("temperature".into(), Json::Num(self.temperature));
        if !self.metrics.is_empty() {
            let metrics = self
                .metrics
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v)))
                .collect();
            m.insert("metrics".into(), Json::Obj(metrics));
        }
        let weights = Json::parse(&self.model.to_json())
            .expect("TrainedModel::to_json emits valid JSON");
        m.insert("weights".into(), weights);
        Json::Obj(m).to_string()
    }

    pub fn parse(text: &str) -> anyhow::Result<ModelArtifact> {
        let j = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("model artifact: {e}"))?;
        // Only a well-formed *string* tag can name another version; a
        // wrong-typed `format` is corruption and fails loudly.
        let format = j
            .req("format")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("format must be a string"))?;
        if format != MODEL_FORMAT {
            return Err(UnsupportedFormat(format.into()).into());
        }
        let uint = |key: &str| -> anyhow::Result<u64> {
            j.req(key)?.as_uint().ok_or_else(|| {
                anyhow::anyhow!("{key} must be a non-negative integer")
            })
        };
        let num = |key: &str| -> anyhow::Result<f64> {
            j.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("{key} must be a number"))
        };
        let hex = |key: &str| -> anyhow::Result<u64> {
            j.req(key)?
                .as_str()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or_else(|| {
                    anyhow::anyhow!("{key} must be a hex identity string")
                })
        };
        let boolean = |key: &str| -> anyhow::Result<bool> {
            match j.req(key)? {
                Json::Bool(b) => Ok(*b),
                _ => anyhow::bail!("{key} must be a boolean"),
            }
        };
        let fpn_seed = match j.req("fpn_seed")? {
            Json::Null => None,
            Json::Str(s) => Some(u64::from_str_radix(s, 16).map_err(|_| {
                anyhow::anyhow!("fpn_seed must be a hex string or null")
            })?),
            _ => anyhow::bail!("fpn_seed must be a hex string or null"),
        };
        let mut metrics = BTreeMap::new();
        if let Some(m) = j.get("metrics").and_then(|m| m.as_obj()) {
            for (k, v) in m {
                if let Some(x) = v.as_f64() {
                    metrics.insert(k.clone(), x);
                }
            }
        }
        let model = TrainedModel::parse(&j.req("weights")?.to_string())?;
        Ok(ModelArtifact {
            substrate: hex("substrate")?,
            chip: uint("chip")? as usize,
            chip_time_us: uint("chip_time_us")?,
            seed: hex("seed")?,
            fpn_seed,
            drift: boolean("drift")?,
            augmented: boolean("augmented")?,
            epochs: uint("epochs")? as usize,
            batch: uint("batch")? as usize,
            lr: num("lr")?,
            momentum: num("momentum")?,
            temperature: num("temperature")?,
            metrics,
            model,
        })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> anyhow::Result<ModelArtifact> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// The engine configuration that reconstructs the training substrate.
    ///
    /// The stamped `fpn_seed` is already the final per-chip value (the
    /// trainer stamps it *after* `for_chip` splitting), so it is used
    /// verbatim — do not split it again.
    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            use_pjrt: false,
            chip: self.chip,
            fpn_seed: self.fpn_seed,
            drift: if self.drift {
                Some(DriftParams::default())
            } else {
                None
            },
            ..EngineConfig::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ModelArtifact {
        let mut metrics = BTreeMap::new();
        metrics.insert("val_det".into(), 0.91);
        metrics.insert("val_fp".into(), 0.07);
        ModelArtifact {
            substrate: 0xdead_beef_cafe_f00d,
            chip: 3,
            chip_time_us: 123_456,
            seed: 42,
            fpn_seed: Some(0xB55C2),
            drift: true,
            augmented: false,
            epochs: 8,
            batch: 16,
            lr: 0.4,
            momentum: 0.9,
            temperature: 8.0,
            metrics,
            model: TrainedModel::synthetic(7),
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let a = sample();
        let b = ModelArtifact::parse(&a.to_json()).unwrap();
        assert_eq!(b.substrate, a.substrate, "identity must roundtrip");
        assert_eq!(b.chip, a.chip);
        assert_eq!(b.chip_time_us, a.chip_time_us);
        assert_eq!(b.seed, a.seed);
        assert_eq!(b.fpn_seed, a.fpn_seed);
        assert_eq!(b.drift, a.drift);
        assert_eq!(b.augmented, a.augmented);
        assert_eq!((b.epochs, b.batch), (a.epochs, a.batch));
        assert_eq!((b.lr, b.momentum, b.temperature), (0.4, 0.9, 8.0));
        assert_eq!(b.metrics, a.metrics);
        for p in 0..3 {
            assert_eq!(
                b.model.pass_weights[p], a.model.pass_weights[p],
                "pass {p} weights must roundtrip bit-exactly"
            );
        }
        assert_eq!(b.model.scales, a.model.scales);
    }

    #[test]
    fn none_fpn_seed_roundtrips() {
        let mut a = sample();
        a.fpn_seed = None;
        let b = ModelArtifact::parse(&a.to_json()).unwrap();
        assert_eq!(b.fpn_seed, None);
        assert_eq!(b.engine_config().fpn_seed, None);
    }

    #[test]
    fn engine_config_reconstructs_training_substrate() {
        let a = sample();
        let cfg = a.engine_config();
        assert!(!cfg.use_pjrt, "training substrate is native-only");
        assert_eq!(cfg.chip, 3);
        assert_eq!(cfg.fpn_seed, Some(0xB55C2), "used verbatim, not re-split");
        assert!(cfg.drift.is_some());
    }

    #[test]
    fn parse_rejects_bad_format_and_types() {
        let a = sample();
        let stale = a.to_json().replace(MODEL_FORMAT, "bss2-model-v0");
        let err = ModelArtifact::parse(&stale).unwrap_err();
        assert!(err.downcast_ref::<UnsupportedFormat>().is_some(), "{err}");
        // Missing format is corruption, not another version.
        let err = ModelArtifact::parse("{}").unwrap_err();
        assert!(err.downcast_ref::<UnsupportedFormat>().is_none(), "{err}");
        // Wrong-typed fields fail loudly.
        for (key, bad) in [
            ("format", Json::Num(42.0)),
            ("drift", Json::Str("yes".into())),
            ("substrate", Json::Num(1.0)),
            ("fpn_seed", Json::Num(1.0)),
            ("epochs", Json::Str("eight".into())),
        ] {
            let mut j = Json::parse(&a.to_json()).unwrap();
            if let Json::Obj(m) = &mut j {
                m.insert(key.into(), bad);
            }
            let err = ModelArtifact::parse(&j.to_string()).unwrap_err();
            assert!(
                err.downcast_ref::<UnsupportedFormat>().is_none(),
                "wrong-typed `{key}` must be corruption: {err}"
            );
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let a = sample();
        let path = std::env::temp_dir().join("bss2_model_artifact_test.json");
        a.save(&path).unwrap();
        let b = ModelArtifact::load(&path).unwrap();
        assert_eq!(b.substrate, a.substrate);
        assert_eq!(b.model.pass_weights[1], a.model.pass_weights[1]);
        let _ = std::fs::remove_file(&path);
    }
}
