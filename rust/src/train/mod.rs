//! Hardware-in-the-loop training against the simulated analog substrate.
//!
//! The real BrainScaleS-2 flow does not train a model and hope it
//! transfers: it trains *through* the hardware (hxtorch, arXiv
//! 2006.13138; Weis et al., arXiv 2006.13177).  Forward passes execute
//! on the chip — fixed-pattern noise, temporal noise, quantisation,
//! drift and all — while the backward pass runs on the host against a
//! straight-through surrogate.  The network thereby learns weights that
//! are robust to the specific non-idealities of the silicon it will
//! serve on, which is what lets the accuracy pin ratchet past the
//! hand-built baselines.
//!
//! Module map:
//!
//! * [`shadow`] — f32 shadow weights, 6-bit projection, SGD-momentum.
//! * [`ste`]    — straight-through estimator across the analog stack.
//! * [`data`]   — seeded windows from [`ContinuousEcg`], held-out val.
//! * [`artifact`] — the versioned `bss2-model-v1` artifact.
//!
//! The whole loop is deterministic per seed: data order, init, noise,
//! drift and fault schedules all derive from explicit seeds, so two
//! runs with the same [`TrainConfig`] produce byte-identical artifacts.
//!
//! [`ContinuousEcg`]: crate::ecg::stream::ContinuousEcg

pub mod artifact;
pub mod data;
pub mod shadow;
pub mod ste;

use crate::coordinator::engine::{Engine, EngineConfig};
use crate::ecg::gen::Trace;
use crate::fault::{FaultInjector, FaultPlan, FAULT_TAG};

use artifact::ModelArtifact;
use data::{shuffle, stream_windows, val_set};
use shadow::{Momentum, ShadowWeights};
use ste::{backward_logistic, Grads};

/// Default FPN seed for training substrates.  Training against *some*
/// fixed-pattern realisation (rather than the ideal substrate) is the
/// point of in-the-loop training; serving reconstructs the same silicon
/// from the artifact's stamped seed.
pub const TRAIN_FPN_SEED: u64 = 0xB55C2;

/// Seed-space splits so data, shuffling and init draw from independent
/// streams of the one user-facing seed.
const DATA_SPLIT: u64 = 0x5D17_A7A5_EC61_39D1;
const SHUFFLE_SPLIT: u64 = 0x94D0_49BB_1331_11EB;
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Full configuration of a training run (everything the artifact needs
/// to stamp for reproducibility).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch: usize,
    /// Training windows cut from the continuous stream.
    pub windows: usize,
    /// Held-out validation traces per rhythm class.
    pub val_per_class: usize,
    pub lr: f64,
    pub momentum: f64,
    /// Logistic-loss temperature [score LSB per logit unit].
    pub temperature: f64,
    /// Master seed: init, data order, stream episodes.
    pub seed: u64,
    /// Per-pass analog scales served with the weights.
    pub scales: [f32; 3],
    /// Uniform init amplitude on the ±63 weight grid.
    pub init_amp: f32,
    /// Validation detection rate that counts as "target reached".
    pub target_det: f64,
    /// Validation false-positive ceiling for the target.
    pub target_fp: f64,
    /// Optional fault plan armed as training-time augmentation
    /// (faulted batches are skipped, surviving ones see the faulted
    /// analog state).
    pub fault_plan: Option<FaultPlan>,
    /// Substrate to train against.  Must be native; the default arms
    /// [`TRAIN_FPN_SEED`] and drift so training sees realistic silicon.
    pub engine: EngineConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 8,
            batch: 16,
            windows: 192,
            val_per_class: 25,
            lr: 0.4,
            momentum: 0.9,
            temperature: 8.0,
            seed: 1,
            scales: [0.2, 0.08, 0.1],
            init_amp: 4.0,
            target_det: 0.85,
            target_fp: 0.15,
            fault_plan: None,
            engine: EngineConfig {
                use_pjrt: false,
                fpn_seed: Some(TRAIN_FPN_SEED),
                drift: Some(Default::default()),
                ..EngineConfig::default()
            },
        }
    }
}

/// Per-run training telemetry (mirrored into the artifact's metrics).
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Mean loss per epoch.
    pub epoch_loss: Vec<f64>,
    /// Validation (detection rate, false-positive rate) per epoch.
    pub epoch_val: Vec<(f64, f64)>,
    pub final_det: f64,
    pub final_fp: f64,
    /// First 1-based epoch whose validation met the target band.
    pub epochs_to_target: Option<usize>,
    /// Chip time per optimizer step [µs] (weight write + batch forward).
    pub chip_us_per_step: f64,
    pub steps: usize,
    /// Batches lost to injected faults (augmentation mode).
    pub skipped_batches: usize,
    /// Training windows per class `[sinus, afib]`.
    pub train_windows: [usize; 2],
}

/// A finished run: the servable artifact plus its telemetry.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub artifact: ModelArtifact,
    pub report: TrainReport,
}

/// The mini-batch training loop.
pub struct Trainer;

impl Trainer {
    /// Run a full training session.  Deterministic per [`TrainConfig`]:
    /// identical configs produce byte-identical artifacts.
    pub fn run(cfg: &TrainConfig) -> anyhow::Result<TrainOutcome> {
        anyhow::ensure!(
            !cfg.engine.use_pjrt,
            "training requires the native backend (gradient taps and \
             weight reload are not wired through PJRT)"
        );
        anyhow::ensure!(cfg.epochs >= 1, "need at least one epoch");
        anyhow::ensure!(cfg.batch >= 1, "need a positive batch size");
        anyhow::ensure!(cfg.windows >= 2, "need at least two windows");
        anyhow::ensure!(cfg.val_per_class >= 1, "need validation traces");

        let mut shadow = ShadowWeights::init(cfg.seed, cfg.init_amp);
        let mut engine =
            Engine::native(shadow.to_model(cfg.scales), cfg.engine.clone());
        let mut augmented = false;
        if let Some(plan) = &cfg.fault_plan {
            if let Some(inj) = FaultInjector::from_plan(plan, cfg.engine.chip)
            {
                engine.arm_faults(inj);
                augmented = true;
            }
        }

        let train = stream_windows(cfg.seed ^ DATA_SPLIT, cfg.windows);
        let val = val_set(cfg.val_per_class);
        let n_afib = train.iter().filter(|t| t.label == 1).count();
        let train_windows = [train.len() - n_afib, n_afib];

        let mut opt = Momentum::new(cfg.lr as f32, cfg.momentum as f32);
        let mut order: Vec<usize> = (0..train.len()).collect();
        let mut report = TrainReport {
            epoch_loss: Vec::with_capacity(cfg.epochs),
            epoch_val: Vec::with_capacity(cfg.epochs),
            final_det: 0.0,
            final_fp: 1.0,
            epochs_to_target: None,
            chip_us_per_step: 0.0,
            steps: 0,
            skipped_batches: 0,
            train_windows,
        };
        let mut train_chip_us = 0u64;

        for epoch in 0..cfg.epochs {
            shuffle(
                &mut order,
                cfg.seed ^ (epoch as u64).wrapping_mul(GOLDEN) ^ SHUFFLE_SPLIT,
            );
            let (mut loss_sum, mut loss_n) = (0.0f64, 0usize);
            for chunk in order.chunks(cfg.batch) {
                let model = shadow.to_model(cfg.scales);
                engine
                    .load_model_weights(&model.pass_weights, model.scales)?;
                let traces: Vec<Trace> =
                    chunk.iter().map(|&i| train[i].clone()).collect();
                let t0 = engine.chip_time_us();
                let (infs, taps) = match engine.classify_batch_taps(&traces) {
                    Ok(out) => out,
                    Err(e) if e.to_string().contains(FAULT_TAG) => {
                        report.skipped_batches += 1;
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                let q = shadow.quantised();
                let mut grads = Grads::zero();
                for ((inf, tap), trace) in
                    infs.iter().zip(&taps).zip(&traces)
                {
                    loss_sum += backward_logistic(
                        tap,
                        &q,
                        cfg.scales,
                        inf.scores,
                        trace.label,
                        cfg.temperature as f32,
                        &mut grads,
                    );
                    loss_n += 1;
                }
                grads.scale(1.0 / chunk.len() as f32);
                opt.step(&mut shadow, &grads);
                report.steps += 1;
                train_chip_us += engine.chip_time_us() - t0;
            }
            report
                .epoch_loss
                .push(loss_sum / loss_n.max(1) as f64);

            // Per-epoch validation on the freshly stepped weights.
            let model = shadow.to_model(cfg.scales);
            engine.load_model_weights(&model.pass_weights, model.scales)?;
            let (det, fp) = validate(&mut engine, &val, cfg.batch)?;
            report.epoch_val.push((det, fp));
            if report.epochs_to_target.is_none()
                && det >= cfg.target_det
                && fp <= cfg.target_fp
            {
                report.epochs_to_target = Some(epoch + 1);
            }
            log::info!(
                "train: epoch {}/{}: loss {:.4} val det {:.3} fp {:.3}",
                epoch + 1,
                cfg.epochs,
                report.epoch_loss[epoch],
                det,
                fp
            );
        }

        let (final_det, final_fp) =
            *report.epoch_val.last().expect("epochs >= 1");
        report.final_det = final_det;
        report.final_fp = final_fp;
        report.chip_us_per_step =
            train_chip_us as f64 / report.steps.max(1) as f64;

        let mut model = shadow.to_model(cfg.scales);
        let metrics = [
            ("val_det", final_det),
            ("val_fp", final_fp),
            ("loss_final", *report.epoch_loss.last().expect("epochs >= 1")),
            (
                "epochs_to_target",
                report.epochs_to_target.map_or(-1.0, |e| e as f64),
            ),
            ("chip_us_per_step", report.chip_us_per_step),
            ("steps", report.steps as f64),
            ("skipped_batches", report.skipped_batches as f64),
            ("windows_sinus", train_windows[0] as f64),
            ("windows_afib", train_windows[1] as f64),
        ];
        for (k, v) in metrics {
            model.train_metrics.insert(k.into(), v);
        }

        let artifact = ModelArtifact {
            substrate: engine
                .substrate_hash()
                .expect("native backend always has a substrate identity"),
            chip: cfg.engine.chip,
            chip_time_us: engine.chip_time_us(),
            seed: cfg.seed,
            fpn_seed: cfg.engine.fpn_seed,
            drift: cfg.engine.drift.is_some(),
            augmented,
            epochs: cfg.epochs,
            batch: cfg.batch,
            lr: cfg.lr,
            momentum: cfg.momentum,
            temperature: cfg.temperature,
            metrics: model.train_metrics.clone(),
            model,
        };
        Ok(TrainOutcome { artifact, report })
    }
}

/// Detection rate (afib recall) and false-positive rate (sinus windows
/// flagged afib) over a labelled trace set.  Faulted batches are skipped
/// — the rates are over the traces that actually classified.
fn validate(
    engine: &mut Engine,
    val: &[Trace],
    batch: usize,
) -> anyhow::Result<(f64, f64)> {
    let (mut det_hit, mut det_n) = (0usize, 0usize);
    let (mut fp_hit, mut fp_n) = (0usize, 0usize);
    for chunk in val.chunks(batch.max(1)) {
        let infs = match engine.classify_batch(chunk) {
            Ok(infs) => infs,
            Err(e) if e.to_string().contains(FAULT_TAG) => continue,
            Err(e) => return Err(e),
        };
        for (inf, trace) in infs.iter().zip(chunk) {
            if trace.label == 1 {
                det_n += 1;
                det_hit += usize::from(inf.pred == 1);
            } else {
                fp_n += 1;
                fp_hit += usize::from(inf.pred == 1);
            }
        }
    }
    Ok((
        det_hit as f64 / det_n.max(1) as f64,
        fp_hit as f64 / fp_n.max(1) as f64,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_pjrt_substrate() {
        let cfg = TrainConfig {
            engine: EngineConfig::default(), // use_pjrt: true
            ..TrainConfig::default()
        };
        let err = Trainer::run(&cfg).unwrap_err();
        assert!(err.to_string().contains("native backend"), "{err}");
    }

    #[test]
    fn default_config_arms_realistic_substrate() {
        let cfg = TrainConfig::default();
        assert!(!cfg.engine.use_pjrt);
        assert_eq!(cfg.engine.fpn_seed, Some(TRAIN_FPN_SEED));
        assert!(cfg.engine.drift.is_some());
    }
}
