//! Host-side shadow weights and the optimizer that moves them.
//!
//! The chip only ever sees 6-bit weights; the learning signal is far
//! finer-grained than one weight LSB per step.  Hardware-in-the-loop
//! training (hxtorch, arXiv:2006.13138) therefore keeps a full-precision
//! *shadow* copy of every logical weight on the host: forward passes run
//! on the quantised projection ([`ShadowWeights::quantised`] →
//! [`ShadowWeights::to_model`]), gradients accumulate into the f32
//! shadow, and the projection is rewritten onto the chip each step
//! (`Engine::load_model_weights`).  Rounding is treated as identity by
//! the straight-through estimator in [`super::ste`].

use crate::asic::consts as c;
use crate::nn::mapping;
use crate::nn::weights::TrainedModel;
use crate::util::rng::SplitMix64;

/// Logical-layout f32 weights (same shapes the `weights.json` exporter
/// uses: conv `[C_OUT][C_IN][K]`, fc1 `[K_LOGICAL][FC1_OUT]`, fc2
/// `[FC1_OUT][FC2_OUT]`).
#[derive(Debug, Clone)]
pub struct ShadowWeights {
    pub wc: Vec<f32>,
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
}

/// The quantised (on-grid) projection the forward pass executes — also
/// the weights the straight-through estimator differentiates through
/// when it back-propagates activations.
#[derive(Debug, Clone)]
pub struct QuantWeights {
    pub wc: Vec<f32>,
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
}

/// Project one shadow value onto the 6-bit synapse grid.
#[inline]
fn quantise(v: f32) -> f32 {
    v.round().clamp(-(c::W_MAX as f32), c::W_MAX as f32)
}

impl ShadowWeights {
    /// Seeded uniform init in `[-amp, amp]` per logical weight.  Small
    /// relative to the ±63 grid: the first quantised projections carry a
    /// few LSB of structure, enough to break symmetry without driving
    /// any ADC column into its rail before training starts.
    pub fn init(seed: u64, amp: f32) -> ShadowWeights {
        let mut rng = SplitMix64::new(seed);
        let mut draw = |n: usize| -> Vec<f32> {
            (0..n)
                .map(|_| rng.uniform(-(amp as f64), amp as f64) as f32)
                .collect()
        };
        ShadowWeights {
            wc: draw(c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL),
            w1: draw(c::K_LOGICAL * c::FC1_OUT),
            w2: draw(c::FC1_OUT * c::FC2_OUT),
        }
    }

    /// The on-grid projection the chip executes.
    pub fn quantised(&self) -> QuantWeights {
        QuantWeights {
            wc: self.wc.iter().map(|&v| quantise(v)).collect(),
            w1: self.w1.iter().map(|&v| quantise(v)).collect(),
            w2: self.w2.iter().map(|&v| quantise(v)).collect(),
        }
    }

    /// Pack the quantised projection into a servable model (nominal
    /// calibration vectors — under an `fpn_seed` the engine draws its own
    /// silicon, and without one nominal vectors mean an ideal substrate).
    pub fn to_model(&self, scales: [f32; 3]) -> TrainedModel {
        let q = self.quantised();
        TrainedModel {
            pass_weights: [
                mapping::pack_conv(&q.wc),
                mapping::pack_fc1(&q.w1),
                mapping::pack_fc2(&q.w2),
            ],
            scales,
            gain: [vec![1.0; c::N_COLS], vec![1.0; c::N_COLS]],
            offset: [vec![0.0; c::N_COLS], vec![0.0; c::N_COLS]],
            noise_sigma: c::NOISE_SIGMA,
            train_metrics: Default::default(),
        }
    }
}

/// SGD with momentum over the shadow weights, with per-layer RMS
/// gradient normalisation.  The three layers sit behind very different
/// effective gains (each analog stage multiplies by its `scale` and
/// requantises), so raw gradient magnitudes differ by orders of
/// magnitude between conv and fc2; normalising each layer's gradient to
/// unit RMS makes `lr` mean "weight-grid units per step" uniformly —
/// the robust choice on a ±63 integer grid.
#[derive(Debug, Clone)]
pub struct Momentum {
    lr: f32,
    mu: f32,
    vc: Vec<f32>,
    v1: Vec<f32>,
    v2: Vec<f32>,
}

impl Momentum {
    pub fn new(lr: f32, mu: f32) -> Momentum {
        Momentum {
            lr,
            mu,
            vc: vec![0.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL],
            v1: vec![0.0; c::K_LOGICAL * c::FC1_OUT],
            v2: vec![0.0; c::FC1_OUT * c::FC2_OUT],
        }
    }

    fn layer(w: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, mu: f32) {
        let ms: f64 = g.iter().map(|&x| x as f64 * x as f64).sum::<f64>()
            / g.len().max(1) as f64;
        // A silent layer (all gradients masked) takes no step.
        let s = if ms > 1e-24 { (1.0 / ms.sqrt()) as f32 } else { 0.0 };
        for ((wi, vi), &gi) in w.iter_mut().zip(v.iter_mut()).zip(g) {
            *vi = mu * *vi - lr * gi * s;
            *wi = (*wi + *vi).clamp(-(c::W_MAX as f32), c::W_MAX as f32);
        }
    }

    /// One descent step from accumulated (batch-averaged) gradients.
    pub fn step(&mut self, w: &mut ShadowWeights, g: &super::ste::Grads) {
        Self::layer(&mut w.wc, &mut self.vc, &g.wc, self.lr, self.mu);
        Self::layer(&mut w.w1, &mut self.v1, &g.w1, self.lr, self.mu);
        Self::layer(&mut w.w2, &mut self.v2, &g.w2, self.lr, self.mu);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_seeded_and_bounded() {
        let a = ShadowWeights::init(7, 4.0);
        let b = ShadowWeights::init(7, 4.0);
        assert_eq!(a.wc, b.wc);
        assert_eq!(a.w1, b.w1);
        assert_ne!(a.wc, ShadowWeights::init(8, 4.0).wc, "seed matters");
        assert!(a.wc.iter().chain(&a.w1).chain(&a.w2).all(|v| v.abs() <= 4.0));
    }

    #[test]
    fn quantised_projection_is_on_grid() {
        let mut s = ShadowWeights::init(1, 4.0);
        s.w2[0] = 70.0;
        s.w2[1] = -2.4;
        let q = s.quantised();
        assert_eq!(q.w2[0], c::W_MAX as f32, "clamped to the grid");
        assert_eq!(q.w2[1], -2.0, "rounded to the grid");
        for v in q.wc.iter().chain(&q.w1).chain(&q.w2) {
            assert!(*v == v.trunc() && v.abs() <= c::W_MAX as f32);
        }
        // The packed model passes the strict weights.json parser.
        let m = s.to_model([0.2, 0.08, 0.1]);
        assert!(crate::nn::weights::TrainedModel::parse(&m.to_json()).is_ok());
    }

    #[test]
    fn momentum_moves_weights_toward_negative_gradient() {
        let mut w = ShadowWeights::init(2, 0.0); // all zero
        let mut opt = Momentum::new(0.5, 0.9);
        let mut g = crate::train::ste::Grads::zero();
        g.w2[3] = 1.0; // unit-RMS normalisation acts per layer
        let before = w.w2[3];
        opt.step(&mut w, &g);
        assert!(w.w2[3] < before, "descends against the gradient");
        // Momentum keeps moving with a zero gradient.
        let pos = w.w2[3];
        opt.step(&mut w, &crate::train::ste::Grads::zero());
        assert!(w.w2[3] < pos, "momentum carries the step");
        // And a silent layer never moves.
        assert!(w.wc.iter().all(|&v| v == 0.0));
    }
}
