//! Straight-through estimator across the analog substrate.
//!
//! The forward pass runs on the simulated chip; nothing in it is
//! differentiable (6-bit weights, 5-bit activations, ADC saturation,
//! integer requantisation).  The backward pass therefore differentiates
//! a *surrogate*: each analog matmul is treated as the linear map
//! `adc ≈ scale · Wᵠ x` through the quantised weights, with two masks
//! applied where the hardware clips (hxtorch's approach, arXiv
//! 2006.13138):
//!
//! * **rail** — an ADC column pinned at `ADC_MIN`/`ADC_MAX` passes no
//!   gradient (saturated amplifier).
//! * **requant** — the `Relu → >>RELU_SHIFT → clamp(0, X_MAX)` stage has
//!   surrogate slope `1/2^RELU_SHIFT` on its linear segment and zero
//!   outside it (straight-through across the floor rounding).
//!
//! Weight quantisation itself is straight-through: gradients land on the
//! f32 shadow weights as if rounding were identity.
//!
//! Index conventions mirror `nn/mapping.rs` exactly — the gradient of a
//! packed Toeplitz cell is accumulated onto its *logical* conv tap, once
//! per replicated position.

use crate::asic::consts as c;
use crate::coordinator::engine::PassTap;

use super::shadow::QuantWeights;

/// Per-layer gradient accumulators in logical layout (same shapes as
/// [`super::shadow::ShadowWeights`]).
#[derive(Debug, Clone)]
pub struct Grads {
    pub wc: Vec<f32>,
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
}

impl Grads {
    pub fn zero() -> Grads {
        Grads {
            wc: vec![0.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL],
            w1: vec![0.0; c::K_LOGICAL * c::FC1_OUT],
            w2: vec![0.0; c::FC1_OUT * c::FC2_OUT],
        }
    }

    /// Scale all accumulators (batch averaging).
    pub fn scale(&mut self, s: f32) {
        for g in self
            .wc
            .iter_mut()
            .chain(self.w1.iter_mut())
            .chain(self.w2.iter_mut())
        {
            *g *= s;
        }
    }
}

/// Saturation mask: a railed ADC column passes no gradient.
#[inline]
fn rail(adc: i32) -> f32 {
    if adc > c::ADC_MIN && adc < c::ADC_MAX {
        1.0
    } else {
        0.0
    }
}

/// Surrogate slope of `relu(pre) >> RELU_SHIFT` clamped to `0..=X_MAX`,
/// given the pre-activation and the 5-bit activation it produced.
#[inline]
fn requant(pre: i32, x: u8) -> f32 {
    if pre > 0 && (x as i32) < c::X_MAX {
        1.0 / (1 << c::RELU_SHIFT) as f32
    } else {
        0.0
    }
}

/// Back-propagate a gradient on the two class scores through the three
/// captured passes, accumulating weight gradients into `grads`.
///
/// `g_scores[q]` is ∂L/∂score(class q).  Scores average 5 fc2 columns
/// with round-to-nearest; the surrogate treats the rounding as identity
/// (slope 1/5 per column).
pub fn backward_scores(
    tap: &[PassTap; 3],
    q: &QuantWeights,
    scales: [f32; 3],
    g_scores: [f32; 2],
    grads: &mut Grads,
) {
    // --- output stage: scores → fc2 ADC columns (246..256) -----------
    let mut g_adc2 = [0.0f32; c::FC2_OUT];
    for (qi, ga) in g_adc2.iter_mut().enumerate() {
        let cls = qi / (c::FC2_OUT / 2);
        *ga = g_scores[cls] / (c::FC2_OUT / 2) as f32
            * rail(tap[2].adc[2 * c::FC1_OUT + qi]);
    }
    if g_adc2.iter().all(|&g| g == 0.0) {
        return;
    }

    // --- pass 2 (fc2): x2 = tap[2].x, w2 [FC1_OUT][FC2_OUT] ----------
    let mut dx2 = vec![0.0f32; c::FC1_OUT];
    for r in 0..c::FC1_OUT {
        let x2 = tap[2].x[r] as f32;
        let mut acc = 0.0f32;
        for (j, &ga) in g_adc2.iter().enumerate() {
            let g = ga * scales[2];
            grads.w2[r * c::FC2_OUT + j] += g * x2;
            acc += g * q.w2[r * c::FC2_OUT + j];
        }
        dx2[r] = acc;
    }

    // --- requant + partial-sum split back onto pass-1 ADC columns ----
    // x2[j] came from relu-shift of psum[j] = adc1[j] + adc1[123+j].
    let mut g_adc1 = vec![0.0f32; 2 * c::FC1_OUT];
    for j in 0..c::FC1_OUT {
        let pre = tap[1].adc[j] + tap[1].adc[c::FC1_OUT + j];
        let g_ps = dx2[j] * requant(pre, tap[2].x[j]);
        g_adc1[j] = g_ps * rail(tap[1].adc[j]);
        g_adc1[c::FC1_OUT + j] = g_ps * rail(tap[1].adc[c::FC1_OUT + j]);
    }

    // --- pass 1 (fc1): x1 = tap[1].x, w1 [K_LOGICAL][FC1_OUT], two
    // column blocks selected by the input row ------------------------
    let mut dx1 = vec![0.0f32; c::K_LOGICAL];
    for r in 0..c::K_LOGICAL {
        let block = if r < c::K_SIGNED { 0 } else { c::FC1_OUT };
        let x1 = tap[1].x[r] as f32;
        let mut acc = 0.0f32;
        for j in 0..c::FC1_OUT {
            let g = g_adc1[block + j] * scales[1];
            grads.w1[r * c::FC1_OUT + j] += g * x1;
            acc += g * q.w1[r * c::FC1_OUT + j];
        }
        dx1[r] = acc;
    }

    // --- requant back onto pass-0 ADC columns ------------------------
    let mut g_adc0 = vec![0.0f32; c::K_LOGICAL];
    for (k, ga) in g_adc0.iter_mut().enumerate() {
        let adc = tap[0].adc[k];
        *ga = dx1[k] * requant(adc, tap[1].x[k]) * rail(adc);
    }

    // --- pass 0 (conv): mirror pack_conv's Toeplitz loops, folding
    // each placed cell's gradient onto its logical tap ----------------
    let x0 = &tap[0].x;
    for p in 0..c::CONV_POSITIONS {
        let start = p as isize * c::CONV_STRIDE as isize - c::CONV_PAD as isize;
        for o in 0..c::CONV_CHANNELS {
            let ga = g_adc0[p * c::CONV_CHANNELS + o];
            if ga == 0.0 {
                continue;
            }
            let g = ga * scales[0];
            for ch in 0..c::ECG_CHANNELS {
                for t in 0..c::CONV_KERNEL {
                    let ti = start + t as isize;
                    if ti >= 0 && (ti as usize) < c::POOLED_LEN {
                        let row = ch * c::POOLED_LEN + ti as usize;
                        grads.wc[(o * c::ECG_CHANNELS + ch) * c::CONV_KERNEL
                            + t] += g * x0[row] as f32;
                    }
                }
            }
        }
    }
}

/// Logistic loss on the score margin: `z = (s1 − s0)/T`,
/// `p = σ(z)`, `L = −ln p(label)`.  Back-propagates through
/// [`backward_scores`] and returns the loss value.
pub fn backward_logistic(
    tap: &[PassTap; 3],
    q: &QuantWeights,
    scales: [f32; 3],
    scores: [f32; 2],
    label: u8,
    temperature: f32,
    grads: &mut Grads,
) -> f64 {
    let z = ((scores[1] - scores[0]) / temperature) as f64;
    // lint:allow(det-float-intrinsic: logistic loss; libm exp is deterministic per build)
    let p = 1.0 / (1.0 + (-z).exp());
    let y = label as f64;
    let g = ((p - y) / temperature as f64) as f32;
    backward_scores(tap, q, scales, [-g, g], grads);
    let likelihood = if label == 1 { p } else { 1.0 - p };
    // lint:allow(det-float-intrinsic: libm ln, same libm on every host this artifact targets)
    -likelihood.max(1e-12).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A hand-made tap: every ADC in-range, every activation mid-range,
    /// so every mask is open and the fc2 gradient has a closed form.
    fn open_tap() -> [PassTap; 3] {
        let mk = |x: u8, adc: i32| PassTap {
            x: vec![x; c::K_LOGICAL],
            adc: vec![adc; c::N_COLS],
        };
        // pass-1 psum = 5 + 5 = 10 > 0, activations 2 < X_MAX: open.
        [mk(3, 5), mk(2, 5), mk(2, 5)]
    }

    fn unit_quant() -> QuantWeights {
        QuantWeights {
            wc: vec![1.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL],
            w1: vec![1.0; c::K_LOGICAL * c::FC1_OUT],
            w2: vec![1.0; c::FC1_OUT * c::FC2_OUT],
        }
    }

    #[test]
    fn fc2_gradient_matches_closed_form() {
        let tap = open_tap();
        let mut grads = Grads::zero();
        backward_scores(&tap, &unit_quant(), [0.2, 0.08, 0.1], [-1.0, 1.0], &mut grads);
        // dw2[r, j] = g_scores[j/5]/5 · scale2 · x2[r]; x2 = 2.
        let want = -1.0 / 5.0 * 0.1 * 2.0;
        assert!((grads.w2[0] - want).abs() < 1e-6, "{} vs {want}", grads.w2[0]);
        // Class-1 columns carry the opposite sign.
        assert!((grads.w2[c::FC2_OUT - 1] + want).abs() < 1e-6);
        // Gradient reached every layer.
        assert!(grads.w1.iter().any(|&g| g != 0.0));
        assert!(grads.wc.iter().any(|&g| g != 0.0));
    }

    #[test]
    fn railed_outputs_pass_no_gradient() {
        let mut tap = open_tap();
        // Rail every fc2 output column.
        for j in 0..c::FC2_OUT {
            tap[2].adc[2 * c::FC1_OUT + j] = c::ADC_MAX;
        }
        let mut grads = Grads::zero();
        backward_scores(&tap, &unit_quant(), [0.2, 0.08, 0.1], [-1.0, 1.0], &mut grads);
        assert!(grads.wc.iter().all(|&g| g == 0.0));
        assert!(grads.w1.iter().all(|&g| g == 0.0));
        assert!(grads.w2.iter().all(|&g| g == 0.0));
    }

    #[test]
    fn closed_requant_blocks_earlier_layers() {
        let mut tap = open_tap();
        // Saturated pass-1 activations: x2 at X_MAX closes the requant
        // mask between fc1 and fc2; fc2 still gets a weight gradient.
        tap[2].x = vec![c::X_MAX as u8; c::K_LOGICAL];
        let mut grads = Grads::zero();
        backward_scores(&tap, &unit_quant(), [0.2, 0.08, 0.1], [-1.0, 1.0], &mut grads);
        assert!(grads.w2.iter().any(|&g| g != 0.0));
        assert!(grads.w1.iter().all(|&g| g == 0.0));
        assert!(grads.wc.iter().all(|&g| g == 0.0));
    }

    #[test]
    fn logistic_loss_is_confidence_calibrated() {
        let tap = open_tap();
        let q = unit_quant();
        let scales = [0.2, 0.08, 0.1];
        let mut g = Grads::zero();
        // Equal scores → p = 0.5 → loss = ln 2 for either label.
        let l = backward_logistic(&tap, &q, scales, [10.0, 10.0], 1, 8.0, &mut g);
        assert!((l - std::f64::consts::LN_2).abs() < 1e-9);
        // Confidently correct → small loss; wrong → large loss.
        let mut g2 = Grads::zero();
        let lc = backward_logistic(&tap, &q, scales, [0.0, 40.0], 1, 8.0, &mut g2);
        let mut g3 = Grads::zero();
        let lw = backward_logistic(&tap, &q, scales, [40.0, 0.0], 1, 8.0, &mut g3);
        assert!(lc < l && l < lw, "{lc} < {l} < {lw}");
    }
}
