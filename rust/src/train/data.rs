//! Deterministic training data from the continuous ECG stream.
//!
//! Training windows are cut from a seeded [`ContinuousEcg`] episode
//! stream (the same generator the serving path replays), labelled by the
//! stream's own episode schedule via `afib_fraction`.  Validation uses
//! `generate_trace` on a held-out seed range far from both the training
//! stream and the accuracy-pin seeds (10_000/20_000), so the per-epoch
//! metric is measured on rhythms the optimizer never saw.

use crate::asic::consts as c;
use crate::ecg::gen::{self, Trace};
use crate::ecg::stream::{ContinuousEcg, EpisodeConfig};
use crate::util::rng::SplitMix64;

/// Held-out validation seed bases (sinus / afib).  Distinct from the
/// training stream and from `tests/accuracy_regression.rs`'s pin seeds.
pub const VAL_SINUS_BASE: u64 = 30_000;
pub const VAL_AFIB_BASE: u64 = 40_000;

/// Cut `n` labelled windows from a seeded continuous stream.
///
/// Windows hop by half a window; one is kept when the episode schedule
/// covers ≥ 75 % of it with one rhythm (label 1 for afib, 0 for sinus).
/// Mixed windows are dropped — the boundary is genuinely ambiguous.
/// Deterministic per seed: same seed, same `n` → identical traces.
pub fn stream_windows(seed: u64, n: usize) -> Vec<Trace> {
    let cfg = EpisodeConfig {
        lead_in_s: 16.0,
        sinus_s: (16.0, 26.0),
        afib_s: (16.0, 26.0),
    };
    let mut s = ContinuousEcg::new(seed, 1.0, cfg);
    let mut raw: Vec<Vec<u16>> = vec![Vec::new(); c::ECG_CHANNELS];
    let mut out = Vec::with_capacity(n);
    let mut start = 0usize;
    let hop = c::ECG_WINDOW / 2;
    while out.len() < n {
        while raw[0].len() < start + c::ECG_WINDOW {
            let chunk = s.next_chunk(4 * c::ECG_WINDOW);
            for (buf, ch) in raw.iter_mut().zip(chunk) {
                buf.extend(ch);
            }
        }
        let frac = s.afib_fraction(start as u64, c::ECG_WINDOW as u64);
        if !(0.25..=0.75).contains(&frac) {
            let samples: Vec<Vec<u16>> = raw
                .iter()
                .map(|ch| ch[start..start + c::ECG_WINDOW].to_vec())
                .collect();
            out.push(Trace {
                samples,
                label: u8::from(frac > 0.75),
            });
        }
        start += hop;
    }
    out
}

/// Held-out validation set: `per_class` traces per rhythm class,
/// interleaved sinus/afib so truncation stays balanced.
pub fn val_set(per_class: usize) -> Vec<Trace> {
    let mut out = Vec::with_capacity(2 * per_class);
    for i in 0..per_class {
        out.push(gen::generate_trace(VAL_SINUS_BASE + i as u64, false, 1.0));
        out.push(gen::generate_trace(VAL_AFIB_BASE + i as u64, true, 1.0));
    }
    out
}

/// Seeded Fisher–Yates shuffle of an index order (per-epoch data order).
pub fn shuffle(order: &mut [usize], seed: u64) {
    let mut rng = SplitMix64::new(seed);
    for i in (1..order.len()).rev() {
        let j = rng.below(i as u64 + 1) as usize;
        order.swap(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_windows_are_deterministic_and_labelled() {
        let a = stream_windows(5, 12);
        let b = stream_windows(5, 12);
        assert_eq!(a.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.samples, y.samples);
            assert_eq!(x.label, y.label);
        }
        for t in &a {
            assert_eq!(t.samples.len(), c::ECG_CHANNELS);
            assert_eq!(t.samples[0].len(), c::ECG_WINDOW);
        }
        // The episode schedule alternates rhythms, so a modest harvest
        // contains both classes.
        assert!(a.iter().any(|t| t.label == 0), "sinus windows present");
        assert!(a.iter().any(|t| t.label == 1), "afib windows present");
        // A different seed cuts different signal.
        let c2 = stream_windows(6, 12);
        assert!(a.iter().zip(&c2).any(|(x, y)| x.samples != y.samples));
    }

    #[test]
    fn val_set_is_balanced_and_off_pin_seeds() {
        let v = val_set(4);
        assert_eq!(v.len(), 8);
        assert_eq!(v.iter().filter(|t| t.label == 1).count(), 4);
        assert_eq!(v.iter().filter(|t| t.label == 0).count(), 4);
    }

    #[test]
    fn shuffle_is_seeded_permutation() {
        let mut a: Vec<usize> = (0..50).collect();
        let mut b: Vec<usize> = (0..50).collect();
        shuffle(&mut a, 9);
        shuffle(&mut b, 9);
        assert_eq!(a, b);
        let mut c2: Vec<usize> = (0..50).collect();
        shuffle(&mut c2, 10);
        assert_ne!(a, c2);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
