//! Multi-chip fleet scheduler: shard inference across a pool of BSS-2
//! engine replicas.
//!
//! The paper serves one ECG trace at a time on a single mobile unit
//! (276 µs/inference, batch-size-1, §II-D/§IV).  This layer scales that
//! *out* — the way hxtorch partitions larger networks across multiple
//! BrainScaleS-2 substrates — by running N independent engine replicas,
//! each a faithful single-unit simulation with its own worker thread,
//! noise seed, and calibration state.  Single-trace semantics (timing,
//! energy, accuracy accounting) stay bit-identical to the paper; only
//! aggregate throughput changes.  Batched requests (`classify_batch`)
//! keep per-sample *predictions* bit-identical while amortising timing
//! and energy over the batch (DESIGN.md §9).
//!
//! * [`pool`] — replica lifecycle: worker threads, engine construction
//!   via builder closures (PJRT handles are not `Send`), drain/join.
//! * [`scheduler`] — least-loaded admission with a bounded per-chip
//!   queue (accounted in samples: a classify_batch of B occupies B
//!   slots, and a batch that only partially fits is partially admitted)
//!   and explicit shed (backpressure) responses.
//! * [`health`] — per-chip served/error/latency counters and the
//!   unhealthy → drain → re-admit state machine (plus the
//!   `Calibrating` drain state and calibration-age counters).
//! * [`telemetry`] — fleet-wide latency histogram (p50/p95/p99) and
//!   per-chip throughput, cross-checked against `util::stats`.
//!
//! The calibration loop (`calib` subsystem) is fleet-integrated here:
//! `FleetConfig::recalib` arms an age-/margin-triggered policy under
//! which the pool drains one replica at a time into
//! `ChipState::Calibrating` (no regular work, no probes), re-measures its
//! profile on the worker, and re-admits it — while the rest of the pool
//! keeps serving.
//!
//! Streaming sessions (`stream_*` wire commands, DESIGN.md §11) dispatch
//! preprocessed activation *frames* through [`FleetCore::dispatch_acts`]:
//! the FPGA-side incremental windower already ran, so the chip only
//! executes the three analog passes.  Frames are accounted exactly like
//! single-trace requests (one sample each).
//!
//! **Transparent failover** (DESIGN.md §12): a job whose engine call
//! fails — organically or via an injected fault (`fault` subsystem,
//! `FleetConfig::fault_plan`) — is re-dispatched by the failing worker
//! onto the least-loaded healthy sibling, bounded by
//! `FleetConfig::redirects` hops.  The reply channel travels with the
//! job, so the service's ordered-reply writer delivers the eventual
//! result in the original request order; only when the budget runs out
//! (or no sibling is dispatchable) does the error reach the client.
//! Chips that keep failing are quarantined (`Unhealthy`) and
//! periodically re-probed, which is how *transient* whole-chip faults
//! heal back into rotation.
//!
//! `coordinator::service` dispatches through a [`Fleet`]; `repro serve
//! --chips N` sizes it from the CLI.

pub mod health;
pub mod pool;
pub mod scheduler;
pub mod telemetry;

pub use health::{ChipHealth, ChipHealthSnapshot, ChipState};
pub use pool::{
    BatchDispatchOutcome, CalibReply, ChipId, ChipReply, DispatchOutcome,
    Fleet, FleetConfig, FleetCore, ReplyNotify,
};
pub use scheduler::ShedReason;
pub use telemetry::{FleetTelemetry, LatencyHistogram, TelemetrySnapshot};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{Engine, EngineConfig};
    use crate::nn::weights::TrainedModel;

    fn native_fleet(chips: usize, queue_depth: usize) -> Fleet {
        Fleet::start(
            FleetConfig { chips, queue_depth, ..Default::default() },
            |chip| {
                Ok(Engine::native(
                    TrainedModel::synthetic(0xF1EE7),
                    EngineConfig {
                        use_pjrt: false,
                        noise_off: true,
                        ..Default::default()
                    }
                    .for_chip(chip),
                ))
            },
        )
        .unwrap()
    }

    #[test]
    fn fleet_starts_and_serves_one_trace() {
        let fleet = native_fleet(2, 8);
        assert_eq!(fleet.size(), 2);
        assert_eq!(fleet.healthy_count(), 2);
        let trace = crate::ecg::gen::generate_trace(3, true, 1.0);
        let (chip, inf) = fleet.classify_blocking(&trace).unwrap();
        assert!(chip < 2);
        assert!(inf.pred <= 1);
        assert!(inf.sim_time_s > 100e-6);
        assert_eq!(fleet.telemetry().served(), 1);
        fleet.shutdown();
    }

    #[test]
    fn all_chip_init_failures_fail_start() {
        let err = Fleet::start(
            FleetConfig { chips: 2, ..Default::default() },
            |_chip| anyhow::bail!("no substrate"),
        )
        .err()
        .expect("must fail");
        assert!(err.to_string().contains("no substrate"), "{err}");
    }

    #[test]
    fn partial_init_failure_leaves_survivors_serving() {
        let fleet = Fleet::start(
            FleetConfig { chips: 3, queue_depth: 8, ..Default::default() },
            |chip| {
                anyhow::ensure!(chip != 1, "chip 1 substrate missing");
                Ok(Engine::native(
                    TrainedModel::synthetic(1),
                    EngineConfig {
                        use_pjrt: false,
                        noise_off: true,
                        ..Default::default()
                    },
                ))
            },
        )
        .unwrap();
        assert_eq!(fleet.healthy_count(), 2);
        let snaps = fleet.chip_snapshots();
        assert_eq!(snaps[1].state, ChipState::Dead);
        let trace = crate::ecg::gen::generate_trace(5, false, 1.0);
        for _ in 0..4 {
            let (chip, _) = fleet.classify_blocking(&trace).unwrap();
            assert_ne!(chip, 1, "dead chip must not serve");
        }
        fleet.shutdown();
    }

    #[test]
    fn stats_json_is_valid_and_complete() {
        let fleet = native_fleet(2, 8);
        let trace = crate::ecg::gen::generate_trace(7, true, 1.0);
        for _ in 0..3 {
            fleet.classify_blocking(&trace).unwrap();
        }
        let j = crate::util::json::Json::parse(&fleet.stats_json()).unwrap();
        assert_eq!(j.get("ok"), Some(&crate::util::json::Json::Bool(true)));
        assert_eq!(j.get("chips").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("served").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(j.get("calibrating").and_then(|v| v.as_usize()), Some(0));
        let per_chip = j.get("per_chip").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(per_chip.len(), 2);
        // Calibration fields are reported per chip.
        assert!(per_chip[0].get("calib_age_us").is_some());
        assert!(per_chip[0].get("residual_rms").is_some());
        assert!(per_chip[0].get("recalibrations").is_some());
        assert!(j.get("p99_us").and_then(|v| v.as_f64()).unwrap() > 0.0);
        fleet.shutdown();
    }

    #[test]
    fn manual_recalibration_drains_and_readmits() {
        let fleet = native_fleet(2, 8);
        let trace = crate::ecg::gen::generate_trace(9, false, 1.0);
        fleet.classify_blocking(&trace).unwrap();
        let rx = fleet.recalibrate_chip(0, 16).unwrap();
        // The drain state is set synchronously by `recalibrate_chip`; by
        // the time we look, the worker may already have finished and
        // re-admitted the chip — both observations are valid, anything
        // else is a state-machine bug.
        let s0 = fleet.chip_snapshots()[0].clone();
        assert!(
            s0.state == ChipState::Calibrating
                || (s0.state == ChipState::Healthy && s0.recalibrations == 1),
            "unexpected state {:?}",
            s0.state
        );
        // The pool keeps serving while chip 0 drains.  (The scheduler-
        // level guarantee that a Calibrating chip is never picked is
        // deterministic and lives in `scheduler::tests`; here we only
        // assert the race-safe direction: a job that DID land on chip 0
        // implies the chip had already been re-admitted.)
        for _ in 0..8 {
            let (chip, _) = fleet.classify_blocking(&trace).unwrap();
            if chip == 0 {
                assert_ne!(
                    fleet.chip_snapshots()[0].state,
                    ChipState::Calibrating,
                    "calibrating chip was dispatched work"
                );
            }
        }
        let reply = rx.recv().expect("calibration reply");
        assert_eq!(reply.chip, 0);
        let (stamp, residual) = reply.result.expect("calibration succeeds");
        assert!(stamp > 0, "measurement consumed chip time");
        assert!(residual >= 0.0);
        assert_eq!(fleet.recalibration_count(), 1);
        let snap = &fleet.chip_snapshots()[0];
        assert_eq!(snap.state, ChipState::Healthy, "re-admitted");
        assert_eq!(snap.recalibrations, 1);
        // Out-of-range chips are rejected up front.
        assert!(fleet.recalibrate_chip(5, 4).is_err());
        fleet.shutdown();
    }
}
