//! Per-chip health tracking: served/error/latency counters and the
//! unhealthy → drain → re-admit state machine.
//!
//! A replica that keeps failing (engine errors, worker channel gone) is
//! marked [`ChipState::Unhealthy`]: the scheduler stops admitting new work
//! while jobs already queued on the replica drain normally.  Unhealthy
//! chips are periodically *probed* (one real request routed to them); a
//! success re-admits the chip.  A chip whose engine never constructed, or
//! whose worker thread died, is [`ChipState::Dead`] and never re-admitted.
//!
//! [`ChipState::Calibrating`] is the planned counterpart of Unhealthy: the
//! pool takes a healthy replica out of rotation (drain → calibrate →
//! re-admit, `calib::scheduler` policy), during which the scheduler must
//! route it *neither* regular work *nor* probes.  Health additionally
//! carries the chip-time counters the policy reads: the engine's served
//! chip time, the stamp of the last applied calibration, and the worst
//! residual of that profile's fit.

use std::sync::atomic::{
    AtomicBool, AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering,
};
use std::sync::Mutex;

use crate::util::sync::lock_clean;

/// Replica lifecycle state (stored as an `AtomicU8`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChipState {
    /// Admitting work normally.
    Healthy,
    /// Too many consecutive errors: draining, probe-only admission.
    Unhealthy,
    /// Engine init failed or worker gone: never dispatched again.
    Dead,
    /// Drained out of rotation for recalibration: no regular work, no
    /// probes, until the measurement finishes.
    Calibrating,
}

impl ChipState {
    fn from_u8(v: u8) -> ChipState {
        match v {
            0 => ChipState::Healthy,
            1 => ChipState::Unhealthy,
            3 => ChipState::Calibrating,
            _ => ChipState::Dead,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ChipState::Healthy => "healthy",
            ChipState::Unhealthy => "unhealthy",
            ChipState::Dead => "dead",
            ChipState::Calibrating => "calibrating",
        }
    }
}

/// Shared (lock-free on the hot path) health record of one chip replica.
///
/// Ownership split vs `fleet::telemetry`: health carries the per-chip
/// *operational* view (state machine, inflight, served/error counters the
/// scheduler and `fleet_stats` read); telemetry carries the fleet-wide
/// histogram and windowed rates.  Both are written from exactly one site
/// — the success/error arms of `pool::chip_worker` — so the two views
/// cannot drift unless that single write site changes.
pub struct ChipHealth {
    state: AtomicU8,
    /// Jobs admitted but not yet completed (queued + executing).
    inflight: AtomicUsize,
    served: AtomicU64,
    errors: AtomicU64,
    consecutive_errors: AtomicU32,
    error_threshold: u32,
    /// Sum of simulated inference time [ns] over served jobs (paper
    /// accounting; ns so sub-µs precision survives millions of requests).
    sim_time_ns_sum: AtomicU64,
    /// Latest engine chip time [µs] (reported by the worker per job).
    chip_time_us: AtomicU64,
    /// Chip-time stamp of the last applied calibration [µs].
    last_calib_us: AtomicU64,
    /// Worst per-half residual rms of the applied profile (f32 bits).
    residual_bits: AtomicU32,
    /// Completed recalibrations.
    recalibrations: AtomicU64,
    /// Whether the chip's engine backend supports recalibration at all
    /// (false for PJRT replicas — the policy must never drain them).
    calib_capable: AtomicBool,
    last_error: Mutex<Option<String>>,
}

/// Point-in-time copy of one chip's counters (for stats/tests).
#[derive(Debug, Clone)]
pub struct ChipHealthSnapshot {
    pub state: ChipState,
    pub inflight: usize,
    pub served: u64,
    pub errors: u64,
    pub mean_sim_time_us: f64,
    /// Chip-time age of the applied calibration [µs].
    pub calib_age_us: u64,
    /// Worst residual rms of the applied profile [LSB] (0 before any).
    pub residual_rms: f32,
    pub recalibrations: u64,
    pub last_error: Option<String>,
}

impl ChipHealth {
    pub fn new(error_threshold: u32) -> ChipHealth {
        ChipHealth {
            state: AtomicU8::new(0),
            inflight: AtomicUsize::new(0),
            served: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            consecutive_errors: AtomicU32::new(0),
            error_threshold: error_threshold.max(1),
            sim_time_ns_sum: AtomicU64::new(0),
            chip_time_us: AtomicU64::new(0),
            last_calib_us: AtomicU64::new(0),
            residual_bits: AtomicU32::new(0f32.to_bits()),
            recalibrations: AtomicU64::new(0),
            calib_capable: AtomicBool::new(true),
            last_error: Mutex::new(None),
        }
    }

    pub fn state(&self) -> ChipState {
        ChipState::from_u8(self.state.load(Ordering::Acquire))
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// May the scheduler route regular traffic here?
    pub fn is_dispatchable(&self) -> bool {
        self.state() == ChipState::Healthy
    }

    /// May the scheduler route a re-admission probe here?
    pub fn is_probeable(&self) -> bool {
        self.state() == ChipState::Unhealthy
    }

    /// Called by the scheduler when a job is admitted (before enqueue).
    /// Admission is accounted in **samples**: a batch of B counts B.
    pub fn begin_job(&self) {
        self.begin_jobs(1);
    }

    /// Batch admission: `samples` inflight slots at once.
    pub fn begin_jobs(&self, samples: usize) {
        self.inflight.fetch_add(samples, Ordering::AcqRel);
    }

    /// Worker: job finished successfully.  A success on an unhealthy chip
    /// re-admits it (the probe path).
    pub fn record_success(&self, sim_time_ns: u64) {
        self.record_batch_success(1, sim_time_ns);
    }

    /// Worker: a batch of `samples` finished successfully;
    /// `sim_time_ns_total` is the summed per-sample simulated time.
    pub fn record_batch_success(&self, samples: usize, sim_time_ns_total: u64) {
        self.inflight.fetch_sub(samples, Ordering::AcqRel);
        self.served.fetch_add(samples as u64, Ordering::Relaxed);
        self.sim_time_ns_sum
            .fetch_add(sim_time_ns_total, Ordering::Relaxed);
        self.consecutive_errors.store(0, Ordering::Release);
        // Dead stays dead; Unhealthy recovers.
        let _ = self.state.compare_exchange(
            1,
            0,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
    }

    /// Worker: job failed.  Crossing the consecutive-error threshold marks
    /// the chip unhealthy (drain + probe-only).
    pub fn record_error(&self, msg: &str) {
        self.record_error_event(1, msg);
    }

    /// Worker: a batch of `samples` failed as one engine call — the
    /// inflight slots drain, but it counts as *one* error event toward
    /// the consecutive-error threshold.
    pub fn record_batch_error(&self, samples: usize, msg: &str) {
        self.record_error_event(samples, msg);
    }

    /// The one error-accounting primitive both paths route through: one
    /// failed *engine call* is one error event and one strike, no matter
    /// how many samples it carried.  Counting strikes per sample would
    /// let a single bad 32-sample batch blow straight through any sane
    /// `error_threshold` and kill a healthy chip on one transient fault;
    /// counting the `errors` total per sample while striking per call
    /// would make `fleet_stats` disagree with the state machine.  Keeping
    /// exactly one site enforces that both tallies stay per-call.
    fn record_error_event(&self, samples: usize, msg: &str) {
        self.inflight.fetch_sub(samples, Ordering::AcqRel);
        self.errors.fetch_add(1, Ordering::Relaxed);
        let consec = self.consecutive_errors.fetch_add(1, Ordering::AcqRel) + 1;
        *lock_clean(&self.last_error) = Some(msg.to_string());
        if consec >= self.error_threshold {
            let _ = self.state.compare_exchange(
                0,
                1,
                Ordering::AcqRel,
                Ordering::Acquire,
            );
        }
    }

    /// Permanently remove the chip from scheduling (engine init failure or
    /// worker death).  Does not touch inflight: the pool unwinds those.
    pub fn mark_dead(&self, msg: &str) {
        self.state.store(2, Ordering::Release);
        *lock_clean(&self.last_error) = Some(msg.to_string());
    }

    // --- calibration state machine (drain -> calibrate -> re-admit) --------

    pub fn is_calibrating(&self) -> bool {
        self.state() == ChipState::Calibrating
    }

    /// Take a *healthy* chip out of rotation for recalibration.  Returns
    /// false when the chip is not currently Healthy (racing dispatchers
    /// resolve here: only one wins the CAS).  Jobs already queued drain
    /// normally; the scheduler admits nothing new — not even probes.
    pub fn begin_calibration(&self) -> bool {
        self.state
            .compare_exchange(0, 3, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Worker: recalibration finished — record the profile figures and
    /// re-admit the chip.
    pub fn finish_calibration(&self, chip_time_us: u64, residual_rms: f32) {
        self.chip_time_us.store(chip_time_us, Ordering::Release);
        self.last_calib_us.store(chip_time_us, Ordering::Release);
        self.residual_bits
            .store(residual_rms.to_bits(), Ordering::Release);
        self.recalibrations.fetch_add(1, Ordering::Relaxed);
        self.consecutive_errors.store(0, Ordering::Release);
        // Calibrating -> Healthy; Dead stays dead.
        let _ = self.state.compare_exchange(
            3,
            0,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
    }

    /// Worker: recalibration failed — park the chip Unhealthy so the
    /// ordinary probe path decides whether it ever serves again.
    pub fn fail_calibration(&self, msg: &str) {
        self.errors.fetch_add(1, Ordering::Relaxed);
        *lock_clean(&self.last_error) = Some(msg.to_string());
        let _ = self.state.compare_exchange(
            3,
            1,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
    }

    /// Worker: latest engine chip time after a served job [µs].
    pub fn set_chip_time_us(&self, t: u64) {
        self.chip_time_us.store(t, Ordering::Release);
    }

    /// Worker (at engine construction): this replica's backend cannot be
    /// recalibrated — the policy and manual triggers must skip it.
    pub fn set_calib_incapable(&self) {
        self.calib_capable.store(false, Ordering::Release);
    }

    pub fn is_calib_capable(&self) -> bool {
        self.calib_capable.load(Ordering::Acquire)
    }

    /// Chip-time age of the applied calibration [µs].
    pub fn calib_age_us(&self) -> u64 {
        self.chip_time_us
            .load(Ordering::Acquire)
            .saturating_sub(self.last_calib_us.load(Ordering::Acquire))
    }

    pub fn recalibrations(&self) -> u64 {
        self.recalibrations.load(Ordering::Relaxed)
    }

    pub fn residual_rms(&self) -> f32 {
        f32::from_bits(self.residual_bits.load(Ordering::Acquire))
    }

    pub fn snapshot(&self) -> ChipHealthSnapshot {
        let served = self.served();
        let sim_ns = self.sim_time_ns_sum.load(Ordering::Relaxed);
        ChipHealthSnapshot {
            state: self.state(),
            inflight: self.inflight(),
            served,
            errors: self.errors.load(Ordering::Relaxed),
            mean_sim_time_us: if served > 0 {
                sim_ns as f64 / served as f64 / 1e3
            } else {
                0.0
            },
            calib_age_us: self.calib_age_us(),
            residual_rms: self.residual_rms(),
            recalibrations: self.recalibrations(),
            last_error: lock_clean(&self.last_error).clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_until_threshold() {
        let h = ChipHealth::new(3);
        assert!(h.is_dispatchable());
        for _ in 0..2 {
            h.begin_job();
            h.record_error("boom");
        }
        assert!(h.is_dispatchable(), "below threshold stays healthy");
        h.begin_job();
        h.record_error("boom");
        assert_eq!(h.state(), ChipState::Unhealthy);
        assert!(h.is_probeable());
        assert!(!h.is_dispatchable());
    }

    #[test]
    fn success_resets_consecutive_and_readmits() {
        let h = ChipHealth::new(2);
        h.begin_job();
        h.record_error("a");
        h.begin_job();
        h.record_success(276_000);
        h.begin_job();
        h.record_error("b");
        assert!(h.is_dispatchable(), "streak was broken by the success");
        h.begin_job();
        h.record_error("c");
        assert_eq!(h.state(), ChipState::Unhealthy);
        // Probe succeeds -> re-admitted.
        h.begin_job();
        h.record_success(276_000);
        assert_eq!(h.state(), ChipState::Healthy);
    }

    #[test]
    fn dead_is_terminal() {
        let h = ChipHealth::new(1);
        h.mark_dead("engine init failed");
        assert_eq!(h.state(), ChipState::Dead);
        assert!(!h.is_dispatchable() && !h.is_probeable());
        h.begin_job();
        h.record_success(1);
        assert_eq!(h.state(), ChipState::Dead, "success cannot resurrect");
    }

    #[test]
    fn batch_accounting_in_samples() {
        let h = ChipHealth::new(3);
        h.begin_jobs(5);
        assert_eq!(h.inflight(), 5);
        h.record_batch_success(5, 5 * 100_000);
        let s = h.snapshot();
        assert_eq!(s.inflight, 0);
        assert_eq!(s.served, 5);
        assert!((s.mean_sim_time_us - 100.0).abs() < 1e-9);
        // A failed batch drains its slots but is one error event.
        h.begin_jobs(4);
        h.record_batch_error(4, "boom");
        assert_eq!(h.inflight(), 0);
        assert_eq!(h.snapshot().errors, 1);
        assert!(h.is_dispatchable(), "one batch failure is one strike");
    }

    #[test]
    fn one_bad_batch_is_one_strike_regardless_of_size() {
        // The error-threshold accounting is per engine *call*, not per
        // sample: a single failed 100-sample batch must not instantly
        // kill a chip whose threshold is 3, and the `errors` total must
        // agree with the strike count (one event).
        let h = ChipHealth::new(3);
        h.begin_jobs(100);
        h.record_batch_error(100, "one transient engine fault");
        assert!(h.is_dispatchable(), "one bad batch is one strike");
        assert_eq!(h.inflight(), 0, "all 100 slots drained");
        assert_eq!(h.snapshot().errors, 1, "one event, not 100");
        // Batch and single-sample errors carry identical weight: two
        // more events of either shape reach the threshold together.
        h.begin_jobs(50);
        h.record_batch_error(50, "again");
        h.begin_job();
        h.record_error("and again");
        assert_eq!(h.state(), ChipState::Unhealthy, "3 events = threshold");
        assert_eq!(h.snapshot().errors, 3);
    }

    #[test]
    fn calibration_state_machine() {
        let h = ChipHealth::new(3);
        assert!(h.begin_calibration(), "healthy chip may calibrate");
        assert_eq!(h.state(), ChipState::Calibrating);
        assert!(!h.is_dispatchable(), "no regular work while calibrating");
        assert!(!h.is_probeable(), "no probes while calibrating");
        assert!(!h.begin_calibration(), "second CAS must lose");
        // Draining jobs admitted before the transition must not flip the
        // state back to Healthy.
        h.begin_job();
        h.record_success(276_000);
        assert_eq!(h.state(), ChipState::Calibrating, "drain keeps state");
        h.finish_calibration(5_000, 1.25);
        assert_eq!(h.state(), ChipState::Healthy, "re-admitted");
        let s = h.snapshot();
        assert_eq!(s.recalibrations, 1);
        assert_eq!(s.calib_age_us, 0);
        assert!((s.residual_rms - 1.25).abs() < 1e-6);
        // Age grows as the worker reports served chip time.
        h.set_chip_time_us(12_000);
        assert_eq!(h.calib_age_us(), 7_000);
    }

    #[test]
    fn incapable_chip_is_flagged_but_serves() {
        let h = ChipHealth::new(3);
        assert!(h.is_calib_capable());
        h.set_calib_incapable();
        assert!(!h.is_calib_capable());
        assert!(h.is_dispatchable(), "incapable ≠ unhealthy");
    }

    #[test]
    fn failed_calibration_parks_unhealthy() {
        let h = ChipHealth::new(3);
        assert!(h.begin_calibration());
        h.fail_calibration("substrate unreachable");
        assert_eq!(h.state(), ChipState::Unhealthy);
        assert!(h.is_probeable(), "probe path decides re-admission");
        assert_eq!(h.snapshot().errors, 1);
        // A successful probe re-admits as usual.
        h.begin_job();
        h.record_success(276_000);
        assert_eq!(h.state(), ChipState::Healthy);
    }

    #[test]
    fn unhealthy_and_dead_chips_cannot_begin_calibration() {
        let h = ChipHealth::new(1);
        h.begin_job();
        h.record_error("boom");
        assert_eq!(h.state(), ChipState::Unhealthy);
        assert!(!h.begin_calibration());
        h.mark_dead("gone");
        assert!(!h.begin_calibration());
        // finish_calibration on a dead chip must not resurrect it.
        h.finish_calibration(1, 0.5);
        assert_eq!(h.state(), ChipState::Dead);
    }

    #[test]
    fn inflight_and_means_tracked() {
        let h = ChipHealth::new(3);
        h.begin_job();
        h.begin_job();
        assert_eq!(h.inflight(), 2);
        h.record_success(276_000);
        h.record_success(280_000);
        let s = h.snapshot();
        assert_eq!(s.inflight, 0);
        assert_eq!(s.served, 2);
        assert!((s.mean_sim_time_us - 278.0).abs() < 1e-9);
        assert_eq!(s.state, ChipState::Healthy);
    }
}
