//! Least-loaded dispatch with bounded admission and explicit backpressure.
//!
//! Admission policy per request (queue depth and load are accounted in
//! **samples** — a classify_batch of B counts as B):
//! 1. Among *healthy* chips, pick the one with the fewest inflight
//!    samples (queued + executing).  Ties rotate round-robin with the
//!    admission counter so equal-load replicas share work
//!    deterministically.
//! 2. If the least-loaded healthy chip already holds `queue_depth`
//!    inflight samples, the request is **shed** (`ShedReason::Saturated`)
//!    instead of queueing unboundedly — the client gets an explicit
//!    backpressure response it can retry against.  A batch that only
//!    *partially* fits is partially admitted: the fitting prefix is
//!    dispatched and the shed remainder reported back to the client.
//! 3. Every `probe_period`-th admission is offered to an *unhealthy*
//!    (draining) chip first: one real request probes it, and a success
//!    re-admits the chip (see `fleet::health`).
//! 4. A chip in `ChipState::Calibrating` (drained for recalibration,
//!    `calib::scheduler`) is invisible to both paths: it receives neither
//!    regular work nor probes until the pool re-admits it.
//!
//! The inflight bound is soft under races (two concurrent admissions can
//! both observe the same snapshot), so the true bound is
//! `queue_depth + #concurrent dispatchers` — acceptable for shedding,
//! which is a load-control mechanism, not an exactness guarantee.

use std::sync::atomic::{AtomicU64, Ordering};

use super::health::ChipHealth;

/// Why a request was not admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Every healthy chip is at its admission bound.
    Saturated,
    /// No chip is currently healthy (all draining or dead).
    NoHealthyChips,
}

impl ShedReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShedReason::Saturated => "fleet saturated",
            ShedReason::NoHealthyChips => "no healthy chips",
        }
    }
}

pub struct Scheduler {
    queue_depth: usize,
    probe_period: u64,
    admissions: AtomicU64,
    shed: AtomicU64,
}

impl Scheduler {
    pub fn new(queue_depth: usize, probe_period: u64) -> Scheduler {
        Scheduler {
            queue_depth: queue_depth.max(1),
            probe_period: probe_period.max(2),
            admissions: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn admissions(&self) -> u64 {
        self.admissions.load(Ordering::Relaxed)
    }

    /// Pick a chip for one request, or decide to shed it.  The caller must
    /// `begin_job()` on the returned chip's health before enqueueing.
    pub fn pick(&self, chips: &[std::sync::Arc<ChipHealth>]) -> Result<usize, ShedReason> {
        self.pick_batch(chips, 1).map(|(chip, _)| chip)
    }

    /// Pick a chip for a batch of `samples`.  Queue depth is accounted in
    /// **samples**, not requests: a batch that only partially fits the
    /// least-loaded chip's remaining depth is *partially* admitted — the
    /// returned count is the prefix that fits (always ≥ 1) and the caller
    /// sheds or retries the remainder.  The caller must `begin_jobs(n)`
    /// on the returned chip's health before enqueueing.
    pub fn pick_batch(
        &self,
        chips: &[std::sync::Arc<ChipHealth>],
        samples: usize,
    ) -> Result<(usize, usize), ShedReason> {
        let samples = samples.max(1);
        let tick = self.admissions.fetch_add(1, Ordering::Relaxed);
        let n = chips.len();

        // Re-admission probe: periodically offer one request to an idle
        // draining chip so it can prove itself again.  A probe admits a
        // single sample regardless of the batch size — the blast radius
        // of a still-broken chip must stay one sample, not one batch
        // (the caller partially sheds the rest).
        if tick % self.probe_period == self.probe_period - 1 {
            if let Some(i) = (0..n)
                .map(|k| ((tick as usize) + k) % n)
                .find(|&i| chips[i].is_probeable() && chips[i].inflight() == 0)
            {
                return Ok((i, 1));
            }
        }

        let mut best: Option<(usize, usize)> = None; // (inflight, chip)
        for k in 0..n {
            let i = ((tick as usize) + k) % n;
            if !chips[i].is_dispatchable() {
                continue;
            }
            let load = chips[i].inflight();
            if best.map(|(bl, _)| load < bl).unwrap_or(true) {
                best = Some((load, i));
            }
        }
        match best {
            Some((load, i)) if load < self.queue_depth => {
                Ok((i, samples.min(self.queue_depth - load)))
            }
            Some(_) => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                Err(ShedReason::Saturated)
            }
            None => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                Err(ShedReason::NoHealthyChips)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn chips(n: usize) -> Vec<Arc<ChipHealth>> {
        (0..n).map(|_| Arc::new(ChipHealth::new(3))).collect()
    }

    #[test]
    fn rotates_over_equal_load() {
        let cs = chips(4);
        let s = Scheduler::new(8, 1_000_000);
        let mut hit = [0usize; 4];
        for _ in 0..16 {
            let i = s.pick(&cs).unwrap();
            hit[i] += 1;
            // Complete immediately: load stays equal, rotation drives spread.
            cs[i].begin_job();
            cs[i].record_success(1);
        }
        assert_eq!(hit, [4, 4, 4, 4], "round-robin tie-break");
    }

    #[test]
    fn prefers_least_loaded() {
        let cs = chips(3);
        // Chip 0 and 1 busy, chip 2 idle.
        cs[0].begin_job();
        cs[0].begin_job();
        cs[1].begin_job();
        let s = Scheduler::new(8, 1_000_000);
        for _ in 0..3 {
            assert_eq!(s.pick(&cs).unwrap(), 2);
        }
    }

    #[test]
    fn sheds_when_saturated() {
        let cs = chips(2);
        let s = Scheduler::new(2, 1_000_000);
        for c in &cs {
            c.begin_job();
            c.begin_job();
        }
        assert_eq!(s.pick(&cs), Err(ShedReason::Saturated));
        assert_eq!(s.shed_count(), 1);
        // A completion frees a slot.
        cs[1].record_success(1);
        assert_eq!(s.pick(&cs), Ok(1));
    }

    #[test]
    fn batch_admission_is_sample_accounted() {
        let cs = chips(1);
        let s = Scheduler::new(8, 1_000_000);
        // Empty chip: a batch of 5 fits whole.
        assert_eq!(s.pick_batch(&cs, 5), Ok((0, 5)));
        cs[0].begin_jobs(5);
        // 3 slots left: a batch of 6 is partially admitted.
        assert_eq!(s.pick_batch(&cs, 6), Ok((0, 3)));
        cs[0].begin_jobs(3);
        // Full: shed, even for a 1-sample batch.
        assert_eq!(s.pick_batch(&cs, 2), Err(ShedReason::Saturated));
        assert_eq!(s.pick_batch(&cs, 1), Err(ShedReason::Saturated));
        // Draining four samples frees four slots.
        cs[0].record_batch_success(4, 4);
        assert_eq!(s.pick_batch(&cs, 8), Ok((0, 4)));
    }

    #[test]
    fn calibrating_chip_never_picked_even_by_probes() {
        let cs = chips(2);
        assert!(cs[1].begin_calibration());
        // Probe every 2nd admission: across many ticks, both the regular
        // and the probe path must avoid the calibrating chip.
        let s = Scheduler::new(8, 2);
        for _ in 0..32 {
            let (i, n) = s.pick_batch(&cs, 1).unwrap();
            assert_eq!(i, 0, "calibrating chip received work");
            assert_eq!(n, 1);
            cs[0].begin_job();
            cs[0].record_success(1);
        }
        // With every other chip saturated the request sheds rather than
        // leaking onto the calibrating replica.
        for _ in 0..8 {
            cs[0].begin_job();
        }
        assert_eq!(s.pick_batch(&cs, 1), Err(ShedReason::Saturated));
        // Re-admission makes it eligible again.
        cs[1].finish_calibration(1_000, 0.5);
        assert_eq!(s.pick(&cs), Ok(1));
    }

    #[test]
    fn sheds_when_no_healthy_chips() {
        let cs = chips(1);
        cs[0].mark_dead("gone");
        let s = Scheduler::new(4, 1_000_000);
        assert_eq!(s.pick(&cs), Err(ShedReason::NoHealthyChips));
    }

    #[test]
    fn probes_unhealthy_chip_periodically() {
        let cs = chips(2);
        // Chip 1 goes unhealthy.
        for _ in 0..3 {
            cs[1].begin_job();
            cs[1].record_error("x");
        }
        let s = Scheduler::new(8, 4);
        let mut probed = false;
        for _ in 0..8 {
            let i = s.pick(&cs).unwrap();
            if i == 1 {
                probed = true;
                cs[1].begin_job();
                cs[1].record_success(1);
            } else {
                cs[i].begin_job();
                cs[i].record_success(1);
            }
        }
        assert!(probed, "unhealthy chip must receive a probe");
        assert!(cs[1].is_dispatchable(), "probe success re-admits");
    }
}
