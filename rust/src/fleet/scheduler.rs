//! Least-loaded dispatch with bounded admission and explicit backpressure.
//!
//! Admission policy per request:
//! 1. Among *healthy* chips, pick the one with the fewest inflight jobs
//!    (queued + executing).  Ties rotate round-robin with the admission
//!    counter so equal-load replicas share work deterministically.
//! 2. If the least-loaded healthy chip already holds `queue_depth`
//!    inflight jobs, the request is **shed** (`ShedReason::Saturated`)
//!    instead of queueing unboundedly — the client gets an explicit
//!    backpressure response it can retry against.
//! 3. Every `probe_period`-th admission is offered to an *unhealthy*
//!    (draining) chip first: one real request probes it, and a success
//!    re-admits the chip (see `fleet::health`).
//!
//! The inflight bound is soft under races (two concurrent admissions can
//! both observe the same snapshot), so the true bound is
//! `queue_depth + #concurrent dispatchers` — acceptable for shedding,
//! which is a load-control mechanism, not an exactness guarantee.

use std::sync::atomic::{AtomicU64, Ordering};

use super::health::ChipHealth;

/// Why a request was not admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Every healthy chip is at its admission bound.
    Saturated,
    /// No chip is currently healthy (all draining or dead).
    NoHealthyChips,
}

impl ShedReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShedReason::Saturated => "fleet saturated",
            ShedReason::NoHealthyChips => "no healthy chips",
        }
    }
}

pub struct Scheduler {
    queue_depth: usize,
    probe_period: u64,
    admissions: AtomicU64,
    shed: AtomicU64,
}

impl Scheduler {
    pub fn new(queue_depth: usize, probe_period: u64) -> Scheduler {
        Scheduler {
            queue_depth: queue_depth.max(1),
            probe_period: probe_period.max(2),
            admissions: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn admissions(&self) -> u64 {
        self.admissions.load(Ordering::Relaxed)
    }

    /// Pick a chip for one request, or decide to shed it.  The caller must
    /// `begin_job()` on the returned chip's health before enqueueing.
    pub fn pick(&self, chips: &[std::sync::Arc<ChipHealth>]) -> Result<usize, ShedReason> {
        let tick = self.admissions.fetch_add(1, Ordering::Relaxed);
        let n = chips.len();

        // Re-admission probe: periodically offer one request to an idle
        // draining chip so it can prove itself again.
        if tick % self.probe_period == self.probe_period - 1 {
            if let Some(i) = (0..n)
                .map(|k| ((tick as usize) + k) % n)
                .find(|&i| chips[i].is_probeable() && chips[i].inflight() == 0)
            {
                return Ok(i);
            }
        }

        let mut best: Option<(usize, usize)> = None; // (inflight, chip)
        for k in 0..n {
            let i = ((tick as usize) + k) % n;
            if !chips[i].is_dispatchable() {
                continue;
            }
            let load = chips[i].inflight();
            if best.map(|(bl, _)| load < bl).unwrap_or(true) {
                best = Some((load, i));
            }
        }
        match best {
            Some((load, i)) if load < self.queue_depth => Ok(i),
            Some(_) => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                Err(ShedReason::Saturated)
            }
            None => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                Err(ShedReason::NoHealthyChips)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn chips(n: usize) -> Vec<Arc<ChipHealth>> {
        (0..n).map(|_| Arc::new(ChipHealth::new(3))).collect()
    }

    #[test]
    fn rotates_over_equal_load() {
        let cs = chips(4);
        let s = Scheduler::new(8, 1_000_000);
        let mut hit = [0usize; 4];
        for _ in 0..16 {
            let i = s.pick(&cs).unwrap();
            hit[i] += 1;
            // Complete immediately: load stays equal, rotation drives spread.
            cs[i].begin_job();
            cs[i].record_success(1);
        }
        assert_eq!(hit, [4, 4, 4, 4], "round-robin tie-break");
    }

    #[test]
    fn prefers_least_loaded() {
        let cs = chips(3);
        // Chip 0 and 1 busy, chip 2 idle.
        cs[0].begin_job();
        cs[0].begin_job();
        cs[1].begin_job();
        let s = Scheduler::new(8, 1_000_000);
        for _ in 0..3 {
            assert_eq!(s.pick(&cs).unwrap(), 2);
        }
    }

    #[test]
    fn sheds_when_saturated() {
        let cs = chips(2);
        let s = Scheduler::new(2, 1_000_000);
        for c in &cs {
            c.begin_job();
            c.begin_job();
        }
        assert_eq!(s.pick(&cs), Err(ShedReason::Saturated));
        assert_eq!(s.shed_count(), 1);
        // A completion frees a slot.
        cs[1].record_success(1);
        assert_eq!(s.pick(&cs), Ok(1));
    }

    #[test]
    fn sheds_when_no_healthy_chips() {
        let cs = chips(1);
        cs[0].mark_dead("gone");
        let s = Scheduler::new(4, 1_000_000);
        assert_eq!(s.pick(&cs), Err(ShedReason::NoHealthyChips));
    }

    #[test]
    fn probes_unhealthy_chip_periodically() {
        let cs = chips(2);
        // Chip 1 goes unhealthy.
        for _ in 0..3 {
            cs[1].begin_job();
            cs[1].record_error("x");
        }
        let s = Scheduler::new(8, 4);
        let mut probed = false;
        for _ in 0..8 {
            let i = s.pick(&cs).unwrap();
            if i == 1 {
                probed = true;
                cs[1].begin_job();
                cs[1].record_success(1);
            } else {
                cs[i].begin_job();
                cs[i].record_success(1);
            }
        }
        assert!(probed, "unhealthy chip must receive a probe");
        assert!(cs[1].is_dispatchable(), "probe success re-admits");
    }
}
