//! Fleet-wide telemetry: a lock-free log-bucketed latency histogram with
//! p50/p95/p99 estimation, plus per-chip throughput accounting.
//!
//! Two time bases are tracked deliberately:
//! * **host latency** — wall-clock from admission to completion (queueing
//!   + engine execution), the number a serving system cares about; and
//! * **simulated inference time** — the paper's 276 µs per-inference
//!   accounting, which stays bit-identical per chip no matter how many
//!   replicas run (reported as a mean, accumulated in ns).
//!
//! Percentiles come from the histogram (geometric mid-point of the hit
//! bucket, ~±15 % resolution by construction); `util::stats::Summary` is
//! the exact oracle the unit tests cross-check against.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::pm;
use crate::util::sync::lock_clean;

/// Log-spaced buckets: bucket `i` covers `[BASE_US * RATIO^i, BASE_US *
/// RATIO^(i+1))`.  64 buckets at ratio 1.3 span 1 µs .. ~2e7 µs (20 s).
const N_BUCKETS: usize = 64;
const BASE_US: f64 = 1.0;
const RATIO: f64 = 1.3;

/// Minimum per-chip rate window [s]: snapshots taken closer together than
/// this do not advance the window (and rate-compute against this floor),
/// so concurrent `fleet_stats` pollers cannot corrupt each other's rates.
pub const MIN_RATE_WINDOW_S: f64 = 0.05;

/// Clamp NaN and negative inputs to 0 (they are clock/measurement bugs,
/// not latencies; `as u64` would otherwise bucket NaN silently as 0 ns
/// while still counting it wherever the cast result landed).
fn sanitize_us(us: f64) -> f64 {
    if us.is_finite() && us > 0.0 {
        us
    } else {
        0.0
    }
}

fn bucket_of(us: f64) -> usize {
    if us <= BASE_US {
        return 0;
    }
    let idx = (us / BASE_US).ln() / RATIO.ln();
    (idx as usize).min(N_BUCKETS - 1)
}

fn bucket_mid_us(i: usize) -> f64 {
    // Geometric mid-point of the bucket's bounds.
    BASE_US * RATIO.powi(i as i32) * RATIO.sqrt()
}

/// Concurrent latency histogram (host-latency µs).
pub struct LatencyHistogram {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum_ns: AtomicU64,
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            counts: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    pub fn record_us(&self, us: f64) {
        // NaN and negative latencies are measurement bugs, not data: clamp
        // them to zero instead of letting `as u64` silently bucket them.
        let us = sanitize_us(us);
        // lint:allow(panic-index: bucket_of clamps to N_BUCKETS - 1)
        self.counts[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        // Round to the nearest ns: flooring every sample systematically
        // understated the mean by up to 1 ns/sample.
        self.sum_ns.fetch_add((us * 1e3).round() as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64 / 1e3
    }

    /// Histogram quantile, `q` in [0, 100].  Returns 0 when empty.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0 * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= rank {
                return bucket_mid_us(i);
            }
        }
        bucket_mid_us(N_BUCKETS - 1)
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-chip completion counters (successes only; errors live in health).
struct ChipCounters {
    completed: AtomicU64,
    host_ns_sum: AtomicU64,
}

/// Previous-snapshot marker: per-chip rates are computed over the window
/// since the last `snapshot()` call, so a long-idle service reports its
/// *current* throughput, not a lifetime average decayed toward zero.
struct RateWindow {
    at: Instant,
    completed: Vec<u64>,
}

/// Aggregated fleet telemetry shared by workers, scheduler, and service.
pub struct FleetTelemetry {
    histogram: LatencyHistogram,
    sim_time_ns_sum: AtomicU64,
    per_chip: Vec<ChipCounters>,
    started: Instant,
    window: Mutex<RateWindow>,
}

/// Point-in-time fleet telemetry (stable shape for stats/tests).
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    pub served: u64,
    pub mean_host_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_sim_time_us: f64,
    pub elapsed_s: f64,
    /// Per chip: (completed jobs, mean host latency µs, jobs/s over the
    /// window since the previous snapshot).
    pub per_chip: Vec<(u64, f64, f64)>,
}

impl FleetTelemetry {
    pub fn new(chips: usize) -> FleetTelemetry {
        let now = Instant::now();
        FleetTelemetry {
            histogram: LatencyHistogram::new(),
            sim_time_ns_sum: AtomicU64::new(0),
            per_chip: (0..chips)
                .map(|_| ChipCounters {
                    completed: AtomicU64::new(0),
                    host_ns_sum: AtomicU64::new(0),
                })
                .collect(),
            started: now,
            window: Mutex::new(RateWindow {
                at: now,
                completed: vec![0; chips],
            }),
        }
    }

    /// Record one completed inference on `chip`.
    pub fn record(&self, chip: usize, host_latency_us: f64, sim_time_ns: u64) {
        self.histogram.record_us(host_latency_us);
        self.sim_time_ns_sum.fetch_add(sim_time_ns, Ordering::Relaxed);
        if let Some(c) = self.per_chip.get(chip) {
            c.completed.fetch_add(1, Ordering::Relaxed);
            c.host_ns_sum.fetch_add(
                (sanitize_us(host_latency_us) * 1e3).round() as u64,
                Ordering::Relaxed,
            );
        }
    }

    pub fn served(&self) -> u64 {
        self.histogram.count()
    }

    pub fn mean_host_us(&self) -> f64 {
        self.histogram.mean_us()
    }

    /// Point-in-time snapshot.  Per-chip `jobs/s` covers the window since
    /// the last window *advance*, and the window only advances once at
    /// least [`MIN_RATE_WINDOW_S`] has elapsed: two monitoring clients
    /// polling `fleet_stats` back to back no longer reset each other's
    /// window to a near-zero dt (which turned per-chip jobs/s into
    /// garbage).  Reads inside the floor are read-only and rate-compute
    /// against the floor, so they are idempotent.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let served = self.served();
        let now = Instant::now();
        let elapsed = (now - self.started).as_secs_f64().max(1e-9);
        let mut window = lock_clean(&self.window);
        let dt = (now - window.at).as_secs_f64();
        // Rate denominator is floored: a snapshot taken moments after the
        // previous advance reports a slightly *conservative* rate instead
        // of an inflated one.
        let eff_dt = dt.max(MIN_RATE_WINDOW_S);
        let per_chip = self
            .per_chip
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let n = c.completed.load(Ordering::Relaxed);
                let mean = if n > 0 {
                    c.host_ns_sum.load(Ordering::Relaxed) as f64 / n as f64 / 1e3
                } else {
                    0.0
                };
                let prev = window.completed.get(i).copied().unwrap_or(0);
                let rate = n.saturating_sub(prev) as f64 / eff_dt;
                (n, mean, rate)
            })
            .collect::<Vec<_>>();
        if dt >= MIN_RATE_WINDOW_S {
            window.at = now;
            window.completed = per_chip.iter().map(|c| c.0).collect();
        }
        drop(window);
        TelemetrySnapshot {
            served,
            mean_host_us: self.histogram.mean_us(),
            p50_us: self.histogram.quantile_us(50.0),
            p95_us: self.histogram.quantile_us(95.0),
            p99_us: self.histogram.quantile_us(99.0),
            mean_sim_time_us: if served > 0 {
                self.sim_time_ns_sum.load(Ordering::Relaxed) as f64
                    / served as f64
                    / 1e3
            } else {
                0.0
            },
            elapsed_s: elapsed,
            per_chip,
        }
    }

    /// One-line human report (`mean ± spread` in the paper's style).
    pub fn report(&self) -> String {
        let s = self.snapshot();
        format!(
            "fleet: {} served, host latency {} µs (p50 {:.0}, p95 {:.0}, \
             p99 {:.0}), sim {:.1} µs/inference",
            s.served,
            pm(s.mean_host_us, s.p95_us - s.p50_us, 1),
            s.p50_us,
            s.p95_us,
            s.p99_us,
            s.mean_sim_time_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn buckets_are_monotone_and_cover() {
        assert_eq!(bucket_of(0.5), 0);
        assert_eq!(bucket_of(1.0), 0);
        let mut prev = 0;
        for us in [2.0, 10.0, 100.0, 5e3, 1e6, 1e9] {
            let b = bucket_of(us);
            assert!(b >= prev, "bucket must not decrease");
            prev = b;
        }
        assert_eq!(bucket_of(1e12), N_BUCKETS - 1);
    }

    #[test]
    fn quantiles_track_exact_summary_within_bucket_resolution() {
        let h = LatencyHistogram::new();
        let mut rng = crate::util::rng::SplitMix64::new(42);
        let samples: Vec<f64> =
            (0..5000).map(|_| 100.0 + 400.0 * rng.unit()).collect();
        for &s in &samples {
            h.record_us(s);
        }
        let exact = Summary::from(&samples);
        for (q, want) in [(50.0, exact.p50), (95.0, exact.p95), (99.0, exact.p99)]
        {
            let got = h.quantile_us(q);
            // One bucket is a factor of RATIO wide; mid-point estimation is
            // within ±RATIO of the exact value.
            assert!(
                got > want / RATIO && got < want * RATIO,
                "q{q}: histogram {got} vs exact {want}"
            );
        }
        assert!((h.mean_us() - exact.mean).abs() / exact.mean < 0.01);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(50.0), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn per_chip_accounting() {
        let t = FleetTelemetry::new(2);
        t.record(0, 300.0, 276_000);
        t.record(1, 500.0, 276_000);
        t.record(1, 700.0, 276_000);
        let s = t.snapshot();
        assert_eq!(s.served, 3);
        assert_eq!(s.per_chip[0].0, 1);
        assert_eq!(s.per_chip[1].0, 2);
        assert!((s.per_chip[1].1 - 600.0).abs() < 1.0);
        assert!((s.mean_sim_time_us - 276.0).abs() < 1e-9);
        assert!(s.per_chip[1].2 > 0.0, "throughput rate positive");
        // Out-of-range chip ids are ignored, not panicking.
        t.record(9, 100.0, 1);
        assert_eq!(t.snapshot().served, 4);
    }

    #[test]
    fn record_rounds_instead_of_flooring() {
        // 0.4999 µs floors to 0 ns but rounds to 500 ns/sample; the old
        // truncation understated this mean by 100 %.
        let h = LatencyHistogram::new();
        for _ in 0..1000 {
            h.record_us(0.4999);
        }
        assert!((h.mean_us() - 0.5).abs() < 1e-3, "mean {}", h.mean_us());
    }

    #[test]
    fn nan_and_negative_latencies_are_clamped() {
        let h = LatencyHistogram::new();
        h.record_us(f64::NAN);
        h.record_us(-17.0);
        h.record_us(f64::NEG_INFINITY);
        assert_eq!(h.count(), 3, "clamped samples still count");
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(99.0), bucket_mid_us(0));
        // Fleet-level record path tolerates them too.
        let t = FleetTelemetry::new(1);
        t.record(0, f64::NAN, 0);
        assert_eq!(t.snapshot().per_chip[0].1, 0.0);
    }

    #[test]
    fn concurrent_snapshots_do_not_corrupt_rates() {
        // Two monitoring clients polling back to back: the second read
        // lands inside the rate-window floor, stays read-only, and both
        // report a sane (non-inflated, non-zero) rate.
        let t = FleetTelemetry::new(1);
        for _ in 0..10 {
            t.record(0, 300.0, 276_000);
        }
        let a = t.snapshot();
        let b = t.snapshot(); // immediately after: inside the floor
        assert!(a.per_chip[0].2 > 0.0);
        // Neither read can report more than delta/floor.
        let cap = 10.0 / MIN_RATE_WINDOW_S + 1e-9;
        assert!(a.per_chip[0].2 <= cap, "rate {} > cap {cap}", a.per_chip[0].2);
        assert!(b.per_chip[0].2 <= cap, "rate {} > cap {cap}", b.per_chip[0].2);
    }

    #[test]
    fn report_mentions_percentiles() {
        let t = FleetTelemetry::new(1);
        t.record(0, 300.0, 276_000);
        let r = t.report();
        assert!(r.contains("p95"), "{r}");
        assert!(r.contains("served"), "{r}");
    }
}
