//! Replica pool: N independent `Engine` replicas, each owned by its own
//! worker thread — a rack of simulated BSS-2 mobile units behind one
//! dispatch surface.
//!
//! Engines are constructed *inside* each worker thread via a builder
//! closure (PJRT handles are not `Send`, same pattern as
//! `coordinator::service` used for its single worker).  Each replica gets
//! its own noise seed and calibration state through the builder, so every
//! chip's per-inference semantics — timing, energy, noise stream — stay
//! bit-identical to the single-unit paper setup while aggregate
//! throughput scales with the chip count.
//!
//! ## Structure: [`Fleet`] vs [`FleetCore`]
//!
//! The shared dispatch state (worker queues, health records, scheduler,
//! telemetry, failover counters) lives in [`FleetCore`], an `Arc` every
//! worker holds a clone of.  [`Fleet`] is the owning handle: it adds the
//! join handles and drains/joins the pool on shutdown, and `Deref`s to
//! the core so the public dispatch API reads the same as before the
//! split.  The split exists for **transparent failover**: a worker whose
//! engine fails a job re-dispatches that job onto a healthy sibling
//! *itself* (bounded by [`FleetConfig::redirects`]), which requires
//! workers to reach the dispatch surface.  The reply channel travels
//! with the job, so the service's ordered-reply writer never notices —
//! the reply fills the same FIFO slot whichever replica finally serves
//! it, preserving the client's request order.
//!
//! Shutdown still works because the per-chip senders live in
//! `Mutex<Option<Sender>>` slots inside the core: draining takes them
//! out of the `Option`, closing each worker's queue even though the
//! workers themselves keep the core alive until they exit.
//!
//! ## Fault injection
//!
//! [`FleetConfig::fault_plan`] arms a seeded [`FaultPlan`] on the
//! replicas (each worker arms its chip's `FaultInjector` right after
//! engine construction).  Erroring faults (chip death, frame drops)
//! surface as engine errors: the health state machine strikes the chip
//! (quarantine after `error_threshold` consecutive strikes, periodic
//! re-probe for transient-fault recovery) and failover retries the job
//! elsewhere; `fleet_stats` reports the redirect counters.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::calib::monitor::DriftMonitor;
use crate::calib::scheduler::{RecalibPolicy, RecalibReason};
use crate::coordinator::engine::{Engine, Inference};
use crate::ecg::gen::Trace;
use crate::fault::{FaultInjector, FaultPlan, FAULT_TAG};
use crate::obs::trace::HostStages;
use crate::obs::{EventKind, MetricSample, ObsHub};
use crate::util::sync::lock_clean;

use super::health::{ChipHealth, ChipHealthSnapshot, ChipState};
use super::scheduler::{Scheduler, ShedReason};
use super::telemetry::FleetTelemetry;

/// Index of a chip replica within the fleet.
pub type ChipId = usize;

/// EWMA weight of the per-chip drift monitors (one new margin sample).
const MONITOR_ALPHA: f64 = 1.0 / 64.0;

/// Fleet sizing and admission-control knobs.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of engine replicas (simulated mobile units).
    pub chips: usize,
    /// Per-chip admission bound in **samples** (queued + executing)
    /// before shedding — a batch of B occupies B slots.
    pub queue_depth: usize,
    /// Consecutive engine errors before a chip is marked unhealthy.
    pub error_threshold: u32,
    /// Admissions between re-admission probes of unhealthy chips.
    pub probe_period: u64,
    /// Auto-recalibration policy (`calib::scheduler`): when set, the pool
    /// drains one aged/degraded replica at a time into
    /// `ChipState::Calibrating` while the rest keep serving.  `None`
    /// disables automatic recalibration (manual
    /// [`FleetCore::recalibrate_chip`] still works).
    pub recalib: Option<RecalibPolicy>,
    /// Whether the wire `shutdown` command may stop the whole service.
    /// Off by default: any TCP client being able to kill the fleet is an
    /// unauthenticated kill switch.  `repro serve` opts in via
    /// `--allow-remote-shutdown`; in-process tests opt in explicitly
    /// (or go through [`Service::start`](crate::coordinator::service::Service::start),
    /// which enables it for its single-chip legacy contract).
    pub allow_remote_shutdown: bool,
    /// Hard cap on concurrent client connections; connection number
    /// `max_connections + 1` gets an explicit accept-time shed reply
    /// instead of a handler thread.
    pub max_connections: usize,
    /// Transparent-failover budget: how many times one failed job may be
    /// redirected onto another healthy replica before its error is
    /// answered to the client.  0 disables failover (every engine error
    /// reaches the client, the pre-failover behaviour).
    pub redirects: u32,
    /// Deterministic fault schedule armed on the simulated hardware
    /// (`fault` subsystem; `repro serve --fault-plan`, `repro chaos`).
    pub fault_plan: Option<FaultPlan>,
    /// Stage-level tracing: keep every Nth completed span whole in the
    /// trace ring (`obs::trace`, the `trace` wire command; `repro serve
    /// --trace-sample N`).  0 disables the ring; the per-stage
    /// histograms behind `fleet_stats`/`metrics` always record.
    pub trace_sample: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            chips: 1,
            queue_depth: 32,
            error_threshold: 3,
            probe_period: 64,
            recalib: None,
            allow_remote_shutdown: false,
            max_connections: 256,
            redirects: 2,
            fault_plan: None,
            trace_sample: 16,
        }
    }
}

impl FleetConfig {
    /// Single-chip fleet (the paper's original serving topology).
    pub fn single() -> FleetConfig {
        FleetConfig::default()
    }
}

/// One unit of work for a chip worker.  The mpsc queue is FIFO, which is
/// what gives `Calibrate` its drain semantics: classification jobs
/// admitted before the state flipped to `Calibrating` complete first.
enum ChipJob {
    /// A batch of ≥ 1 traces the engine executes as one program
    /// (`Engine::classify_batch`, one weight reconfiguration per layer
    /// per batch).
    Classify {
        traces: Vec<Trace>,
        admitted: Instant,
        /// Start of the current queue residence (== `admitted` at first
        /// enqueue; reset by every failover re-enqueue).  With `retry_ns`
        /// this gives contiguous host-span stages: `retry + (dequeue -
        /// enq) + execute == completion - admitted` exactly.
        enq: Instant,
        /// Queue + execute nanoseconds burnt in failed attempts.
        retry_ns: u64,
        resp: ReplySink<ChipReply>,
        /// Remaining transparent-failover budget for this job.
        redirects_left: u32,
    },
    /// One preprocessed activation frame (`Engine::classify_acts`) — the
    /// streaming path: the FPGA-side incremental windower already ran, so
    /// the chip only executes the three analog passes.
    ClassifyActs {
        acts: Vec<i32>,
        admitted: Instant,
        /// See `Classify::enq`.
        enq: Instant,
        /// See `Classify::retry_ns`.
        retry_ns: u64,
        resp: ReplySink<ChipReply>,
        /// Remaining transparent-failover budget for this frame.
        redirects_left: u32,
    },
    /// Full-chip recalibration (`Engine::recalibrate`): measure, apply,
    /// re-admit.  `resp` is optional — policy-triggered recalibrations
    /// are fire-and-forget, manual ones want the summary back.
    /// `drain_token` is the pool-level one-at-a-time latch, held by both
    /// the policy and manual trigger paths; the worker releases it when
    /// the measurement finishes.  Never redirected: the measurement is
    /// meaningful only on the drained chip itself.
    Calibrate {
        reps: usize,
        reason: RecalibReason,
        resp: Option<ReplySink<CalibReply>>,
        drain_token: Option<Arc<AtomicBool>>,
    },
}

/// Worker's answer to one job: one `Inference` per admitted sample.
#[derive(Debug)]
pub struct ChipReply {
    /// The chip that finally *served* (or terminally failed) the job —
    /// under failover this may differ from the chip the job was
    /// originally admitted to.
    pub chip: ChipId,
    /// Host latency from admission to completion [µs] (includes any
    /// failover hops).
    pub host_latency_us: f64,
    pub result: Result<Vec<Inference>, String>,
}

/// Worker's answer to a recalibration job.
#[derive(Debug)]
pub struct CalibReply {
    pub chip: ChipId,
    pub reason: RecalibReason,
    /// On success: (chip-time stamp [µs], worst per-half residual [LSB]).
    pub result: Result<(u64, f32), String>,
}

/// Completion hook fired after a worker delivers a reply.  The threaded
/// service blocks on the reply receiver and needs none; the readiness
/// loop (`coordinator::service::readiness`) cannot block, so its
/// `*_notify` dispatches install a hook that wakes the poll thread to
/// `try_recv` the finished reply.
pub type ReplyNotify = Arc<dyn Fn() + Send + Sync>;

/// Where a worker's reply goes: the mpsc sender plus the optional
/// completion hook.  Travels with the job through failover redirects, so
/// the hook fires whichever replica finally serves.
struct ReplySink<T> {
    tx: mpsc::Sender<T>,
    notify: Option<ReplyNotify>,
}

impl<T> ReplySink<T> {
    fn new(tx: mpsc::Sender<T>, notify: Option<ReplyNotify>) -> ReplySink<T> {
        ReplySink { tx, notify }
    }

    /// Deliver one reply.  A closed receiver is fine — the client may
    /// have given up — and the hook still fires so pollers re-check
    /// their queues rather than missing the final state change.
    fn send(&self, value: T) {
        let _ = self.tx.send(value);
        if let Some(notify) = &self.notify {
            notify();
        }
    }
}

/// Outcome of a single-trace admission attempt.
pub enum DispatchOutcome {
    /// Admitted: the reply arrives on `resp`.
    Enqueued { chip: ChipId, resp: mpsc::Receiver<ChipReply> },
    /// Backpressure: not admitted; retry after roughly `retry_after_us`.
    Shed { reason: ShedReason, retry_after_us: u64 },
}

/// Outcome of a batch admission attempt.  Admission is accounted in
/// samples, so a batch can be *partially* accepted: the fitting prefix is
/// enqueued and the remainder reported back for the client to retry.
pub enum BatchDispatchOutcome {
    Enqueued {
        chip: ChipId,
        /// Samples admitted (a prefix of the submitted batch).
        accepted: usize,
        /// Samples shed (the suffix), to be retried by the caller.
        rejected: usize,
        resp: mpsc::Receiver<ChipReply>,
        /// Retry hint for the rejected remainder (0 when none).
        retry_after_us: u64,
    },
    Shed { reason: ShedReason, retry_after_us: u64 },
}

struct ChipHandle {
    tx: Mutex<Option<mpsc::Sender<ChipJob>>>,
}

/// Failover accounting (all `fleet_stats` fields).
#[derive(Default)]
struct FailoverStats {
    /// Jobs successfully moved onto another replica after a failure.
    redirects: AtomicU64,
    /// Jobs whose failure reached the client because the redirect budget
    /// ran out or no other replica was dispatchable.
    exhausted: AtomicU64,
    /// Engine errors carrying the injected-fault tag (`fault` subsystem).
    injected: AtomicU64,
}

/// The shared dispatch surface: everything the workers, the connection
/// handlers, and the failover path need.  [`Fleet`] (the owning handle)
/// `Deref`s here, so `fleet.dispatch(..)` etc. keep working unchanged.
pub struct FleetCore {
    handles: Vec<ChipHandle>,
    health: Vec<Arc<ChipHealth>>,
    /// Per-chip logit-margin monitors feeding the recalibration policy.
    monitors: Vec<Arc<DriftMonitor>>,
    telemetry: Arc<FleetTelemetry>,
    scheduler: Scheduler,
    /// Auto-recalibration policy (None = manual only).
    recalib: Option<RecalibPolicy>,
    /// Pool-level latch serialising *all* drains: taken by
    /// `maybe_recalibrate` before electing a chip and by manual
    /// `recalibrate_chip` requests, released by the worker when the
    /// measurement finishes — so concurrent triggers can never drain two
    /// replicas at once (the per-chip CAS alone only serialises drains
    /// of the *same* chip).
    policy_drain: Arc<AtomicBool>,
    /// Admissions refused at the transport layer (dead worker channels);
    /// scheduler-level sheds are counted separately.
    transport_rejects: AtomicU64,
    /// Per-job transparent-failover budget (`FleetConfig::redirects`).
    redirects_budget: u32,
    failover: FailoverStats,
    /// Observability surface: metrics registry, stage tracer, event
    /// journal (`obs`; the `metrics`/`trace`/`journal` wire commands).
    obs: Arc<ObsHub>,
}

/// The running fleet: the shared core plus worker-thread ownership.
/// `Fleet` is `Sync`; share it across connection handlers with an `Arc`.
pub struct Fleet {
    core: Arc<FleetCore>,
    joins: Vec<std::thread::JoinHandle<()>>,
}

impl std::ops::Deref for Fleet {
    type Target = FleetCore;

    fn deref(&self) -> &FleetCore {
        &self.core
    }
}

impl Fleet {
    /// Spin up `cfg.chips` replicas.  `make_engine(chip)` runs once per
    /// chip, inside that chip's worker thread.  Fails only if *every*
    /// replica fails to construct; partial failures are logged and the
    /// affected chips marked dead.
    pub fn start<F>(cfg: FleetConfig, make_engine: F) -> anyhow::Result<Fleet>
    where
        F: Fn(ChipId) -> anyhow::Result<Engine> + Send + Sync + 'static,
    {
        anyhow::ensure!(cfg.chips >= 1, "fleet needs at least one chip");
        if let Some(plan) = &cfg.fault_plan {
            // Fail loudly on a plan naming chips this fleet doesn't
            // have — silently arming nothing would fake resilience.
            plan.validate_for(cfg.chips)?;
        }
        let make = Arc::new(make_engine);
        let plan = cfg.fault_plan.clone().map(Arc::new);
        let mut handles = Vec::with_capacity(cfg.chips);
        let mut health = Vec::with_capacity(cfg.chips);
        let mut monitors = Vec::with_capacity(cfg.chips);
        let mut rxs = Vec::with_capacity(cfg.chips);
        for _ in 0..cfg.chips {
            let (tx, rx) = mpsc::channel::<ChipJob>();
            handles.push(ChipHandle { tx: Mutex::new(Some(tx)) });
            rxs.push(rx);
            health.push(Arc::new(ChipHealth::new(cfg.error_threshold)));
            monitors.push(Arc::new(DriftMonitor::new(MONITOR_ALPHA)));
        }
        let core = Arc::new(FleetCore {
            handles,
            health,
            monitors,
            telemetry: Arc::new(FleetTelemetry::new(cfg.chips)),
            scheduler: Scheduler::new(cfg.queue_depth, cfg.probe_period),
            recalib: cfg.recalib.clone(),
            policy_drain: Arc::new(AtomicBool::new(false)),
            transport_rejects: AtomicU64::new(0),
            redirects_budget: cfg.redirects,
            failover: FailoverStats::default(),
            obs: Arc::new(ObsHub::new(cfg.trace_sample)),
        });

        let (ack_tx, ack_rx) = mpsc::channel::<(ChipId, Result<(), String>)>();
        let mut joins = Vec::with_capacity(cfg.chips);
        for (chip, rx) in rxs.into_iter().enumerate() {
            let worker_core = core.clone();
            let worker_make = make.clone();
            let worker_plan = plan.clone();
            let worker_ack = ack_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("bss2-chip-{chip}"))
                .spawn(move || {
                    chip_worker(
                        chip,
                        rx,
                        worker_core,
                        worker_make,
                        worker_plan,
                        worker_ack,
                    )
                });
            match spawned {
                Ok(j) => joins.push(j),
                Err(e) => {
                    // Unwind the partial pool: close every queue so the
                    // already-spawned workers exit, then join them.
                    core.close_channels();
                    for j in joins {
                        let _ = j.join();
                    }
                    return Err(e.into());
                }
            }
        }
        drop(ack_tx);

        // Wait for every replica to report engine construction.  Workers
        // drop their ack sender right after reporting, so this loop ends
        // once all replicas have checked in (or died).
        let mut ok = 0usize;
        let mut first_err: Option<String> = None;
        while let Ok((chip_id, res)) = ack_rx.recv() {
            match res {
                Ok(()) => ok += 1,
                Err(e) => {
                    log::warn!("fleet: chip {chip_id} failed to start: {e}");
                    first_err.get_or_insert(e);
                }
            }
        }
        let mut fleet = Fleet { core, joins };
        if ok == 0 {
            fleet.shutdown_inner();
            anyhow::bail!(
                "fleet: all {} chips failed to start: {}",
                cfg.chips,
                first_err.unwrap_or_else(|| "worker died before ack".into())
            );
        }
        if ok < cfg.chips {
            log::warn!("fleet: {ok} of {} chips healthy at start", cfg.chips);
        }
        Ok(fleet)
    }

    fn shutdown_inner(&mut self) {
        // Dropping the senders closes the worker queues; queued jobs
        // still drain before the threads exit.  The workers' own core
        // clones keep the (now senderless) core alive until they return.
        self.core.close_channels();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }

    /// Drain and join all replicas.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

impl FleetCore {
    fn close_channels(&self) {
        for h in &self.handles {
            lock_clean(&h.tx).take();
        }
    }

    /// Admit one trace, or shed it.  Non-blocking: the reply arrives on
    /// the returned receiver.
    pub fn dispatch(&self, trace: Trace) -> DispatchOutcome {
        self.dispatch_inner(trace, None)
    }

    /// [`Self::dispatch`] with a completion hook fired when the reply is
    /// delivered — for pollers that `try_recv` instead of blocking.
    pub fn dispatch_notify(
        &self,
        trace: Trace,
        notify: ReplyNotify,
    ) -> DispatchOutcome {
        self.dispatch_inner(trace, Some(notify))
    }

    fn dispatch_inner(
        &self,
        trace: Trace,
        notify: Option<ReplyNotify>,
    ) -> DispatchOutcome {
        match self.dispatch_batch_inner(vec![trace], notify) {
            BatchDispatchOutcome::Enqueued { chip, resp, .. } => {
                DispatchOutcome::Enqueued { chip, resp }
            }
            BatchDispatchOutcome::Shed { reason, retry_after_us } => {
                DispatchOutcome::Shed { reason, retry_after_us }
            }
        }
    }

    /// Hand `job` to `chip`'s worker queue.  On a dead worker (channel
    /// gone) the chip is marked dead and the job returned so the caller
    /// can reclaim its payload and retry another replica.  Shared by
    /// every admission path so the locked-send / reclaim dance exists
    /// exactly once.
    fn try_send(&self, chip: ChipId, job: ChipJob) -> Result<(), ChipJob> {
        let send_result = {
            let guard = lock_clean(&self.handles[chip].tx);
            match guard.as_ref() {
                Some(tx) => tx.send(job).map_err(|mpsc::SendError(j)| j),
                None => Err(job),
            }
        };
        send_result.map_err(|job| {
            // First discovery of the dead worker makes the journal; the
            // repeat discoveries every later send attempt would only spam.
            if self.health[chip].state() != ChipState::Dead {
                self.obs.journal.log(
                    EventKind::ChipDead,
                    Some(chip),
                    "worker channel closed",
                );
            }
            self.health[chip].mark_dead("worker channel closed");
            job
        })
    }

    /// Admit one preprocessed activation frame (the streaming path:
    /// `MODEL_IN` 5-bit activations from the incremental windower), or
    /// shed it.  Non-blocking; accounted as one sample, exactly like a
    /// single-trace `dispatch`.
    pub fn dispatch_acts(&self, acts: Vec<i32>) -> DispatchOutcome {
        self.dispatch_acts_inner(acts, None)
    }

    /// [`Self::dispatch_acts`] with a completion hook (see
    /// [`Self::dispatch_notify`]).
    pub fn dispatch_acts_notify(
        &self,
        acts: Vec<i32>,
        notify: ReplyNotify,
    ) -> DispatchOutcome {
        self.dispatch_acts_inner(acts, Some(notify))
    }

    fn dispatch_acts_inner(
        &self,
        acts: Vec<i32>,
        notify: Option<ReplyNotify>,
    ) -> DispatchOutcome {
        self.maybe_recalibrate();
        let mut acts = acts;
        for _ in 0..self.handles.len() {
            let chip = match self.scheduler.pick_batch(&self.health, 1) {
                Ok((chip, _)) => chip,
                Err(reason) => {
                    return DispatchOutcome::Shed {
                        reason,
                        retry_after_us: self.retry_hint_us(),
                    };
                }
            };
            let (rtx, rrx) = mpsc::channel();
            self.health[chip].begin_job();
            let now = Instant::now();
            let job = ChipJob::ClassifyActs {
                acts,
                admitted: now,
                enq: now,
                retry_ns: 0,
                resp: ReplySink::new(rtx, notify.clone()),
                redirects_left: self.redirects_budget,
            };
            match self.try_send(chip, job) {
                Ok(()) => return DispatchOutcome::Enqueued { chip, resp: rrx },
                Err(ChipJob::ClassifyActs { acts: reclaimed, .. }) => {
                    self.health[chip]
                        .record_batch_error(1, "worker channel closed");
                    acts = reclaimed;
                }
                // lint:allow(panic-macro: try_send echoes back the exact job we sent)
                Err(_) => unreachable!("acts dispatch returned a foreign job"),
            }
        }
        self.transport_rejects.fetch_add(1, Ordering::Relaxed);
        DispatchOutcome::Shed {
            reason: ShedReason::NoHealthyChips,
            retry_after_us: self.retry_hint_us(),
        }
    }

    /// Admit a batch of traces — possibly only a prefix of it (admission
    /// is bounded in samples; see [`BatchDispatchOutcome`]).  Non-blocking.
    pub fn dispatch_batch(&self, traces: Vec<Trace>) -> BatchDispatchOutcome {
        self.dispatch_batch_inner(traces, None)
    }

    /// [`Self::dispatch_batch`] with a completion hook (see
    /// [`Self::dispatch_notify`]).
    pub fn dispatch_batch_notify(
        &self,
        traces: Vec<Trace>,
        notify: ReplyNotify,
    ) -> BatchDispatchOutcome {
        self.dispatch_batch_inner(traces, Some(notify))
    }

    fn dispatch_batch_inner(
        &self,
        mut traces: Vec<Trace>,
        notify: Option<ReplyNotify>,
    ) -> BatchDispatchOutcome {
        // An empty batch is a caller bug; never let it reach a worker
        // (it would error in the engine and charge the healthy chip an
        // error strike).  Report it as a zero-accepted shed instead.
        debug_assert!(!traces.is_empty(), "dispatch_batch needs ≥ 1 trace");
        if traces.is_empty() {
            return BatchDispatchOutcome::Shed {
                reason: ShedReason::Saturated,
                retry_after_us: 0,
            };
        }
        // Piggyback the recalibration policy on the dispatch path: an
        // aged/degraded healthy replica is drained into `Calibrating`
        // *before* this request is placed, so the request never lands on
        // a chip about to leave the pool.
        self.maybe_recalibrate();
        // A dead worker channel is discovered lazily; retry the pick at
        // most once per chip before giving up.
        for _ in 0..self.handles.len() {
            let (chip, accepted) =
                match self.scheduler.pick_batch(&self.health, traces.len()) {
                    Ok(pick) => pick,
                    Err(reason) => {
                        return BatchDispatchOutcome::Shed {
                            reason,
                            retry_after_us: self.retry_hint_us(),
                        };
                    }
                };
            let rest = traces.split_off(accepted.min(traces.len()));
            let (rtx, rrx) = mpsc::channel();
            self.health[chip].begin_jobs(traces.len());
            let now = Instant::now();
            let job = ChipJob::Classify {
                traces,
                admitted: now,
                enq: now,
                retry_ns: 0,
                resp: ReplySink::new(rtx, notify.clone()),
                redirects_left: self.redirects_budget,
            };
            match self.try_send(chip, job) {
                Ok(()) => {
                    let retry_after_us =
                        if rest.is_empty() { 0 } else { self.retry_hint_us() };
                    return BatchDispatchOutcome::Enqueued {
                        chip,
                        accepted,
                        rejected: rest.len(),
                        resp: rrx,
                        retry_after_us,
                    };
                }
                Err(ChipJob::Classify { traces: reclaimed, .. }) => {
                    // Worker gone (chip marked dead by try_send): reclaim
                    // the whole batch and try the next candidate.
                    self.health[chip].record_batch_error(
                        reclaimed.len(),
                        "worker channel closed",
                    );
                    traces = reclaimed;
                    traces.extend(rest);
                }
                Err(_) => {
                    // lint:allow(panic-macro: try_send echoes back the job we sent)
                    unreachable!("classify dispatch returned a foreign job")
                }
            }
        }
        self.transport_rejects.fetch_add(1, Ordering::Relaxed);
        BatchDispatchOutcome::Shed {
            reason: ShedReason::NoHealthyChips,
            retry_after_us: self.retry_hint_us(),
        }
    }

    /// Blocking convenience: admit, wait, unwrap.  Sheds become errors.
    pub fn classify_blocking(
        &self,
        trace: &Trace,
    ) -> anyhow::Result<(ChipId, Inference)> {
        match self.dispatch(trace.clone()) {
            DispatchOutcome::Shed { reason, retry_after_us } => anyhow::bail!(
                "request shed: {} (retry in ~{retry_after_us} µs)",
                reason.as_str()
            ),
            DispatchOutcome::Enqueued { chip, resp } => {
                let reply = resp
                    .recv()
                    .map_err(|_| anyhow::anyhow!("chip {chip} worker gone"))?;
                let infs = reply.result.map_err(|e| anyhow::anyhow!(e))?;
                let inf = infs
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("empty reply"))?;
                Ok((reply.chip, inf))
            }
        }
    }

    /// Blocking batch convenience: admit (possibly partially), wait,
    /// unwrap.  Returns the serving chip, one `Inference` per *admitted*
    /// sample, and the rejected sample count (0 when fully admitted).
    pub fn classify_batch_blocking(
        &self,
        traces: &[Trace],
    ) -> anyhow::Result<(ChipId, Vec<Inference>, usize)> {
        anyhow::ensure!(!traces.is_empty(), "empty batch");
        match self.dispatch_batch(traces.to_vec()) {
            BatchDispatchOutcome::Shed { reason, retry_after_us } => {
                anyhow::bail!(
                    "batch shed: {} (retry in ~{retry_after_us} µs)",
                    reason.as_str()
                )
            }
            BatchDispatchOutcome::Enqueued { chip, rejected, resp, .. } => {
                let reply = resp
                    .recv()
                    .map_err(|_| anyhow::anyhow!("chip {chip} worker gone"))?;
                let infs = reply.result.map_err(|e| anyhow::anyhow!(e))?;
                Ok((reply.chip, infs, rejected))
            }
        }
    }

    /// Samples currently admitted fleet-wide (queued + executing) — the
    /// queue-depth figure shed replies carry as a backoff hint.
    pub fn inflight_samples(&self) -> usize {
        self.health.iter().map(|h| h.inflight()).sum()
    }

    /// Rough client-facing backpressure hint [µs]: the mean host latency
    /// times the number of queued rounds ahead of the request.
    fn retry_hint_us(&self) -> u64 {
        let mean = self.telemetry.mean_host_us();
        let per = if mean > 0.0 { mean } else { 300.0 };
        let inflight = self.inflight_samples();
        let lanes = self
            .health
            .iter()
            .filter(|h| h.is_dispatchable())
            .count()
            .max(1);
        (per * ((inflight / lanes) as f64 + 1.0)).max(1.0) as u64
    }

    // --- transparent failover ----------------------------------------------

    /// The replacement replica for a job that failed on `exclude`: the
    /// least-loaded dispatchable chip other than the failing one
    /// (lowest index on ties — deterministic, and no admission tick is
    /// consumed, so client-visible scheduling is unaffected).
    fn pick_failover(&self, exclude: ChipId) -> Option<ChipId> {
        let mut best: Option<(usize, ChipId)> = None;
        for (i, h) in self.health.iter().enumerate() {
            if i == exclude || !h.is_dispatchable() {
                continue;
            }
            let load = h.inflight();
            if best.map(|(bl, _)| load < bl).unwrap_or(true) {
                best = Some((load, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Move a failed (or undeliverable) job onto another replica.
    /// Returns the job back when the redirect budget is exhausted, the
    /// job is not redirectable (`Calibrate`), or no other replica is
    /// dispatchable — the caller then answers the client with the error.
    ///
    /// Redirected jobs bypass the queue-depth bound on purpose: the job
    /// was already admitted once (its original slot drained with the
    /// failure), so placing it adds no *net* load — shedding it here
    /// would turn an internal fault into a client-visible failure the
    /// budget was meant to absorb.
    fn redirect(&self, from: ChipId, mut job: ChipJob) -> Result<(), ChipJob> {
        if matches!(job, ChipJob::Calibrate { .. }) {
            // A measurement is only meaningful on the drained chip
            // itself — never redirected, and not a failover event.
            return Err(job);
        }
        let exhausted = match &mut job {
            ChipJob::Classify { redirects_left, .. }
            | ChipJob::ClassifyActs { redirects_left, .. } => {
                if *redirects_left == 0 {
                    true
                } else {
                    *redirects_left -= 1;
                    false
                }
            }
            // lint:allow(panic-macro: caller matches out Calibrate before this)
            ChipJob::Calibrate { .. } => unreachable!("checked above"),
        };
        if exhausted {
            self.failover.exhausted.fetch_add(1, Ordering::Relaxed);
            self.obs.journal.log(
                EventKind::RedirectExhausted,
                Some(from),
                "redirect budget exhausted",
            );
            return Err(job);
        }
        // Fold the failed attempt (its queue residence + execution) into
        // the span's retry stage and restart the queue clock, so the
        // stage chain stays contiguous across hops.
        let now = Instant::now();
        match &mut job {
            ChipJob::Classify { enq, retry_ns, .. }
            | ChipJob::ClassifyActs { enq, retry_ns, .. } => {
                *retry_ns +=
                    now.saturating_duration_since(*enq).as_nanos() as u64;
                *enq = now;
            }
            // lint:allow(panic-macro: caller matches out Calibrate before this)
            ChipJob::Calibrate { .. } => unreachable!("checked above"),
        }
        let samples = match &job {
            ChipJob::Classify { traces, .. } => traces.len(),
            _ => 1,
        };
        loop {
            let Some(target) = self.pick_failover(from) else {
                self.failover.exhausted.fetch_add(1, Ordering::Relaxed);
                self.obs.journal.log(
                    EventKind::RedirectExhausted,
                    Some(from),
                    "no dispatchable sibling",
                );
                return Err(job);
            };
            self.health[target].begin_jobs(samples);
            match self.try_send(target, job) {
                Ok(()) => {
                    self.failover.redirects.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                Err(j) => {
                    // Target's worker is gone (try_send marked it dead):
                    // undo the admission and scan for the next candidate.
                    self.health[target]
                        .record_batch_error(samples, "worker channel closed");
                    job = j;
                }
            }
        }
    }

    /// Jobs transparently moved onto another replica after a failure.
    pub fn redirect_count(&self) -> u64 {
        self.failover.redirects.load(Ordering::Relaxed)
    }

    /// Failures that reached a client because the redirect budget ran
    /// out or no other replica was dispatchable.
    pub fn redirects_exhausted_count(&self) -> u64 {
        self.failover.exhausted.load(Ordering::Relaxed)
    }

    /// Engine errors tagged as injected faults (`fault` subsystem).
    pub fn injected_fault_errors(&self) -> u64 {
        self.failover.injected.load(Ordering::Relaxed)
    }

    // --- recalibration (drain -> calibrate -> re-admit) --------------------

    /// Policy check on the dispatch path: drain at most one aged or
    /// margin-degraded replica into `Calibrating`, provided enough healthy
    /// chips remain serving.  Cheap (a few atomic loads per chip).  The
    /// pool-level `policy_drain` latch makes "one replica at a time"
    /// exact even under concurrent dispatchers; replicas whose backend
    /// cannot recalibrate (PJRT) are exempt rather than drained into a
    /// doomed measurement.
    fn maybe_recalibrate(&self) {
        let Some(policy) = &self.recalib else {
            return;
        };
        if self.calibrating_count() > 0 {
            return; // a drain is in progress (cheap early-out; the
                    // latch below is what makes one-at-a-time exact)
        }
        if self.healthy_count() <= policy.min_serving {
            return; // never drain below the availability floor
        }
        if self
            .policy_drain
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return; // another dispatcher holds the drain latch
        }
        for chip in 0..self.health.len() {
            let h = &self.health[chip];
            if !h.is_dispatchable() || !h.is_calib_capable() {
                continue;
            }
            let reason = policy.should_recalibrate(
                h.calib_age_us(),
                self.monitors[chip].degradation(),
            );
            if let Some(reason) = reason {
                if self.start_recalibration(
                    chip,
                    policy.reps,
                    reason,
                    None,
                    Some(self.policy_drain.clone()),
                ) {
                    // Latch ownership handed to the worker, which
                    // releases it when the measurement finishes.
                    return;
                }
                // Failed start (lost the per-chip CAS, or the worker is
                // gone): the token clone was *dropped*, never stored, so
                // we still own the latch — keep scanning.
            }
        }
        // No chip drained: we still own the latch; release it.
        self.policy_drain.store(false, Ordering::Release);
    }

    /// Flip `chip` Healthy -> Calibrating and enqueue the measurement
    /// behind its queued work (FIFO = drain).  Returns false if the chip
    /// was not Healthy or its worker is gone.
    ///
    /// Drain-token ownership: the token is only *handed over* (to the
    /// worker, which stores `false` when the measurement finishes) when
    /// this returns true.  On every failure path the token clone is
    /// dropped without a store, so the caller keeps ownership of the
    /// latch — releasing here would let a concurrent dispatcher acquire
    /// it while the caller is still scanning.
    fn start_recalibration(
        &self,
        chip: ChipId,
        reps: usize,
        reason: RecalibReason,
        resp: Option<ReplySink<CalibReply>>,
        drain_token: Option<Arc<AtomicBool>>,
    ) -> bool {
        if !self.health[chip].begin_calibration() {
            return false;
        }
        self.obs.journal.log(
            EventKind::CalibDrain,
            Some(chip),
            reason.as_str(),
        );
        let job = ChipJob::Calibrate { reps, reason, resp, drain_token };
        // On a dead worker try_send marks the chip dead; dropping the
        // returned job drops any drain-token clone inside it, so the
        // caller keeps latch ownership.
        self.try_send(chip, job).is_ok()
    }

    /// Manually drain `chip` for recalibration with `reps` measurement
    /// repetitions.  Returns the receiver for the worker's summary.
    ///
    /// Manual drains honour the same availability rules as the policy:
    /// one chip at a time — exact, because they acquire the same
    /// pool-level `policy_drain` latch the policy dispatcher holds from
    /// electing a chip until the worker finishes the measurement — and
    /// never the last healthy replica of a multi-chip pool.  A
    /// single-chip pool may drain itself — the operator accepts shed
    /// responses until the measurement finishes.
    pub fn recalibrate_chip(
        &self,
        chip: ChipId,
        reps: usize,
    ) -> anyhow::Result<mpsc::Receiver<CalibReply>> {
        self.recalibrate_chip_inner(chip, reps, None)
    }

    /// [`Self::recalibrate_chip`] with a completion hook (see
    /// [`Self::dispatch_notify`]).
    pub fn recalibrate_chip_notify(
        &self,
        chip: ChipId,
        reps: usize,
        notify: ReplyNotify,
    ) -> anyhow::Result<mpsc::Receiver<CalibReply>> {
        self.recalibrate_chip_inner(chip, reps, Some(notify))
    }

    fn recalibrate_chip_inner(
        &self,
        chip: ChipId,
        reps: usize,
        notify: Option<ReplyNotify>,
    ) -> anyhow::Result<mpsc::Receiver<CalibReply>> {
        anyhow::ensure!(chip < self.handles.len(), "chip {chip} out of range");
        anyhow::ensure!(
            self.health[chip].is_calib_capable(),
            "chip {chip}'s backend does not support recalibration"
        );
        // A `calibrating_count() == 0` check alone would race the policy
        // path between its latch acquisition and the chip's CAS; taking
        // the latch itself makes one-at-a-time exact across both paths.
        anyhow::ensure!(
            self.policy_drain
                .compare_exchange(
                    false,
                    true,
                    Ordering::AcqRel,
                    Ordering::Acquire
                )
                .is_ok(),
            "another chip is already calibrating"
        );
        // Past this point every failure path must release the latch; on
        // success, ownership passes to the worker (which releases it
        // when the measurement finishes, like the policy path).
        if self.handles.len() > 1 && self.healthy_count() <= 1 {
            self.policy_drain.store(false, Ordering::Release);
            anyhow::bail!("refusing to drain the last healthy chip of the pool");
        }
        let (tx, rx) = mpsc::channel();
        if !self.start_recalibration(
            chip,
            reps,
            RecalibReason::Aged,
            Some(ReplySink::new(tx, notify)),
            Some(self.policy_drain.clone()),
        ) {
            self.policy_drain.store(false, Ordering::Release);
            anyhow::bail!(
                "chip {chip} is not healthy (state {})",
                self.health[chip].state().as_str()
            );
        }
        Ok(rx)
    }

    /// Chips currently drained for recalibration.
    pub fn calibrating_count(&self) -> usize {
        self.health.iter().filter(|h| h.is_calibrating()).count()
    }

    /// Completed recalibrations across the fleet.
    pub fn recalibration_count(&self) -> u64 {
        self.health.iter().map(|h| h.recalibrations()).sum()
    }

    pub fn size(&self) -> usize {
        self.handles.len()
    }

    pub fn healthy_count(&self) -> usize {
        self.health.iter().filter(|h| h.is_dispatchable()).count()
    }

    pub fn shed_count(&self) -> u64 {
        self.scheduler.shed_count()
            + self.transport_rejects.load(Ordering::Relaxed)
    }

    pub fn telemetry(&self) -> &FleetTelemetry {
        &self.telemetry
    }

    /// The fleet's observability surface (metrics registry, stage
    /// tracer, event journal).
    pub fn obs(&self) -> &ObsHub {
        &self.obs
    }

    pub fn chip_snapshots(&self) -> Vec<ChipHealthSnapshot> {
        self.health.iter().map(|h| h.snapshot()).collect()
    }

    /// The `fleet_stats` service payload (line-protocol JSON object).
    pub fn stats_json(&self) -> String {
        let t = self.telemetry.snapshot();
        let mut s = format!(
            "{{\"ok\":true,\"chips\":{},\"healthy\":{},\"calibrating\":{},\
             \"recalibrations\":{},\"served\":{},\
             \"shed\":{},\"redirects\":{},\"redirects_exhausted\":{},\
             \"fault_errors\":{},\"mean_host_us\":{:.1},\"p50_us\":{:.1},\
             \"p95_us\":{:.1},\"p99_us\":{:.1},\"mean_sim_time_us\":{:.3},\
             \"stages\":{{\"host\":[",
            self.size(),
            self.healthy_count(),
            self.calibrating_count(),
            self.recalibration_count(),
            t.served,
            self.shed_count(),
            self.redirect_count(),
            self.redirects_exhausted_count(),
            self.injected_fault_errors(),
            t.mean_host_us,
            t.p50_us,
            t.p95_us,
            t.p99_us,
            t.mean_sim_time_us,
        );
        let push_stages =
            |s: &mut String, stats: &[crate::obs::StageStat]| {
                for (i, st) in stats.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    s.push_str(&format!(
                        "{{\"stage\":\"{}\",\"count\":{},\"mean_us\":{:.3},\
                         \"p50_us\":{:.3},\"p95_us\":{:.3},\"p99_us\":{:.3}}}",
                        st.name,
                        st.count,
                        st.mean_us,
                        st.p50_us,
                        st.p95_us,
                        st.p99_us,
                    ));
                }
            };
        push_stages(&mut s, &self.obs.tracer.host_stage_stats());
        s.push_str("],\"sim\":[");
        push_stages(&mut s, &self.obs.tracer.sim_stage_stats());
        s.push_str("]},\"per_chip\":[");
        for (i, h) in self.chip_snapshots().iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let rate = t.per_chip.get(i).map(|c| c.2).unwrap_or(0.0);
            s.push_str(&format!(
                "{{\"chip\":{i},\"state\":\"{}\",\"served\":{},\
                 \"errors\":{},\"inflight\":{},\"mean_sim_time_us\":{:.3},\
                 \"rate_per_s\":{rate:.2},\"calib_age_us\":{},\
                 \"residual_rms\":{:.4},\"recalibrations\":{}}}",
                h.state.as_str(),
                h.served,
                h.errors,
                h.inflight,
                h.mean_sim_time_us,
                h.calib_age_us,
                h.residual_rms,
                h.recalibrations,
            ));
        }
        s.push_str("]}");
        s
    }

    /// The unified metrics snapshot behind the `metrics` wire command:
    /// registry-owned metrics first, then the scattered fleet stats
    /// (telemetry, scheduler/failover counters, per-chip health, stage
    /// quantiles) folded into the same [`MetricSample`] shape — one
    /// snapshot, rendered by `obs::expo` as JSON or Prometheus text.
    pub fn metrics_samples(&self) -> Vec<MetricSample> {
        let state_code = |s: ChipState| match s {
            ChipState::Healthy => 0.0,
            ChipState::Unhealthy => 1.0,
            ChipState::Dead => 2.0,
            ChipState::Calibrating => 3.0,
        };
        let mut out = self.obs.registry.snapshot();
        let t = self.telemetry.snapshot();
        out.push(MetricSample::counter(
            "bss2_fleet_served_total",
            "Completed inferences across the fleet.",
            t.served as f64,
        ));
        out.push(MetricSample::counter(
            "bss2_fleet_shed_total",
            "Requests shed (admission control + transport rejects).",
            self.shed_count() as f64,
        ));
        out.push(MetricSample::counter(
            "bss2_fleet_redirects_total",
            "Jobs transparently failed over onto another replica.",
            self.redirect_count() as f64,
        ));
        out.push(MetricSample::counter(
            "bss2_fleet_redirects_exhausted_total",
            "Failures that reached a client after the redirect budget ran out.",
            self.redirects_exhausted_count() as f64,
        ));
        out.push(MetricSample::counter(
            "bss2_fleet_fault_errors_total",
            "Engine errors carrying the injected-fault tag.",
            self.injected_fault_errors() as f64,
        ));
        out.push(MetricSample::counter(
            "bss2_fleet_recalibrations_total",
            "Completed recalibrations across the fleet.",
            self.recalibration_count() as f64,
        ));
        out.push(MetricSample::gauge(
            "bss2_fleet_healthy_chips",
            "Chips currently admitting work.",
            self.healthy_count() as f64,
        ));
        out.push(MetricSample::gauge(
            "bss2_fleet_calibrating_chips",
            "Chips currently drained for recalibration.",
            self.calibrating_count() as f64,
        ));
        for (q, v) in
            [("0.5", t.p50_us), ("0.95", t.p95_us), ("0.99", t.p99_us)]
        {
            out.push(
                MetricSample::gauge(
                    "bss2_host_latency_us",
                    "Host latency quantiles [µs].",
                    v,
                )
                .with_label("quantile", q),
            );
        }
        out.push(MetricSample::gauge(
            "bss2_host_latency_mean_us",
            "Mean host latency [µs].",
            t.mean_host_us,
        ));
        out.push(MetricSample::gauge(
            "bss2_sim_time_mean_us",
            "Mean simulated inference time [µs/sample] (paper: 276).",
            t.mean_sim_time_us,
        ));
        let snaps = self.chip_snapshots();
        for (i, h) in snaps.iter().enumerate() {
            out.push(
                MetricSample::counter(
                    "bss2_chip_served_total",
                    "Samples served, per chip.",
                    h.served as f64,
                )
                .with_label("chip", i),
            );
        }
        for (i, h) in snaps.iter().enumerate() {
            out.push(
                MetricSample::counter(
                    "bss2_chip_errors_total",
                    "Error events, per chip.",
                    h.errors as f64,
                )
                .with_label("chip", i),
            );
        }
        for (i, h) in snaps.iter().enumerate() {
            out.push(
                MetricSample::gauge(
                    "bss2_chip_inflight",
                    "Admitted-but-incomplete samples, per chip.",
                    h.inflight as f64,
                )
                .with_label("chip", i),
            );
        }
        for (i, h) in snaps.iter().enumerate() {
            out.push(
                MetricSample::gauge(
                    "bss2_chip_state",
                    "Chip state (0 healthy, 1 unhealthy, 2 dead, \
                     3 calibrating).",
                    state_code(h.state),
                )
                .with_label("chip", i),
            );
        }
        for st in self.obs.tracer.host_stage_stats() {
            for (q, v) in [
                ("0.5", st.p50_us),
                ("0.95", st.p95_us),
                ("0.99", st.p99_us),
            ] {
                out.push(
                    MetricSample::gauge(
                        "bss2_host_stage_us",
                        "Host span stage quantiles [µs].",
                        v,
                    )
                    .with_label("stage", st.name)
                    .with_label("quantile", q),
                );
            }
        }
        for st in self.obs.tracer.sim_stage_stats() {
            for (q, v) in [
                ("0.5", st.p50_us),
                ("0.95", st.p95_us),
                ("0.99", st.p99_us),
            ] {
                out.push(
                    MetricSample::gauge(
                        "bss2_sim_stage_us",
                        "Simulated chip-time stage quantiles [µs/sample].",
                        v,
                    )
                    .with_label("stage", st.name)
                    .with_label("quantile", q),
                );
            }
        }
        out.push(MetricSample::counter(
            "bss2_trace_spans_total",
            "Completed spans observed by the stage tracer.",
            self.obs.tracer.seen() as f64,
        ));
        out.push(MetricSample::counter(
            "bss2_journal_events_total",
            "Events appended to the structured journal.",
            self.obs.journal.next_seq() as f64,
        ));
        out
    }
}

/// Answer a job the failover path could not place anywhere (the terminal
/// error path — the client must hear *something*, never silence).
fn answer_failed(chip: ChipId, job: ChipJob, msg: &str) {
    match job {
        ChipJob::Classify { admitted, resp, .. }
        | ChipJob::ClassifyActs { admitted, resp, .. } => {
            resp.send(ChipReply {
                chip,
                host_latency_us: admitted.elapsed().as_secs_f64() * 1e6,
                result: Err(format!("chip {chip}: {msg}")),
            });
        }
        ChipJob::Calibrate { reason, resp, drain_token, .. } => {
            if let Some(t) = drain_token {
                t.store(false, Ordering::Release);
            }
            if let Some(resp) = resp {
                resp.send(CalibReply {
                    chip,
                    reason,
                    result: Err(format!("chip {chip}: {msg}")),
                });
            }
        }
    }
}

fn chip_worker<F>(
    chip: ChipId,
    rx: mpsc::Receiver<ChipJob>,
    core: Arc<FleetCore>,
    make_engine: Arc<F>,
    plan: Option<Arc<FaultPlan>>,
    ack: mpsc::Sender<(ChipId, Result<(), String>)>,
) where
    F: Fn(ChipId) -> anyhow::Result<Engine> + Send + Sync + 'static,
{
    let health = core.health[chip].clone();
    let monitor = core.monitors[chip].clone();
    let telemetry = core.telemetry.clone();
    let mut engine = match make_engine(chip) {
        Ok(mut e) => {
            // Record backend capability *before* acking, so once
            // `Fleet::start` returns the recalibration policy can already
            // see which replicas are exempt.
            if !e.supports_recalibration() {
                health.set_calib_incapable();
            }
            // Arm this chip's slice of the fault plan (after capability,
            // before serving: the first program can already be faulted).
            if let Some(plan) = plan.as_deref() {
                if let Some(inj) = FaultInjector::from_plan(plan, chip) {
                    log::info!(
                        "chip {chip}: armed {} injected fault(s)",
                        plan.faults_for(chip).len()
                    );
                    e.arm_faults(inj);
                }
            }
            let _ = ack.send((chip, Ok(())));
            drop(ack);
            e
        }
        Err(e) => {
            health.mark_dead(&format!("engine init: {e}"));
            core.obs.journal.log(
                EventKind::ChipDead,
                Some(chip),
                &format!("engine init: {e}"),
            );
            let _ = ack.send((chip, Err(e.to_string())));
            drop(ack);
            // Drain with failover (or error replies) so racing clients
            // never hang on a chip that never came up.
            while let Ok(job) = rx.recv() {
                match &job {
                    ChipJob::Classify { traces, .. } => {
                        health.record_batch_error(
                            traces.len(),
                            "engine init failed",
                        );
                    }
                    ChipJob::ClassifyActs { .. } => {
                        health.record_batch_error(1, "engine init failed");
                    }
                    ChipJob::Calibrate { .. } => {
                        health.fail_calibration("engine init failed");
                    }
                }
                if let Err(job) = core.redirect(chip, job) {
                    answer_failed(chip, job, "engine init failed");
                }
            }
            return;
        }
    };

    while let Ok(job) = rx.recv() {
        match job {
            ChipJob::Classify {
                traces,
                admitted,
                enq,
                retry_ns,
                resp,
                redirects_left,
            } => {
                let samples = traces.len();
                let dequeued = Instant::now();
                // One engine program per job: a 1-batch is bit-identical
                // to the legacy single-trace path, larger batches amortise
                // weight reconfiguration (Engine::classify_batch).
                match engine.classify_batch(&traces) {
                    Ok(infs) => {
                        let done = Instant::now();
                        let host_us = done
                            .saturating_duration_since(admitted)
                            .as_secs_f64()
                            * 1e6;
                        let host = HostStages {
                            queue_ns: dequeued
                                .saturating_duration_since(enq)
                                .as_nanos()
                                as u64,
                            execute_ns: done
                                .saturating_duration_since(dequeued)
                                .as_nanos()
                                as u64,
                            retry_ns,
                        };
                        let mut total_sim_ns = 0u64;
                        for inf in &infs {
                            let sim_ns = (inf.sim_time_s * 1e9).round() as u64;
                            total_sim_ns += sim_ns;
                            telemetry.record(chip, host_us, sim_ns);
                            monitor.record_scores(&inf.scores);
                        }
                        health.record_batch_success(samples, total_sim_ns);
                        health.set_chip_time_us(engine.chip_time_us());
                        core.obs.tracer.observe(
                            chip,
                            if samples == 1 { "classify" } else { "batch" },
                            samples,
                            core.redirects_budget - redirects_left,
                            host,
                            infs.first()
                                .map(|i| i.stages)
                                .unwrap_or_default(),
                        );
                        // The client may have given up; a closed reply
                        // channel is fine.
                        resp.send(ChipReply {
                            chip,
                            host_latency_us: host_us,
                            result: Ok(infs),
                        });
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        if msg.starts_with(FAULT_TAG) {
                            core.failover
                                .injected
                                .fetch_add(1, Ordering::Relaxed);
                            core.obs.journal.log(
                                EventKind::FaultFired,
                                Some(chip),
                                &msg,
                            );
                        }
                        let was_healthy =
                            health.state() == ChipState::Healthy;
                        health.record_batch_error(samples, &msg);
                        if was_healthy
                            && health.state() == ChipState::Unhealthy
                        {
                            core.obs.journal.log(
                                EventKind::ChipQuarantined,
                                Some(chip),
                                &msg,
                            );
                        }
                        health.set_chip_time_us(engine.chip_time_us());
                        // Transparent failover: hand the whole job to a
                        // healthy sibling; the reply channel travels with
                        // it, so the client's ordered-reply slot is
                        // filled by whichever replica finally serves.
                        let job = ChipJob::Classify {
                            traces,
                            admitted,
                            enq,
                            retry_ns,
                            resp,
                            redirects_left,
                        };
                        if let Err(job) = core.redirect(chip, job) {
                            answer_failed(chip, job, &msg);
                        }
                    }
                }
            }
            ChipJob::ClassifyActs {
                acts,
                admitted,
                enq,
                retry_ns,
                resp,
                redirects_left,
            } => {
                let dequeued = Instant::now();
                // One activation frame from the streaming frontend: the
                // chip runs the three analog passes; preprocessing
                // already happened incrementally on the FPGA side.
                match engine.classify_acts(&acts) {
                    Ok(inf) => {
                        let done = Instant::now();
                        let host_us = done
                            .saturating_duration_since(admitted)
                            .as_secs_f64()
                            * 1e6;
                        let host = HostStages {
                            queue_ns: dequeued
                                .saturating_duration_since(enq)
                                .as_nanos()
                                as u64,
                            execute_ns: done
                                .saturating_duration_since(dequeued)
                                .as_nanos()
                                as u64,
                            retry_ns,
                        };
                        let sim_ns = (inf.sim_time_s * 1e9).round() as u64;
                        telemetry.record(chip, host_us, sim_ns);
                        monitor.record_scores(&inf.scores);
                        health.record_batch_success(1, sim_ns);
                        health.set_chip_time_us(engine.chip_time_us());
                        core.obs.tracer.observe(
                            chip,
                            "acts",
                            1,
                            core.redirects_budget - redirects_left,
                            host,
                            inf.stages,
                        );
                        resp.send(ChipReply {
                            chip,
                            host_latency_us: host_us,
                            result: Ok(vec![inf]),
                        });
                    }
                    Err(e) => {
                        let msg = e.to_string();
                        if msg.starts_with(FAULT_TAG) {
                            core.failover
                                .injected
                                .fetch_add(1, Ordering::Relaxed);
                            core.obs.journal.log(
                                EventKind::FaultFired,
                                Some(chip),
                                &msg,
                            );
                        }
                        let was_healthy =
                            health.state() == ChipState::Healthy;
                        health.record_batch_error(1, &msg);
                        if was_healthy
                            && health.state() == ChipState::Unhealthy
                        {
                            core.obs.journal.log(
                                EventKind::ChipQuarantined,
                                Some(chip),
                                &msg,
                            );
                        }
                        health.set_chip_time_us(engine.chip_time_us());
                        // In-flight stream windows are re-dispatched, not
                        // dropped: the window's result line still arrives
                        // (in order) from the replacement replica.
                        let job = ChipJob::ClassifyActs {
                            acts,
                            admitted,
                            enq,
                            retry_ns,
                            resp,
                            redirects_left,
                        };
                        if let Err(job) = core.redirect(chip, job) {
                            answer_failed(chip, job, &msg);
                        }
                    }
                }
            }
            ChipJob::Calibrate { reps, reason, resp, drain_token } => {
                // The FIFO queue already drained everything admitted
                // before the state flipped to Calibrating.
                let result = match engine.recalibrate(reps) {
                    Ok(profile) => {
                        let stamp = engine.chip_time_us();
                        let residual = profile.worst_residual();
                        health.finish_calibration(stamp, residual);
                        monitor.reset();
                        core.obs.journal.log(
                            EventKind::CalibReadmit,
                            Some(chip),
                            &format!(
                                "{} residual {residual:.3} LSB",
                                reason.as_str()
                            ),
                        );
                        log::info!(
                            "chip {chip}: recalibrated ({}), residual \
                             {residual:.3} LSB",
                            reason.as_str()
                        );
                        Ok((stamp, residual))
                    }
                    Err(e) => {
                        let msg = format!("chip {chip}: {e}");
                        health.fail_calibration(&msg);
                        core.obs.journal.log(
                            EventKind::CalibFailed,
                            Some(chip),
                            &msg,
                        );
                        log::warn!("recalibration failed: {msg}");
                        Err(msg)
                    }
                };
                if let Some(t) = drain_token {
                    t.store(false, Ordering::Release);
                }
                if let Some(resp) = resp {
                    resp.send(CalibReply { chip, reason, result });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineConfig;
    use crate::fault::{FaultKind, FaultSpec};
    use crate::nn::weights::TrainedModel;

    fn native_cfg(chip: usize) -> EngineConfig {
        EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() }
            .for_chip(chip)
    }

    fn fleet_with(cfg: FleetConfig) -> Fleet {
        Fleet::start(cfg, |chip| {
            Ok(Engine::native(TrainedModel::synthetic(0xF1EE7), native_cfg(chip)))
        })
        .unwrap()
    }

    /// A plan that kills `chip` from t = 0, permanently.
    fn death_plan(chip: usize) -> FaultPlan {
        FaultPlan {
            seed: 1,
            faults: vec![FaultSpec {
                chip,
                at_us: 0,
                duration_us: None,
                kind: FaultKind::ChipDeath,
            }],
        }
    }

    #[test]
    fn failover_redirects_failed_jobs_transparently() {
        // Chip 1 is dead-on-arrival (fault-injected).  Every request must
        // still succeed — jobs landing on chip 1 fail there and are
        // transparently re-dispatched onto a healthy sibling.
        let fleet = fleet_with(FleetConfig {
            chips: 2,
            queue_depth: 16,
            redirects: 2,
            fault_plan: Some(death_plan(1)),
            ..Default::default()
        });
        let trace = crate::ecg::gen::generate_trace(11, true, 1.0);
        for _ in 0..8 {
            let (served_by, inf) = fleet.classify_blocking(&trace).unwrap();
            assert_eq!(served_by, 0, "only chip 0 can actually serve");
            assert!(inf.pred <= 1);
        }
        assert!(
            fleet.redirect_count() >= 1,
            "chip 1 must have been picked and failed over at least once"
        );
        assert!(fleet.injected_fault_errors() >= 1);
        assert_eq!(fleet.redirects_exhausted_count(), 0);
        // Chip 1 earned strikes and is quarantined by now or soon.
        let errors1 = fleet.chip_snapshots()[1].errors;
        assert!(errors1 >= 1, "the faulty chip recorded its failures");
        fleet.shutdown();
    }

    #[test]
    fn fault_plan_naming_missing_chips_fails_start() {
        // A typo'd plan (say, 1-based chip index) must fail the fleet
        // loudly instead of silently arming nothing — a chaos run over
        // an unarmed fleet would fake resilience.
        let err = Fleet::start(
            FleetConfig {
                chips: 2,
                fault_plan: Some(death_plan(2)),
                ..Default::default()
            },
            |chip| {
                Ok(Engine::native(
                    TrainedModel::synthetic(0xF1EE7),
                    native_cfg(chip),
                ))
            },
        )
        .err()
        .expect("must fail");
        assert!(err.to_string().contains("targets chip 2"), "{err}");
    }

    #[test]
    fn failover_budget_zero_surfaces_errors() {
        // redirects = 0 restores the pre-failover contract: the engine
        // error reaches the client.
        let fleet = fleet_with(FleetConfig {
            chips: 2,
            queue_depth: 16,
            redirects: 0,
            fault_plan: Some(death_plan(0)),
            ..Default::default()
        });
        let trace = crate::ecg::gen::generate_trace(12, false, 1.0);
        let mut saw_error = false;
        for _ in 0..4 {
            if let Err(e) = fleet.classify_blocking(&trace) {
                assert!(e.to_string().contains("fault:"), "{e}");
                saw_error = true;
            }
        }
        assert!(saw_error, "with a zero budget some error must surface");
        assert_eq!(fleet.redirect_count(), 0);
        assert!(fleet.redirects_exhausted_count() >= 1);
        fleet.shutdown();
    }

    #[test]
    fn single_chip_fleet_exhausts_instead_of_hanging() {
        // No sibling to fail over to: the error must reach the client
        // (never silence), and the exhaustion is counted.
        let fleet = fleet_with(FleetConfig {
            chips: 1,
            queue_depth: 8,
            redirects: 3,
            fault_plan: Some(death_plan(0)),
            ..Default::default()
        });
        let trace = crate::ecg::gen::generate_trace(13, true, 1.0);
        let err = fleet.classify_blocking(&trace).unwrap_err();
        assert!(err.to_string().contains("fault:"), "{err}");
        assert!(fleet.redirects_exhausted_count() >= 1);
        fleet.shutdown();
    }

    #[test]
    fn transient_death_quarantines_then_recovers_via_probes() {
        // Chip 1 dies at t = 0 for 1200 µs of chip time.  Each failed
        // attempt consumes chip time (the host's timeout), so after
        // enough re-admission probes the chip crosses the window and a
        // probe succeeds, re-admitting it.
        let fleet = Fleet::start(
            FleetConfig {
                chips: 2,
                queue_depth: 8,
                error_threshold: 2,
                probe_period: 4,
                redirects: 2,
                fault_plan: Some(FaultPlan {
                    seed: 3,
                    faults: vec![FaultSpec {
                        chip: 1,
                        at_us: 0,
                        duration_us: Some(1200),
                        kind: FaultKind::ChipDeath,
                    }],
                }),
                ..Default::default()
            },
            |chip| {
                Ok(Engine::native(
                    TrainedModel::synthetic(0xF1EE7),
                    native_cfg(chip),
                ))
            },
        )
        .unwrap();
        let trace = crate::ecg::gen::generate_trace(14, false, 1.0);
        let mut chip1_served = false;
        // Sequential requests: every one must succeed (failover hides
        // the fault); eventually a probe lands past the window and chip 1
        // serves again.
        for _ in 0..120 {
            let (chip, _) = fleet.classify_blocking(&trace).unwrap();
            if chip == 1 {
                chip1_served = true;
                break;
            }
        }
        assert!(chip1_served, "transient fault must heal via probes");
        assert_eq!(fleet.healthy_count(), 2, "chip 1 re-admitted");
        assert!(fleet.redirect_count() >= 1);
        fleet.shutdown();
    }

    #[test]
    fn init_failed_chip_redirects_raced_jobs() {
        // answer_failed / redirect on the init-failure drain path: jobs
        // racing the death of a chip still get answered (via a sibling).
        let fleet = Fleet::start(
            FleetConfig { chips: 2, queue_depth: 8, ..Default::default() },
            |chip| {
                anyhow::ensure!(chip != 1, "chip 1 substrate missing");
                Ok(Engine::native(
                    TrainedModel::synthetic(0xF1EE7),
                    native_cfg(chip),
                ))
            },
        )
        .unwrap();
        assert_eq!(fleet.healthy_count(), 1);
        let trace = crate::ecg::gen::generate_trace(15, true, 1.0);
        for _ in 0..4 {
            let (chip, _) = fleet.classify_blocking(&trace).unwrap();
            assert_eq!(chip, 0);
        }
        fleet.shutdown();
    }

    #[test]
    fn stats_json_reports_failover_counters() {
        let fleet = fleet_with(FleetConfig {
            chips: 2,
            queue_depth: 8,
            redirects: 2,
            fault_plan: Some(death_plan(1)),
            ..Default::default()
        });
        let trace = crate::ecg::gen::generate_trace(16, false, 1.0);
        for _ in 0..6 {
            fleet.classify_blocking(&trace).unwrap();
        }
        let j = crate::util::json::Json::parse(&fleet.stats_json()).unwrap();
        assert_eq!(j.get("ok"), Some(&crate::util::json::Json::Bool(true)));
        let redirects =
            j.get("redirects").and_then(|v| v.as_uint()).unwrap();
        assert_eq!(redirects, fleet.redirect_count());
        assert!(redirects >= 1, "{j}");
        assert!(j.get("fault_errors").and_then(|v| v.as_uint()).unwrap() >= 1);
        assert_eq!(
            j.get("redirects_exhausted").and_then(|v| v.as_uint()),
            Some(0)
        );
        // The additive stage block: host + sim per-stage aggregates.
        let stages = j.get("stages").expect("stages block");
        let host = stages.get("host").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(host.len(), 3);
        assert_eq!(host[0].get("stage").and_then(|v| v.as_str()), Some("queue"));
        let sim = stages.get("sim").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(sim.len(), 8);
        assert!(sim
            .iter()
            .any(|s| s.get("stage").and_then(|v| v.as_str())
                == Some("weight_write")));
        fleet.shutdown();
    }

    #[test]
    fn span_stages_sum_to_host_latency() {
        let fleet = fleet_with(FleetConfig {
            chips: 1,
            queue_depth: 8,
            trace_sample: 1,
            ..Default::default()
        });
        let trace = crate::ecg::gen::generate_trace(23, true, 1.0);
        match fleet.dispatch(trace) {
            DispatchOutcome::Enqueued { resp, .. } => {
                let reply = resp.recv().unwrap();
                let infs = reply.result.unwrap();
                let spans = fleet.obs().tracer.recent(1);
                assert_eq!(spans.len(), 1);
                // Host stages are contiguous: they sum to the reply's
                // end-to-end latency (same Instant chain, float-rounding
                // slop only).
                let total_us = spans[0].host.total_ns() as f64 / 1e3;
                let diff = (total_us - reply.host_latency_us).abs();
                assert!(
                    diff < 1e-3,
                    "span {total_us} µs vs e2e {} µs",
                    reply.host_latency_us
                );
                // Sim stages sum to the inference's simulated time.
                let sim_us = infs[0].sim_time_s * 1e6;
                assert!((spans[0].sim.total_us() - sim_us).abs() < 1e-6);
            }
            DispatchOutcome::Shed { .. } => panic!("unexpected shed"),
        }
        fleet.shutdown();
    }

    #[test]
    fn spans_and_journal_capture_failover() {
        let fleet = fleet_with(FleetConfig {
            chips: 2,
            queue_depth: 16,
            redirects: 2,
            trace_sample: 1,
            fault_plan: Some(death_plan(1)),
            ..Default::default()
        });
        let trace = crate::ecg::gen::generate_trace(21, true, 1.0);
        for _ in 0..12 {
            fleet.classify_blocking(&trace).unwrap();
        }
        let spans = fleet.obs().tracer.recent(usize::MAX);
        assert_eq!(spans.len(), 12, "trace_sample=1 keeps every span");
        for s in &spans {
            assert_eq!(s.chip, 0, "only chip 0 can actually serve");
            assert_eq!(s.kind, "classify");
            assert!(s.sim.total_us() > 100.0, "sim stages populated");
            assert!(s.host.execute_ns > 0);
        }
        assert!(
            spans.iter().any(|s| s.redirects >= 1 && s.host.retry_ns > 0),
            "redirected jobs must carry retry time in their span"
        );
        // The journal saw the injected faults and, once chip 1 crossed
        // its error threshold (round-robin guarantees ≥ 3 picks in 12
        // sequential requests), the quarantine transition.
        let events = fleet.obs().journal.since(0);
        assert!(events
            .iter()
            .any(|e| e.kind == EventKind::FaultFired && e.chip == Some(1)));
        assert!(events.iter().any(
            |e| e.kind == EventKind::ChipQuarantined && e.chip == Some(1)
        ));
        fleet.shutdown();
    }

    #[test]
    fn metrics_samples_unify_registry_and_fleet_stats() {
        let fleet = fleet_with(FleetConfig {
            chips: 2,
            queue_depth: 8,
            ..Default::default()
        });
        // Registry-owned metrics appear in the same snapshot.
        fleet.obs().registry.counter("bss2_test_custom", "Custom.").add(7);
        let trace = crate::ecg::gen::generate_trace(22, false, 1.0);
        for _ in 0..3 {
            fleet.classify_blocking(&trace).unwrap();
        }
        let samples = fleet.metrics_samples();
        let get = |name: &str| {
            samples.iter().find(|s| s.name == name).map(|s| s.value)
        };
        assert_eq!(get("bss2_test_custom"), Some(7.0));
        assert_eq!(get("bss2_fleet_served_total"), Some(3.0));
        assert_eq!(get("bss2_fleet_healthy_chips"), Some(2.0));
        assert!(get("bss2_sim_time_mean_us").unwrap() > 100.0);
        assert_eq!(
            samples
                .iter()
                .filter(|s| s.name == "bss2_chip_served_total")
                .count(),
            2,
            "one per-chip sample per replica"
        );
        // Stage quantiles are labeled by stage name.
        assert!(samples.iter().any(|s| s.name == "bss2_sim_stage_us"
            && s.labels.iter().any(|(k, v)| k == "stage" && v == "vmm")));
        // Both expositions render the same snapshot.
        let txt = crate::obs::expo::prometheus(&samples);
        assert!(txt.contains("bss2_fleet_served_total 3"), "{txt}");
        assert!(txt.contains("bss2_chip_served_total{chip=\"0\"}"), "{txt}");
        let json = crate::obs::expo::json_array(&samples);
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), samples.len());
        fleet.shutdown();
    }
}
