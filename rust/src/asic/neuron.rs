//! Spiking neuron model of the analog core (paper §II-A: AdEx neurons in
//! 1000-fold accelerated continuous time).
//!
//! The ECG showcase configures the neurons as linear integrators (see
//! [`super::array`]); this module models the *spiking* operation mode the
//! chip simultaneously supports — the paper's §V argues the key advantage of
//! BSS-2 is hosting CDNN layers and SNN layers on one substrate.  We
//! implement the leaky/adaptive-exponential integrate-and-fire dynamics with
//! forward-Euler integration in accelerated model time, enough to run small
//! SNN demos (`examples` / `repro snn`) on the same synapse arrays.


/// AdEx parameters (hardware-calibrated units: membrane in ADC-LSB-like
/// voltage units, time in microseconds of *accelerated* chip time).
#[derive(Debug, Clone, Copy)]
pub struct AdexParams {
    pub tau_mem_us: f64,
    pub tau_syn_us: f64,
    pub tau_adapt_us: f64,
    pub v_rest: f64,
    pub v_thresh: f64,
    pub v_reset: f64,
    /// Exponential slope; 0 disables the AdEx term (plain LIF).
    pub delta_t: f64,
    /// Sub-threshold adaptation strength.
    pub a: f64,
    /// Spike-triggered adaptation increment.
    pub b: f64,
    pub refractory_us: f64,
}

impl Default for AdexParams {
    fn default() -> Self {
        AdexParams {
            tau_mem_us: 10.0,
            tau_syn_us: 5.0,
            tau_adapt_us: 100.0,
            v_rest: 0.0,
            v_thresh: 60.0,
            v_reset: -10.0,
            delta_t: 2.0,
            a: 0.0,
            b: 8.0,
            refractory_us: 2.0,
        }
    }
}

impl AdexParams {
    pub fn lif() -> Self {
        AdexParams { delta_t: 0.0, a: 0.0, b: 0.0, ..Default::default() }
    }
}

/// State of one neuron circuit in spiking mode.
#[derive(Debug, Clone)]
pub struct NeuronState {
    pub v: f64,
    pub i_syn: f64,
    pub w_adapt: f64,
    pub refrac_until: f64,
    pub spikes: Vec<f64>,
}

impl NeuronState {
    pub fn new(p: &AdexParams) -> NeuronState {
        NeuronState {
            v: p.v_rest,
            i_syn: 0.0,
            w_adapt: 0.0,
            refrac_until: -1.0,
            spikes: Vec::new(),
        }
    }
}

/// A population of spiking neurons sharing parameters (one array column
/// group).  Forward-Euler at `dt_us` in accelerated time.
pub struct SpikingPopulation {
    pub p: AdexParams,
    pub neurons: Vec<NeuronState>,
    pub t_us: f64,
    pub dt_us: f64,
}

impl SpikingPopulation {
    pub fn new(n: usize, p: AdexParams) -> SpikingPopulation {
        SpikingPopulation {
            neurons: (0..n).map(|_| NeuronState::new(&p)).collect(),
            p,
            t_us: 0.0,
            dt_us: 0.1,
        }
    }

    /// Inject synaptic charge (from the synapse array) into neuron `i`.
    /// `weight` is the 6-bit signed weight; events come from the router.
    pub fn receive(&mut self, i: usize, weight: i8) {
        self.neurons[i].i_syn += weight as f64;
    }

    /// Advance one Euler step; returns indices of neurons that spiked.
    pub fn step(&mut self) -> Vec<usize> {
        let p = self.p;
        let dt = self.dt_us;
        self.t_us += dt;
        let mut spiked = Vec::new();
        for (i, n) in self.neurons.iter_mut().enumerate() {
            // Synaptic current decay.
            n.i_syn -= n.i_syn * dt / p.tau_syn_us;
            if self.t_us < n.refrac_until {
                continue;
            }
            // AdEx membrane dynamics.
            let exp_term = if p.delta_t > 0.0 {
                // lint:allow(det-float-intrinsic: AdEx spike term; libm exp fixed per build)
                p.delta_t * ((n.v - p.v_thresh) / p.delta_t).exp()
            } else {
                0.0
            };
            let dv = (-(n.v - p.v_rest) + exp_term + n.i_syn - n.w_adapt)
                * dt
                / p.tau_mem_us;
            n.v += dv;
            // Adaptation dynamics.
            let dw = (p.a * (n.v - p.v_rest) - n.w_adapt) * dt / p.tau_adapt_us;
            n.w_adapt += dw;
            if n.v >= p.v_thresh {
                n.v = p.v_reset;
                n.w_adapt += p.b;
                n.refrac_until = self.t_us + p.refractory_us;
                n.spikes.push(self.t_us);
                spiked.push(i);
            }
        }
        spiked
    }

    /// Run for `dur_us`, feeding a constant current into every neuron.
    pub fn run_constant_input(&mut self, current: f64, dur_us: f64) {
        let steps = (dur_us / self.dt_us).round() as usize;
        for _ in 0..steps {
            for n in &mut self.neurons {
                n.i_syn += current * self.dt_us / self.p.tau_syn_us;
            }
            self.step();
        }
    }

    pub fn rates_hz(&self, dur_us: f64) -> Vec<f64> {
        // Rates in *accelerated* time; biological equivalent is /1000.
        self.neurons
            .iter()
            .map(|n| n.spikes.len() as f64 / (dur_us * 1e-6))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lif_stays_at_rest_without_input() {
        let mut pop = SpikingPopulation::new(4, AdexParams::lif());
        for _ in 0..1000 {
            assert!(pop.step().is_empty());
        }
        assert!(pop.neurons.iter().all(|n| n.v.abs() < 1e-6));
    }

    #[test]
    fn strong_input_causes_spiking() {
        let mut pop = SpikingPopulation::new(2, AdexParams::lif());
        pop.run_constant_input(150.0, 200.0);
        assert!(!pop.neurons[0].spikes.is_empty(), "no spikes");
    }

    #[test]
    fn subthreshold_input_does_not_spike() {
        let mut pop = SpikingPopulation::new(1, AdexParams::lif());
        pop.run_constant_input(10.0, 200.0);
        assert!(pop.neurons[0].spikes.is_empty());
    }

    #[test]
    fn rate_increases_with_current() {
        let rate = |cur: f64| {
            let mut pop = SpikingPopulation::new(1, AdexParams::lif());
            pop.run_constant_input(cur, 500.0);
            pop.rates_hz(500.0)[0]
        };
        assert!(rate(200.0) > rate(100.0));
    }

    #[test]
    fn adaptation_slows_firing() {
        let spikes = |b: f64| {
            let p = AdexParams { b, delta_t: 0.0, ..Default::default() };
            let mut pop = SpikingPopulation::new(1, p);
            pop.run_constant_input(150.0, 500.0);
            pop.neurons[0].spikes.len()
        };
        assert!(spikes(30.0) < spikes(0.0));
    }

    #[test]
    fn refractory_enforced() {
        let mut pop = SpikingPopulation::new(1, AdexParams::lif());
        pop.run_constant_input(400.0, 100.0);
        let sp = &pop.neurons[0].spikes;
        assert!(sp.len() >= 2);
        for w in sp.windows(2) {
            assert!(w[1] - w[0] >= pop.p.refractory_us - 1e-9);
        }
    }

    #[test]
    fn synapse_events_drive_membrane() {
        let mut pop = SpikingPopulation::new(2, AdexParams::lif());
        pop.receive(0, 63);
        pop.step();
        assert!(pop.neurons[0].v > pop.neurons[1].v);
    }
}
