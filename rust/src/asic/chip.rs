//! Whole-ASIC composition: two array halves + event router + pass
//! configuration + the chip-level timing model (paper §II-A).
//!
//! [`NativeChip`] implements [`simd::ChipOps`] against the in-process
//! [`AnalogArray`] model — the engine used in mock mode, in tests, and as
//! the numeric cross-check for the PJRT artifact path (which implements the
//! same trait in `coordinator::engine`).

use super::array::{AnalogArray, ColumnCalib};
use super::consts as c;
use super::simd::ChipOps;
use crate::util::rng::SplitMix64;

/// Per-pass analog configuration (the "right-shift"/amplification setting).
#[derive(Debug, Clone, Copy)]
pub struct PassConfig {
    pub half: u8,
    pub scale: f32,
}

/// Activity counters feeding the timing/energy model.
#[derive(Debug, Default, Clone)]
pub struct ChipStats {
    pub events_sent: u64,
    pub vmm_cycles: u64,
    pub adc_reads: u64,
    pub simd_cycles: u64,
    /// Synapse-matrix rewrites (per-pass weight reconfigurations).
    pub weight_writes: u64,
}

/// Chip-level timing model: simulated nanoseconds per activity
/// (paper: 8 ns event period, 5 µs integration cycle).
///
/// `ns` stays the authoritative total (everything downstream — engine
/// sim-time, chip-time drift clocks — reads it); the per-category fields
/// split the same nanoseconds by pipeline stage so stage-level tracing
/// (`obs::trace`) can answer where an inference's time goes.  Every
/// `add_*` bumps its category and the total together, so the categories
/// always sum to `ns` exactly.
#[derive(Debug, Default, Clone)]
pub struct ChipTiming {
    pub ns: f64,
    /// Event streaming into the synapse drivers.
    pub events_ns: f64,
    /// Analog VMM integration cycles.
    pub integration_ns: f64,
    /// Synapse-matrix weight reconfigurations.
    pub weight_write_ns: f64,
    /// Parallel CADC readouts.
    pub adc_ns: f64,
    /// Embedded SIMD CPU post-processing.
    pub simd_ns: f64,
    /// Explicit waits (DMA handshakes etc.).
    pub wait_ns: f64,
}

impl ChipTiming {
    /// Streaming `n_events` into the synapse drivers.  Rows receive events
    /// back-to-back at `EVENT_PERIOD_NS`; the link layer interleaves across
    /// `LVDS_LINKS`, so the array-side period dominates for our bursts.
    pub fn add_event_burst(&mut self, n_events: usize) {
        let array_side = n_events as f64 * c::EVENT_PERIOD_NS;
        let link_side = (n_events * c::EVENT_PACKET_BITS) as f64
            / (c::LVDS_LINKS as f64 * c::LVDS_GBPS); // bits / (Gbit/s) = ns
        let ns = array_side.max(link_side);
        self.events_ns += ns;
        self.ns += ns;
    }

    /// One integration cycle incl. membrane reset (5 µs).
    pub fn add_integration(&mut self) {
        self.integration_ns += c::INTEGRATION_CYCLE_US * 1e3;
        self.ns += c::INTEGRATION_CYCLE_US * 1e3;
    }

    /// Rewrite one half's synapse matrix (per-pass weight reconfiguration).
    pub fn add_weight_write(&mut self) {
        self.weight_write_ns += c::WEIGHT_WRITE_US * 1e3;
        self.ns += c::WEIGHT_WRITE_US * 1e3;
    }

    /// Parallel CADC conversion + digital transfer of one half.
    pub fn add_adc_read(&mut self) {
        // 1024 parallel channels, 8-bit ramp conversion ~1.5 µs on BSS-2.
        self.adc_ns += 1.5e3;
        self.ns += 1.5e3;
    }

    pub fn add_simd_cycles(&mut self, cycles: u64) {
        let ns = cycles as f64 / super::simd::CLOCK_HZ * 1e9;
        self.simd_ns += ns;
        self.ns += ns;
    }

    /// Explicit wait (DMA handshake round trips, settling).
    pub fn add_wait_ns(&mut self, ns: f64) {
        self.wait_ns += ns;
        self.ns += ns;
    }

    pub fn us(&self) -> f64 {
        self.ns / 1e3
    }
}

/// In-process chip model: the numeric + timing reference implementation.
pub struct NativeChip {
    pub halves: [AnalogArray; c::N_HALVES],
    pub pass_scale: [f32; c::N_HALVES],
    pub relu_in_adc: bool,
    queued: [Vec<u8>; c::N_HALVES],
    adc_latch: [Vec<i16>; c::N_HALVES],
    /// DRAM slots (via the FPGA memory switch) for activations/results.
    pub slots: std::collections::BTreeMap<u8, Vec<i32>>,
    pub noise_rng: SplitMix64,
    pub noise_sigma: f64,
    pub stats: ChipStats,
    pub timing: ChipTiming,
}

impl NativeChip {
    pub fn new(calib: [ColumnCalib; c::N_HALVES], noise_seed: u64) -> NativeChip {
        let [c0, c1] = calib;
        NativeChip {
            halves: [
                AnalogArray::new(c::K_LOGICAL, c::N_COLS, c0),
                AnalogArray::new(c::K_LOGICAL, c::N_COLS, c1),
            ],
            pass_scale: [1.0; c::N_HALVES],
            relu_in_adc: false,
            queued: [vec![0; c::K_LOGICAL], vec![0; c::K_LOGICAL]],
            adc_latch: [vec![0; c::N_COLS], vec![0; c::N_COLS]],
            slots: Default::default(),
            noise_rng: SplitMix64::new(noise_seed),
            noise_sigma: c::NOISE_SIGMA,
            stats: ChipStats::default(),
            timing: ChipTiming::default(),
        }
    }

    pub fn nominal(noise_seed: u64) -> NativeChip {
        NativeChip::new(
            [
                ColumnCalib::nominal(c::N_COLS),
                ColumnCalib::nominal(c::N_COLS),
            ],
            noise_seed,
        )
    }

    /// Sample this cycle's temporal-noise realisation (physics on the real
    /// chip; from the PRNG here — the PJRT engine samples the *same* stream
    /// and passes it into the artifact, keeping both paths bit-identical).
    pub fn sample_noise(&mut self) -> Vec<f32> {
        let sigma = self.noise_sigma;
        (0..c::N_COLS)
            .map(|_| (sigma * self.noise_rng.gauss()) as f32)
            .collect()
    }

    pub fn set_scale(&mut self, half: u8, scale: f32) {
        self.pass_scale[half as usize] = scale;
    }
}

impl ChipOps for NativeChip {
    fn send_events(&mut self, half: u8, activations: &[i32]) {
        let q = &mut self.queued[half as usize];
        let mut n_events = 0;
        for (row, slot) in q.iter_mut().enumerate() {
            let v = activations
                .get(row)
                .copied()
                .unwrap_or(0)
                .clamp(0, c::X_MAX) as u8;
            *slot = v;
            if v > 0 {
                n_events += 1;
            }
        }
        self.stats.events_sent += n_events as u64;
        self.timing.add_event_burst(n_events);
    }

    fn run_vmm(&mut self, half: u8) -> anyhow::Result<()> {
        let h = half as usize;
        anyhow::ensure!(h < c::N_HALVES, "bad half {half}");
        let noise = self.sample_noise();
        let out = self.halves[h].integrate(
            &self.queued[h],
            self.pass_scale[h],
            &noise,
            self.relu_in_adc,
        );
        self.adc_latch[h] = out;
        self.queued[h].fill(0); // drivers consumed the events
        self.stats.vmm_cycles += 1;
        self.timing.add_integration();
        Ok(())
    }

    fn read_adc(&mut self, half: u8) -> Vec<i32> {
        self.stats.adc_reads += 1;
        self.timing.add_adc_read();
        self.adc_latch[half as usize]
            .iter()
            .map(|&x| x as i32)
            .collect()
    }

    fn load_slot(&mut self, slot: u8) -> Vec<i32> {
        self.slots.get(&slot).cloned().unwrap_or_default()
    }

    fn store_slot(&mut self, slot: u8, data: &[i32]) {
        self.slots.insert(slot, data.to_vec());
    }

    fn wait_dma(&mut self) {
        // DMA handshake latency (FPGA round trip over the link).
        self.timing.add_wait_ns(200.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_queue_and_clear() {
        let mut chip = NativeChip::nominal(1);
        chip.noise_sigma = 0.0;
        chip.halves[0].load_weights(&vec![1i8; c::K_LOGICAL * c::N_COLS]);
        chip.set_scale(0, 0.01);
        chip.send_events(0, &vec![10; c::K_LOGICAL]);
        assert_eq!(chip.stats.events_sent, c::K_LOGICAL as u64);
        chip.run_vmm(0).unwrap();
        let adc = chip.read_adc(0);
        // acc = 10*1*256 = 2560; v = 25.6 -> 26
        assert!(adc.iter().all(|&x| x == 26), "got {:?}", &adc[..4]);
        // Queue cleared: a second cycle integrates nothing.
        chip.run_vmm(0).unwrap();
        let adc2 = chip.read_adc(0);
        assert!(adc2.iter().all(|&x| x == 0));
    }

    #[test]
    fn noise_stream_is_deterministic() {
        let mut a = NativeChip::nominal(7);
        let mut b = NativeChip::nominal(7);
        assert_eq!(a.sample_noise(), b.sample_noise());
        assert_ne!(a.sample_noise(), NativeChip::nominal(8).sample_noise());
    }

    #[test]
    fn timing_accumulates() {
        let mut chip = NativeChip::nominal(1);
        chip.send_events(0, &vec![5; 64]);
        chip.run_vmm(0).unwrap();
        chip.read_adc(0);
        // 64 events * 8 ns + 5 µs + 1.5 µs = 7.012 µs
        assert!((chip.timing.us() - 7.012).abs() < 0.01,
                "got {}", chip.timing.us());
    }

    #[test]
    fn event_burst_respects_link_bandwidth() {
        let mut t = ChipTiming::default();
        t.add_event_burst(256);
        // array side: 2048 ns; link side: 256*24/(5*2) = 614 ns -> max = 2048
        assert!((t.ns - 2048.0).abs() < 1e-9);
    }

    #[test]
    fn timing_categories_sum_to_total() {
        let mut t = ChipTiming::default();
        t.add_event_burst(300);
        t.add_weight_write();
        t.add_integration();
        t.add_adc_read();
        t.add_simd_cycles(250);
        t.add_wait_ns(200.0);
        let sum = t.events_ns
            + t.integration_ns
            + t.weight_write_ns
            + t.adc_ns
            + t.simd_ns
            + t.wait_ns;
        assert!((sum - t.ns).abs() < 1e-9, "categories {sum} vs total {}", t.ns);
        assert!(t.weight_write_ns > 0.0 && t.wait_ns == 200.0);
    }

    #[test]
    fn bad_half_errors() {
        let mut chip = NativeChip::nominal(1);
        assert!(chip.run_vmm(5).is_err());
    }

    #[test]
    fn slots_roundtrip() {
        let mut chip = NativeChip::nominal(1);
        chip.store_slot(3, &[1, 2, 3]);
        assert_eq!(chip.load_slot(3), vec![1, 2, 3]);
        assert!(chip.load_slot(9).is_empty());
    }
}
