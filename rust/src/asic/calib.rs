//! Calibration routines for the analog network core (Weis et al., referenced
//! in the paper's contributions: "calibration routines for the analog
//! network core").
//!
//! The real system measures per-column gain/offset by sweeping known test
//! inputs and fitting the ADC response; the trained model then relies on the
//! *measured* fixed pattern.  Our substrate mirrors that: given an
//! uncalibrated [`AnalogArray`] (unknown gain/offset realisation), the
//! routines below recover the fixed pattern from test-pulse measurements —
//! exercising exactly the code path the paper's commissioning used.

use super::array::AnalogArray;
use super::consts as c;

/// Result of a per-column calibration measurement.
#[derive(Debug, Clone)]
pub struct CalibMeasurement {
    pub gain_est: Vec<f32>,
    pub offset_est: Vec<f32>,
    /// Residual rms between fit and measurements [LSB].
    pub residual_rms: f32,
}

/// Estimate per-column offsets: integrate with *no* input events; the ADC
/// then reads `offset + noise`.  Averaging `reps` cycles suppresses the
/// temporal noise by sqrt(reps).
pub fn measure_offsets(
    array: &AnalogArray,
    noise: impl FnMut(usize) -> Vec<f32>,
    reps: usize,
) -> Vec<f32> {
    let mut noise = noise;
    let zeros = vec![0u8; array.k];
    let mut acc = vec![0.0f64; array.n];
    for r in 0..reps {
        let nv = noise(r);
        let out = array.integrate(&zeros, 1.0, &nv, false);
        for (a, &o) in acc.iter_mut().zip(&out) {
            *a += o as f64;
        }
    }
    acc.into_iter().map(|a| (a / reps as f64) as f32).collect()
}

/// Estimate per-column gain with a two-point test-pulse measurement on a
/// uniform diagnostic weight pattern: send x_lo and x_hi on `rows_used`
/// rows of weight `w_test`, fit the slope.
pub fn measure_gains(
    array: &AnalogArray,
    offsets: &[f32],
    mut noise: impl FnMut(usize) -> Vec<f32>,
    scale: f32,
    w_test: i8,
    rows_used: usize,
    reps: usize,
) -> CalibMeasurement {
    let (x_lo, x_hi) = (4u8, 16u8);
    let mk = |x: u8| {
        let mut v = vec![0u8; array.k];
        v[..rows_used].fill(x);
        v
    };
    let charge = |x: u8| (x as f64) * (w_test as f64) * rows_used as f64;

    let mut lo_mean = vec![0.0f64; array.n];
    let mut hi_mean = vec![0.0f64; array.n];
    for r in 0..reps {
        let out_lo = array.integrate(&mk(x_lo), scale, &noise(2 * r), false);
        let out_hi = array.integrate(&mk(x_hi), scale, &noise(2 * r + 1), false);
        for n in 0..array.n {
            lo_mean[n] += out_lo[n] as f64;
            hi_mean[n] += out_hi[n] as f64;
        }
    }
    let reps_f = reps as f64;
    let d_charge = (charge(x_hi) - charge(x_lo)) * scale as f64;
    let mut gain_est = Vec::with_capacity(array.n);
    let mut offset_est = Vec::with_capacity(array.n);
    let mut resid = 0.0f64;
    for n in 0..array.n {
        let lo = lo_mean[n] / reps_f;
        let hi = hi_mean[n] / reps_f;
        let g = (hi - lo) / d_charge;
        gain_est.push(g as f32);
        // Offset consistent with the two points (should match `offsets`).
        let o = lo - g * charge(x_lo) * scale as f64;
        offset_est.push(o as f32);
        resid += (o - offsets[n] as f64).powi(2);
    }
    CalibMeasurement {
        gain_est,
        offset_est,
        residual_rms: ((resid / array.n as f64).sqrt()) as f32,
    }
}

/// Uniform diagnostic weight loaded for the gain fit.  Test-pulse
/// amplitude chosen so x_hi lands at ~100 LSB (16 * 32 * 64 * 0.003 = 98),
/// well inside the linear range.
pub const W_TEST: i8 = 32;
/// Rows driven by the test pulses.
pub const ROWS_TEST: usize = 64;
/// Per-column amplification during the diagnostic measurement.
pub const SCALE_TEST: f32 = 0.003;

/// End-to-end calibration of one array half: offsets then gains, with the
/// substrate's nominal temporal-noise sigma.
pub fn calibrate_half(
    array: &mut AnalogArray,
    rng: &mut crate::util::rng::SplitMix64,
    reps: usize,
) -> CalibMeasurement {
    calibrate_half_with(array, rng, reps, c::NOISE_SIGMA)
}

/// [`calibrate_half`] with an explicit measurement-noise sigma (the engine
/// passes its own, so noise-off ablations calibrate noise-free).
///
/// The gain fit needs a *known* uniform weight pattern: the serving
/// weights are saved, the [`W_TEST`] diagnostic pattern is written, and
/// the original synapse matrix is restored afterwards — so a calibration
/// is correct (and side-effect-free) mid-serving, whatever the array
/// currently holds.
pub fn calibrate_half_with(
    array: &mut AnalogArray,
    rng: &mut crate::util::rng::SplitMix64,
    reps: usize,
    sigma: f64,
) -> CalibMeasurement {
    let n = array.n;
    let mut mk_noise = |_r: usize| -> Vec<f32> {
        (0..n).map(|_| (sigma * rng.gauss()) as f32).collect()
    };
    let saved = array.weights.clone();
    array.load_weights(&vec![W_TEST; array.k * n]);
    let offsets = measure_offsets(array, &mut mk_noise, reps);
    let m = measure_gains(
        array, &offsets, mk_noise, SCALE_TEST, W_TEST, ROWS_TEST, reps,
    );
    // Exact restore: the saved weights were already on the 6-bit grid.
    array.weights = saved;
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asic::array::ColumnCalib;
    use crate::util::rng::SplitMix64;

    fn diagnostic_array(rng: &mut SplitMix64) -> AnalogArray {
        let calib = ColumnCalib::fixed_pattern(c::N_COLS, rng);
        let mut a = AnalogArray::new(c::K_LOGICAL, c::N_COLS, calib);
        a.load_weights(&vec![32i8; c::K_LOGICAL * c::N_COLS]);
        a
    }

    #[test]
    fn offsets_recovered_within_noise() {
        let mut rng = SplitMix64::new(11);
        let array = diagnostic_array(&mut rng);
        let sigma = c::NOISE_SIGMA;
        let mut nrng = SplitMix64::new(99);
        let est = measure_offsets(
            &array,
            |_| (0..array.n).map(|_| (sigma * nrng.gauss()) as f32).collect(),
            64,
        );
        for (e, t) in est.iter().zip(&array.calib.offset) {
            assert!((e - t).abs() < 1.5, "offset est {e} vs true {t}");
        }
    }

    #[test]
    fn gains_recovered_within_percent() {
        let mut rng = SplitMix64::new(12);
        let mut array = diagnostic_array(&mut rng);
        let m = calibrate_half(&mut array, &mut SplitMix64::new(5), 64);
        let mut worst = 0.0f32;
        for (e, t) in m.gain_est.iter().zip(&array.calib.gain) {
            worst = worst.max((e - t).abs() / t);
        }
        assert!(worst < 0.06, "worst relative gain error {worst}");
        assert!(m.residual_rms < 2.0, "residual {}", m.residual_rms);
    }

    #[test]
    fn calibration_is_correct_mid_serving() {
        // The array holds an arbitrary (non-uniform) serving matrix: the
        // routine must fit against its own diagnostic pattern — not
        // "whatever the array currently holds" — and restore the serving
        // weights afterwards.
        let mut rng = SplitMix64::new(21);
        let calib = ColumnCalib::fixed_pattern(c::N_COLS, &mut rng);
        let mut array = AnalogArray::new(c::K_LOGICAL, c::N_COLS, calib);
        let serving: Vec<i8> = (0..c::K_LOGICAL * c::N_COLS)
            .map(|i| ((i * 7 + 3) % 127) as i8 - 63)
            .collect();
        array.load_weights(&serving);
        let before = array.weights.clone();
        let m = calibrate_half(&mut array, &mut SplitMix64::new(6), 64);
        assert_eq!(array.weights, before, "serving weights restored");
        let mut worst = 0.0f32;
        for (e, t) in m.gain_est.iter().zip(&array.calib.gain) {
            worst = worst.max((e - t).abs() / t);
        }
        assert!(worst < 0.06, "worst relative gain error {worst}");
        assert!(m.residual_rms < 2.0, "residual {}", m.residual_rms);
    }

    #[test]
    fn averaging_improves_offset_estimate() {
        let mut rng = SplitMix64::new(13);
        let array = diagnostic_array(&mut rng);
        let sigma = c::NOISE_SIGMA;
        let err = |reps: usize, seed: u64| -> f32 {
            let mut nrng = SplitMix64::new(seed);
            let est = measure_offsets(
                &array,
                |_| {
                    (0..array.n)
                        .map(|_| (sigma * nrng.gauss()) as f32)
                        .collect()
                },
                reps,
            );
            est.iter()
                .zip(&array.calib.offset)
                .map(|(e, t)| (e - t).powi(2))
                .sum::<f32>()
                .sqrt()
        };
        // Averaged over many columns, more reps must shrink the rms error.
        assert!(err(64, 1) < err(2, 1));
    }
}
