//! Synapse correlation sensors + STDP plasticity (paper §II-A: "Each
//! synapse contains correlation sensors enabling spike-timing dependent
//! plasticity in SNNs", executed as freely-programmable learning rules on
//! the embedded SIMD CPUs — the capability that distinguishes BSS-2 from
//! Tianjic/MONETA in the paper's introduction).
//!
//! Model: each synapse integrates exponentially-weighted causal (pre→post)
//! and anti-causal (post→pre) correlation traces in analog storage; the
//! SIMD CPU periodically reads them through the parallel ADC and applies a
//! weight update on the 6-bit grid.  This reproduces the measurable
//! behaviour of the BSS-2 correlation sensors (Pehle et al.) without the
//! device physics.

use crate::util::rng::SplitMix64;

/// Correlation sensor of one synapse: analog causal/anti-causal traces.
#[derive(Debug, Clone, Default)]
pub struct CorrelationSensor {
    /// Causal accumulation a+ (pre before post).
    pub c_plus: f32,
    /// Anti-causal accumulation a- (post before pre).
    pub c_minus: f32,
}

/// Sensor parameters (accelerated-time constants, µs).
#[derive(Debug, Clone, Copy)]
pub struct SensorParams {
    pub tau_plus_us: f64,
    pub tau_minus_us: f64,
    /// Per-event trace increment.
    pub eta: f32,
    /// Analog storage saturates (paper: limited dynamic range).
    pub saturation: f32,
}

impl Default for SensorParams {
    fn default() -> Self {
        SensorParams {
            tau_plus_us: 20.0,
            tau_minus_us: 20.0,
            eta: 1.0,
            saturation: 63.0,
        }
    }
}

impl CorrelationSensor {
    /// Record a (pre, post) spike pair with `dt_us = t_post - t_pre`.
    pub fn record_pair(&mut self, dt_us: f64, p: &SensorParams) {
        if dt_us >= 0.0 {
            // lint:allow(det-float-intrinsic: STDP kernel; libm exp fixed per build)
            let w = (-dt_us / p.tau_plus_us).exp() as f32;
            self.c_plus = (self.c_plus + p.eta * w).min(p.saturation);
        } else {
            // lint:allow(det-float-intrinsic: STDP kernel; libm exp fixed per build)
            let w = (dt_us / p.tau_minus_us).exp() as f32;
            self.c_minus = (self.c_minus + p.eta * w).min(p.saturation);
        }
    }

    /// ADC readout with reset (the SIMD CPU reads and clears the sensors).
    pub fn read_and_reset(&mut self) -> (i8, i8) {
        let out = (self.c_plus.round() as i8, self.c_minus.round() as i8);
        self.c_plus = 0.0;
        self.c_minus = 0.0;
        out
    }
}

/// A plastic synapse row: sensors + 6-bit weights, updated by a
/// SIMD-CPU-style rule.
pub struct PlasticRow {
    pub weights: Vec<i8>,
    pub sensors: Vec<CorrelationSensor>,
    pub params: SensorParams,
}

impl PlasticRow {
    pub fn new(n: usize, init_w: i8, params: SensorParams) -> PlasticRow {
        PlasticRow {
            weights: vec![init_w.clamp(-63, 63); n],
            sensors: vec![CorrelationSensor::default(); n],
            params,
        }
    }

    /// Record spike pairs for synapse `i`.
    pub fn observe(&mut self, i: usize, dt_us: f64) {
        let p = self.params;
        self.sensors[i].record_pair(dt_us, &p);
    }

    /// The plasticity kernel the embedded processor runs: additive STDP
    /// `w += lr * (a+ - a-)`, clamped to the 6-bit grid.  `lr_shift` is the
    /// right-shift implementing the learning rate in integer arithmetic.
    pub fn apply_stdp(&mut self, lr_shift: u32) {
        for (w, s) in self.weights.iter_mut().zip(&mut self.sensors) {
            let (cp, cm) = s.read_and_reset();
            let dw = (cp as i32 - cm as i32) >> lr_shift;
            *w = (*w as i32 + dw).clamp(-63, 63) as i8;
        }
    }

    /// Drive the row with poisson pre/post spike trains of given rates for
    /// `dur_us`; returns the number of recorded pairs (nearest-neighbour
    /// pairing, as the hardware sensors implement).
    pub fn drive_poisson(
        &mut self,
        i: usize,
        pre_rate_hz: f64,
        post_rate_hz: f64,
        offset_us: f64,
        dur_us: f64,
        rng: &mut SplitMix64,
    ) -> usize {
        // Generate spike times (accelerated µs).
        let mk = |rate: f64, rng: &mut SplitMix64| -> Vec<f64> {
            let mut t = 0.0;
            let mut out = Vec::new();
            let mean_isi = 1e6 / rate;
            while t < dur_us {
                // lint:allow(det-float-intrinsic: seeded Poisson ISI; libm ln fixed per build)
                t += -mean_isi * rng.unit().max(1e-12).ln();
                if t < dur_us {
                    out.push(t);
                }
            }
            out
        };
        let pre = mk(pre_rate_hz, rng);
        let post: Vec<f64> =
            mk(post_rate_hz, rng).iter().map(|t| t + offset_us).collect();
        // Nearest-neighbour pairing.
        let mut pairs = 0;
        for &tp in &pre {
            if let Some(&tq) = post
                .iter()
                .min_by(|a, b| {
                    (*a - tp).abs().partial_cmp(&(*b - tp).abs()).unwrap()
                })
            {
                self.observe(i, tq - tp);
                pairs += 1;
            }
        }
        pairs
    }

    /// Drive with a causally locked pair process: pre spikes are poisson,
    /// each evokes a post spike `offset_us` later with probability
    /// `coupling` (a synaptically driven neuron), plus independent post
    /// noise.  This is the canonical STDP protocol.
    pub fn drive_locked(
        &mut self,
        i: usize,
        pre_rate_hz: f64,
        offset_us: f64,
        coupling: f64,
        dur_us: f64,
        rng: &mut SplitMix64,
    ) -> usize {
        let mean_isi = 1e6 / pre_rate_hz;
        let mut t = 0.0;
        let mut pairs = 0;
        while t < dur_us {
            // lint:allow(det-float-intrinsic: seeded Poisson ISI; libm ln fixed per build)
            t += -mean_isi * rng.unit().max(1e-12).ln();
            if t >= dur_us {
                break;
            }
            if rng.unit() < coupling {
                // The evoked post spike: dt = offset + 0.5 µs jitter.
                let dt = offset_us + 0.5 * rng.gauss();
                self.observe(i, dt);
                pairs += 1;
            }
        }
        pairs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn causal_pair_increments_cplus() {
        let mut s = CorrelationSensor::default();
        let p = SensorParams::default();
        s.record_pair(5.0, &p); // pre 5 µs before post
        assert!(s.c_plus > 0.0 && s.c_minus == 0.0);
        let w_near = s.c_plus;
        s.record_pair(40.0, &p); // distant pair adds less
        assert!(s.c_plus - w_near < w_near);
    }

    #[test]
    fn anticausal_pair_increments_cminus() {
        let mut s = CorrelationSensor::default();
        let p = SensorParams::default();
        s.record_pair(-5.0, &p);
        assert!(s.c_minus > 0.0 && s.c_plus == 0.0);
    }

    #[test]
    fn sensor_saturates() {
        let mut s = CorrelationSensor::default();
        let p = SensorParams { eta: 50.0, ..Default::default() };
        for _ in 0..10 {
            s.record_pair(0.1, &p);
        }
        assert!(s.c_plus <= p.saturation);
    }

    #[test]
    fn read_and_reset_clears() {
        let mut s = CorrelationSensor::default();
        let p = SensorParams::default();
        s.record_pair(1.0, &p);
        let (cp, cm) = s.read_and_reset();
        assert!(cp >= 1 && cm == 0);
        assert_eq!(s.c_plus, 0.0);
    }

    #[test]
    fn stdp_potentiates_causal_synapse() {
        let mut row = PlasticRow::new(2, 0, SensorParams::default());
        for _ in 0..20 {
            row.observe(0, 2.0); // causal
            row.observe(1, -2.0); // anti-causal
        }
        row.apply_stdp(2);
        assert!(row.weights[0] > 0, "causal synapse must potentiate");
        assert!(row.weights[1] < 0, "anti-causal synapse must depress");
    }

    #[test]
    fn weights_stay_on_grid() {
        let mut row = PlasticRow::new(1, 60, SensorParams::default());
        for _ in 0..100 {
            row.observe(0, 0.5);
        }
        row.apply_stdp(0);
        assert!(row.weights[0] <= 63);
    }

    #[test]
    fn poisson_correlated_drive_potentiates() {
        // Post following pre closely (positive offset) => net potentiation;
        // strongly anti-causal offset => net depression.
        let run = |offset: f64, seed: u64| -> i8 {
            let mut row = PlasticRow::new(1, 0, SensorParams::default());
            let mut rng = SplitMix64::new(seed);
            // Moderate rates + periodic updates so the analog sensors stay
            // below saturation between SIMD readouts (as on hardware).
            for _ in 0..10 {
                row.drive_locked(0, 10_000.0, offset, 0.8, 500.0, &mut rng);
                row.apply_stdp(0);
            }
            row.weights[0]
        };
        let potentiated = run(3.0, 11);
        let depressed = run(-3.0, 11);
        assert!(
            potentiated > depressed,
            "causal offset {potentiated} vs anti-causal {depressed}"
        );
    }
}
