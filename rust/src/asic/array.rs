//! Behavioural model of one analog synapse-array half (paper §II-A, Fig 4).
//!
//! This is the *native rust* implementation of exactly the semantics the L1
//! pallas kernel implements (and which `artifacts/vmm.hlo.txt` executes via
//! PJRT).  It serves three purposes:
//!   1. the reference cross-check against the compiled artifact
//!      (`tests/artifact_roundtrip.rs` must see identical ADC counts),
//!   2. the "mock-mode" fallback engine when artifacts are not present,
//!   3. the membrane-trace instrumentation behind Fig 4.
//!
//! Semantics per integration cycle:
//! ```text
//! acc[n]  = Σ_k x[k] · w[k,n]                    (charge accumulation)
//! v[n]    = scale · gain[n] · acc[n] + offset[n] + noise[n]
//! v[n]    = clip(v, ±MEMBRANE_CLIP)              (membrane saturation)
//! adc[n]  = clip(round(v[n]), ADC_MIN, ADC_MAX)  (8-bit parallel readout)
//! ```

use super::consts as c;

/// Static per-column analog state of one array half (from calibration).
#[derive(Debug, Clone)]
pub struct ColumnCalib {
    /// Per-column transconductance gain (~1 after calibration).
    pub gain: Vec<f32>,
    /// Per-column membrane/ADC offset [LSB].
    pub offset: Vec<f32>,
}

impl ColumnCalib {
    pub fn nominal(n: usize) -> ColumnCalib {
        ColumnCalib { gain: vec![1.0; n], offset: vec![0.0; n] }
    }

    /// Draw a fixed-pattern realisation (what the real chip's calibration
    /// routines measure; Weis et al.).
    pub fn fixed_pattern(n: usize, rng: &mut crate::util::rng::SplitMix64) -> ColumnCalib {
        let gain = (0..n)
            .map(|_| (1.0 + c::GAIN_FPN_SIGMA * rng.gauss()) as f32)
            .collect();
        let offset = (0..n)
            .map(|_| (c::OFFSET_FPN_SIGMA * rng.gauss()) as f32)
            .collect();
        ColumnCalib { gain, offset }
    }
}

/// Injected analog faults of one array half (`fault` subsystem).  These
/// are *silent* faults: they corrupt the conversion without erroring,
/// which is exactly why the calibration margin monitors exist.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArrayFaults {
    /// Columns whose synapse column is disconnected: accumulated charge
    /// reads as zero, so the column converts to offset + noise only.
    pub dead_columns: Vec<usize>,
    /// CADC reference collapse: every column of the half reads
    /// full-scale regardless of the accumulated charge.
    pub adc_saturated: bool,
}

impl ArrayFaults {
    pub fn is_clean(&self) -> bool {
        self.dead_columns.is_empty() && !self.adc_saturated
    }
}

/// One synapse-array half holding a static 6-bit weight matrix.
#[derive(Debug, Clone)]
pub struct AnalogArray {
    pub k: usize,
    pub n: usize,
    /// Row-major `[k][n]` signed 6-bit weights.
    pub weights: Vec<i8>,
    pub calib: ColumnCalib,
    /// Optional analog drift field: when present, the effective gain and
    /// offset wander around `calib` with chip time (`calib::drift`).
    pub drift: Option<crate::calib::drift::DriftState>,
    /// Currently injected faults (clean by default; `fault` subsystem).
    pub faults: ArrayFaults,
}

impl AnalogArray {
    pub fn new(k: usize, n: usize, calib: ColumnCalib) -> AnalogArray {
        assert_eq!(calib.gain.len(), n);
        AnalogArray {
            k,
            n,
            weights: vec![0; k * n],
            calib,
            drift: None,
            faults: ArrayFaults::default(),
        }
    }

    /// Inject (or, with a clean set, clear) analog faults.  Columns
    /// outside the half are ignored — a sloppy fault plan must degrade
    /// the chip, not panic the serving path.  Affects [`integrate`]
    /// conversions only; [`membrane_trace`] stays instrumentation of the
    /// healthy substrate.
    ///
    /// [`integrate`]: AnalogArray::integrate
    /// [`membrane_trace`]: AnalogArray::membrane_trace
    pub fn set_faults(&mut self, mut faults: ArrayFaults) {
        faults.dead_columns.retain(|&c| c < self.n);
        self.faults = faults;
    }

    pub fn clear_faults(&mut self) {
        self.faults = ArrayFaults::default();
    }

    /// Attach a drift field.  Fails fast on a column-count mismatch —
    /// deferring it would panic out-of-bounds mid-integration instead.
    pub fn set_drift(&mut self, drift: crate::calib::drift::DriftState) {
        assert_eq!(
            drift.columns(),
            self.n,
            "drift field columns must match the array half"
        );
        self.drift = Some(drift);
    }

    /// Advance this half's chip clock (no-op without a drift field).
    pub fn advance_us(&mut self, us: u64) {
        if let Some(d) = &mut self.drift {
            d.advance_us(us);
        }
    }

    /// Effective (drifted) per-column gain at the current chip time.
    #[inline]
    pub fn effective_gain(&self, col: usize) -> f32 {
        match &self.drift {
            Some(d) => self.calib.gain[col] * d.gain_factor(col),
            None => self.calib.gain[col],
        }
    }

    /// Effective (drifted) per-column offset at the current chip time.
    #[inline]
    pub fn effective_offset(&self, col: usize) -> f32 {
        match &self.drift {
            Some(d) => self.calib.offset[col] + d.offset_delta(col),
            None => self.calib.offset[col],
        }
    }

    /// Write the weight matrix (the "synapse matrix is filled with weight
    /// data" step of the paper's dataflow).  Values are clamped to the
    /// 6-bit grid like the synapse SRAM would.
    pub fn load_weights(&mut self, w: &[i8]) {
        assert_eq!(w.len(), self.k * self.n);
        for (dst, &src) in self.weights.iter_mut().zip(w) {
            *dst = src.clamp(-(c::W_MAX as i8), c::W_MAX as i8);
        }
    }

    #[inline]
    pub fn weight(&self, row: usize, col: usize) -> i8 {
        self.weights[row * self.n + col]
    }

    /// One full integration cycle: 5-bit activations in, 8-bit ADC counts
    /// out.  `noise` is this cycle's temporal-noise realisation [LSB].
    pub fn integrate(
        &self,
        x: &[u8],
        scale: f32,
        noise: &[f32],
        relu_in_adc: bool,
    ) -> Vec<i16> {
        let mut acc = vec![0i32; self.n];
        let mut out = vec![0i16; self.n];
        self.integrate_into(x, scale, noise, relu_in_adc, &mut acc, &mut out);
        out
    }

    /// [`integrate`] into caller-provided scratch: `acc` holds the exact
    /// charge accumulation, `out` the converted ADC counts (DESIGN.md §17).
    /// The allocating wrappers delegate here, so both spellings are
    /// bit-identical by construction.
    ///
    /// [`integrate`]: AnalogArray::integrate
    pub fn integrate_into(
        &self,
        x: &[u8],
        scale: f32,
        noise: &[f32],
        relu_in_adc: bool,
        acc: &mut [i32],
        out: &mut [i16],
    ) {
        assert_eq!(x.len(), self.k);
        assert_eq!(noise.len(), self.n);
        self.accumulate_into(x, acc);
        self.digitize_into(acc, scale, noise, relu_in_adc, out);
    }

    /// Integer charge accumulation only (exact; used by Fig 4 and tests).
    pub fn accumulate(&self, x: &[u8]) -> Vec<i32> {
        let mut acc = vec![0i32; self.n];
        self.accumulate_into(x, &mut acc);
        acc
    }

    /// [`accumulate`] into a caller-provided accumulator (overwritten, not
    /// summed into — the zero-fill is part of the contract).
    ///
    /// [`accumulate`]: AnalogArray::accumulate
    pub fn accumulate_into(&self, x: &[u8], acc: &mut [i32]) {
        assert_eq!(acc.len(), self.n);
        acc.fill(0);
        for (row, &xv) in x.iter().enumerate() {
            if xv == 0 {
                continue; // no event -> no synaptic current
            }
            let xv = xv.min(c::X_MAX as u8) as i32;
            let wrow = &self.weights[row * self.n..(row + 1) * self.n];
            for (a, &w) in acc.iter_mut().zip(wrow) {
                *a += xv * w as i32;
            }
        }
    }

    /// Analog front-end + ADC conversion of accumulated charge.
    pub fn digitize(
        &self,
        acc: &[i32],
        scale: f32,
        noise: &[f32],
        relu_in_adc: bool,
    ) -> Vec<i16> {
        let mut out = vec![0i16; acc.len()];
        self.digitize_into(acc, scale, noise, relu_in_adc, &mut out);
        out
    }

    /// [`digitize`] into a caller-provided output slice.
    ///
    /// [`digitize`]: AnalogArray::digitize
    pub fn digitize_into(
        &self,
        acc: &[i32],
        scale: f32,
        noise: &[f32],
        relu_in_adc: bool,
        out: &mut [i16],
    ) {
        assert_eq!(out.len(), acc.len());
        let lo = if relu_in_adc { 0.0 } else { c::ADC_MIN as f32 };
        for (n, (o, &a)) in out.iter_mut().zip(acc).enumerate() {
            if self.faults.adc_saturated {
                // Reference collapse: the comparator ramp never
                // crosses, every column latches full-scale.
                *o = c::ADC_MAX as i16;
                continue;
            }
            // A dead synapse column contributes no charge; the
            // front-end still converts its offset and noise.
            let a = if self.faults.dead_columns.contains(&n) { 0 } else { a };
            let v = scale * self.effective_gain(n) * a as f32
                + self.effective_offset(n)
                + noise[n];
            let v = v.clamp(-c::MEMBRANE_CLIP, c::MEMBRANE_CLIP);
            // jnp.round is roundTiesToEven; the CADC model matches it.
            let r = round_half_even(v);
            *o = r.clamp(lo, c::ADC_MAX as f32) as i16;
        }
    }

    /// Pre-ADC membrane voltage trace for a staged sequence of event
    /// sub-vectors — instrumentation behind paper Fig 4.  Returns the
    /// voltage of `col` after each event batch.
    pub fn membrane_trace(
        &self,
        batches: &[Vec<u8>],
        col: usize,
        scale: f32,
    ) -> Vec<f32> {
        let mut acc = 0i32;
        let mut out = Vec::with_capacity(batches.len());
        for batch in batches {
            assert_eq!(batch.len(), self.k);
            for (row, &xv) in batch.iter().enumerate() {
                acc += (xv.min(c::X_MAX as u8) as i32)
                    * self.weight(row, col) as i32;
            }
            let v = scale * self.effective_gain(col) * acc as f32
                + self.effective_offset(col);
            out.push(v.clamp(-c::MEMBRANE_CLIP, c::MEMBRANE_CLIP));
        }
        out
    }
}

/// Round-half-to-even, matching `jnp.round` / IEEE-754 roundTiesToEven so the
/// rust model agrees bit-for-bit with the pallas kernel and the HLO artifact.
///
/// Pure f32 arithmetic: the previous implementation cast the rounded value
/// to `i64` for the parity check (saturating — and wrong in spirit — for
/// magnitudes beyond the `i64` range) and stepped by `v.signum()`.  Here a
/// tie is `|r - v| == 0.5` exactly (representable, and impossible once the
/// f32 spacing exceeds 0.5, so huge values never take the branch), parity
/// is `r % 2.0` (exact for integral floats of any magnitude), and the even
/// neighbour is reached by stepping from `r` back across `v`.
#[inline]
pub fn round_half_even(v: f32) -> f32 {
    let r = v.round(); // ties away from zero
    if (r - v).abs() == 0.5 && r % 2.0 != 0.0 {
        // Tie on an odd integer: the even neighbour is one step back
        // toward zero, i.e. r minus the signed overshoot of ±0.5 doubled.
        r - (r - v) * 2.0
    } else {
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn small_array() -> AnalogArray {
        let mut a = AnalogArray::new(4, 3, ColumnCalib::nominal(3));
        #[rustfmt::skip]
        let w: Vec<i8> = vec![
            1, -2, 3,
            4, 5, -6,
            -7, 8, 9,
            10, -11, 12,
        ];
        a.load_weights(&w);
        a
    }

    #[test]
    fn accumulate_matches_manual_dot() {
        let a = small_array();
        let acc = a.accumulate(&[1, 2, 0, 3]);
        // col0: 1*1 + 2*4 + 3*10 = 39; col1: -2 + 10 - 33 = -25;
        // col2: 3 - 12 + 36 = 27
        assert_eq!(acc, vec![39, -25, 27]);
    }

    #[test]
    fn zero_input_zero_charge() {
        let a = small_array();
        assert_eq!(a.accumulate(&[0, 0, 0, 0]), vec![0, 0, 0]);
    }

    #[test]
    fn weights_clamped_to_grid() {
        let mut a = AnalogArray::new(1, 2, ColumnCalib::nominal(2));
        a.load_weights(&[127i8 as i8, -128i8 as i8]);
        assert_eq!(a.weight(0, 0), 63);
        assert_eq!(a.weight(0, 1), -63);
    }

    #[test]
    fn activations_clamped_to_5bit() {
        let mut a = AnalogArray::new(1, 1, ColumnCalib::nominal(1));
        a.load_weights(&[1]);
        assert_eq!(a.accumulate(&[255]), vec![31]);
    }

    #[test]
    fn digitize_applies_gain_offset_noise() {
        let mut a = AnalogArray::new(1, 2, ColumnCalib::nominal(2));
        a.calib.gain = vec![2.0, 1.0];
        a.calib.offset = vec![0.5, -1.0];
        a.load_weights(&[10, 10]);
        let out = a.integrate(&[10], 0.1, &[0.0, 0.25], false);
        // col0: 0.1*2*100 + 0.5 = 20.5 -> round-half-even = 20
        // col1: 0.1*1*100 - 1.0 + 0.25 = 9.25 -> 9
        assert_eq!(out, vec![20, 9]);
    }

    #[test]
    fn saturation_and_adc_clip() {
        let mut a = AnalogArray::new(2, 1, ColumnCalib::nominal(1));
        a.load_weights(&[63, 63]);
        let hi = a.integrate(&[31, 31], 1.0, &[0.0], false);
        assert_eq!(hi, vec![c::ADC_MAX as i16]);
        a.load_weights(&[-63, -63]);
        let lo = a.integrate(&[31, 31], 1.0, &[0.0], false);
        assert_eq!(lo, vec![c::ADC_MIN as i16]);
        let relu = a.integrate(&[31, 31], 1.0, &[0.0], true);
        assert_eq!(relu, vec![0]);
    }

    #[test]
    fn round_half_even_matches_numpy() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(1.2), 1.0);
        assert_eq!(round_half_even(-1.7), -2.0);
    }

    #[test]
    fn round_half_even_exhaustive_ties() {
        // Every representable .5 tie in the ADC-relevant range, both signs.
        for n in 0..2048i32 {
            let even = (2 * n) as f32;
            let odd = (2 * n + 1) as f32;
            // k + 0.5 rounds to the even neighbour on either side.
            assert_eq!(round_half_even(even + 0.5), even, "tie above {even}");
            assert_eq!(round_half_even(odd + 0.5), odd + 1.0, "tie above {odd}");
            assert_eq!(round_half_even(-(even + 0.5)), -even);
            assert_eq!(round_half_even(-(odd + 0.5)), -(odd + 1.0));
            // Non-ties still round to nearest.
            assert_eq!(round_half_even(even + 0.25), even);
            assert_eq!(round_half_even(odd + 0.75), odd + 1.0);
        }
    }

    #[test]
    fn round_half_even_large_magnitudes() {
        // Beyond 2^23 every f32 is integral: round is the identity and the
        // tie branch must never fire (no i64 cast to saturate any more).
        for v in [
            8_388_608.0f32,          // 2^23
            16_777_215.0,            // largest odd integral f32
            1e12, -1e12,             // far past 2^23
            9.3e18, -9.3e18,         // ≈ i64::MAX, the old cast's edge
            1e30, -1e30,             // far beyond the i64 range
            f32::MAX, f32::MIN,
        ] {
            assert_eq!(round_half_even(v), v, "integral {v} must be identity");
        }
        // Largest f32 values with a fractional part: spacing 0.5 at 2^22.
        assert_eq!(round_half_even(4_194_303.5), 4_194_304.0);
        assert_eq!(round_half_even(-4_194_303.5), -4_194_304.0);
        assert_eq!(round_half_even(4_194_302.5), 4_194_302.0);
    }

    #[test]
    fn round_half_even_with_negative_calib_offsets() {
        // Ties produced the way `digitize` produces them: accumulated
        // charge scaled then shifted by a *negative* calibration offset.
        let mut a = AnalogArray::new(1, 4, ColumnCalib::nominal(4));
        a.calib.offset = vec![-0.5, -1.5, -2.5, -3.5];
        a.load_weights(&[10, 10, 10, 10]);
        // acc = 10 * 10 = 100; v = 0.1 * 100 + offset = 99.5, 98.5, 97.5,
        // 96.5 -> round-half-even: 100, 98, 98, 96.
        let out = a.integrate(&[10], 0.1, &[0.0; 4], false);
        assert_eq!(out, vec![100, 98, 98, 96]);
    }

    #[test]
    fn into_variants_match_allocating_path() {
        let mut rng = SplitMix64::new(7);
        let mut a =
            AnalogArray::new(8, 5, ColumnCalib::fixed_pattern(5, &mut rng));
        let w: Vec<i8> =
            (0..40).map(|i| ((i * 7) % 127) as i8 - 63).collect();
        a.load_weights(&w);
        let x: Vec<u8> = (0..8).map(|i| (i * 5 % 33) as u8).collect();
        let noise: Vec<f32> = (0..5).map(|_| rng.gauss() as f32).collect();
        for relu in [false, true] {
            let owned = a.integrate(&x, 0.07, &noise, relu);
            // Deliberately dirty scratch: the `_into` contract overwrites.
            let mut acc = vec![123i32; 5];
            let mut out = vec![77i16; 5];
            a.integrate_into(&x, 0.07, &noise, relu, &mut acc, &mut out);
            assert_eq!(out, owned);
            assert_eq!(acc, a.accumulate(&x));
            let mut out2 = vec![-1i16; 5];
            a.digitize_into(&acc, 0.07, &noise, relu, &mut out2);
            assert_eq!(out2, owned);
        }
    }

    #[test]
    fn membrane_trace_monotone_accumulation() {
        let mut a = AnalogArray::new(2, 1, ColumnCalib::nominal(1));
        a.load_weights(&[5, 5]);
        let batches = vec![vec![1, 0], vec![0, 2], vec![3, 3]];
        let tr = a.membrane_trace(&batches, 0, 0.1);
        assert_eq!(tr.len(), 3);
        assert!(tr[0] < tr[1] && tr[1] < tr[2]);
        // Final value equals the full integration (before noise/rounding).
        let acc = a.accumulate(&[4, 5]);
        assert!((tr[2] - 0.1 * acc[0] as f32).abs() < 1e-6);
    }

    #[test]
    fn drift_field_shifts_conversion_deterministically() {
        use crate::calib::drift::{DriftParams, DriftState};
        let params = DriftParams {
            tau_us: 10_000.0,
            sigma_gain: 0.0,
            sigma_offset: 8.0,
            temp_amplitude_k: 0.0,
            ..Default::default()
        };
        let mk = || {
            let mut a = AnalogArray::new(1, 4, ColumnCalib::nominal(4));
            a.load_weights(&[10, 10, 10, 10]);
            a.set_drift(DriftState::new(4, 5, params));
            a
        };
        let mut a = mk();
        // Before any chip time passes, drift is the identity.
        assert_eq!(a.effective_gain(0), 1.0);
        assert_eq!(a.effective_offset(0), 0.0);
        let fresh = a.integrate(&[10], 0.1, &[0.0; 4], false);
        assert_eq!(fresh, vec![10, 10, 10, 10]);
        // After many relaxation times the offsets have wandered.
        a.advance_us(100_000);
        let moved: f32 =
            (0..4).map(|col| a.effective_offset(col).abs()).sum();
        assert!(moved > 0.01, "offsets did not wander: {moved}");
        // Identical seed + identical chip time => identical conversion.
        let mut b = mk();
        b.advance_us(100_000);
        assert_eq!(
            a.integrate(&[10], 0.1, &[0.0; 4], false),
            b.integrate(&[10], 0.1, &[0.0; 4], false)
        );
    }

    #[test]
    fn dead_columns_convert_offset_only() {
        let mut a = AnalogArray::new(1, 4, ColumnCalib::nominal(4));
        a.calib.offset = vec![0.0, 2.0, 0.0, -3.0];
        a.load_weights(&[10, 10, 10, 10]);
        let healthy = a.integrate(&[10], 0.1, &[0.0; 4], false);
        assert_eq!(healthy, vec![10, 12, 10, 7]);
        a.set_faults(ArrayFaults {
            dead_columns: vec![1, 3, 99], // 99 out of range: ignored
            adc_saturated: false,
        });
        assert_eq!(a.faults.dead_columns, vec![1, 3]);
        let faulted = a.integrate(&[10], 0.1, &[0.0; 4], false);
        // Dead columns read their offset only; live columns unchanged.
        assert_eq!(faulted, vec![10, 2, 10, -3]);
        a.clear_faults();
        assert!(a.faults.is_clean());
        assert_eq!(a.integrate(&[10], 0.1, &[0.0; 4], false), healthy);
    }

    #[test]
    fn adc_saturation_pins_every_column() {
        let mut a = AnalogArray::new(1, 3, ColumnCalib::nominal(3));
        a.load_weights(&[-10, 0, 10]);
        a.set_faults(ArrayFaults { dead_columns: vec![], adc_saturated: true });
        let out = a.integrate(&[10], 0.1, &[0.0; 3], false);
        assert_eq!(out, vec![c::ADC_MAX as i16; 3]);
        // ReLU mode saturates high too — full-scale is positive.
        let relu = a.integrate(&[10], 0.1, &[0.0; 3], true);
        assert_eq!(relu, vec![c::ADC_MAX as i16; 3]);
        a.clear_faults();
        assert_ne!(a.integrate(&[10], 0.1, &[0.0; 3], false), out);
    }

    #[test]
    fn fixed_pattern_statistics() {
        let mut rng = SplitMix64::new(3);
        let cal = ColumnCalib::fixed_pattern(4096, &mut rng);
        let gm: f32 = cal.gain.iter().sum::<f32>() / 4096.0;
        assert!((gm - 1.0).abs() < 0.01, "gain mean {gm}");
        let om: f32 = cal.offset.iter().sum::<f32>() / 4096.0;
        assert!(om.abs() < 0.2, "offset mean {om}");
    }
}
