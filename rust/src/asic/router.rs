//! Runtime-configurable event router (paper §II-A "Event Router").
//!
//! A digital crossbar distributes vector-input events from the link layer to
//! the synapse drivers of the two array halves.  Each event carries a 12-bit
//! address; the crossbar maps addresses to (half, logical row) targets.
//! Synapse-level address matching (the second event group used by fc1's
//! split, paper Fig 6) is represented by logical rows 128..255.

use std::collections::BTreeMap;

use super::consts as c;
use super::packets::Event;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Target {
    /// Array half: 0 = top (conv), 1 = bottom (fc layers).
    pub half: u8,
    /// Logical signed input row (0..K_LOGICAL).
    pub row: u16,
}

/// Crossbar configuration + statistics.
#[derive(Debug, Default)]
pub struct EventRouter {
    // BTreeMap, not HashMap: replay of a routed event burst must be
    // byte-identical run to run (lint: det-unordered-map).
    table: BTreeMap<u16, Vec<Target>>,
    pub delivered: u64,
    pub dropped: u64,
}

impl EventRouter {
    pub fn new() -> EventRouter {
        EventRouter::default()
    }

    /// Identity layout used by the ECG experiment: address a targets
    /// half `a / K_LOGICAL`, logical row `a % K_LOGICAL`.
    pub fn identity() -> EventRouter {
        let mut r = EventRouter::new();
        for half in 0..c::N_HALVES as u8 {
            for row in 0..c::K_LOGICAL as u16 {
                let addr = half as u16 * c::K_LOGICAL as u16 + row;
                r.connect(addr, Target { half, row });
            }
        }
        r
    }

    pub fn connect(&mut self, address: u16, target: Target) {
        self.table.entry(address).or_default().push(target);
    }

    pub fn clear(&mut self) {
        self.table.clear();
    }

    pub fn targets(&self, address: u16) -> &[Target] {
        self.table.get(&address).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Route one event; returns the targets it reached.
    pub fn route(&mut self, ev: &Event) -> Vec<Target> {
        match self.table.get(&ev.address) {
            Some(ts) if !ts.is_empty() => {
                self.delivered += 1;
                ts.clone()
            }
            _ => {
                self.dropped += 1;
                Vec::new()
            }
        }
    }

    /// Route a full event burst into per-half logical input vectors
    /// (the last event to hit a row wins, like re-triggering a driver).
    pub fn assemble(&mut self, events: &[Event]) -> [Vec<u8>; c::N_HALVES] {
        let mut halves: [Vec<u8>; c::N_HALVES] =
            [vec![0; c::K_LOGICAL], vec![0; c::K_LOGICAL]];
        for ev in events {
            for t in self.route(ev) {
                halves[t.half as usize][t.row as usize] = ev.payload;
            }
        }
        halves
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_covers_both_halves() {
        let r = EventRouter::identity();
        assert_eq!(
            r.targets(0),
            &[Target { half: 0, row: 0 }]
        );
        assert_eq!(
            r.targets(c::K_LOGICAL as u16 + 5),
            &[Target { half: 1, row: 5 }]
        );
    }

    #[test]
    fn unknown_address_dropped() {
        let mut r = EventRouter::identity();
        let got = r.route(&Event::new(0x0FFF, 3));
        assert!(got.is_empty());
        assert_eq!(r.dropped, 1);
        assert_eq!(r.delivered, 0);
    }

    #[test]
    fn multicast_fanout() {
        let mut r = EventRouter::new();
        r.connect(7, Target { half: 0, row: 1 });
        r.connect(7, Target { half: 1, row: 2 });
        let ts = r.route(&Event::new(7, 9));
        assert_eq!(ts.len(), 2);
        assert_eq!(r.delivered, 1);
    }

    /// Regression for the HashMap→BTreeMap conversion (DESIGN.md §16):
    /// the routing table must behave identically however the crossbar
    /// was programmed, so a replayed burst is byte-identical run to run.
    #[test]
    fn table_is_insertion_order_independent() {
        let wiring = [
            (3u16, Target { half: 0, row: 5 }),
            (900, Target { half: 1, row: 40 }),
            (3, Target { half: 1, row: 6 }),
            (41, Target { half: 0, row: 99 }),
        ];
        let mut fwd = EventRouter::new();
        for (a, t) in wiring {
            fwd.connect(a, t);
        }
        let mut rev = EventRouter::new();
        for (a, t) in wiring.iter().rev() {
            rev.connect(*a, *t);
        }
        // Multicast fanout per address keeps connect() order (it is a
        // Vec); only the *map* must not leak ordering.
        let burst: Vec<Event> =
            [3, 900, 41, 3, 7].iter().map(|&a| Event::new(a, 17)).collect();
        let a = fwd.assemble(&burst);
        let b = rev.assemble(&burst);
        assert_eq!(a, b, "programming order must not leak into the output");
        assert_eq!(fwd.targets(3).len(), 2);
        assert_eq!(a[0][5], 17);
        assert_eq!(a[1][6], 17);
        assert_eq!(a[1][40], 17);
        assert_eq!(a[0][99], 17);
        assert_eq!(fwd.dropped, 1); // address 7 unrouted
    }

    #[test]
    fn assemble_builds_input_vectors() {
        let mut r = EventRouter::identity();
        let evs = vec![
            Event::new(3, 11),
            Event::new(c::K_LOGICAL as u16 + 8, 22),
            Event::new(3, 13), // re-trigger wins
        ];
        let halves = r.assemble(&evs);
        assert_eq!(halves[0][3], 13);
        assert_eq!(halves[1][8], 22);
        assert_eq!(halves[0].iter().filter(|&&v| v != 0).count(), 1);
    }

    #[test]
    fn clear_resets_table() {
        let mut r = EventRouter::identity();
        r.clear();
        assert!(r.targets(0).is_empty());
    }
}
