//! Behavioural model of the BrainScaleS-2 ASIC (paper §II-A, Fig 3).
//!
//! * [`consts`] — hardware constants, mirrored against `hwmodel.py`.
//! * [`array`] — the analog synapse-array VMM (native twin of the L1 kernel).
//! * [`packets`] — event/memory packet formats of the digital core logic.
//! * [`router`] — the runtime-configurable event crossbar.
//! * [`simd`] — embedded SIMD CPUs: ISA + instruction-stream interpreter.
//! * [`chip`] — whole-ASIC composition + timing model.
//! * [`calib`] — analog calibration routines (offset/gain recovery).
//! * [`neuron`] — AdEx/LIF spiking mode (the SNN side of the substrate).

pub mod array;
pub mod calib;
pub mod chip;
pub mod consts;
pub mod neuron;
pub mod packets;
pub mod plasticity;
pub mod router;
pub mod simd;
