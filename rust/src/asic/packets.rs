//! Digital core logic: packet formats of the ASIC's transport layer
//! (paper §II-A "Digital Core Logic").
//!
//! Two traffic classes cross the high-speed serial links:
//!   * **event packets** — unsecured, low-latency vector-input/spike events
//!     (5-bit payload + routing address), optionally timestamped,
//!   * **memory packets** — secured (sequence-numbered, acknowledged)
//!     register/SRAM access from/to the SIMD CPUs and the FPGA.
//!
//! The wire encoding here is a faithful *behavioural* stand-in: framing and
//! sizes follow the paper's link budget (`EVENT_PACKET_BITS`), and the
//! playback/trace buffers and the link model account bandwidth with them.

use super::consts as c;

/// A vector-input (or spike) event: routed to synapse drivers by address.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Routing label: which logical input row group this event targets.
    pub address: u16,
    /// 5-bit activation payload (pulse length).
    pub payload: u8,
    /// Event time in nanoseconds of chip time (0 = untimestamped/real-time).
    pub timestamp_ns: u64,
}

impl Event {
    pub fn new(address: u16, payload: u8) -> Event {
        Event { address, payload: payload.min(c::X_MAX as u8), timestamp_ns: 0 }
    }

    pub fn at(mut self, t_ns: u64) -> Event {
        self.timestamp_ns = t_ns;
        self
    }

    /// Serialize to the 3-byte wire format: addr[11:0] | payload[4:0] |
    /// framing/parity bits.
    pub fn to_wire(&self) -> [u8; 3] {
        let addr = self.address & 0x0FFF;
        let b0 = (addr >> 4) as u8;
        let b1 = (((addr & 0xF) as u8) << 4) | (self.payload & 0x1F) >> 1;
        let b2 = ((self.payload & 0x1) << 7) | self.parity() & 0x7F;
        [b0, b1, b2]
    }

    pub fn from_wire(w: [u8; 3]) -> Option<Event> {
        let addr = ((w[0] as u16) << 4) | ((w[1] >> 4) as u16);
        let payload = ((w[1] & 0x0F) << 1) | (w[2] >> 7);
        let ev = Event { address: addr, payload, timestamp_ns: 0 };
        if ev.parity() & 0x7F == w[2] & 0x7F {
            Some(ev)
        } else {
            None // corrupted frame -> dropped by the link layer
        }
    }

    fn parity(&self) -> u8 {
        let mut p: u8 = 0x2A; // frame marker
        p ^= (self.address & 0xFF) as u8;
        p ^= (self.address >> 8) as u8;
        p ^= self.payload;
        p & 0x7F
    }

    pub const WIRE_BITS: usize = c::EVENT_PACKET_BITS;
}

/// Secured memory access (SIMD CPU <-> FPGA DRAM via the memory switch).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MemPacket {
    Read { addr: u32, len: u32, seq: u16 },
    ReadResp { data: Vec<u32>, seq: u16 },
    Write { addr: u32, data: Vec<u32>, seq: u16 },
    WriteAck { seq: u16 },
}

impl MemPacket {
    /// Wire size in bits (header 64 + payload words).
    pub fn wire_bits(&self) -> usize {
        match self {
            MemPacket::Read { .. } => 64,
            MemPacket::ReadResp { data, .. } => 64 + 32 * data.len(),
            MemPacket::Write { data, .. } => 64 + 32 * data.len(),
            MemPacket::WriteAck { .. } => 64,
        }
    }

    pub fn seq(&self) -> u16 {
        match self {
            MemPacket::Read { seq, .. }
            | MemPacket::ReadResp { seq, .. }
            | MemPacket::Write { seq, .. }
            | MemPacket::WriteAck { seq } => *seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_payload_clamped() {
        let e = Event::new(3, 200);
        assert_eq!(e.payload, 31);
    }

    #[test]
    fn event_wire_roundtrip() {
        for addr in [0u16, 1, 255, 4095] {
            for payload in [0u8, 1, 15, 31] {
                let e = Event::new(addr, payload);
                let w = e.to_wire();
                let d = Event::from_wire(w).expect("parity must hold");
                assert_eq!(d.address, addr);
                assert_eq!(d.payload, payload);
            }
        }
    }

    #[test]
    fn corrupted_frame_dropped() {
        let mut w = Event::new(77, 13).to_wire();
        w[0] ^= 0x10; // flip an address bit
        assert_eq!(Event::from_wire(w), None);
    }

    #[test]
    fn event_timestamping() {
        let e = Event::new(1, 2).at(5000);
        assert_eq!(e.timestamp_ns, 5000);
    }

    #[test]
    fn mem_packet_sizes() {
        assert_eq!(MemPacket::Read { addr: 0, len: 4, seq: 1 }.wire_bits(), 64);
        assert_eq!(
            MemPacket::Write { addr: 0, data: vec![0; 4], seq: 2 }.wire_bits(),
            64 + 128
        );
        assert_eq!(
            MemPacket::ReadResp { data: vec![0; 2], seq: 3 }.wire_bits(),
            128
        );
    }

    #[test]
    fn mem_packet_seq() {
        assert_eq!(MemPacket::WriteAck { seq: 9 }.seq(), 9);
    }
}
