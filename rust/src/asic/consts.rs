//! Hardware constants of the BSS-2 ASIC model — the rust mirror of
//! `python/compile/hwmodel.py`.  `artifacts/manifest.json` carries the
//! python values; `tests/artifact_roundtrip.rs` cross-checks every field so
//! the two sides can never drift apart silently.

// --- Array geometry ----------------------------------------------------------
/// Logical signed inputs per array half (with synapse address matching).
pub const K_LOGICAL: usize = 256;
/// Signed inputs that map 1:1 onto physical excitatory/inhibitory row pairs.
pub const K_SIGNED: usize = 128;
/// Physical synapse rows per array half.
pub const ROWS_PHYS: usize = 256;
/// Neuron columns per array half.
pub const N_COLS: usize = 256;
/// Array halves on the chip (top: conv, bottom: fc1+fc2).
pub const N_HALVES: usize = 2;
/// Quadrants of 128 neurons x (128x256) synapses (paper Fig 3).
pub const N_QUADRANTS: usize = 4;
/// Total neurons on the chip.
pub const N_NEURONS: usize = 512;
/// Total synapses on the chip (256 x 512, paper Eq. 1).
pub const N_SYNAPSES: usize = 256 * 512;

// --- Resolutions --------------------------------------------------------------
/// 6-bit weight magnitude.
pub const W_MAX: i32 = 63;
/// 5-bit input activation (pulse length).
pub const X_MAX: i32 = 31;
/// Signed 8-bit ADC range relative to V_reset.
pub const ADC_MIN: i32 = -128;
pub const ADC_MAX: i32 = 127;
/// Membrane saturation in ADC-LSB units (rails slightly beyond ADC range).
pub const MEMBRANE_CLIP: f32 = 160.0;

// --- Analog non-idealities ------------------------------------------------------
pub const GAIN_FPN_SIGMA: f64 = 0.06;
pub const OFFSET_FPN_SIGMA: f64 = 2.0;
pub const NOISE_SIGMA: f64 = 2.0;

// --- Requantisation (SIMD CPUs) --------------------------------------------------
pub const RELU_SHIFT: u32 = 2;

// --- Timing model (paper §II-A, Eq. 1-2) -----------------------------------------
/// Back-to-back synaptic input period (8 ns -> 125 MHz).
pub const EVENT_PERIOD_NS: f64 = 8.0;
/// Full VMM integration cycle incl. membrane reset.
pub const INTEGRATION_CYCLE_US: f64 = 5.0;
/// Rewriting one half's synapse matrix (per-pass weight reconfiguration:
/// 256 x 256 x 6 bit over the config bus).  Part of what the paper's 276 µs
/// per-inference figure spends outside the integration cycles; batching
/// pays it once per batch instead of once per sample (hxtorch's lever).
pub const WEIGHT_WRITE_US: f64 = 40.0;
/// LVDS links routed to the FPGA (of 8 on the ASIC).
pub const LVDS_LINKS: usize = 5;
/// Per-link bandwidth in Gbit/s.
pub const LVDS_GBPS: f64 = 2.0;
/// Event packet size on the link (bits): address + 5-bit payload + framing.
pub const EVENT_PACKET_BITS: usize = 24;

// --- Area model (paper Eq. 3) -----------------------------------------------------
pub const SYNAPSE_UM2: f64 = 8.0 * 12.0;
pub const DIE_MM2: f64 = 32.0;

// --- ECG model hyperparameters (paper Fig 6 instantiation) -------------------------
pub const ECG_FS_HZ: f64 = 150.0;
pub const ECG_WINDOW: usize = 2048;
pub const ECG_CHANNELS: usize = 2;
pub const POOL_WINDOW: usize = 32;
pub const PREPROC_SHIFT: u32 = 5;
pub const POOLED_LEN: usize = ECG_WINDOW / POOL_WINDOW;
pub const MODEL_IN: usize = POOLED_LEN * ECG_CHANNELS;

pub const CONV_KERNEL: usize = 8;
pub const CONV_STRIDE: usize = 2;
pub const CONV_CHANNELS: usize = 8;
pub const CONV_POSITIONS: usize = 32;
pub const CONV_PAD: usize = 3;
pub const CONV_OUT: usize = CONV_POSITIONS * CONV_CHANNELS;

pub const FC1_OUT: usize = 123;
pub const FC2_OUT: usize = 10;
pub const N_CLASSES: usize = 2;
pub const POOL_GROUP: usize = FC2_OUT / N_CLASSES;

// --- MAC counts --------------------------------------------------------------------
pub const MACS_CONV: usize = CONV_OUT * CONV_KERNEL * ECG_CHANNELS;
pub const MACS_FC1: usize = CONV_OUT * FC1_OUT;
pub const MACS_FC2: usize = FC1_OUT * FC2_OUT;
pub const MACS_TOTAL: usize = MACS_CONV + MACS_FC1 + MACS_FC2;
pub const OPS_TOTAL: usize = 2 * MACS_TOTAL;

/// Peak synapse-array rate, paper Eq. 1: 125 MHz * 256 * 512 * 2 Op.
pub fn peak_ops_per_s() -> f64 {
    (1e9 / EVENT_PERIOD_NS) * 256.0 * 512.0 * 2.0
}

/// Effective full-array VMM rate, paper Eq. 2: 1/5µs * 256 * 512 * 2 Op.
pub fn effective_ops_per_s() -> f64 {
    (1e6 / INTEGRATION_CYCLE_US) * 256.0 * 512.0 * 2.0
}

/// Synapse-array MAC area efficiency, paper Eq. 3 [TOp/(s mm^2)].
pub fn area_efficiency_tops_mm2() -> f64 {
    peak_ops_per_s() / 1e12 / (N_SYNAPSES as f64 * SYNAPSE_UM2 * 1e-6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_consistency() {
        assert_eq!(K_SIGNED * 2, ROWS_PHYS);
        assert_eq!(N_COLS * N_HALVES, N_NEURONS);
        assert_eq!(MODEL_IN, 128);
        assert_eq!(CONV_OUT, 256);
        // fc1 split occupies cols 0..246, fc2 cols 246..256 — exactly N_COLS.
        assert_eq!(2 * FC1_OUT + FC2_OUT, N_COLS);
    }

    #[test]
    fn paper_eq1_peak_rate() {
        // Paper Eq. 1: 32.8 TOp/s.
        assert!((peak_ops_per_s() / 1e12 - 32.768).abs() < 1e-9);
    }

    #[test]
    fn paper_eq2_effective_rate() {
        // Paper Eq. 2: ~52 GOp/s.
        assert!((effective_ops_per_s() / 1e9 - 52.4288).abs() < 1e-9);
    }

    #[test]
    fn paper_eq3_area_efficiency() {
        // Paper Eq. 3: 2.6 TOp/(s mm^2).
        let v = area_efficiency_tops_mm2();
        assert!((v - 2.6).abs() < 0.1, "got {v}");
    }

    #[test]
    fn mac_counts() {
        assert_eq!(MACS_CONV, 4096);
        assert_eq!(MACS_FC1, 31488);
        assert_eq!(MACS_FC2, 1230);
        assert_eq!(OPS_TOTAL, 2 * 36814);
    }
}
