//! Standalone inference engine (paper §II-D "Standalone Inference Mode").
//!
//! Composes the full per-trace dataflow of the mobile system:
//!
//! ```text
//! DRAM (raw 12-bit trace)
//!   → DMA controller → preprocessing chain (Fig 7) → activation slot
//!   → SIMD-CPU instruction stream (graph::ecg_network().lower()):
//!       trigger events → integration cycle → ADC read     (3 passes)
//!       digital ReLU / partial-sum / requantise / avg-pool / argmax
//!   → result slot (classification)
//! ```
//!
//! The analog passes execute on one of two interchangeable backends:
//! * **Pjrt** — the AOT artifact `vmm.hlo.txt` (the L1 pallas kernel lowered
//!   through L2), weights staged once as device buffers.  This is the
//!   production path; python never runs here.
//! * **Native** — the in-process `asic::array` model, used for mock mode
//!   and as a numerical cross-check (both backends must agree bit-exactly;
//!   `tests/engine_parity.rs`).
//!
//! Timing and energy are accounted per activity (DESIGN.md §6) and averaged
//! over 500-trace blocks by `coordinator::batch` exactly like the paper §IV.
//!
//! [`Engine::classify_batch`] additionally executes B traces as *one*
//! program, pass-major, so per-pass weight reconfigurations and the
//! control-flow overhead amortise over the batch (DESIGN.md §9) while
//! per-sample predictions stay bit-identical to sequential `classify`.

use crate::asic::array::{AnalogArray, ColumnCalib};
use crate::asic::chip::{ChipStats, ChipTiming};
use crate::asic::consts as c;
use crate::asic::simd::{ChipOps, Insn, SimdCpu};
use crate::calib::drift::{DriftParams, DriftState};
use crate::calib::profile::{CalibProfile, ColumnCorrection};
use crate::ecg::gen::Trace;
use crate::fault::{FaultCounters, FaultInjector, FAULT_TAG};
use crate::fpga::dma::{Descriptor, DmaController, Dram};
use crate::fpga::eventgen::{self, EventLut};
use crate::fpga::preprocess::StreamingPreprocessor;
use crate::nn::graph;
use crate::nn::mapping;
use crate::nn::weights::TrainedModel;
use crate::obs::trace::SimStages;
use crate::power::energy::{self, Activity, EnergyBreakdown};
use crate::runtime::client::{Runtime, StagedPass, VmmExecutable};
use crate::runtime::ArtifactDir;
use crate::util::rng::SplitMix64;

/// FPGA fabric clock for the preprocessing chain [Hz].
pub const FPGA_CLOCK_HZ: f64 = 100e6;

/// Per-*program* control-flow overhead [µs]: SIMD-CPU instruction fetch
/// from FPGA memory, DMA-descriptor programming round trips, event-generator
/// handshakes and trace readback.  Together with the two explicit per-pass
/// weight reconfigurations charged in `run_vmm` (2 ×
/// [`c::WEIGHT_WRITE_US`]), a standard single-trace inference lands at the
/// paper's 276 µs (Table 1) — the paper itself notes (§V) that the FPGA
/// round trips dominate and could be optimised away by an on-chip memory
/// controller.  A batched program ([`Engine::classify_batch`]) pays this
/// once per batch: one instruction stream, one descriptor program, one
/// readback.
pub const CONTROL_OVERHEAD_US: f64 = 128.0;

/// Chip time consumed by a program attempt that an injected whole-chip
/// death refuses [µs] — the host still programs descriptors and times
/// out waiting for the result.  Close to the paper's 276 µs inference so
/// failed probes age the chip at roughly the serving rate, which is what
/// lets *transient* deaths recover under the fleet's re-admission probes.
pub const FAULT_ATTEMPT_COST_US: u64 = 300;

/// Per-pass gradient tap captured by [`Engine::classify_batch_taps`]: the
/// activation vector the synapse drivers actually saw (post event
/// generation, 5-bit) and the ADC readout the digital chain consumed
/// (post compensation).  These two vectors per pass are exactly what the
/// straight-through estimator in `train::ste` needs to back-propagate
/// through the quantised forward — the chip-in-the-loop boundary of
/// hxtorch (arXiv:2006.13138).
#[derive(Debug, Clone, Default)]
pub struct PassTap {
    /// 5-bit input activations, `[K_LOGICAL]` (row order of the half).
    pub x: Vec<u8>,
    /// ADC readout after compensation, `[N_COLS]`.
    pub adc: Vec<i32>,
}

/// Which VMM implementation executes the analog passes.
pub enum Backend {
    Pjrt { vmm: VmmExecutable, staged: Vec<StagedPass> },
    Native { halves: Box<[AnalogArray; 2]> },
}

/// Result of one classification.
#[derive(Debug, Clone)]
pub struct Inference {
    /// Predicted class (0 = sinus, 1 = A-fib).
    pub pred: u8,
    /// Average-pooled class scores [ADC LSB].
    pub scores: [f32; 2],
    /// Simulated time of the inference [s].
    pub sim_time_s: f64,
    pub energy: EnergyBreakdown,
    /// Per-stage split of `sim_time_s` [µs per sample] — where the
    /// paper's 276 µs goes (obs stage tracing; sums to `sim_time_s`).
    pub stages: SimStages,
}

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub use_pjrt: bool,
    pub noise_seed: u64,
    /// Disable temporal noise (ablation).
    pub noise_off: bool,
    /// Zero-out the analog fixed pattern (ablation: ideal substrate).
    pub nominal_calib: bool,
    /// Fleet ordinal of this replica (stamped into calibration profiles).
    pub chip: usize,
    /// When set, the native arrays draw their *own* per-chip fixed-pattern
    /// realisation from this seed instead of trusting the trained model's
    /// calibration vectors — the heterogeneous-hardware regime the
    /// calibration subsystem exists for.  `None` keeps the legacy
    /// behaviour (the model's measured pattern IS the substrate).
    pub fpn_seed: Option<u64>,
    /// Analog drift field for the native arrays (`calib::drift`): the
    /// fixed pattern wanders with served chip time.  `None` = frozen.
    pub drift: Option<DriftParams>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            use_pjrt: true,
            noise_seed: 0x5EED,
            noise_off: false,
            nominal_calib: false,
            chip: 0,
            fpn_seed: None,
            drift: None,
        }
    }
}

impl EngineConfig {
    /// Derive the config of one fleet replica: same ablation switches,
    /// but a decorrelated noise stream per chip (golden-ratio stream
    /// split, as SplitMix64 seeds sequences).  Chip 0 keeps the base
    /// seed so a single-chip fleet is bit-identical to the paper setup.
    /// The fixed-pattern seed (when present) splits the same way, so
    /// every replica is a *different* piece of silicon.
    pub fn for_chip(self, chip: usize) -> EngineConfig {
        let split = (chip as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        EngineConfig {
            noise_seed: self.noise_seed.wrapping_add(split),
            fpn_seed: self.fpn_seed.map(|s| s.wrapping_add(split)),
            chip,
            ..self
        }
    }
}

pub struct Engine {
    pub model: TrainedModel,
    backend: Backend,
    stream: Vec<Insn>,
    // Chip-side state
    queued: [Vec<f32>; 2],
    adc_latch: [Vec<i32>; 2],
    next_pass: usize,
    /// Which pass's weights occupy the lower array half (fc1 and fc2
    /// share it); `usize::MAX` = undefined, so the first fc pass always
    /// reconfigures.  Persists across inferences like the real synapse
    /// SRAM does.
    half1_pass: usize,
    /// Batched execution only: noise realisations pre-drawn per
    /// (sample, pass) in *sample-major* order, flattened into one
    /// contiguous batch-major bank indexed at
    /// `(sample * 3 + pass) * N_COLS` (DESIGN.md §17), and the sample
    /// whose stream segment currently executes.  `None` on the
    /// sequential path and whenever sigma == 0 (`noise_off`).
    batch_noise: Option<Vec<f32>>,
    batch_sample: usize,
    /// Gradient taps, armed by the `*_taps` entry points: `run_vmm`
    /// records each pass's input activations and ADC readout per sample.
    /// `None` (the serving default) costs one branch per pass.
    taps: Option<Vec<[PassTap; 3]>>,
    noise_rng: SplitMix64,
    noise_sigma: f64,
    /// Per-pass scratch, reused so `run_vmm` and `send_events` are
    /// allocation-free in steady state (DESIGN.md §17): the sequential
    /// noise draw, a shared all-zero noise vector for sigma == 0, the
    /// quantised activation vector, the native accumulator/readout
    /// pair, and the event-generator input.
    noise_scratch: Vec<f32>,
    zero_noise: Vec<f32>,
    xq_scratch: Vec<u8>,
    vmm_acc: Vec<i32>,
    vmm_adc: Vec<i16>,
    acts_scratch: Vec<u8>,
    // Calibration & drift state (calib subsystem)
    /// Fleet ordinal (stamped into calibration profiles).
    chip_ordinal: usize,
    /// Simulated chip time served so far [µs] — drives the drift field.
    chip_time_us: u64,
    /// Chip time of the last applied calibration [µs].
    last_calib_us: u64,
    /// The applied calibration profile, if any.
    profile: Option<CalibProfile>,
    /// Per-half post-ADC correction derived from `profile`.
    compensation: Option<[ColumnCorrection; 2]>,
    /// Identity hash of the native substrate (`calib::substrate_hash`).
    /// `None` on PJRT — the staged artifact has no measurable substrate,
    /// so no profile ever applies to it.
    substrate: Option<u64>,
    /// Measurement-noise stream for recalibration runs (separate from the
    /// inference noise stream so recalibrating never perturbs serving
    /// reproducibility).
    calib_rng: SplitMix64,
    // Fault injection (fault subsystem; None = healthy hardware)
    /// Armed fault schedule, consulted once per program.
    faults: Option<FaultInjector>,
    /// This program's DMA transfer loses its frame (consumed by
    /// `preprocess`).
    pending_frame_drop: bool,
    /// Extra latency charged to this program [µs] (consumed by the
    /// timing accounting).
    pending_latency_us: f64,
    // FPGA-side state
    dram: Dram,
    lut: EventLut,
    // Accounting (reset per inference)
    chip_stats: ChipStats,
    chip_timing: ChipTiming,
    dma_time_ns: f64,
    dma_bytes: u64,
    pp_samples: u64,
    events_generated: u64,
    slots: std::collections::BTreeMap<u8, Vec<i32>>,
    backend_error: Option<anyhow::Error>,
}

impl Engine {
    /// Production constructor: load artifacts and stage weights on PJRT.
    pub fn from_artifacts(
        dir: &ArtifactDir,
        cfg: EngineConfig,
    ) -> anyhow::Result<Engine> {
        dir.require()?;
        let manifest = dir.load_manifest()?;
        let mut model = TrainedModel::load(&dir.weights())?;
        anyhow::ensure!(
            (model.scales[0] as f64 - manifest.scales[0]).abs() < 1e-6,
            "weights/manifest scale mismatch"
        );
        if cfg.nominal_calib {
            for h in 0..2 {
                model.gain[h] = vec![1.0; c::N_COLS];
                model.offset[h] = vec![0.0; c::N_COLS];
            }
        }
        let backend = if cfg.use_pjrt {
            let rt = Runtime::cpu()?;
            let vmm = rt.load_vmm(&dir.vmm_hlo())?;
            let staged = (0..3)
                .map(|p| {
                    let h = TrainedModel::pass_half(p);
                    vmm.stage_pass(
                        &model.pass_weights[p],
                        &model.gain[h],
                        &model.offset[h],
                        model.scales[p],
                    )
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            Backend::Pjrt { vmm, staged }
        } else {
            Self::native_backend(&model, &cfg)
        };
        Ok(Self::assemble(model, backend, cfg))
    }

    /// Mock-mode constructor: native arrays, no PJRT (used when artifacts
    /// are absent in unit tests, and for the backend-parity cross-check).
    pub fn native(model: TrainedModel, cfg: EngineConfig) -> Engine {
        let backend = Self::native_backend(&model, &cfg);
        Self::assemble(model, backend, cfg)
    }

    /// Stream-split constant for the *half* dimension.  Deliberately a
    /// different odd constant than the golden-ratio chip split used by
    /// [`EngineConfig::for_chip`]: with one shared constant, seed(chip,
    /// half=1) would equal seed(chip+1, half=0) and adjacent replicas
    /// would share bit-identical silicon on one half.
    const HALF_SPLIT: u64 = 0xC2B2_AE3D_27D4_EB4F;

    fn native_backend(model: &TrainedModel, cfg: &EngineConfig) -> Backend {
        let mk = |h: usize| {
            // With an `fpn_seed` the substrate is its own piece of silicon
            // (a seeded fixed-pattern realisation per half, decorrelated
            // per chip and per half); without one, the trained model's
            // calibration vectors define the substrate — the legacy
            // behaviour every existing test/bench relies on.
            let calib = match cfg.fpn_seed {
                Some(seed) => {
                    let mut rng = SplitMix64::new(seed.wrapping_add(
                        (h as u64).wrapping_mul(Self::HALF_SPLIT),
                    ));
                    ColumnCalib::fixed_pattern(c::N_COLS, &mut rng)
                }
                None => ColumnCalib {
                    gain: model.gain[h].clone(),
                    offset: model.offset[h].clone(),
                },
            };
            let mut a = AnalogArray::new(c::K_LOGICAL, c::N_COLS, calib);
            if let Some(params) = cfg.drift {
                a.set_drift(DriftState::new(
                    c::N_COLS,
                    cfg.noise_seed
                        .wrapping_add(0xD21F7)
                        .wrapping_add((h as u64).wrapping_mul(Self::HALF_SPLIT)),
                    params,
                ));
            }
            a
        };
        let mut h0 = mk(0);
        let h1 = mk(1);
        // The native backend holds i8 weights per half.  Half 0 (conv) is
        // written once here and never reconfigured; half 1 is shared by
        // passes 1 and 2 and written by `run_vmm` whenever the resident
        // pass changes (tracked in `half1_pass`).
        h0.load_weights(&mapping::to_i8(&model.pass_weights[0]));
        Backend::Native { halves: Box::new([h0, h1]) }
    }

    fn assemble(model: TrainedModel, backend: Backend, cfg: EngineConfig) -> Engine {
        let noise_sigma = if cfg.noise_off { 0.0 } else { model.noise_sigma };
        let substrate = match &backend {
            Backend::Native { halves } => {
                Some(crate::calib::substrate_hash(halves))
            }
            Backend::Pjrt { .. } => None,
        };
        Engine {
            stream: graph::ecg_network().lower(),
            backend,
            queued: [vec![0.0; c::K_LOGICAL], vec![0.0; c::K_LOGICAL]],
            adc_latch: [vec![0; c::N_COLS], vec![0; c::N_COLS]],
            next_pass: 0,
            half1_pass: usize::MAX,
            batch_noise: None,
            batch_sample: 0,
            taps: None,
            noise_rng: SplitMix64::new(cfg.noise_seed),
            noise_sigma,
            noise_scratch: vec![0.0; c::N_COLS],
            zero_noise: vec![0.0; c::N_COLS],
            xq_scratch: vec![0; c::K_LOGICAL],
            vmm_acc: vec![0; c::N_COLS],
            vmm_adc: vec![0; c::N_COLS],
            acts_scratch: Vec::new(),
            chip_ordinal: cfg.chip,
            chip_time_us: 0,
            last_calib_us: 0,
            profile: None,
            compensation: None,
            substrate,
            calib_rng: SplitMix64::new(cfg.noise_seed ^ 0xCA11_B8A7_E5EED),
            faults: None,
            pending_frame_drop: false,
            pending_latency_us: 0.0,
            dram: Dram::default(),
            lut: EventLut::identity(0, c::K_LOGICAL),
            chip_stats: ChipStats::default(),
            chip_timing: ChipTiming::default(),
            dma_time_ns: 0.0,
            dma_bytes: 0,
            pp_samples: 0,
            events_generated: 0,
            slots: Default::default(),
            model,
            backend_error: None,
        }
    }

    /// Draw one pass's noise realisation into `noise_scratch`.  With
    /// sigma == 0 (`noise_off`) both the draw and the RNG advance are
    /// skipped: the old per-column entries were `(0.0 * gauss()) as f32`,
    /// i.e. ±0.0, and `v + ±0.0` rounds to the same integer readout for
    /// every v, so skipping is readout-identical — and because
    /// `noise_sigma` is fixed at construction (calibration uses the
    /// separate `calib_rng`), the unconsumed RNG positions are never
    /// observable.
    fn sample_noise_into_scratch(&mut self) {
        if self.noise_sigma == 0.0 {
            return;
        }
        let sigma = self.noise_sigma;
        for n in self.noise_scratch.iter_mut() {
            *n = (sigma * self.noise_rng.gauss()) as f32;
        }
    }

    fn reset_accounting(&mut self) {
        self.chip_stats = ChipStats::default();
        self.chip_timing = ChipTiming::default();
        self.dma_time_ns = 0.0;
        self.dma_bytes = 0;
        self.pp_samples = 0;
        self.events_generated = 0;
        self.next_pass = 0;
    }

    /// Land one raw trace in DRAM and run the Fig-7 preprocessing chain,
    /// charging DMA + fabric time.  Returns the 5-bit activation vector.
    /// (USB mass storage → DRAM on the real system; we charge only the
    /// DMA read like the paper's block measurement, which starts "with
    /// raw ECG data in DRAM".)  Fails when an injected frame drop loses
    /// the transfer — a partial activation vector must never reach the
    /// chip silently.
    fn preprocess(&mut self, trace: &Trace) -> anyhow::Result<Vec<i32>> {
        let mut acts: Vec<i32> = Vec::with_capacity(c::MODEL_IN);
        let mut dma = DmaController::new();
        if self.pending_frame_drop {
            self.pending_frame_drop = false;
            dma.inject_drop();
        }
        for (ch, samples) in trace.samples.iter().enumerate() {
            let addr = (ch as u32) * 0x10_0000;
            self.dram.write_samples(addr, samples);
            let mut pp = StreamingPreprocessor::new();
            dma.run(
                &mut self.dram,
                Descriptor { src_addr: addr, n_samples: c::ECG_WINDOW },
                &mut pp,
            );
            self.pp_samples += c::ECG_WINDOW as u64;
            acts.extend(pp.out.iter().map(|&a| a as i32));
            // Preprocessing runs sample-per-clock in the fabric.
            self.dma_time_ns += pp.cycles as f64 / FPGA_CLOCK_HZ * 1e9;
        }
        self.dma_time_ns += dma.stats.time_ns;
        self.dma_bytes += dma.stats.bytes;
        if dma.stats.drops > 0 {
            // Like a refused program on a dead chip, the aborted attempt
            // still consumes chip time (descriptor round trips + host
            // timeout) — which is what lets a *transient* frame-drop
            // window expire under the fleet's re-admission probes
            // instead of quarantining the chip forever.
            self.advance_chip_time_us(FAULT_ATTEMPT_COST_US);
            anyhow::bail!(
                "{FAULT_TAG} dma frame dropped (raw trace lost in flight)"
            );
        }
        Ok(acts)
    }

    /// Classify one raw trace: the full paper dataflow.
    pub fn classify(&mut self, trace: &Trace) -> anyhow::Result<Inference> {
        self.reset_accounting();
        self.begin_faulted_program(true)?;
        let acts = self.preprocess(trace)?;
        self.run_stream(&acts)
    }

    /// Classify a batch of raw traces with amortised chip
    /// reconfiguration: the instruction stream executes *pass-major*
    /// (every sample's conv pass, then every sample's fc1 pass, …), so
    /// each per-pass weight configuration is written once per batch
    /// instead of once per sample, and the per-program control overhead
    /// is paid once.  Per-sample predictions and scores are bit-identical
    /// to sequential [`classify`](Engine::classify) calls on a fresh
    /// engine with the same seed (noise realisations are pre-drawn in
    /// sample-major order); per-sample *time and energy* drop with the
    /// batch size — the batching-vs-latency tradeoff against the paper's
    /// 276 µs single-trace figure.
    pub fn classify_batch(
        &mut self,
        traces: &[Trace],
    ) -> anyhow::Result<Vec<Inference>> {
        anyhow::ensure!(!traces.is_empty(), "empty batch");
        self.reset_accounting();
        self.begin_faulted_program(true)?;
        let acts_all = traces
            .iter()
            .map(|t| self.preprocess(t))
            .collect::<anyhow::Result<Vec<Vec<i32>>>>()?;
        self.run_stream_batch(&acts_all)
    }

    /// Classify from preprocessed activations (entry point for the
    /// streaming path, the fused model comparison, and kernel-level
    /// tests).  DMA frame-drop faults do not apply here — the raw-trace
    /// transfer happened FPGA-side in the incremental windower — but
    /// chip death, latency, link and array faults do.
    pub fn classify_acts(&mut self, acts: &[i32]) -> anyhow::Result<Inference> {
        self.reset_accounting();
        self.begin_faulted_program(false)?;
        self.run_stream(acts)
    }

    /// [`classify_batch`](Engine::classify_batch) with gradient taps: each
    /// sample's per-pass input activations and ADC readouts are recorded
    /// for the straight-through estimator (`train::ste`).  Numerically
    /// identical to `classify_batch` — the taps are copies of values the
    /// forward pass computes anyway.
    pub fn classify_batch_taps(
        &mut self,
        traces: &[Trace],
    ) -> anyhow::Result<(Vec<Inference>, Vec<[PassTap; 3]>)> {
        self.taps = Some(vec![Default::default(); traces.len()]);
        let run = self.classify_batch(traces);
        let taps = self.taps.take().expect("armed above");
        Ok((run?, taps))
    }

    /// [`classify_acts`](Engine::classify_acts) with gradient taps (the
    /// single-sample variant `tests` use for finite-difference checks).
    pub fn classify_acts_taps(
        &mut self,
        acts: &[i32],
    ) -> anyhow::Result<(Inference, [PassTap; 3])> {
        // The sequential path never touches `batch_sample`; pin it to the
        // single tap slot armed here.
        self.batch_sample = 0;
        self.taps = Some(vec![Default::default()]);
        let run = self.classify_acts(acts);
        let mut taps = self.taps.take().expect("armed above");
        Ok((run?, taps.pop().expect("one sample")))
    }

    /// Rewrite the serving weights in place (the training loop's
    /// per-step update path, and `serve`'s trained-artifact adoption).
    /// Native backend only — the PJRT artifact serves its staged weights,
    /// same refusal convention as [`apply_profile`](Engine::apply_profile).
    ///
    /// Half 0 (conv) is reloaded immediately; the shared half 1 is marked
    /// non-resident so the next program's first fc pass rewrites it (and
    /// charges its reconfiguration, as always).  The explicit half-0 write
    /// consumes chip time like any other weight write — training time
    /// ages the drift field, which is the point of in-the-loop training.
    pub fn load_model_weights(
        &mut self,
        pass_weights: &[mapping::PhysMatrix; 3],
        scales: [f32; 3],
    ) -> anyhow::Result<()> {
        match &mut self.backend {
            Backend::Native { halves } => {
                halves[0].load_weights(&mapping::to_i8(&pass_weights[0]));
            }
            Backend::Pjrt { .. } => anyhow::bail!(
                "weight reload requires the native backend (the PJRT \
                 artifact serves its staged weights)"
            ),
        }
        self.model.pass_weights = pass_weights.clone();
        self.model.scales = scales;
        self.half1_pass = usize::MAX;
        self.advance_chip_time_us(c::WEIGHT_WRITE_US as u64);
        Ok(())
    }

    /// Per-stage split of the *current* program's simulated time [µs]:
    /// the engine's per-category chip-time accounting plus DMA and the
    /// program-level control overhead.  By construction it sums to the
    /// program's `sim_time_s` (same addends, same order of magnitude
    /// splits the engine already charges).
    fn sim_stages(&self, control_us: f64) -> SimStages {
        let t = &self.chip_timing;
        SimStages {
            dma_us: self.dma_time_ns / 1e3,
            events_us: t.events_ns / 1e3,
            weight_write_us: t.weight_write_ns / 1e3,
            vmm_us: t.integration_ns / 1e3,
            adc_us: t.adc_ns / 1e3,
            simd_us: t.simd_ns / 1e3,
            wait_us: t.wait_ns / 1e3,
            control_us,
        }
    }

    fn run_stream(&mut self, acts: &[i32]) -> anyhow::Result<Inference> {
        anyhow::ensure!(acts.len() == c::MODEL_IN, "need {} acts", c::MODEL_IN);
        self.slots.insert(0, acts.to_vec());

        // 2. SIMD CPUs execute the standalone instruction stream.
        let mut cpu = SimdCpu::new();
        let stream = std::mem::take(&mut self.stream);
        let stats = cpu.execute(&stream, self);
        self.stream = stream;
        let stats = stats?;
        if let Some(err) = self.backend_error.take() {
            return Err(err);
        }
        self.chip_stats.simd_cycles += stats.cycles;
        self.chip_timing.add_simd_cycles(stats.cycles);

        let result = self
            .slots
            .get(&1)
            .ok_or_else(|| anyhow::anyhow!("no result stored"))?;
        let scores = [result[0] as f32, result[1] as f32];
        let pred = stats
            .argmax
            .ok_or_else(|| anyhow::anyhow!("stream did not classify"))?
            as u8;

        // 3. Timing + energy accounting (an injected latency spike is
        // charged to the program like any other FPGA round-trip stall).
        let latency_extra_us = std::mem::take(&mut self.pending_latency_us);
        let sim_time_s = (self.dma_time_ns + self.chip_timing.ns) / 1e9
            + (CONTROL_OVERHEAD_US + latency_extra_us) / 1e6;
        // Serving consumes chip time: the drift field wanders with it.
        self.advance_chip_time_us((sim_time_s * 1e6).round() as u64);
        let activity = Activity {
            chip: self.chip_stats.clone(),
            dma: crate::fpga::dma::DmaStats {
                transfers: 2,
                bytes: self.dma_bytes,
                time_ns: self.dma_time_ns,
                drops: 0,
            },
            preprocessed_samples: self.pp_samples,
            events_generated: self.events_generated,
            duration_s: sim_time_s,
        };
        Ok(Inference {
            pred,
            scores,
            sim_time_s,
            energy: energy::energy_of(&activity),
            stages: self.sim_stages(CONTROL_OVERHEAD_US + latency_extra_us),
        })
    }

    /// Batched stream execution: per-sample CPU/chip contexts advance
    /// segment by segment (pass-major), sharing one accounting pass.
    fn run_stream_batch(
        &mut self,
        acts_all: &[Vec<i32>],
    ) -> anyhow::Result<Vec<Inference>> {
        let b = acts_all.len();
        anyhow::ensure!(b >= 1, "empty batch");
        for acts in acts_all {
            anyhow::ensure!(
                acts.len() == c::MODEL_IN,
                "need {} acts",
                c::MODEL_IN
            );
        }
        // Pre-draw every (sample, pass) noise realisation into one flat
        // batch-major bank, filled in *sample-major* order — the order
        // the sequential path consumes the RNG — so each sample's result
        // stays bit-identical under pass-major execution.  With
        // sigma == 0 the bank (and the RNG advance) is skipped entirely;
        // `run_vmm` then borrows the shared zero vector instead.
        self.batch_noise = if self.noise_sigma != 0.0 {
            let sigma = self.noise_sigma;
            let mut bank = vec![0.0f32; b * 3 * c::N_COLS];
            for v in bank.iter_mut() {
                *v = (sigma * self.noise_rng.gauss()) as f32;
            }
            Some(bank)
        } else {
            None
        };
        let run = self.exec_segments(acts_all);
        self.batch_noise = None;
        let (ctxs, total_cycles) = run?;
        if let Some(err) = self.backend_error.take() {
            return Err(err);
        }
        self.chip_stats.simd_cycles += total_cycles;
        self.chip_timing.add_simd_cycles(total_cycles);

        // One batched program: control overhead (and any injected
        // latency spike) is per batch, not per sample.
        let latency_extra_us = std::mem::take(&mut self.pending_latency_us);
        let batch_time_s = (self.dma_time_ns + self.chip_timing.ns) / 1e9
            + (CONTROL_OVERHEAD_US + latency_extra_us) / 1e6;
        // Serving consumes chip time: the drift field wanders with it.
        self.advance_chip_time_us((batch_time_s * 1e6).round() as u64);
        let activity = Activity {
            chip: self.chip_stats.clone(),
            dma: crate::fpga::dma::DmaStats {
                transfers: 2 * b as u64,
                bytes: self.dma_bytes,
                time_ns: self.dma_time_ns,
                drops: 0,
            },
            preprocessed_samples: self.pp_samples,
            events_generated: self.events_generated,
            duration_s: batch_time_s,
        };
        let per_sample_energy =
            energy::energy_of(&activity).scaled(1.0 / b as f64);
        let sim_time_s = batch_time_s / b as f64;
        let per_sample_stages = self
            .sim_stages(CONTROL_OVERHEAD_US + latency_extra_us)
            .scaled(1.0 / b as f64);

        ctxs.into_iter()
            .map(|ctx| {
                let result = ctx
                    .slots
                    .get(&1)
                    .ok_or_else(|| anyhow::anyhow!("no result stored"))?;
                let pred = ctx
                    .argmax
                    .ok_or_else(|| anyhow::anyhow!("stream did not classify"))?
                    as u8;
                Ok(Inference {
                    pred,
                    scores: [result[0] as f32, result[1] as f32],
                    sim_time_s,
                    energy: per_sample_energy.clone(),
                    stages: per_sample_stages,
                })
            })
            .collect()
    }

    /// Run every stream segment for every sample (pass-major).  Returns
    /// the finished per-sample contexts and the total SIMD cycle count.
    fn exec_segments(
        &mut self,
        acts_all: &[Vec<i32>],
    ) -> anyhow::Result<(Vec<SampleCtx>, u64)> {
        let mut ctxs: Vec<SampleCtx> =
            acts_all.iter().map(|acts| SampleCtx::new(acts)).collect();
        let stream = std::mem::take(&mut self.stream);
        let mut total_cycles = 0u64;
        let mut failure: Option<anyhow::Error> = None;
        'outer: for segment in split_at_passes(&stream) {
            for (sample, ctx) in ctxs.iter_mut().enumerate() {
                self.batch_sample = sample;
                ctx.swap_with(self);
                let run = ctx.cpu.execute(segment, self);
                ctx.swap_with(self);
                match run {
                    Ok(stats) => {
                        total_cycles += stats.cycles;
                        if let Some(a) = stats.argmax {
                            ctx.argmax = Some(a);
                        }
                    }
                    Err(e) => {
                        failure = Some(e);
                        break 'outer;
                    }
                }
            }
        }
        self.stream = stream;
        match failure {
            Some(e) => Err(e),
            None => Ok((ctxs, total_cycles)),
        }
    }

    /// Total MACs per inference (for the Op/s figures in Table 1).
    pub fn macs_per_inference(&self) -> usize {
        c::MACS_TOTAL
    }

    // --- fault injection (fault subsystem) ---------------------------------

    /// Arm a fault schedule on this chip (`fault::FaultInjector`).  From
    /// now on every program start consults the schedule at the current
    /// chip time and applies whatever is active.
    ///
    /// Analog array faults (dead columns, ADC saturation) inject into
    /// the native array model only; arming them on a PJRT backend warns
    /// loudly — same convention as `apply_profile` refusing profiles on
    /// PJRT — because a chaos run must not report survival of faults
    /// that never physically happened.  Chip death, frame drops, link
    /// corruption, and latency spikes apply on both backends.
    pub fn arm_faults(&mut self, inj: FaultInjector) {
        if matches!(self.backend, Backend::Pjrt { .. })
            && inj.has_analog_faults()
        {
            log::warn!(
                "chip {}: fault plan contains analog array faults \
                 (dead_columns/adc_saturation) that cannot be injected \
                 into the staged PJRT artifact — they will NOT occur; \
                 use --native for analog fault experiments",
                self.chip_ordinal
            );
        }
        self.faults = Some(inj);
    }

    /// Running fault tally (None when no schedule is armed).
    pub fn fault_counters(&self) -> Option<&FaultCounters> {
        self.faults.as_ref().map(|f| f.counters())
    }

    /// Evaluate the armed fault schedule for the program starting now:
    /// refuse it outright (chip death), arm a frame drop for
    /// `preprocess` (only for `dma_transfer` programs — the streaming
    /// acts path has no raw-trace DMA to lose), set this program's
    /// latency surcharge and link BER, and (re)apply the active analog
    /// faults to the native halves.  No-op without an armed injector.
    fn begin_faulted_program(&mut self, dma_transfer: bool) -> anyhow::Result<()> {
        self.pending_frame_drop = false;
        self.pending_latency_us = 0.0;
        let Some(inj) = self.faults.as_mut() else {
            return Ok(());
        };
        let active = inj.begin_program(self.chip_time_us, dma_transfer);
        if active.chip_dead {
            // The host still talked to the chip and timed out: the
            // attempt consumes chip time, which is what lets transient
            // deaths age past their window under re-admission probes.
            self.advance_chip_time_us(FAULT_ATTEMPT_COST_US);
            anyhow::bail!(
                "{FAULT_TAG} injected chip death (chip {})",
                self.chip_ordinal
            );
        }
        if let Backend::Native { halves } = &mut self.backend {
            // `active.array` is clean outside fault windows, so this
            // also *clears* faults whose window just closed.
            for (h, half) in halves.iter_mut().enumerate() {
                half.set_faults(active.array[h].clone());
            }
        }
        self.pending_frame_drop = active.drop_frame;
        self.pending_latency_us = active.latency_extra_us;
        Ok(())
    }

    // --- calibration & drift (calib subsystem) -----------------------------

    /// Advance the chip clock (and the drift field) by `us` simulated µs.
    fn advance_chip_time_us(&mut self, us: u64) {
        self.chip_time_us += us;
        if let Backend::Native { halves } = &mut self.backend {
            for half in halves.iter_mut() {
                half.advance_us(us);
            }
        }
    }

    /// Let the chip age without serving (power-gated idle still drifts:
    /// temperature cycles, bias wander).  Used by benches/tests to reach
    /// interesting drift states quickly.
    pub fn advance_idle_us(&mut self, us: u64) {
        self.advance_chip_time_us(us);
    }

    /// Simulated chip time served/aged so far [µs].
    pub fn chip_time_us(&self) -> u64 {
        self.chip_time_us
    }

    /// Chip-time age of the applied calibration [µs] (chip time itself
    /// when nothing was ever applied).
    pub fn calib_age_us(&self) -> u64 {
        self.chip_time_us.saturating_sub(self.last_calib_us)
    }

    /// The applied calibration profile, if any.
    pub fn calib_profile(&self) -> Option<&CalibProfile> {
        self.profile.as_ref()
    }

    /// Whether [`recalibrate`](Engine::recalibrate) can run on this
    /// backend (only the native arrays expose the substrate for
    /// measurement).  The fleet reads this to exempt PJRT replicas from
    /// the auto-recalibration policy instead of draining them into a
    /// doomed measurement.
    pub fn supports_recalibration(&self) -> bool {
        matches!(self.backend, Backend::Native { .. })
    }

    /// Identity of the native substrate (`calib::substrate_hash` of the
    /// un-drifted base pattern), `None` on the PJRT backend.  A saved
    /// profile applies only to the silicon whose hash it carries.
    pub fn substrate_hash(&self) -> Option<u64> {
        self.substrate
    }

    /// Apply a calibration profile: every subsequent ADC readout is
    /// corrected against the profile's measured gain/offset
    /// (`calib::ColumnCorrection`), so MACs are compensated against the
    /// measured fixed pattern rather than the ideal one.
    ///
    /// The profile must have been measured on *this* substrate
    /// (verified via its identity hash): correcting against a pattern
    /// the silicon does not have would corrupt every inference instead
    /// of compensating it.  PJRT engines refuse all profiles — the
    /// staged artifact already serves its own calibration.
    pub fn apply_profile(
        &mut self,
        profile: &CalibProfile,
    ) -> anyhow::Result<()> {
        let ours = self.substrate.ok_or_else(|| {
            anyhow::anyhow!(
                "no measurable substrate: the PJRT artifact serves its \
                 staged calibration"
            )
        })?;
        anyhow::ensure!(
            ours == profile.substrate,
            "profile substrate {:016x} does not match this chip's {:016x} \
             (measured on different silicon — re-run `repro calibrate` \
             with this chip's backend and fpn-seed configuration)",
            profile.substrate,
            ours
        );
        self.compensation = Some([profile.correction(0), profile.correction(1)]);
        self.profile = Some(profile.clone());
        self.last_calib_us = self.chip_time_us;
        Ok(())
    }

    /// Full-chip recalibration: measure both array halves against the
    /// diagnostic pattern (serving weights are saved and restored —
    /// `asic::calib::calibrate_half_with`), apply the resulting profile,
    /// and charge the measurement's chip time.  The measurement sees the
    /// *drifted* pattern, which is exactly why a fresh profile recovers
    /// accuracy.  Only the native backend exposes the substrate for
    /// measurement; the PJRT path serves its staged calibration.
    pub fn recalibrate(&mut self, reps: usize) -> anyhow::Result<CalibProfile> {
        let reps = reps.max(1);
        let sigma = self.noise_sigma;
        let (chip, now_us) = (self.chip_ordinal, self.chip_time_us);
        let profile = match &mut self.backend {
            Backend::Native { halves } => {
                // Measure the substrate, not a transient injected fault:
                // a dead column reads near-zero gain and its "inverse"
                // correction would blow up.  Any active fault re-applies
                // at the next program start anyway.
                for half in halves.iter_mut() {
                    half.clear_faults();
                }
                CalibProfile::measure(
                    halves,
                    &mut self.calib_rng,
                    reps,
                    sigma,
                    chip,
                    now_us,
                )
            }
            Backend::Pjrt { .. } => anyhow::bail!(
                "recalibration requires the native backend (the PJRT \
                 artifact serves its staged calibration)"
            ),
        };
        let cost = CalibProfile::measurement_cost_us(reps).round() as u64;
        self.advance_chip_time_us(cost);
        self.apply_profile(&profile)
            .expect("a profile measured here matches this substrate");
        Ok(profile)
    }
}

/// Per-sample CPU/chip state for batched (pass-major) execution: each
/// sample owns its SIMD register file and chip-side latches, swapped into
/// the engine around each of its stream segments.
struct SampleCtx {
    cpu: SimdCpu,
    queued: [Vec<f32>; 2],
    adc_latch: [Vec<i32>; 2],
    next_pass: usize,
    slots: std::collections::BTreeMap<u8, Vec<i32>>,
    argmax: Option<usize>,
}

impl SampleCtx {
    fn new(acts: &[i32]) -> SampleCtx {
        let mut slots = std::collections::BTreeMap::new();
        slots.insert(0, acts.to_vec());
        SampleCtx {
            cpu: SimdCpu::new(),
            queued: [vec![0.0; c::K_LOGICAL], vec![0.0; c::K_LOGICAL]],
            adc_latch: [vec![0; c::N_COLS], vec![0; c::N_COLS]],
            next_pass: 0,
            slots,
            argmax: None,
        }
    }

    /// Exchange this sample's chip-side state with the engine's live
    /// fields (called before and after running one stream segment).
    fn swap_with(&mut self, eng: &mut Engine) {
        std::mem::swap(&mut self.queued, &mut eng.queued);
        std::mem::swap(&mut self.adc_latch, &mut eng.adc_latch);
        std::mem::swap(&mut self.next_pass, &mut eng.next_pass);
        std::mem::swap(&mut self.slots, &mut eng.slots);
    }
}

/// Split a lowered stream at analog-pass boundaries: segment 0 is the
/// prologue, each further segment starts at a `TriggerEvents` and carries
/// exactly one integration plus its digital epilogue.  Batched execution
/// runs each segment for all samples before advancing, which is what lets
/// a per-pass weight configuration be written once per batch.
fn split_at_passes(stream: &[Insn]) -> Vec<&[Insn]> {
    let mut cuts = vec![0usize];
    for (i, insn) in stream.iter().enumerate() {
        if i > 0 && matches!(insn, Insn::TriggerEvents { .. }) {
            cuts.push(i);
        }
    }
    cuts.push(stream.len());
    cuts.windows(2).map(|w| &stream[w[0]..w[1]]).collect()
}

impl ChipOps for Engine {
    fn send_events(&mut self, half: u8, activations: &[i32]) {
        // FPGA vector event generator: LUT lookup, zero suppression,
        // 8 ns spacing (fpga::eventgen), then the link + synapse drivers.
        // The quantised view lives in a reused scratch (DESIGN.md §17).
        self.acts_scratch.clear();
        self.acts_scratch
            .extend(activations.iter().map(|&a| a.clamp(0, c::X_MAX) as u8));
        let (events, gstats) =
            eventgen::generate(&self.acts_scratch, &self.lut, 0);
        self.events_generated += gstats.events as u64;
        self.chip_stats.events_sent += gstats.events as u64;
        self.chip_timing.add_event_burst(gstats.events);
        // Injected link corruption: the burst crosses the (fault-seeded)
        // link model, which drops frames that fail parity.  With no
        // active BER the burst passes through untouched.
        let events = match self.faults.as_mut() {
            Some(inj) => inj.transfer_events(events),
            None => events,
        };
        let q = &mut self.queued[half as usize];
        q.fill(0.0);
        for ev in &events {
            // Identity LUT: address == logical row for the half.
            let row = (ev.address as usize) % c::K_LOGICAL;
            q[row] = ev.payload as f32;
        }
    }

    fn run_vmm(&mut self, half: u8) -> anyhow::Result<()> {
        let h = half as usize;
        let pass = self.next_pass;
        anyhow::ensure!(pass < 3, "more passes than scheduled");
        anyhow::ensure!(
            TrainedModel::pass_half(pass) == h,
            "pass {pass} scheduled on wrong half {h}"
        );
        self.next_pass += 1;
        // Both fc passes share the lower half: entering a pass whose
        // weights are not resident reconfigures the synapse matrix.  Both
        // backends charge the same reconfiguration schedule (so the PJRT
        // and Native paths keep identical timing); the native backend
        // additionally performs the reload.  Under pass-major batched
        // execution the write therefore happens once per batch, not once
        // per sample.
        let reconfigure = pass >= 1 && self.half1_pass != pass;
        if reconfigure {
            self.half1_pass = pass;
            self.chip_stats.weight_writes += 1;
            self.chip_timing.add_weight_write();
        }
        // Scratch-buffer pass (DESIGN.md §17): the quantised activation
        // vector, the noise realisation, and the ADC readout all live in
        // reusable engine buffers — no per-pass heap traffic.
        for (q, &v) in self.xq_scratch.iter_mut().zip(self.queued[h].iter()) {
            *q = v as u8;
        }
        // Noise selection as a borrowed slice: a batched program indexes
        // the flat pre-drawn bank; the sequential path draws into the
        // engine scratch; sigma == 0 borrows the shared zero vector
        // (readout-identical to the old ±0.0 draws, see
        // `sample_noise_into_scratch`).
        if self.batch_noise.is_none() {
            self.sample_noise_into_scratch();
        }
        let noise: &[f32] = match &self.batch_noise {
            Some(bank) => {
                let at = (self.batch_sample * 3 + pass) * c::N_COLS;
                &bank[at..at + c::N_COLS]
            }
            None if self.noise_sigma != 0.0 => &self.noise_scratch,
            None => &self.zero_noise,
        };
        match &mut self.backend {
            Backend::Pjrt { vmm, staged } => {
                let res = vmm.run_pass(&staged[pass], &self.queued[h], noise)?;
                let latch = &mut self.adc_latch[h];
                latch.clear();
                latch.extend(res.iter().map(|&v| v as i32));
            }
            Backend::Native { halves } => {
                if reconfigure {
                    // The real chip holds fc1 and fc2 in disjoint columns
                    // of one static matrix — numerically identical because
                    // the column sets are disjoint and inputs are disjoint;
                    // we keep per-pass matrices for exactness.
                    halves[1].load_weights(&mapping::to_i8(
                        &self.model.pass_weights[pass],
                    ));
                }
                halves[h].integrate_into(
                    &self.xq_scratch,
                    self.model.scales[pass],
                    noise,
                    false,
                    &mut self.vmm_acc,
                    &mut self.vmm_adc,
                );
                let latch = &mut self.adc_latch[h];
                latch.clear();
                latch.extend(self.vmm_adc.iter().map(|&v| v as i32));
            }
        }
        if let Some(corr) = &self.compensation {
            // Profile compensation: the SIMD CPUs undo the measured
            // per-column gain/offset right after the parallel readout.
            corr[h].apply_i32(&mut self.adc_latch[h]);
        }
        if let Some(taps) = self.taps.as_mut() {
            // Gradient tap: what the synapse drivers saw and what the
            // digital chain will consume (post compensation) — the STE
            // boundary.  `batch_sample` is 0 on the sequential path
            // (pinned by `classify_acts_taps`).
            taps[self.batch_sample][pass] = PassTap {
                x: self.xq_scratch.clone(),
                adc: self.adc_latch[h].clone(),
            };
        }
        self.queued[h].fill(0.0);
        self.chip_stats.vmm_cycles += 1;
        self.chip_timing.add_integration();
        Ok(())
    }

    fn read_adc(&mut self, half: u8) -> Vec<i32> {
        self.chip_stats.adc_reads += 1;
        self.chip_timing.add_adc_read();
        self.adc_latch[half as usize].clone()
    }

    fn load_slot(&mut self, slot: u8) -> Vec<i32> {
        self.slots.get(&slot).cloned().unwrap_or_default()
    }

    fn store_slot(&mut self, slot: u8, data: &[i32]) {
        self.slots.insert(slot, data.to_vec());
    }

    fn wait_dma(&mut self) {
        self.chip_timing.add_wait_ns(200.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::{FaultKind, FaultPlan, FaultSpec};

    fn tiny_model() -> TrainedModel {
        // Hand-built weights: conv all-1 taps, fc1 identity-ish, fc2 routes
        // class energy; enough to check plumbing end to end.
        let wc = vec![1.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL];
        let mut w1 = vec![0.0; c::K_LOGICAL * c::FC1_OUT];
        for i in 0..c::FC1_OUT {
            w1[i * c::FC1_OUT + i] = 20.0;
        }
        let mut w2 = vec![0.0; c::FC1_OUT * c::FC2_OUT];
        for j in 0..c::FC2_OUT {
            w2[j * c::FC2_OUT + j] = 30.0;
        }
        TrainedModel {
            pass_weights: [
                mapping::pack_conv(&wc),
                mapping::pack_fc1(&w1),
                mapping::pack_fc2(&w2),
            ],
            scales: [0.05, 0.05, 0.1],
            gain: [vec![1.0; c::N_COLS], vec![1.0; c::N_COLS]],
            offset: [vec![0.0; c::N_COLS], vec![0.0; c::N_COLS]],
            noise_sigma: 0.0,
            train_metrics: Default::default(),
        }
    }

    #[test]
    fn native_engine_classifies_trace() {
        let mut eng = Engine::native(
            tiny_model(),
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        );
        let trace = crate::ecg::gen::generate_trace(5, false, 1.0);
        let inf = eng.classify(&trace).unwrap();
        assert!(inf.pred <= 1);
        assert!(inf.sim_time_s > 200e-6, "time {}", inf.sim_time_s);
        assert!(inf.sim_time_s < 400e-6, "time {}", inf.sim_time_s);
        assert!(inf.energy.total_j() > 0.0);
    }

    #[test]
    fn timing_lands_near_paper() {
        let mut eng = Engine::native(
            tiny_model(),
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        );
        let trace = crate::ecg::gen::generate_trace(6, true, 1.0);
        let inf = eng.classify(&trace).unwrap();
        let us = inf.sim_time_s * 1e6;
        assert!((us - 276.0).abs() < 30.0, "per-inference time {us} µs");
    }

    #[test]
    fn stage_breakdown_sums_to_sim_time() {
        let mut eng = Engine::native(
            tiny_model(),
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        );
        let trace = crate::ecg::gen::generate_trace(6, true, 1.0);
        let inf = eng.classify(&trace).unwrap();
        let total_us = inf.stages.total_us();
        assert!(
            (total_us - inf.sim_time_s * 1e6).abs() < 1e-6,
            "stages {total_us} µs vs sim {} µs",
            inf.sim_time_s * 1e6
        );
        // The known dominant stages of the 276 µs: 128 µs control,
        // 2x40 µs weight writes, 3x5 µs integrations, 3x1.5 µs ADC reads.
        assert_eq!(inf.stages.control_us, CONTROL_OVERHEAD_US);
        assert!((inf.stages.weight_write_us - 80.0).abs() < 1e-9);
        assert!((inf.stages.vmm_us - 15.0).abs() < 1e-9);
        assert!((inf.stages.adc_us - 4.5).abs() < 1e-9);
        assert!(inf.stages.events_us > 0.0 && inf.stages.simd_us > 0.0);

        // Batched: per-sample stages scale 1/B and still sum.
        let traces: Vec<_> = (0..4)
            .map(|i| crate::ecg::gen::generate_trace(20 + i, i % 2 == 0, 1.0))
            .collect();
        let infs = eng.classify_batch(&traces).unwrap();
        for inf in &infs {
            assert!(
                (inf.stages.total_us() - inf.sim_time_s * 1e6).abs() < 1e-6
            );
        }
        // Weight writes amortise: 2 per batch -> 80/4 µs per sample.
        assert!((infs[0].stages.weight_write_us - 20.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            Engine::native(
                tiny_model(),
                EngineConfig { use_pjrt: false, ..Default::default() },
            )
        };
        let trace = crate::ecg::gen::generate_trace(7, true, 1.0);
        let a = mk().classify(&trace).unwrap();
        let b = mk().classify(&trace).unwrap();
        assert_eq!(a.scores, b.scores);
        assert_eq!(a.pred, b.pred);
    }

    #[test]
    fn noise_off_vs_on_differ() {
        let trace = crate::ecg::gen::generate_trace(8, false, 1.0);
        let mut on = Engine::native(
            TrainedModel { noise_sigma: 2.0, ..tiny_model() },
            EngineConfig { use_pjrt: false, ..Default::default() },
        );
        let mut off = Engine::native(
            tiny_model(),
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        );
        let a = on.classify(&trace).unwrap();
        let b = off.classify(&trace).unwrap();
        // Scores may coincide after pooling, but usually differ.
        let _ = (a, b); // smoke: both complete
    }

    #[test]
    fn noise_off_skips_rng_draws_entirely() {
        // With sigma == 0 both the sequential and the batched path skip
        // the draw *and* the RNG advance (satellite of ISSUE 10): the old
        // ±0.0 realisations were readout-identical to the zero vector,
        // so results must be unchanged and the stream untouched.
        let seed = 0xD00Du64;
        let mk = |noise_seed: u64| {
            Engine::native(
                TrainedModel { noise_sigma: 2.0, ..tiny_model() },
                EngineConfig {
                    use_pjrt: false,
                    noise_off: true,
                    noise_seed,
                    ..Default::default()
                },
            )
        };
        let mut eng = mk(seed);
        let trace = crate::ecg::gen::generate_trace(70, false, 1.0);
        let single = eng.classify(&trace).unwrap();
        let traces: Vec<_> = (0..3)
            .map(|i| crate::ecg::gen::generate_trace(71 + i, i % 2 == 0, 1.0))
            .collect();
        let _ = eng.classify_batch(&traces).unwrap();
        assert_eq!(
            eng.noise_rng.next_u64(),
            SplitMix64::new(seed).next_u64(),
            "noise-off must not advance the noise RNG"
        );
        // And the results are noise-seed-independent: the skip changes
        // nothing the stream could have influenced.
        let other = mk(seed ^ 0x5EED).classify(&trace).unwrap();
        assert_eq!(single.scores, other.scores);
        assert_eq!(single.pred, other.pred);
    }

    #[test]
    fn noise_on_stream_position_survives_batching() {
        // A 1-batch pre-draws exactly the 3 realisations the sequential
        // path would consume, so a *later* classify on either engine
        // still reads the same stream position — the flat batch-major
        // bank (and the noise-off skip) must not perturb noise-on
        // streams.
        let model = || TrainedModel { noise_sigma: 2.0, ..tiny_model() };
        let cfg = EngineConfig { use_pjrt: false, ..Default::default() };
        let t1 = crate::ecg::gen::generate_trace(80, true, 1.0);
        let t2 = crate::ecg::gen::generate_trace(81, false, 1.0);
        let mut seq = Engine::native(model(), cfg.clone());
        let mut bat = Engine::native(model(), cfg);
        let a1 = seq.classify(&t1).unwrap();
        let a2 = seq.classify(&t2).unwrap();
        let b1 = bat.classify_batch(std::slice::from_ref(&t1)).unwrap();
        let b2 = bat.classify(&t2).unwrap();
        assert_eq!(a1.scores, b1[0].scores);
        assert_eq!(a2.scores, b2.scores, "bank draw shifted the RNG stream");
        assert_eq!(a2.pred, b2.pred);
    }

    #[test]
    fn three_passes_accounted() {
        let mut eng = Engine::native(
            tiny_model(),
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        );
        let trace = crate::ecg::gen::generate_trace(9, false, 1.0);
        let _ = eng.classify(&trace).unwrap();
        assert_eq!(eng.chip_stats.vmm_cycles, 3);
        assert_eq!(eng.chip_stats.adc_reads, 3);
        assert_eq!(eng.chip_stats.weight_writes, 2, "fc1 + fc2 reconfigure");
        assert!(eng.chip_stats.events_sent > 0);
        // Steady state: the next inference pays the same 2 writes.
        let _ = eng.classify(&trace).unwrap();
        assert_eq!(eng.chip_stats.weight_writes, 2);
    }

    #[test]
    fn rejects_bad_act_length() {
        let mut eng = Engine::native(
            tiny_model(),
            EngineConfig { use_pjrt: false, ..Default::default() },
        );
        assert!(eng.classify_acts(&[1, 2, 3]).is_err());
    }

    #[test]
    fn batch_of_one_matches_single_accounting_exactly() {
        // The fleet routes single requests through `classify_batch`, so a
        // 1-batch must reproduce `classify` bit-for-bit *including* the
        // timing and energy accounting.
        let mk = || {
            Engine::native(
                tiny_model(),
                EngineConfig { use_pjrt: false, ..Default::default() },
            )
        };
        let trace = crate::ecg::gen::generate_trace(12, true, 1.0);
        let (mut a, mut b) = (mk(), mk());
        let one = a.classify(&trace).unwrap();
        let batch = b.classify_batch(std::slice::from_ref(&trace)).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].pred, one.pred);
        assert_eq!(batch[0].scores, one.scores);
        assert_eq!(batch[0].sim_time_s, one.sim_time_s, "timing drifted");
        assert_eq!(
            batch[0].energy.total_j(),
            one.energy.total_j(),
            "energy drifted"
        );
    }

    #[test]
    fn taps_capture_the_forward_pass_and_change_nothing() {
        let mk = || {
            Engine::native(
                tiny_model(),
                EngineConfig { use_pjrt: false, ..Default::default() },
            )
        };
        let traces: Vec<_> = (0..3)
            .map(|i| crate::ecg::gen::generate_trace(30 + i, i % 2 == 0, 1.0))
            .collect();
        let plain = mk().classify_batch(&traces).unwrap();
        let (tapped, taps) = mk().classify_batch_taps(&traces).unwrap();
        assert_eq!(taps.len(), traces.len());
        for (a, b) in plain.iter().zip(&tapped) {
            assert_eq!(a.pred, b.pred, "taps must not perturb the forward");
            assert_eq!(a.scores, b.scores);
        }
        for t in &taps {
            for tap in t.iter() {
                assert_eq!(tap.x.len(), c::K_LOGICAL);
                assert_eq!(tap.adc.len(), c::N_COLS);
                assert!(tap.x.iter().all(|&v| v <= c::X_MAX as u8));
            }
            // The pass-0 tap is the preprocessed activation vector.
            assert!(t[0].x[..c::MODEL_IN].iter().any(|&v| v > 0));
        }
        // The sequential acts variant agrees with `classify_acts`.
        let acts: Vec<i32> = crate::fpga::preprocess::preprocess(
            &traces[0].samples,
        )
        .iter()
        .map(|&a| a as i32)
        .collect();
        let one = mk().classify_acts(&acts).unwrap();
        let (inf, tap) = mk().classify_acts_taps(&acts).unwrap();
        assert_eq!(inf.scores, one.scores);
        assert_eq!(tap[2].adc.len(), c::N_COLS);
    }

    #[test]
    fn load_model_weights_matches_fresh_engine() {
        let cfg = || EngineConfig {
            use_pjrt: false,
            noise_off: true,
            ..Default::default()
        };
        let mut eng = Engine::native(tiny_model(), cfg());
        let trace = crate::ecg::gen::generate_trace(31, true, 1.0);
        let _ = eng.classify(&trace).unwrap();
        let other = TrainedModel::synthetic(3);
        let t0 = eng.chip_time_us();
        eng.load_model_weights(&other.pass_weights, other.scales).unwrap();
        assert!(eng.chip_time_us() > t0, "weight write consumes chip time");
        let after = eng.classify(&trace).unwrap();
        // The reloaded engine serves exactly what a fresh engine built
        // from the same model serves (noise off ⇒ comparable).
        let fresh = Engine::native(other, cfg()).classify(&trace).unwrap();
        assert_eq!(after.scores, fresh.scores);
        assert_eq!(after.pred, fresh.pred);
    }

    /// Acceptance property: `classify_batch(B)[i]` is bit-identical to
    /// `classify(trace_i)` on a fresh engine with the same seed, for
    /// random batch sizes, seeds, and traces — noise ON, so the
    /// sample-major noise bank is exercised.
    #[test]
    fn classify_batch_parity_property() {
        crate::util::propcheck::check("classify_batch_parity", 6, 0xBA7C9, |g| {
            let b = g.usize_in(1, 6);
            let noise_seed = g.rng.next_u64();
            let model = TrainedModel { noise_sigma: 2.0, ..tiny_model() };
            let cfg = EngineConfig {
                use_pjrt: false,
                noise_seed,
                ..Default::default()
            };
            let traces: Vec<_> = (0..b)
                .map(|i| {
                    crate::ecg::gen::generate_trace(
                        g.rng.next_u64() % 10_000,
                        i % 2 == 0,
                        1.0,
                    )
                })
                .collect();
            let mut seq = Engine::native(model.clone(), cfg.clone());
            let mut batched = Engine::native(model, cfg);
            let got =
                batched.classify_batch(&traces).map_err(|e| e.to_string())?;
            for (i, trace) in traces.iter().enumerate() {
                let want = seq.classify(trace).map_err(|e| e.to_string())?;
                crate::prop_assert!(
                    got[i].pred == want.pred && got[i].scores == want.scores,
                    "sample {i}/{b}: batch ({}, {:?}) != seq ({}, {:?})",
                    got[i].pred,
                    got[i].scores,
                    want.pred,
                    want.scores
                );
            }
            Ok(())
        });
    }

    #[test]
    fn batch_amortises_reconfiguration_and_overhead() {
        let mk = || {
            Engine::native(
                tiny_model(),
                EngineConfig {
                    use_pjrt: false,
                    noise_off: true,
                    ..Default::default()
                },
            )
        };
        let traces: Vec<_> = (0..8)
            .map(|i| crate::ecg::gen::generate_trace(60 + i, i % 2 == 1, 1.0))
            .collect();
        let mut single = mk();
        let one = single.classify(&traces[0]).unwrap();

        let mut batched = mk();
        let infs = batched.classify_batch(&traces).unwrap();
        assert_eq!(infs.len(), 8);
        // 2 weight writes per *batch* (fc1 + fc2), 3 integrations/sample.
        assert_eq!(batched.chip_stats.weight_writes, 2);
        assert_eq!(batched.chip_stats.vmm_cycles, 24);
        // Per-sample time drops well below the 276 µs single-trace figure
        // because control overhead + weight writes are shared.
        assert!(
            infs[0].sim_time_s < one.sim_time_s * 0.5,
            "batched {} vs single {}",
            infs[0].sim_time_s,
            one.sim_time_s
        );
        // Monotone amortisation over growing batches.
        let mut prev = f64::INFINITY;
        for b in [1usize, 2, 4, 8] {
            let mut eng = mk();
            let infs = eng.classify_batch(&traces[..b]).unwrap();
            assert!(
                infs[0].sim_time_s < prev,
                "B={b}: {} !< {prev}",
                infs[0].sim_time_s
            );
            prev = infs[0].sim_time_s;
        }
    }

    #[test]
    fn empty_batch_rejected() {
        let mut eng = Engine::native(
            tiny_model(),
            EngineConfig { use_pjrt: false, ..Default::default() },
        );
        assert!(eng.classify_batch(&[]).is_err());
    }

    #[test]
    fn chip_time_advances_with_serving_and_idle() {
        let mut eng = Engine::native(
            tiny_model(),
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        );
        assert_eq!(eng.chip_time_us(), 0);
        let trace = crate::ecg::gen::generate_trace(40, false, 1.0);
        let inf = eng.classify(&trace).unwrap();
        let t1 = eng.chip_time_us();
        assert_eq!(t1, (inf.sim_time_s * 1e6).round() as u64);
        eng.advance_idle_us(1_000);
        assert_eq!(eng.chip_time_us(), t1 + 1_000);
        // No profile ever applied: the whole chip life is the calib age.
        assert_eq!(eng.calib_age_us(), t1 + 1_000);
        // A batch advances chip time once, by the batch program time.
        let traces: Vec<_> = (0..4)
            .map(|i| crate::ecg::gen::generate_trace(41 + i, i % 2 == 0, 1.0))
            .collect();
        let before = eng.chip_time_us();
        let infs = eng.classify_batch(&traces).unwrap();
        let batch_us = infs[0].sim_time_s * 1e6 * 4.0;
        let grew = (eng.chip_time_us() - before) as f64;
        assert!((grew - batch_us).abs() <= 1.0, "batch {batch_us} vs {grew}");
    }

    #[test]
    fn recalibration_stamps_profile_and_resets_age() {
        let mut eng = Engine::native(
            tiny_model(),
            EngineConfig {
                use_pjrt: false,
                noise_off: true,
                fpn_seed: Some(0xF1),
                chip: 7,
                ..Default::default()
            },
        );
        eng.advance_idle_us(5_000);
        assert!(eng.calib_profile().is_none());
        let p = eng.recalibrate(16).unwrap();
        assert_eq!(p.chip, 7);
        assert_eq!(p.chip_time_us, 5_000, "stamped at measurement start");
        assert_eq!(p.reps, 16);
        assert!(eng.calib_profile().is_some());
        assert_eq!(eng.calib_age_us(), 0, "age resets at application");
        // The measurement itself consumed chip time.
        let cost = CalibProfile::measurement_cost_us(16).round() as u64;
        assert_eq!(eng.chip_time_us(), 5_000 + cost);
    }

    /// The heart of the subsystem: on a drifted chip, a *fresh* profile
    /// recovers (near-)ideal predictions while a stale day-0 profile
    /// deviates measurably.
    #[test]
    fn recalibration_compensates_a_drifted_chip() {
        let drift = DriftParams {
            tau_us: 100_000.0,
            sigma_gain: 0.05,
            sigma_offset: 8.0,
            temp_amplitude_k: 0.0,
            ..Default::default()
        };
        let mk = |drift: Option<DriftParams>| {
            Engine::native(
                tiny_model(),
                EngineConfig {
                    use_pjrt: false,
                    noise_off: true,
                    fpn_seed: Some(0xF1D0),
                    drift,
                    ..Default::default()
                },
            )
        };
        let traces: Vec<_> = (0..8)
            .map(|i| crate::ecg::gen::generate_trace(900 + i, i % 2 == 0, 1.0))
            .collect();
        // Reference: same silicon, freshly compensated, frozen pattern.
        let mut fresh = mk(None);
        fresh.recalibrate(64).unwrap();
        let reference: Vec<[f32; 2]> = traces
            .iter()
            .map(|t| fresh.classify(t).unwrap().scores)
            .collect();

        let dev_of = |eng: &mut Engine| -> f64 {
            let mut dev = 0.0f64;
            for (t, want) in traces.iter().zip(&reference) {
                let got = eng.classify(t).unwrap().scores;
                dev += (got[0] - want[0]).abs() as f64
                    + (got[1] - want[1]).abs() as f64;
            }
            dev / (2.0 * traces.len() as f64)
        };

        // Stale arm: day-0 profile, then 20 relaxation times of drift.
        let mut stale = mk(Some(drift));
        stale.recalibrate(64).unwrap();
        stale.advance_idle_us(2_000_000);
        let dev_stale = dev_of(&mut stale);

        // Recalibrated arm: identical silicon + drift path, but the
        // profile is re-measured after the wander.
        let mut recal = mk(Some(drift));
        recal.recalibrate(64).unwrap();
        recal.advance_idle_us(2_000_000);
        recal.recalibrate(64).unwrap();
        let dev_recal = dev_of(&mut recal);

        assert!(
            dev_stale > 2.0,
            "stale profile must deviate measurably, got {dev_stale}"
        );
        assert!(
            dev_recal < dev_stale,
            "recalibration must beat the stale profile \
             ({dev_recal} vs {dev_stale})"
        );
        assert!(
            dev_recal <= 8.0,
            "fresh profile must track the ideal substrate, got {dev_recal}"
        );
    }

    #[test]
    fn apply_profile_refuses_foreign_substrates() {
        let mk = |seed: u64| {
            Engine::native(
                tiny_model(),
                EngineConfig {
                    use_pjrt: false,
                    noise_off: true,
                    fpn_seed: Some(seed),
                    ..Default::default()
                },
            )
        };
        let mut a = mk(0xA);
        let profile = a.recalibrate(16).unwrap();
        assert_eq!(a.substrate_hash(), Some(profile.substrate));

        // Same seed = same silicon: the saved profile applies.
        let mut twin = mk(0xA);
        twin.apply_profile(&profile).unwrap();
        assert!(twin.calib_profile().is_some());

        // Different seed = different silicon: applying the inverse
        // gain/offset of chip A would corrupt chip B, so it is refused.
        let mut b = mk(0xB);
        assert_ne!(b.substrate_hash(), a.substrate_hash());
        let err = b.apply_profile(&profile).unwrap_err();
        assert!(err.to_string().contains("different silicon"), "{err}");
        assert!(b.calib_profile().is_none(), "refusal leaves no profile");
        // The per-chip split of `EngineConfig::for_chip` is a different
        // substrate too — a chip-0 measurement must not apply to chip 1.
        let cfg = EngineConfig {
            use_pjrt: false,
            noise_off: true,
            fpn_seed: Some(0xA),
            ..Default::default()
        };
        let mut chip1 = Engine::native(tiny_model(), cfg.for_chip(1));
        assert!(chip1.apply_profile(&profile).is_err());
    }

    fn armed(model: TrainedModel, plan: FaultPlan) -> Engine {
        let mut eng = Engine::native(
            model,
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        );
        if let Some(inj) = FaultInjector::from_plan(&plan, 0) {
            eng.arm_faults(inj);
        }
        eng
    }

    fn one_fault(kind: FaultKind, at_us: u64, duration_us: Option<u64>) -> FaultPlan {
        FaultPlan {
            seed: 5,
            faults: vec![FaultSpec { chip: 0, at_us, duration_us, kind }],
        }
    }

    #[test]
    fn injected_chip_death_errors_then_ages_past_the_window() {
        let plan = one_fault(FaultKind::ChipDeath, 0, Some(900));
        let mut eng = armed(tiny_model(), plan);
        let trace = crate::ecg::gen::generate_trace(70, false, 1.0);
        // Attempts at t = 0, 300, 600 all die; each consumes the
        // attempt cost, so the fourth attempt starts at t = 900 — past
        // the window — and serves normally.
        for attempt in 0..3u64 {
            let err = eng.classify(&trace).unwrap_err().to_string();
            assert!(err.starts_with("fault:"), "attempt {attempt}: {err}");
            assert_eq!(
                eng.chip_time_us(),
                (attempt + 1) * FAULT_ATTEMPT_COST_US,
                "failed attempts must consume chip time"
            );
        }
        let inf = eng.classify(&trace).unwrap();
        assert!(inf.pred <= 1);
        let c = eng.fault_counters().unwrap();
        assert_eq!(c.dead_programs, 3);
        assert_eq!(c.faulted_programs, 3);
    }

    #[test]
    fn injected_frame_drop_aborts_the_program_and_consumes_chip_time() {
        // Rate 1.0 in a short window: the first program (chip time 0)
        // drops its frame; the aborted attempt consumes chip time — like
        // a dead-chip attempt — so the transient window expires under
        // retries and the next program is clean.
        let plan = one_fault(
            FaultKind::FrameDrops { rate: 1.0 },
            0,
            Some(FAULT_ATTEMPT_COST_US),
        );
        let mut eng = armed(tiny_model(), plan);
        let trace = crate::ecg::gen::generate_trace(71, true, 1.0);
        let err = eng.classify(&trace).unwrap_err().to_string();
        assert!(err.contains("dma frame dropped"), "{err}");
        assert!(err.starts_with("fault:"), "{err}");
        assert_eq!(eng.fault_counters().unwrap().frame_drops, 1);
        assert_eq!(
            eng.chip_time_us(),
            FAULT_ATTEMPT_COST_US,
            "an aborted attempt must age the chip (transient recovery)"
        );
        // Chip time crossed the window: the retry is clean.
        let inf = eng.classify(&trace).unwrap();
        assert!(inf.pred <= 1);
        assert_eq!(eng.fault_counters().unwrap().frame_drops, 1);
    }

    #[test]
    fn adc_saturation_corrupts_silently_then_clears() {
        let trace = crate::ecg::gen::generate_trace(72, false, 1.0);
        let mut clean = Engine::native(
            tiny_model(),
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        );
        let want = clean.classify(&trace).unwrap();
        let plan =
            one_fault(FaultKind::AdcSaturation { half: 0 }, 0, Some(1000));
        let mut eng = armed(tiny_model(), plan);
        let got = eng.classify(&trace).unwrap();
        assert_ne!(
            got.scores, want.scores,
            "a saturated conv half must corrupt the scores"
        );
        assert!(eng.fault_counters().unwrap().faulted_programs >= 1);
        // Past the window the fault clears at the next program start and
        // the conversion matches the healthy engine bit for bit.
        eng.advance_idle_us(2_000);
        let healed = eng.classify(&trace).unwrap();
        assert_eq!(healed.scores, want.scores);
        assert_eq!(healed.pred, want.pred);
    }

    #[test]
    fn dead_columns_shift_scores_silently() {
        let trace = crate::ecg::gen::generate_trace(73, true, 1.0);
        let mut clean = Engine::native(
            tiny_model(),
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        );
        let want = clean.classify(&trace).unwrap();
        // Kill the two class columns' worth of fc2 outputs (half 1,
        // columns 0/1 carry the class scores after pooling).
        let plan = one_fault(
            FaultKind::DeadColumns { half: 1, columns: (0..32).collect() },
            0,
            None,
        );
        let mut eng = armed(tiny_model(), plan);
        let got = eng.classify(&trace).unwrap();
        assert_ne!(got.scores, want.scores, "dead fc columns must show");
    }

    #[test]
    fn latency_spike_charges_program_time() {
        let trace = crate::ecg::gen::generate_trace(74, false, 1.0);
        let mut clean = Engine::native(
            tiny_model(),
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        );
        let base = clean.classify(&trace).unwrap();
        let plan =
            one_fault(FaultKind::LatencySpike { extra_us: 5_000 }, 0, None);
        let mut eng = armed(tiny_model(), plan);
        let slow = eng.classify(&trace).unwrap();
        let extra_s = slow.sim_time_s - base.sim_time_s;
        assert!(
            (extra_s - 5e-3).abs() < 1e-6,
            "spike must add exactly 5 ms, added {extra_s}"
        );
        assert_eq!(slow.pred, base.pred, "slow, not wrong");
        assert_eq!(slow.scores, base.scores);
        assert_eq!(eng.fault_counters().unwrap().latency_spikes, 1);
    }

    #[test]
    fn link_corruption_thins_events_without_erroring() {
        let trace = crate::ecg::gen::generate_trace(75, true, 1.0);
        let plan =
            one_fault(FaultKind::LinkCorruption { ber: 0.5 }, 0, None);
        let mut eng = armed(tiny_model(), plan);
        let inf = eng.classify(&trace).unwrap();
        assert!(inf.pred <= 1, "corruption degrades, never errors");
        assert!(
            eng.fault_counters().unwrap().link_events_dropped > 0,
            "BER 0.5 over hundreds of events must drop some"
        );
    }

    #[test]
    fn armed_faults_replay_deterministically() {
        let plan = FaultPlan {
            seed: 21,
            faults: vec![
                FaultSpec {
                    chip: 0,
                    at_us: 0,
                    duration_us: None,
                    kind: FaultKind::FrameDrops { rate: 0.5 },
                },
                FaultSpec {
                    chip: 0,
                    at_us: 0,
                    duration_us: None,
                    kind: FaultKind::LinkCorruption { ber: 0.02 },
                },
            ],
        };
        let run = |plan: &FaultPlan| -> Vec<Result<[f32; 2], String>> {
            let mut eng = armed(tiny_model(), plan.clone());
            (0..6)
                .map(|i| {
                    let t = crate::ecg::gen::generate_trace(80 + i, i % 2 == 0, 1.0);
                    eng.classify(&t)
                        .map(|inf| inf.scores)
                        .map_err(|e| e.to_string())
                })
                .collect()
        };
        assert_eq!(run(&plan), run(&plan), "same plan, same outcome");
    }

    #[test]
    fn recalibration_preserves_serving_weights_and_residency() {
        // A recalibration mid-serving must leave the synapse matrices (and
        // thus subsequent predictions) exactly as a never-recalibrated
        // engine sees them, modulo the applied compensation.  With an
        // ideal substrate the measured profile is near-identity, so the
        // *predictions* must survive recalibration unchanged.
        let mk = || {
            Engine::native(
                tiny_model(),
                EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
            )
        };
        let trace = crate::ecg::gen::generate_trace(55, true, 1.0);
        let mut control = mk();
        let a = control.classify(&trace).unwrap();
        let mut eng = mk();
        assert!(eng.supports_recalibration(), "native backend measures");
        let b0 = eng.classify(&trace).unwrap();
        eng.recalibrate(32).unwrap();
        let b1 = eng.classify(&trace).unwrap();
        assert_eq!(a.pred, b0.pred);
        assert_eq!(a.scores, b0.scores);
        // Near-identity compensation: scores stay within a few LSB
        // (quantisation of the noise-free two-point fit).
        assert!(
            (b1.scores[0] - b0.scores[0]).abs() <= 4.0
                && (b1.scores[1] - b0.scores[1]).abs() <= 4.0,
            "recalibration perturbed an ideal chip: {:?} -> {:?}",
            b0.scores,
            b1.scores
        );
    }
}
