//! Classification metrics: detection rate / false positives as the paper
//! reports them (Table 1), with uncertainty over repeated blocks.

/// Confusion counts for the two-class A-fib task.
#[derive(Debug, Default, Clone, Copy)]
pub struct Confusion {
    pub tp: usize,
    pub fp: usize,
    pub tn: usize,
    pub fn_: usize,
}

impl Confusion {
    pub fn add(&mut self, pred: u8, label: u8) {
        match (pred, label) {
            (1, 1) => self.tp += 1,
            (1, 0) => self.fp += 1,
            (0, 0) => self.tn += 1,
            (0, 1) => self.fn_ += 1,
            _ => panic!("labels must be 0/1"),
        }
    }

    pub fn total(&self) -> usize {
        self.tp + self.fp + self.tn + self.fn_
    }

    /// Detection rate = sensitivity = TP / (TP + FN).
    pub fn detection_rate(&self) -> f64 {
        let pos = self.tp + self.fn_;
        if pos == 0 {
            return 0.0;
        }
        self.tp as f64 / pos as f64
    }

    /// False-positive rate = FP / (FP + TN).
    pub fn false_positive_rate(&self) -> f64 {
        let neg = self.fp + self.tn;
        if neg == 0 {
            return 0.0;
        }
        self.fp as f64 / neg as f64
    }

    pub fn accuracy(&self) -> f64 {
        if self.total() == 0 {
            return 0.0;
        }
        (self.tp + self.tn) as f64 / self.total() as f64
    }

    /// Fold another confusion into this one (aggregating per-chip blocks
    /// into fleet-wide metrics — counts are additive across replicas).
    pub fn merge(&mut self, other: &Confusion) {
        self.tp += other.tp;
        self.fp += other.fp;
        self.tn += other.tn;
        self.fn_ += other.fn_;
    }
}

/// Mean ± std of a metric across repeated measurement blocks (the paper's
/// "(93.7 ± 0.7) %" style).  Bessel-corrected sample std (`n - 1`): the
/// blocks are repeated runs estimating an underlying rate, so the paper's
/// ± figure is a sample statistic; fewer than two blocks report 0.
pub fn mean_std<F: Fn(&Confusion) -> f64>(
    blocks: &[Confusion],
    f: F,
) -> (f64, f64) {
    let vals: Vec<f64> = blocks.iter().map(f).collect();
    let n = vals.len();
    let mean = vals.iter().sum::<f64>() / n.max(1) as f64;
    let var = if n > 1 {
        vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn confusion_accumulates() {
        let mut c = Confusion::default();
        c.add(1, 1);
        c.add(1, 0);
        c.add(0, 0);
        c.add(0, 1);
        assert_eq!((c.tp, c.fp, c.tn, c.fn_), (1, 1, 1, 1));
        assert_eq!(c.total(), 4);
        assert_eq!(c.detection_rate(), 0.5);
        assert_eq!(c.false_positive_rate(), 0.5);
        assert_eq!(c.accuracy(), 0.5);
    }

    #[test]
    fn empty_classes_are_zero() {
        let c = Confusion::default();
        assert_eq!(c.detection_rate(), 0.0);
        assert_eq!(c.false_positive_rate(), 0.0);
    }

    #[test]
    fn perfect_classifier() {
        let mut c = Confusion::default();
        for _ in 0..10 {
            c.add(1, 1);
            c.add(0, 0);
        }
        assert_eq!(c.detection_rate(), 1.0);
        assert_eq!(c.false_positive_rate(), 0.0);
        assert_eq!(c.accuracy(), 1.0);
    }

    #[test]
    fn mean_std_over_blocks() {
        let mut a = Confusion::default();
        a.add(1, 1); // det 1.0
        let mut b = Confusion::default();
        b.add(0, 1); // det 0.0
        let (m, s) = mean_std(&[a, b], |c| c.detection_rate());
        assert_eq!(m, 0.5);
        // Sample std over {0, 1}: sqrt(0.5 / (2 - 1)).
        assert!((s - 0.5f64.sqrt()).abs() < 1e-12, "std {s}");
    }

    #[test]
    fn mean_std_single_block_is_zero_spread() {
        let mut a = Confusion::default();
        a.add(1, 1);
        let (m, s) = mean_std(&[a], |c| c.detection_rate());
        assert_eq!(m, 1.0);
        assert_eq!(s, 0.0, "one block: no spread estimate, not NaN");
        let (m, s) = mean_std(&[], |c| c.detection_rate());
        assert_eq!(m, 0.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn merge_is_additive() {
        let mut a = Confusion::default();
        a.add(1, 1);
        a.add(0, 0);
        let mut b = Confusion::default();
        b.add(1, 0);
        a.merge(&b);
        assert_eq!((a.tp, a.fp, a.tn, a.fn_), (1, 1, 1, 0));
        assert_eq!(a.total(), 3);
    }

    #[test]
    #[should_panic]
    fn bad_label_panics() {
        Confusion::default().add(2, 0);
    }
}
