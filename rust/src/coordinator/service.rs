//! Experiment execution service (paper §II-D "Embedded System Environment").
//!
//! "An experiment execution service enables users to run Python-based
//! interfaces on host computers that exchange serialized experiment
//! configurations and result data with the mobile system."
//!
//! Ours is a line-delimited JSON protocol over TCP (the mobile system's
//! USB-Ethernet remote path).  Requests are queued to a single worker
//! thread that owns the engine — inference remains strictly batch-size-1
//! (the paper's edge constraint), while accepting concurrent clients.
//!
//! Protocol (one JSON object per line):
//! ```text
//! -> {"cmd": "classify", "trace": [[...ch0 u12...], [...ch1...]]}
//! <- {"ok": true, "pred": 1, "scores": [a, b], "time_us": t, "energy_mj": e}
//! -> {"cmd": "stats"}
//! <- {"ok": true, "served": n, "mean_time_us": t}
//! -> {"cmd": "ping"} | {"cmd": "shutdown"}
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::asic::consts as c;
use crate::ecg::gen::Trace;
use crate::util::json::Json;

use super::engine::Engine;

/// Shared service statistics.
#[derive(Default)]
pub struct ServiceStats {
    pub served: AtomicU64,
    /// Sum of simulated inference times [µs] for mean reporting.
    pub sim_time_us_sum: AtomicU64,
}

enum Job {
    Classify { trace: Trace, resp: mpsc::Sender<String> },
    Stats { resp: mpsc::Sender<String> },
}

/// The running service handle.
pub struct Service {
    pub addr: std::net::SocketAddr,
    pub stats: Arc<ServiceStats>,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    worker_handle: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    /// Start the service on `addr` (use port 0 for an ephemeral port).
    /// The engine is constructed *inside* the worker thread (PJRT handles
    /// are not `Send`): pass a builder closure.
    pub fn start<F>(addr: &str, make_engine: F) -> anyhow::Result<Service>
    where
        F: FnOnce() -> anyhow::Result<Engine> + Send + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stats = Arc::new(ServiceStats::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<Job>();

        // Worker: owns the engine, processes jobs strictly in order
        // (batch size 1 — the paper's edge constraint).
        let wstats = stats.clone();
        let worker_handle = std::thread::spawn(move || {
            let mut engine = match make_engine() {
                Ok(e) => e,
                Err(e) => {
                    // Drain jobs with an error reply so clients don't hang.
                    let msg = format!("{{\"ok\":false,\"error\":\"engine init: {e}\"}}");
                    while let Ok(job) = rx.recv() {
                        match job {
                            Job::Classify { resp, .. } => { let _ = resp.send(msg.clone()); }
                            Job::Stats { resp } => { let _ = resp.send(msg.clone()); }
                        }
                    }
                    return;
                }
            };
            while let Ok(job) = rx.recv() {
                match job {
                    Job::Classify { trace, resp } => {
                        let reply = match engine.classify(&trace) {
                            Ok(inf) => {
                                wstats.served.fetch_add(1, Ordering::Relaxed);
                                wstats.sim_time_us_sum.fetch_add(
                                    (inf.sim_time_s * 1e6) as u64,
                                    Ordering::Relaxed,
                                );
                                format!(
                                    "{{\"ok\":true,\"pred\":{},\"scores\":[{},{}],\
                                     \"time_us\":{:.1},\"energy_mj\":{:.4}}}",
                                    inf.pred,
                                    inf.scores[0],
                                    inf.scores[1],
                                    inf.sim_time_s * 1e6,
                                    inf.energy.total_j() * 1e3
                                )
                            }
                            Err(e) => {
                                format!("{{\"ok\":false,\"error\":\"{e}\"}}")
                            }
                        };
                        let _ = resp.send(reply);
                    }
                    Job::Stats { resp } => {
                        let served = wstats.served.load(Ordering::Relaxed);
                        let sum = wstats.sim_time_us_sum.load(Ordering::Relaxed);
                        let mean = if served > 0 { sum / served } else { 0 };
                        let _ = resp.send(format!(
                            "{{\"ok\":true,\"served\":{served},\
                             \"mean_time_us\":{mean}}}"
                        ));
                    }
                }
            }
        });

        // Acceptor: non-blocking accept loop; per-connection handler threads.
        let sdown = shutdown.clone();
        let accept_handle = std::thread::spawn(move || {
            let mut handlers = Vec::new();
            while !sdown.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let tx = tx.clone();
                        let sdown2 = sdown.clone();
                        handlers.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, tx, sdown2);
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for h in handlers {
                let _ = h.join();
            }
            drop(tx); // closes the worker queue
        });

        Ok(Service {
            addr: local,
            stats,
            shutdown,
            accept_handle: Some(accept_handle),
            worker_handle: Some(worker_handle),
        })
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.worker_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

fn handle_conn(
    stream: TcpStream,
    tx: mpsc::Sender<Job>,
    shutdown: Arc<AtomicBool>,
) -> anyhow::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if shutdown.load(Ordering::Relaxed) {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(line.trim()) {
            Err(e) => format!("{{\"ok\":false,\"error\":\"bad json: {e}\"}}"),
            Ok(req) => match req.get("cmd").and_then(|c| c.as_str()) {
                Some("ping") => "{\"ok\":true,\"pong\":true}".to_string(),
                Some("shutdown") => {
                    shutdown.store(true, Ordering::Relaxed);
                    "{\"ok\":true,\"bye\":true}".to_string()
                }
                Some("stats") => {
                    let (rtx, rrx) = mpsc::channel();
                    tx.send(Job::Stats { resp: rtx })
                        .map_err(|_| anyhow::anyhow!("worker gone"))?;
                    rrx.recv()?
                }
                Some("classify") => match parse_trace(&req) {
                    Err(e) => format!("{{\"ok\":false,\"error\":\"{e}\"}}"),
                    Ok(trace) => {
                        let (rtx, rrx) = mpsc::channel();
                        tx.send(Job::Classify { trace, resp: rtx })
                            .map_err(|_| anyhow::anyhow!("worker gone"))?;
                        rrx.recv()?
                    }
                },
                _ => "{\"ok\":false,\"error\":\"unknown cmd\"}".to_string(),
            },
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        if reply.contains("\"bye\"") {
            return Ok(());
        }
    }
}

fn parse_trace(req: &Json) -> anyhow::Result<Trace> {
    let chans = req
        .req("trace")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("trace must be an array"))?;
    anyhow::ensure!(chans.len() == c::ECG_CHANNELS, "need 2 channels");
    let mut samples = Vec::with_capacity(c::ECG_CHANNELS);
    for ch in chans {
        let vals = ch
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("channel must be an array"))?;
        anyhow::ensure!(
            vals.len() == c::ECG_WINDOW,
            "channel needs {} samples, got {}",
            c::ECG_WINDOW,
            vals.len()
        );
        let mut chan = Vec::with_capacity(c::ECG_WINDOW);
        for v in vals {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric sample"))?;
            anyhow::ensure!((0.0..4096.0).contains(&x), "sample out of 12-bit range");
            chan.push(x as u16);
        }
        samples.push(chan);
    }
    Ok(Trace { samples, label: 0 })
}

/// Client helper (used by tests + the remote_client example).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn call(&mut self, req: &str) -> anyhow::Result<Json> {
        self.stream.write_all(req.as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }

    pub fn classify(&mut self, trace: &Trace) -> anyhow::Result<Json> {
        let mut req = String::from("{\"cmd\":\"classify\",\"trace\":[");
        for (i, ch) in trace.samples.iter().enumerate() {
            if i > 0 {
                req.push(',');
            }
            req.push('[');
            for (j, &s) in ch.iter().enumerate() {
                if j > 0 {
                    req.push(',');
                }
                req.push_str(&s.to_string());
            }
            req.push(']');
        }
        req.push_str("]}");
        self.call(&req)
    }
}

// Keep Mutex imported for future use in stats extensions.
#[allow(unused)]
type _Unused = Mutex<()>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineConfig;

    fn test_engine() -> Engine {
        let wc = vec![1.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL];
        let w1 = vec![1.0; c::K_LOGICAL * c::FC1_OUT];
        let w2 = vec![1.0; c::FC1_OUT * c::FC2_OUT];
        let model = crate::nn::weights::TrainedModel {
            pass_weights: [
                crate::nn::mapping::pack_conv(&wc),
                crate::nn::mapping::pack_fc1(&w1),
                crate::nn::mapping::pack_fc2(&w2),
            ],
            scales: [0.02, 0.02, 0.02],
            gain: [vec![1.0; c::N_COLS], vec![1.0; c::N_COLS]],
            offset: [vec![0.0; c::N_COLS], vec![0.0; c::N_COLS]],
            noise_sigma: 0.0,
            train_metrics: Default::default(),
        };
        Engine::native(
            model,
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        )
    }

    #[test]
    fn ping_and_classify_roundtrip() {
        let svc = Service::start("127.0.0.1:0", || Ok(test_engine())).unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let pong = cl.call("{\"cmd\":\"ping\"}").unwrap();
        assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

        let trace = crate::ecg::gen::generate_trace(1, true, 1.0);
        let reply = cl.classify(&trace).unwrap();
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
        let pred = reply.get("pred").and_then(|p| p.as_f64()).unwrap();
        assert!(pred == 0.0 || pred == 1.0);
        assert!(reply.get("time_us").and_then(|t| t.as_f64()).unwrap() > 100.0);

        let stats = cl.call("{\"cmd\":\"stats\"}").unwrap();
        assert_eq!(stats.get("served").and_then(|s| s.as_f64()), Some(1.0));
        svc.stop();
    }

    #[test]
    fn malformed_requests_rejected() {
        let svc = Service::start("127.0.0.1:0", || Ok(test_engine())).unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let r = cl.call("not json at all").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = cl.call("{\"cmd\":\"classify\",\"trace\":[[1,2],[3]]}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = cl.call("{\"cmd\":\"nope\"}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        svc.stop();
    }

    #[test]
    fn concurrent_clients_serialised_through_worker() {
        let svc = Service::start("127.0.0.1:0", || Ok(test_engine())).unwrap();
        let addr = svc.addr;
        let mut handles = Vec::new();
        for i in 0..3 {
            handles.push(std::thread::spawn(move || {
                let mut cl = Client::connect(&addr).unwrap();
                let trace = crate::ecg::gen::generate_trace(10 + i, i % 2 == 1, 1.0);
                let reply = cl.classify(&trace).unwrap();
                assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(svc.stats.served.load(Ordering::Relaxed), 3);
        svc.stop();
    }
}
