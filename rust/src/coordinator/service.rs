//! Experiment execution service (paper §II-D "Embedded System Environment").
//!
//! "An experiment execution service enables users to run Python-based
//! interfaces on host computers that exchange serialized experiment
//! configurations and result data with the mobile system."
//!
//! Ours is a line-delimited JSON protocol over TCP (the mobile system's
//! USB-Ethernet remote path).  Requests are dispatched through a
//! [`fleet::Fleet`](crate::fleet::Fleet) of engine replicas.  A `classify`
//! serves one trace at the paper's 276 µs single-sample latency; a
//! `classify_batch` trades latency for throughput: the whole batch runs on
//! one chip as a single program with one weight reconfiguration per layer
//! per batch (DESIGN.md §9).  The fleet spreads concurrent clients across
//! replicas, accounts admission in *samples*, and sheds load explicitly —
//! a batch that only partially fits is partially accepted.
//!
//! Protocol (one JSON object per line):
//! ```text
//! -> {"cmd": "classify", "trace": [[...ch0 u12...], [...ch1...]]}
//! <- {"ok": true, "pred": 1, "scores": [a, b], "time_us": t,
//!     "energy_mj": e, "chip": c}
//! <- {"ok": false, "shed": true, "error": "...", "retry_after_us": n}
//! -> {"cmd": "classify_batch", "traces": [[[..ch0..], [..ch1..]], ...]}
//! <- {"ok": true, "chip": c, "batch": B, "accepted": k, "shed": B - k,
//!     "retry_after_us": n?, "time_us_per_sample": t,
//!     "results": [{"pred": p, "scores": [a, b], "time_us": t,
//!                  "energy_mj": e}, ...k entries...]}
//! <- {"ok": false, "shed": true, "error": "...", "accepted": 0,
//!     "batch": B, "retry_after_us": n}
//! -> {"cmd": "stats"}
//! <- {"ok": true, "served": n, "mean_time_us": t, "chips": c, "shed": s}
//! -> {"cmd": "fleet_stats"}
//! <- {"ok": true, "chips": c, ..., "per_chip": [...]}
//! -> {"cmd": "recalibrate", "chip": c, "reps": r}
//! <- {"ok": true, "chip": c, "chip_time_us": t, "residual_rms": x,
//!     "reason": "..."}   (drain -> calibrate -> re-admit; blocks until
//!                         the measurement finished)
//! -> {"cmd": "ping"} | {"cmd": "shutdown"}
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::asic::consts as c;
use crate::ecg::gen::Trace;
use crate::fleet::{
    BatchDispatchOutcome, ChipId, DispatchOutcome, Fleet, FleetConfig,
};
use crate::util::json::Json;

use super::engine::{Engine, Inference};

/// The running service handle.  Serving statistics live in
/// [`Fleet::telemetry`]: one source of truth, accumulated in integer
/// nanoseconds so mean-latency reporting keeps sub-µs precision across
/// millions of requests.
pub struct Service {
    pub addr: std::net::SocketAddr,
    pub fleet: Arc<Fleet>,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    /// Start a single-chip service (the paper's original topology).  The
    /// engine is constructed *inside* the worker thread (PJRT handles are
    /// not `Send`): pass a builder closure.
    ///
    /// Keeps the legacy contract: an effectively unbounded admission
    /// queue (no shed replies) — opt into backpressure via
    /// [`Service::start_fleet`].  One contract change: engine-init
    /// failure now fails `start` fast instead of serving per-request
    /// `engine init` errors.
    pub fn start<F>(addr: &str, make_engine: F) -> anyhow::Result<Service>
    where
        F: FnOnce() -> anyhow::Result<Engine> + Send + 'static,
    {
        let once = Mutex::new(Some(make_engine));
        let cfg = FleetConfig { queue_depth: usize::MAX, ..FleetConfig::single() };
        Self::start_fleet(addr, cfg, move |_chip| {
            let f = once
                .lock()
                .unwrap()
                .take()
                .ok_or_else(|| anyhow::anyhow!("engine builder already used"))?;
            f()
        })
    }

    /// Start the service on `addr` (use port 0 for an ephemeral port)
    /// backed by a fleet of `cfg.chips` engine replicas.  `make_engine`
    /// runs once per chip, inside that chip's worker thread.  Fails fast
    /// if *every* replica's engine fails to construct (partial failures
    /// serve degraded, with the dead chips reported in `fleet_stats`).
    pub fn start_fleet<F>(
        addr: &str,
        cfg: FleetConfig,
        make_engine: F,
    ) -> anyhow::Result<Service>
    where
        F: Fn(ChipId) -> anyhow::Result<Engine> + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let fleet = Arc::new(Fleet::start(cfg, make_engine)?);
        let shutdown = Arc::new(AtomicBool::new(false));

        // Acceptor: non-blocking accept loop; per-connection handler
        // threads dispatch into the fleet.
        let sdown = shutdown.clone();
        let afleet = fleet.clone();
        let accept_handle = std::thread::spawn(move || {
            let mut handlers = Vec::new();
            while !sdown.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let fleet = afleet.clone();
                        let sdown2 = sdown.clone();
                        handlers.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, fleet, sdown2);
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for h in handlers {
                let _ = h.join();
            }
        });

        Ok(Service {
            addr: local,
            fleet,
            shutdown,
            accept_handle: Some(accept_handle),
        })
    }

    /// Block the calling thread until a client sends `shutdown`, then
    /// stop.  Used by `repro serve`.
    pub fn run_until_shutdown(self) {
        while !self.shutdown.load(Ordering::Relaxed) {
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        self.stop();
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        // All handlers joined: this Arc is the last one; drop drains+joins
        // the chip workers.
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// A message as a JSON string literal (quoted + escaped by the
/// `util::json` writer, so parser and writer can never diverge).
fn json_str(s: &str) -> String {
    Json::Str(s.to_string()).to_string()
}

/// Largest accepted `classify_batch` wire batch (sanity bound for request
/// and reply sizes; larger batches should be split by the client anyway).
pub const MAX_WIRE_BATCH: usize = 64;

/// Largest accepted `recalibrate` repetition count: one request must not
/// wedge a chip in `Calibrating` (and suppress the fleet policy) for an
/// unbounded measurement.  1024 reps ≈ 6k integrations per half, already
/// far past the point of diminishing noise suppression.
pub const MAX_RECALIB_REPS: usize = 1024;

/// One inference as the inner JSON object of a reply.
fn inference_json(inf: &Inference) -> String {
    format!(
        "{{\"pred\":{},\"scores\":[{},{}],\"time_us\":{:.1},\
         \"energy_mj\":{:.4}}}",
        inf.pred,
        inf.scores[0],
        inf.scores[1],
        inf.sim_time_s * 1e6,
        inf.energy.total_j() * 1e3
    )
}

fn classify_reply(fleet: &Fleet, trace: Trace) -> String {
    match fleet.dispatch(trace) {
        DispatchOutcome::Shed { reason, retry_after_us } => format!(
            "{{\"ok\":false,\"shed\":true,\"error\":\"{}\",\
             \"retry_after_us\":{retry_after_us}}}",
            reason.as_str()
        ),
        DispatchOutcome::Enqueued { chip, resp } => match resp.recv() {
            Err(mpsc::RecvError) => format!(
                "{{\"ok\":false,\"error\":\"chip {chip} worker gone\"}}"
            ),
            Ok(reply) => match reply.result {
                Ok(infs) => match infs.first() {
                    Some(inf) => {
                        // Same field formatting as the batch reply (one
                        // source of truth: `inference_json`), plus chip.
                        let fields = inference_json(inf);
                        format!(
                            "{{\"ok\":true,{},\"chip\":{}}}",
                            &fields[1..fields.len() - 1],
                            reply.chip
                        )
                    }
                    None => format!(
                        "{{\"ok\":false,\"error\":\"chip {} empty reply\"}}",
                        reply.chip
                    ),
                },
                Err(e) => {
                    format!("{{\"ok\":false,\"error\":{}}}", json_str(&e))
                }
            },
        },
    }
}

/// Serve one `classify_batch` request: dispatch the whole batch to one
/// chip (amortised weight reconfiguration); report partial acceptance
/// explicitly so the client can retry the shed suffix.
fn classify_batch_reply(fleet: &Fleet, traces: Vec<Trace>) -> String {
    let batch = traces.len();
    match fleet.dispatch_batch(traces) {
        BatchDispatchOutcome::Shed { reason, retry_after_us } => format!(
            "{{\"ok\":false,\"shed\":true,\"error\":\"{}\",\
             \"accepted\":0,\"batch\":{batch},\
             \"retry_after_us\":{retry_after_us}}}",
            reason.as_str()
        ),
        BatchDispatchOutcome::Enqueued {
            chip,
            accepted,
            rejected,
            resp,
            retry_after_us,
        } => match resp.recv() {
            Err(mpsc::RecvError) => format!(
                "{{\"ok\":false,\"error\":\"chip {chip} worker gone\"}}"
            ),
            Ok(reply) => match reply.result {
                Ok(infs) => {
                    let sum_us: f64 =
                        infs.iter().map(|i| i.sim_time_s).sum::<f64>() * 1e6;
                    let per_us = sum_us / infs.len().max(1) as f64;
                    let mut s = format!(
                        "{{\"ok\":true,\"chip\":{},\"batch\":{batch},\
                         \"accepted\":{accepted},\"shed\":{rejected},",
                        reply.chip
                    );
                    if rejected > 0 {
                        s.push_str(&format!(
                            "\"retry_after_us\":{retry_after_us},"
                        ));
                    }
                    s.push_str(&format!(
                        "\"time_us_per_sample\":{per_us:.1},\"results\":["
                    ));
                    for (i, inf) in infs.iter().enumerate() {
                        if i > 0 {
                            s.push(',');
                        }
                        s.push_str(&inference_json(inf));
                    }
                    s.push_str("]}");
                    s
                }
                Err(e) => {
                    format!("{{\"ok\":false,\"error\":{}}}", json_str(&e))
                }
            },
        },
    }
}

/// Serve one `recalibrate` request: drain the chip, measure, re-admit.
/// Blocks until the worker reports back (queued work drains first).
fn recalibrate_reply(fleet: &Fleet, chip: usize, reps: usize) -> String {
    match fleet.recalibrate_chip(chip, reps) {
        Err(e) => {
            format!("{{\"ok\":false,\"error\":{}}}", json_str(&e.to_string()))
        }
        Ok(rx) => match rx.recv() {
            Err(mpsc::RecvError) => format!(
                "{{\"ok\":false,\"error\":\"chip {chip} worker gone\"}}"
            ),
            Ok(reply) => match reply.result {
                Ok((stamp, residual)) => format!(
                    "{{\"ok\":true,\"chip\":{chip},\"chip_time_us\":{stamp},\
                     \"residual_rms\":{residual:.4},\"reason\":\"{}\"}}",
                    reply.reason.as_str()
                ),
                Err(e) => {
                    format!("{{\"ok\":false,\"error\":{}}}", json_str(&e))
                }
            },
        },
    }
}

fn handle_conn(
    stream: TcpStream,
    fleet: Arc<Fleet>,
    shutdown: Arc<AtomicBool>,
) -> anyhow::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if shutdown.load(Ordering::Relaxed) {
            return Ok(());
        }
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Timeout mid-line: keep the partial request buffered —
                // read_line appends, so the next pass completes it.
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        let reply = match Json::parse(line.trim()) {
            Err(e) => format!(
                "{{\"ok\":false,\"error\":{}}}",
                json_str(&format!("bad json: {e}"))
            ),
            Ok(req) => match req.get("cmd").and_then(|c| c.as_str()) {
                Some("ping") => "{\"ok\":true,\"pong\":true}".to_string(),
                Some("shutdown") => {
                    shutdown.store(true, Ordering::Relaxed);
                    "{\"ok\":true,\"bye\":true}".to_string()
                }
                Some("stats") => {
                    let t = fleet.telemetry().snapshot();
                    format!(
                        "{{\"ok\":true,\"served\":{},\"mean_time_us\":{:.3},\
                         \"chips\":{},\"shed\":{}}}",
                        t.served,
                        t.mean_sim_time_us,
                        fleet.size(),
                        fleet.shed_count()
                    )
                }
                Some("fleet_stats") => fleet.stats_json(),
                Some("recalibrate") => {
                    // Malformed fields are rejected, never defaulted: a
                    // bad `chip` would drain a replica the client never
                    // named, a bad `reps` would silently run a
                    // measurement length they never asked for.
                    let chip = req
                        .get("chip")
                        .and_then(|c| c.as_uint())
                        .map(|c| c as usize);
                    let reps = match req.get("reps") {
                        None => Some(32),
                        Some(r) => r.as_uint().map(|r| r as usize),
                    }
                    .filter(|r| (1..=MAX_RECALIB_REPS).contains(r));
                    match (chip, reps) {
                        (None, _) => "{\"ok\":false,\"error\":\"recalibrate \
                                      requires a non-negative integer `chip` \
                                      field\"}"
                            .to_string(),
                        (_, None) => format!(
                            "{{\"ok\":false,\"error\":\"reps must be an \
                             integer in 1..={MAX_RECALIB_REPS}\"}}"
                        ),
                        (Some(chip), Some(reps)) => {
                            recalibrate_reply(&fleet, chip, reps)
                        }
                    }
                }
                Some("classify") => match parse_trace(&req) {
                    Err(e) => format!(
                        "{{\"ok\":false,\"error\":{}}}",
                        json_str(&e.to_string())
                    ),
                    Ok(trace) => classify_reply(&fleet, trace),
                },
                Some("classify_batch") => match parse_trace_batch(&req) {
                    Err(e) => format!(
                        "{{\"ok\":false,\"error\":{}}}",
                        json_str(&e.to_string())
                    ),
                    Ok(traces) => classify_batch_reply(&fleet, traces),
                },
                _ => "{\"ok\":false,\"error\":\"unknown cmd\"}".to_string(),
            },
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        if reply.contains("\"bye\"") {
            return Ok(());
        }
        line.clear();
    }
}

fn parse_trace(req: &Json) -> anyhow::Result<Trace> {
    parse_trace_value(req.req("trace")?)
}

fn parse_trace_batch(req: &Json) -> anyhow::Result<Vec<Trace>> {
    let items = req
        .req("traces")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("traces must be an array"))?;
    anyhow::ensure!(!items.is_empty(), "empty batch");
    anyhow::ensure!(
        items.len() <= MAX_WIRE_BATCH,
        "batch of {} exceeds the wire limit of {MAX_WIRE_BATCH}",
        items.len()
    );
    items.iter().map(parse_trace_value).collect()
}

fn parse_trace_value(v: &Json) -> anyhow::Result<Trace> {
    let chans = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("trace must be an array"))?;
    anyhow::ensure!(chans.len() == c::ECG_CHANNELS, "need 2 channels");
    let mut samples = Vec::with_capacity(c::ECG_CHANNELS);
    for ch in chans {
        let vals = ch
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("channel must be an array"))?;
        anyhow::ensure!(
            vals.len() == c::ECG_WINDOW,
            "channel needs {} samples, got {}",
            c::ECG_WINDOW,
            vals.len()
        );
        let mut chan = Vec::with_capacity(c::ECG_WINDOW);
        for v in vals {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric sample"))?;
            anyhow::ensure!((0.0..4096.0).contains(&x), "sample out of 12-bit range");
            chan.push(x as u16);
        }
        samples.push(chan);
    }
    Ok(Trace { samples, label: 0 })
}

/// Client helper (used by tests + the remote_client example).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn call(&mut self, req: &str) -> anyhow::Result<Json> {
        self.stream.write_all(req.as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }

    pub fn classify(&mut self, trace: &Trace) -> anyhow::Result<Json> {
        let mut req = String::from("{\"cmd\":\"classify\",\"trace\":");
        push_trace_json(trace, &mut req);
        req.push('}');
        self.call(&req)
    }

    /// Submit a whole batch as one `classify_batch` request (amortised
    /// weight reconfiguration server-side).  The reply may report partial
    /// acceptance: `accepted` < batch with the shed suffix to retry.
    pub fn classify_batch(&mut self, traces: &[Trace]) -> anyhow::Result<Json> {
        let mut req = String::from("{\"cmd\":\"classify_batch\",\"traces\":[");
        for (i, trace) in traces.iter().enumerate() {
            if i > 0 {
                req.push(',');
            }
            push_trace_json(trace, &mut req);
        }
        req.push_str("]}");
        self.call(&req)
    }
}

/// Append one trace as the nested-array wire format.
fn push_trace_json(trace: &Trace, req: &mut String) {
    req.push('[');
    for (i, ch) in trace.samples.iter().enumerate() {
        if i > 0 {
            req.push(',');
        }
        req.push('[');
        for (j, &s) in ch.iter().enumerate() {
            if j > 0 {
                req.push(',');
            }
            req.push_str(&s.to_string());
        }
        req.push(']');
    }
    req.push(']');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineConfig;

    fn test_engine() -> Engine {
        let wc = vec![1.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL];
        let w1 = vec![1.0; c::K_LOGICAL * c::FC1_OUT];
        let w2 = vec![1.0; c::FC1_OUT * c::FC2_OUT];
        let model = crate::nn::weights::TrainedModel {
            pass_weights: [
                crate::nn::mapping::pack_conv(&wc),
                crate::nn::mapping::pack_fc1(&w1),
                crate::nn::mapping::pack_fc2(&w2),
            ],
            scales: [0.02, 0.02, 0.02],
            gain: [vec![1.0; c::N_COLS], vec![1.0; c::N_COLS]],
            offset: [vec![0.0; c::N_COLS], vec![0.0; c::N_COLS]],
            noise_sigma: 0.0,
            train_metrics: Default::default(),
        };
        Engine::native(
            model,
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        )
    }

    #[test]
    fn ping_and_classify_roundtrip() {
        let svc = Service::start("127.0.0.1:0", || Ok(test_engine())).unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let pong = cl.call("{\"cmd\":\"ping\"}").unwrap();
        assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

        let trace = crate::ecg::gen::generate_trace(1, true, 1.0);
        let reply = cl.classify(&trace).unwrap();
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
        let pred = reply.get("pred").and_then(|p| p.as_f64()).unwrap();
        assert!(pred == 0.0 || pred == 1.0);
        assert!(reply.get("time_us").and_then(|t| t.as_f64()).unwrap() > 100.0);
        // Single-chip fleet: everything lands on chip 0.
        assert_eq!(reply.get("chip").and_then(|v| v.as_usize()), Some(0));

        let stats = cl.call("{\"cmd\":\"stats\"}").unwrap();
        assert_eq!(stats.get("served").and_then(|s| s.as_f64()), Some(1.0));
        assert_eq!(stats.get("chips").and_then(|s| s.as_usize()), Some(1));
        assert!(stats.get("mean_time_us").and_then(|s| s.as_f64()).unwrap() > 100.0);
        svc.stop();
    }

    #[test]
    fn malformed_requests_rejected() {
        let svc = Service::start("127.0.0.1:0", || Ok(test_engine())).unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let r = cl.call("not json at all").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = cl.call("{\"cmd\":\"classify\",\"trace\":[[1,2],[3]]}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = cl.call("{\"cmd\":\"classify_batch\",\"traces\":[]}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
        let r = cl.call("{\"cmd\":\"classify_batch\",\"traces\":3}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = cl.call("{\"cmd\":\"nope\"}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        svc.stop();
    }

    #[test]
    fn classify_batch_roundtrip_matches_single() {
        let svc = Service::start("127.0.0.1:0", || Ok(test_engine())).unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let traces: Vec<_> = (0..4)
            .map(|i| {
                crate::ecg::gen::generate_trace(90 + i as u64, i % 2 == 0, 1.0)
            })
            .collect();
        // Noise is off: sequential predictions are the parity reference.
        let mut want = Vec::new();
        for t in &traces {
            let r = cl.classify(t).unwrap();
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
            want.push(r.get("pred").and_then(|p| p.as_f64()).unwrap());
        }
        let reply = cl.classify_batch(&traces).unwrap();
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
        assert_eq!(reply.get("batch").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(reply.get("accepted").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(reply.get("shed").and_then(|v| v.as_usize()), Some(0));
        let results = reply.get("results").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(results.len(), 4);
        for (r, w) in results.iter().zip(&want) {
            assert_eq!(r.get("pred").and_then(|p| p.as_f64()), Some(*w));
        }
        // Amortisation is visible on the wire: per-sample time well under
        // the paper's 276 µs single-trace figure.
        let per = reply
            .get("time_us_per_sample")
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(per < 200.0, "amortised per-sample time {per} µs");
        svc.stop();
    }

    #[test]
    fn classify_batch_partial_acceptance() {
        let svc = Service::start_fleet(
            "127.0.0.1:0",
            FleetConfig { chips: 1, queue_depth: 3, ..Default::default() },
            |chip| {
                Ok(Engine::native(
                    crate::nn::weights::TrainedModel::synthetic(7),
                    EngineConfig {
                        use_pjrt: false,
                        noise_off: true,
                        ..Default::default()
                    }
                    .for_chip(chip),
                ))
            },
        )
        .unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let traces: Vec<_> = (0..5)
            .map(|i| {
                crate::ecg::gen::generate_trace(70 + i as u64, i % 2 == 1, 1.0)
            })
            .collect();
        let reply = cl.classify_batch(&traces).unwrap();
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
        assert_eq!(reply.get("batch").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(reply.get("accepted").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(reply.get("shed").and_then(|v| v.as_usize()), Some(2));
        assert!(
            reply
                .get("retry_after_us")
                .and_then(|v| v.as_f64())
                .unwrap()
                > 0.0,
            "partial acceptance must carry a retry hint: {reply}"
        );
        assert_eq!(
            reply.get("results").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(3)
        );
        // The shed suffix is retriable once the queue drained (the reply
        // above only arrives after the admitted prefix completed).
        let retry = cl.classify_batch(&traces[3..]).unwrap();
        assert_eq!(retry.get("ok"), Some(&Json::Bool(true)), "{retry}");
        assert_eq!(retry.get("accepted").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(retry.get("shed").and_then(|v| v.as_usize()), Some(0));
        svc.stop();
    }

    #[test]
    fn recalibrate_command_roundtrip() {
        let svc = Service::start_fleet(
            "127.0.0.1:0",
            FleetConfig { chips: 2, queue_depth: 8, ..Default::default() },
            |chip| {
                Ok(Engine::native(
                    crate::nn::weights::TrainedModel::synthetic(11),
                    EngineConfig {
                        use_pjrt: false,
                        noise_off: true,
                        fpn_seed: Some(0xCA11B),
                        ..Default::default()
                    }
                    .for_chip(chip),
                ))
            },
        )
        .unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let r = cl.call("{\"cmd\":\"recalibrate\",\"chip\":1,\"reps\":8}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("chip").and_then(|v| v.as_usize()), Some(1));
        assert!(r.get("residual_rms").and_then(|v| v.as_f64()).is_some());
        assert!(
            r.get("chip_time_us").and_then(|v| v.as_f64()).unwrap() > 0.0,
            "measurement consumed chip time: {r}"
        );
        // fleet_stats reports the completed recalibration per chip.
        let fs = cl.call("{\"cmd\":\"fleet_stats\"}").unwrap();
        assert_eq!(
            fs.get("recalibrations").and_then(|v| v.as_usize()),
            Some(1),
            "{fs}"
        );
        let per = fs.get("per_chip").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(
            per[1].get("recalibrations").and_then(|v| v.as_usize()),
            Some(1)
        );
        // Out-of-range chip errors cleanly.
        let bad = cl.call("{\"cmd\":\"recalibrate\",\"chip\":9}").unwrap();
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
        // Unbounded reps are rejected before touching the fleet.
        let bad = cl
            .call("{\"cmd\":\"recalibrate\",\"chip\":0,\"reps\":1000000000}")
            .unwrap();
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)), "{bad}");
        // A missing or malformed `chip` must never default to chip 0:
        // the request is rejected and no replica is drained.
        for req in [
            "{\"cmd\":\"recalibrate\"}",
            "{\"cmd\":\"recalibrate\",\"chip\":\"zero\"}",
            "{\"cmd\":\"recalibrate\",\"chip\":-1}",
            "{\"cmd\":\"recalibrate\",\"chip\":0.5}",
            "{\"cmd\":\"recalibrate\",\"chip\":0,\"reps\":\"many\"}",
            "{\"cmd\":\"recalibrate\",\"chip\":0,\"reps\":-4}",
        ] {
            let bad = cl.call(req).unwrap();
            assert_eq!(bad.get("ok"), Some(&Json::Bool(false)), "{req}");
        }
        let fs = cl.call("{\"cmd\":\"fleet_stats\"}").unwrap();
        assert_eq!(
            fs.get("recalibrations").and_then(|v| v.as_usize()),
            Some(1),
            "malformed requests must not have drained anything: {fs}"
        );
        svc.stop();
    }

    #[test]
    fn concurrent_clients_spread_over_fleet() {
        let svc = Service::start_fleet(
            "127.0.0.1:0",
            FleetConfig { chips: 2, queue_depth: 8, ..Default::default() },
            |chip| {
                Ok(Engine::native(
                    crate::nn::weights::TrainedModel::synthetic(3),
                    EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() }
                        .for_chip(chip),
                ))
            },
        )
        .unwrap();
        let addr = svc.addr;
        let mut handles = Vec::new();
        for i in 0..4u64 {
            handles.push(std::thread::spawn(move || {
                let mut cl = Client::connect(&addr).unwrap();
                let trace = crate::ecg::gen::generate_trace(10 + i, i % 2 == 1, 1.0);
                let reply = cl.classify(&trace).unwrap();
                assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
                reply.get("chip").and_then(|v| v.as_usize()).unwrap()
            }));
        }
        let chips: Vec<usize> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(svc.fleet.telemetry().served(), 4);
        // Round-robin tie-break: both chips must have served.
        assert!(chips.contains(&0) && chips.contains(&1), "{chips:?}");

        let mut cl = Client::connect(&addr).unwrap();
        let fs = cl.call("{\"cmd\":\"fleet_stats\"}").unwrap();
        assert_eq!(fs.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(fs.get("chips").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(
            fs.get("per_chip").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(2)
        );
        svc.stop();
    }

    #[test]
    fn json_str_escapes_via_writer() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(json_str("\u{1}"), "\"\\u0001\"");
    }
}
