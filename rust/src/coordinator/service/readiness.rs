//! Readiness-loop serving model (DESIGN.md §14): a fixed set of worker
//! threads multiplexes every accepted connection over non-blocking
//! sockets and `poll(2)` — thousands of mostly-idle connections cost a
//! file descriptor and a few buffers each, not a reader + writer thread
//! pair each.
//!
//! Structure per worker:
//!
//! * An **inbox** (new connections from the acceptor, a shutdown flag)
//!   plus a **waker pipe** (`UnixStream::pair`): the acceptor, `stop()`,
//!   and — crucially — chip workers completing replies all write one
//!   byte to pop the worker out of `poll`.
//! * Per connection: the shared protocol state machine
//!   ([`super::conn::ProtoState`]), an ordered pending-reply FIFO, and a
//!   write buffer.  Replies resolve front-first ([`Pending::try_resolve`]),
//!   so pipelined replies leave in request order exactly like the
//!   threaded model's writer thread.
//! * Backpressure: once [`PENDING_REPLY_DEPTH`] replies are outstanding
//!   the connection's `POLLIN` interest is dropped — the client's
//!   requests pile up in the kernel buffer and TCP flow control pushes
//!   back, same contract as the threaded model's bounded channel.
//! * A connection with nothing pollable (idle write side, paused read
//!   side) is simply left out of the poll set; chip completions reach it
//!   through the waker.  Idle connections cause zero periodic wakeups.
//!
//! The fleet side of the wake-up is [`ReplyNotify`]
//! (`dispatch_*_notify`): the hook travels with the job and fires after
//! the reply is buffered on its channel, so a `try_resolve` sweep after
//! a wake never misses a completion.
//!
//! `poll(2)` is declared directly (the offline build vendors no `libc`/
//! `mio`); the FFI surface is three constants and one function.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use bss2_proto::{handshake, PENDING_REPLY_DEPTH, PROTO_VERSION};

use super::conn::{Fatal, ProtoState, ReplyFormat, WireEvent};
use super::{
    err_json, handle_request, ConnGuard, Pending, ShutdownSignal,
    StreamSession,
};
use crate::fleet::{Fleet, ReplyNotify};
use crate::util::sync::lock_clean;

// ---------------------------------------------------------------------
// poll(2) FFI — identical layout and flag values on Linux and the BSDs.

#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;
/// Error-ish revents are reported regardless of the requested events;
/// either direction should attempt I/O and observe the failure there.
const POLL_ANY_IN: i16 = POLLIN | POLLERR | POLLHUP | POLLNVAL;
const POLL_ANY_OUT: i16 = POLLOUT | POLLERR | POLLHUP | POLLNVAL;

#[cfg(target_os = "macos")]
type Nfds = u32;
#[cfg(not(target_os = "macos"))]
type Nfds = std::os::raw::c_ulong;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: Nfds, timeout: i32) -> i32;
}

// ---------------------------------------------------------------------

/// Wake a worker out of `poll`.  The pipe is non-blocking: a full pipe
/// already guarantees a pending wake-up, so `WouldBlock` is success.
fn wake(waker: &UnixStream) {
    let mut w = waker;
    let _ = w.write(&[1]);
}

/// Acceptor- and fleet-facing message box of one worker.
struct Inbox {
    new_conns: Mutex<Vec<(TcpStream, ConnGuard)>>,
    shutdown: AtomicBool,
}

struct WorkerHandle {
    inbox: Arc<Inbox>,
    waker: Arc<UnixStream>,
}

/// The running worker set.  Owned by the acceptor thread; connections
/// are distributed round-robin.
pub(super) struct WorkerPool {
    workers: Vec<WorkerHandle>,
    joins: Vec<std::thread::JoinHandle<()>>,
    next: usize,
}

impl WorkerPool {
    pub(super) fn spawn(
        fleet: Arc<Fleet>,
        shutdown: Arc<ShutdownSignal>,
        allow_remote_shutdown: bool,
    ) -> anyhow::Result<WorkerPool> {
        let n = worker_count();
        let mut pool =
            WorkerPool { workers: Vec::new(), joins: Vec::new(), next: 0 };
        for i in 0..n {
            match spawn_worker(i, &fleet, &shutdown, allow_remote_shutdown) {
                Ok((handle, join)) => {
                    pool.workers.push(handle);
                    pool.joins.push(join);
                }
                Err(e) => {
                    pool.stop(); // don't leak the workers already up
                    return Err(e);
                }
            }
        }
        Ok(pool)
    }

    /// Hand an accepted (registered) connection to a worker.
    pub(super) fn submit(&mut self, stream: TcpStream, guard: ConnGuard) {
        if stream.set_nonblocking(true).is_err() {
            return; // dropping the guard deregisters the connection
        }
        // lint:allow(panic-index: modulo by workers.len(), pool is never empty)
        let w = &self.workers[self.next % self.workers.len()];
        self.next = self.next.wrapping_add(1);
        lock_clean(&w.inbox.new_conns).push((stream, guard));
        wake(&w.waker);
    }

    /// Stop every worker and join it.  Open connections are dropped —
    /// the service only calls this after `stop()` closed their sockets.
    pub(super) fn stop(&mut self) {
        for w in &self.workers {
            w.inbox.shutdown.store(true, Ordering::SeqCst);
            wake(&w.waker);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Worker-set size: I/O multiplexing is cheap, so half the cores
/// (bounded to 8) is plenty — the chips, not the sockets, are the
/// expensive resource.
fn worker_count() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .div_ceil(2)
        .clamp(1, 8)
}

fn spawn_worker(
    index: usize,
    fleet: &Arc<Fleet>,
    shutdown: &Arc<ShutdownSignal>,
    allow_remote_shutdown: bool,
) -> anyhow::Result<(WorkerHandle, std::thread::JoinHandle<()>)> {
    let (wake_tx, wake_rx) = UnixStream::pair()?;
    wake_tx.set_nonblocking(true)?;
    wake_rx.set_nonblocking(true)?;
    let waker = Arc::new(wake_tx);
    let inbox = Arc::new(Inbox {
        new_conns: Mutex::new(Vec::new()),
        shutdown: AtomicBool::new(false),
    });
    // The chip-completion hook: one per worker, cloned into every
    // dispatch made on behalf of this worker's connections.
    let notify_waker = waker.clone();
    let notify: ReplyNotify = Arc::new(move || wake(&notify_waker));
    let w_inbox = inbox.clone();
    let w_fleet = fleet.clone();
    let w_shutdown = shutdown.clone();
    let join = std::thread::Builder::new()
        .name(format!("bss2-poll-{index}"))
        .spawn(move || {
            worker_loop(
                &w_inbox,
                &wake_rx,
                &w_fleet,
                &w_shutdown,
                allow_remote_shutdown,
                &notify,
            );
        })?;
    Ok((WorkerHandle { inbox, waker }, join))
}

fn worker_loop(
    inbox: &Inbox,
    wake_rx: &UnixStream,
    fleet: &Fleet,
    shutdown: &ShutdownSignal,
    allow_remote_shutdown: bool,
    notify: &ReplyNotify,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut pollfds: Vec<PollFd> = Vec::new();
    let mut poll_map: Vec<usize> = Vec::new();
    let mut chunk = [0u8; 8192];
    loop {
        if inbox.shutdown.load(Ordering::SeqCst) {
            // Dropping the connections deregisters them; their sockets
            // were already shut down by `stop()`.
            return;
        }
        for (stream, guard) in lock_clean(&inbox.new_conns).drain(..) {
            conns.push(Conn::new(stream, guard));
        }

        // Make progress everywhere: resolve chip replies that are ready
        // (in FIFO order per connection) and flush what each socket will
        // take right now.
        for conn in conns.iter_mut() {
            conn.resolve_ready();
            conn.flush();
        }
        // Sweep finished connections, honouring wire `shutdown` byes.
        let mut i = 0;
        while i < conns.len() {
            // lint:allow(panic-index: i < conns.len() is the loop condition)
            if conns[i].done() {
                let conn = conns.swap_remove(i);
                if conn.bye {
                    shutdown.signal();
                }
            } else {
                i += 1;
            }
        }

        // Poll set: the waker, plus each connection we can make direct
        // socket progress on.  Everything else (reply-paused or idle-
        // write connections) is reached through the waker instead —
        // zero periodic wakeups.
        pollfds.clear();
        poll_map.clear();
        pollfds.push(PollFd {
            fd: wake_rx.as_raw_fd(),
            events: POLLIN,
            revents: 0,
        });
        for (ci, conn) in conns.iter().enumerate() {
            let mut events = 0i16;
            if conn.wants_read() {
                events |= POLLIN;
            }
            if !conn.wbuf.is_empty() {
                events |= POLLOUT;
            }
            if events != 0 {
                pollfds.push(PollFd {
                    fd: conn.stream.as_raw_fd(),
                    events,
                    revents: 0,
                });
                poll_map.push(ci);
            }
        }

        let rc = unsafe {
            poll(pollfds.as_mut_ptr(), pollfds.len() as Nfds, -1)
        };
        if rc < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                continue;
            }
            return; // poll itself failed; nothing sane left to do
        }

        if pollfds[0].revents != 0 {
            drain_waker(wake_rx);
        }
        for (pi, &ci) in poll_map.iter().enumerate() {
            // lint:allow(panic-index: pollfds is waker + one slot per poll_map entry)
            let revents = pollfds[pi + 1].revents;
            if revents == 0 {
                continue;
            }
            // lint:allow(panic-index: poll_map holds indices into conns built this pass)
            let conn = &mut conns[ci];
            if revents & POLL_ANY_OUT != 0 && !conn.wbuf.is_empty() {
                conn.flush();
            }
            if revents & POLL_ANY_IN != 0 && conn.wants_read() {
                conn.fill(&mut chunk, fleet, allow_remote_shutdown, notify);
            }
        }
    }
}

fn drain_waker(mut rx: &UnixStream) {
    let mut buf = [0u8; 256];
    loop {
        match rx.read(&mut buf) {
            Ok(0) => return, // write half gone (pool stopping)
            Ok(_) => continue,
            Err(_) => return, // WouldBlock: drained
        }
    }
}

/// One multiplexed connection.
struct Conn {
    stream: TcpStream,
    _guard: ConnGuard,
    proto: ProtoState,
    fmt: ReplyFormat,
    /// Ordered pending-reply FIFO (the threaded model's bounded channel,
    /// as data).  Resolution is front-only: replies leave in request
    /// order.
    pending: VecDeque<Pending>,
    wbuf: Vec<u8>,
    session: Option<StreamSession>,
    /// Read side is finished (EOF, read error, fatal protocol error, or
    /// an accepted `shutdown`): drain `pending` + `wbuf`, then close.
    closing: bool,
    /// Write side failed: drop the connection at the next sweep.
    dead: bool,
    /// An accepted wire `shutdown` good-bye was serialized: signal
    /// service shutdown when this connection closes.
    bye: bool,
}

impl Conn {
    fn new(stream: TcpStream, guard: ConnGuard) -> Conn {
        Conn {
            stream,
            _guard: guard,
            proto: ProtoState::new(),
            fmt: ReplyFormat::Lines,
            pending: VecDeque::new(),
            wbuf: Vec::new(),
            session: None,
            closing: false,
            dead: false,
            bye: false,
        }
    }

    /// Still interested in bytes from the client?  False once closing,
    /// and false while the pending FIFO is at the pipelining bound —
    /// the readiness-model backpressure.
    fn wants_read(&self) -> bool {
        !self.closing && !self.dead && self.pending.len() < PENDING_REPLY_DEPTH
    }

    fn done(&self) -> bool {
        self.dead
            || (self.closing
                && self.pending.is_empty()
                && self.wbuf.is_empty())
    }

    /// Serialize every already-answered reply at the front of the FIFO.
    fn resolve_ready(&mut self) {
        if self.dead {
            return;
        }
        while let Some(p) = self.pending.pop_front() {
            match p.try_resolve() {
                Err(p) => {
                    self.pending.push_front(p); // still waiting on a chip
                    break;
                }
                Ok((text, bye)) => {
                    self.fmt.serialize(&text, &mut self.wbuf);
                    if bye {
                        self.bye = true;
                        self.closing = true;
                        self.pending.clear();
                        break;
                    }
                }
            }
        }
    }

    /// Write as much of `wbuf` as the socket takes without blocking.
    fn flush(&mut self) {
        let mut written = 0usize;
        while written < self.wbuf.len() {
            let mut w = &self.stream;
            // lint:allow(panic-index: written < wbuf.len() is the loop condition)
            match w.write(&self.wbuf[written..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => written += n,
                Err(e)
                    if e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    continue
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    break
                }
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if written > 0 {
            self.wbuf.drain(..written);
        }
    }

    /// Read whatever the socket has, run the protocol state machine,
    /// and dispatch complete requests.
    fn fill(
        &mut self,
        chunk: &mut [u8],
        fleet: &Fleet,
        allow_remote_shutdown: bool,
        notify: &ReplyNotify,
    ) {
        loop {
            if !self.wants_read() {
                return;
            }
            let n = {
                let mut r = &self.stream;
                match r.read(chunk) {
                    Ok(0) => {
                        self.closing = true; // EOF: drain replies, close
                        return;
                    }
                    Ok(n) => n,
                    Err(e)
                        if e.kind() == std::io::ErrorKind::Interrupted =>
                    {
                        continue
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        return
                    }
                    Err(_) => {
                        // Same as EOF: pending replies still drain, the
                        // flush failing is what declares the conn dead.
                        self.closing = true;
                        return;
                    }
                }
            };
            // lint:allow(panic-index: n is the byte count read() returned for chunk)
            let events = match self.proto.push(&chunk[..n]) {
                Ok(events) => events,
                Err(Fatal::Reject(bytes)) => {
                    self.wbuf.extend_from_slice(&bytes);
                    self.closing = true;
                    return;
                }
                Err(Fatal::Error(msg)) => {
                    self.pending.push_back(Pending::Now(err_json(&msg)));
                    self.closing = true;
                    return;
                }
            };
            for event in events {
                match event {
                    WireEvent::Hello(enc) => {
                        // The hello is the first bytes on the wire, so
                        // appending the ack directly keeps wire order.
                        self.fmt = ReplyFormat::for_encoding(enc);
                        self.wbuf.extend_from_slice(&handshake::ok_bytes(
                            PROTO_VERSION,
                            enc,
                        ));
                    }
                    WireEvent::BadRequest(msg) => {
                        self.pending.push_back(Pending::Now(err_json(&msg)));
                    }
                    WireEvent::Request(req) => {
                        let (replies, bye) = handle_request(
                            &req,
                            fleet,
                            allow_remote_shutdown,
                            &mut self.session,
                            Some(notify),
                        );
                        self.pending.extend(replies);
                        if bye {
                            // Stop reading; the queued `Bye` pending
                            // raises the shutdown signal once it has
                            // been serialized behind its predecessors.
                            self.closing = true;
                            return;
                        }
                    }
                }
            }
        }
    }
}
