//! Thread-per-connection serving model: one reader thread (this module's
//! [`handle_conn`], run on the per-connection `bss2-conn` thread spawned
//! by the acceptor) plus one `bss2-conn-writer` thread per connection.
//!
//! This is the original serving model, kept for `--conn-model threaded`
//! (and as the only model on non-unix hosts) and as the baseline the
//! `repro loadgen` bench compares the readiness loop against.  Both
//! models share the same protocol state machine ([`super::conn`]) and
//! request handler, so they are wire-identical; only the concurrency
//! structure differs.
//!
//! Replies are resolved and written by the writer thread in request
//! order; the bounded channel between reader and writer is the
//! [`PENDING_REPLY_DEPTH`] pipelining backpressure.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Arc};

use bss2_proto::{handshake, PENDING_REPLY_DEPTH, PROTO_VERSION};

use super::conn::{Fatal, ProtoState, ReplyFormat, WireEvent};
use super::{err_json, handle_request, Pending, ShutdownSignal};
use crate::fleet::Fleet;

/// Reader → writer message.  `Mode` travels in-band so the format switch
/// lands exactly between the last legacy reply and the handshake ack.
enum ConnMsg {
    /// Pre-serialized bytes (handshake ack), written verbatim.
    Raw(Vec<u8>),
    /// Switch the reply serialization for everything that follows.
    Mode(ReplyFormat),
    /// A reply to resolve (blocking on the chip if needed) and write.
    Reply(Pending),
}

/// Serve one accepted connection until EOF, a fatal protocol error, or
/// `bye`/shutdown.
pub(super) fn handle_conn(
    stream: TcpStream,
    fleet: Arc<Fleet>,
    shutdown: Arc<ShutdownSignal>,
    allow_remote_shutdown: bool,
) -> anyhow::Result<()> {
    let writer_stream = stream.try_clone()?;
    // The bounded queue is the pipelining depth: a client that floods
    // requests blocks the reader here until replies drain.
    let (tx, rx) = mpsc::sync_channel::<ConnMsg>(PENDING_REPLY_DEPTH);
    let writer_shutdown = shutdown.clone();
    let writer = std::thread::Builder::new()
        .name("bss2-conn-writer".into())
        .spawn(move || write_loop(writer_stream, rx, writer_shutdown))?;

    let mut reader = stream;
    let mut proto = ProtoState::new();
    let mut session = None;
    let mut chunk = [0u8; 8192];
    let result = 'conn: loop {
        let n = match reader.read(&mut chunk) {
            Ok(0) => break Ok(()),
            Ok(n) => n,
            Err(e) => break Err(anyhow::Error::from(e)),
        };
        if shutdown.is_set() {
            break Ok(());
        }
        // lint:allow(panic-index: n is the byte count read() returned for chunk)
        let events = match proto.push(&chunk[..n]) {
            Ok(events) => events,
            Err(fatal) => {
                let msg = match fatal {
                    Fatal::Reject(bytes) => ConnMsg::Raw(bytes.to_vec()),
                    Fatal::Error(text) => {
                        ConnMsg::Reply(Pending::Now(err_json(&text)))
                    }
                };
                let _ = tx.send(msg);
                break Ok(());
            }
        };
        for event in events {
            let (replies, bye) = match event {
                WireEvent::Hello(enc) => {
                    let fmt = ReplyFormat::for_encoding(enc);
                    let ack =
                        handshake::ok_bytes(PROTO_VERSION, enc).to_vec();
                    if tx.send(ConnMsg::Mode(fmt)).is_err()
                        || tx.send(ConnMsg::Raw(ack)).is_err()
                    {
                        break 'conn Ok(()); // writer gone (socket died)
                    }
                    continue;
                }
                WireEvent::BadRequest(msg) => {
                    (vec![Pending::Now(err_json(&msg))], false)
                }
                WireEvent::Request(req) => handle_request(
                    &req,
                    &fleet,
                    allow_remote_shutdown,
                    &mut session,
                    None,
                ),
            };
            for reply in replies {
                if tx.send(ConnMsg::Reply(reply)).is_err() {
                    break 'conn Ok(());
                }
            }
            if bye {
                break 'conn Ok(());
            }
        }
    };
    // Dropping the sender lets the writer drain the remaining replies
    // and exit; joining keeps the guard alive until both halves stop.
    drop(tx);
    let _ = writer.join();
    result
}

/// Writer half: resolves pendings in order and owns the write side.
fn write_loop(
    mut stream: TcpStream,
    rx: mpsc::Receiver<ConnMsg>,
    shutdown: Arc<ShutdownSignal>,
) {
    let mut fmt = ReplyFormat::Lines;
    let mut out = Vec::with_capacity(512);
    while let Ok(msg) = rx.recv() {
        out.clear();
        let bye = match msg {
            ConnMsg::Mode(new_fmt) => {
                fmt = new_fmt;
                continue;
            }
            ConnMsg::Raw(bytes) => {
                out.extend_from_slice(&bytes);
                false
            }
            ConnMsg::Reply(pending) => {
                let (text, bye) = pending.resolve_blocking();
                fmt.serialize(&text, &mut out);
                bye
            }
        };
        let write_ok = stream.write_all(&out).is_ok();
        if bye {
            // Accepted shutdown: the command takes effect even if the
            // good-bye could not be delivered.
            shutdown.signal();
            return;
        }
        if !write_ok {
            return;
        }
    }
}
