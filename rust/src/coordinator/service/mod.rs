//! Experiment execution service (paper §II-D "Embedded System Environment").
//!
//! "An experiment execution service enables users to run Python-based
//! interfaces on host computers that exchange serialized experiment
//! configurations and result data with the mobile system."
//!
//! Ours is a JSON-valued protocol over TCP (the mobile system's
//! USB-Ethernet remote path) with two transports, negotiated per
//! connection by the first byte (DESIGN.md §14, `bss2-proto`):
//!
//! * **Legacy lines**: one JSON object per `\n`-terminated line — the
//!   original protocol, still spoken byte-for-byte by old clients.
//! * **Framed**: an 8-byte magic hello negotiates the protocol version
//!   and an encoding (framed JSON text, or the compact binary value
//!   encoding with packed `u16` sample arrays); every request and reply
//!   is then a length-prefixed frame.  `bss2-client` implements this.
//!
//! Requests are dispatched through a
//! [`fleet::Fleet`](crate::fleet::Fleet) of engine replicas.  A `classify`
//! serves one trace at the paper's 276 µs single-sample latency; a
//! `classify_batch` trades latency for throughput: the whole batch runs on
//! one chip as a single program with one weight reconfiguration per layer
//! per batch (DESIGN.md §9).  The fleet spreads concurrent clients across
//! replicas, accounts admission in *samples*, and sheds load explicitly —
//! a batch that only partially fits is partially accepted; every shed
//! reply carries backoff hints (`queue_depth`, `retry_after_us`).
//!
//! **Connection model** (DESIGN.md §11/§14, [`ServeModel`]): requests
//! pipeline — a client may write N requests before reading any reply;
//! replies come back in request order, each resolved as its chip
//! finishes, with the pending-reply FIFO bounded at
//! [`PENDING_REPLY_DEPTH`].  Two interchangeable implementations:
//!
//! * [`ServeModel::Readiness`] (default on unix): a small worker set
//!   multiplexes *all* connections over non-blocking sockets and
//!   `poll(2)`; chip completions wake the owning worker through a pipe.
//!   Thousands of mostly-idle connections cost two fds and a few kB
//!   each, not two threads each.
//! * [`ServeModel::Threaded`]: the original reader + ordered-reply
//!   writer thread pair per connection — the loadgen baseline, and the
//!   only model on non-unix hosts.
//!
//! Both are shutdown-aware: idle connections cause zero periodic
//! wakeups, and `stop()` unblocks everything by closing the listener and
//! every registered connection.
//!
//! **Streaming sessions**: continuous ECG monitoring pushes an unbroken
//! sample stream in arbitrary chunks; the server windows it incrementally
//! (O(hop) per window, `fpga::preprocess::IncrementalWindower`), dispatches
//! ready frames through the fleet, and pushes result lines asynchronously,
//! in window order.
//!
//! Protocol (one JSON object per line):
//! ```text
//! -> {"cmd": "classify", "trace": [[...ch0 u12...], [...ch1...]]}
//! <- {"ok": true, "pred": 1, "scores": [a, b], "time_us": t,
//!     "energy_mj": e, "chip": c}
//! <- {"ok": false, "shed": true, "error": "...", "queue_depth": q,
//!     "retry_after_us": n}
//! -> {"cmd": "classify_batch", "traces": [[[..ch0..], [..ch1..]], ...]}
//! <- {"ok": true, "chip": c, "batch": B, "accepted": k, "shed": B - k,
//!     "retry_after_us": n?, "time_us_per_sample": t,
//!     "results": [{"pred": p, "scores": [a, b], "time_us": t,
//!                  "energy_mj": e}, ...k entries...]}
//! <- {"ok": false, "shed": true, "error": "...", "accepted": 0,
//!     "batch": B, "queue_depth": q, "retry_after_us": n}
//! <- {"ok": false, "error": "...", "batch": B, "accepted": k}
//!    (terminal engine failure — only after the fleet's transparent
//!     failover budget is exhausted; still echoes batch/accepted so
//!     pipelined clients keep request/reply correlation)
//! -> {"cmd": "stream_open", "hop": H}       (H: samples, multiple of 32)
//! <- {"ok": true, "stream": "open", "hop": H, "window": 2048,
//!     "pool_window": 32}
//! -> {"cmd": "stream_push", "samples": [[...ch0...], [...ch1...]]}
//!    (arbitrary chunk length; results arrive asynchronously, in order:)
//! <- {"ok": true, "stream": true, "window": w, "start_sample": s,
//!     "pred": p, "scores": [a, b], "time_us": t, "energy_mj": e,
//!     "chip": c}
//! <- {"ok": false, "stream": true, "shed": true, "window": w,
//!     "start_sample": s, "error": "...", "queue_depth": q,
//!     "retry_after_us": n}
//! -> {"cmd": "stream_close"}
//! <- {"ok": true, "stream": "closed", "windows": n, "dispatched": d,
//!     "shed": k, "samples": m}   (written after every pending result)
//! -> {"cmd": "stats"}
//! <- {"ok": true, "served": n, "mean_time_us": t, "chips": c, "shed": s}
//! -> {"cmd": "fleet_stats"}
//! <- {"ok": true, "chips": c, ..., "stages": {...}, "per_chip": [...]}
//! -> {"cmd": "metrics"}            ("format": "text" for Prometheus)
//! <- {"ok": true, "metrics": [{"name": "...", "kind": "counter",
//!     "value": v, "labels": {...}}, ...]}
//! <- {"ok": true, "format": "text", "body": "# HELP ...\n..."}
//! -> {"cmd": "trace", "n": 16}
//! <- {"ok": true, "seen": s, "recorded": r, "traces": [{"id": i,
//!     "chip": c, "kind": "classify", "batch": b, "redirects": h,
//!     "host_us": {"total": t, "queue": q, "execute": e, "retry": r},
//!     "sim_us": {"total": t, "dma": ..., ..., "control": ...}}, ...]}
//! -> {"cmd": "journal", "since": S}
//! <- {"ok": true, "next_seq": n, "events": [{"seq": q, "kind": "...",
//!     "chip": c?, "detail": "..."}, ...]}
//!    (if the first returned seq is > S, events in between aged out of
//!     the bounded ring)
//! -> {"cmd": "recalibrate", "chip": c, "reps": r}
//! <- {"ok": true, "chip": c, "chip_time_us": t, "residual_rms": x,
//!     "reason": "..."}   (drain -> calibrate -> re-admit; the reply line
//!                         waits for the measurement, later requests on
//!                         the same connection keep pipelining)
//! -> {"cmd": "ping"} | {"cmd": "shutdown"}
//!    (shutdown requires `FleetConfig::allow_remote_shutdown`, default
//!     off: an open port must not be an unauthenticated kill switch)
//! ```

mod conn;
#[cfg(unix)]
mod readiness;
mod threaded;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

use crate::asic::consts as c;
use crate::ecg::gen::Trace;
use crate::fleet::{
    BatchDispatchOutcome, ChipId, DispatchOutcome, Fleet, FleetConfig,
    ReplyNotify,
};
use crate::fpga::preprocess::IncrementalWindower;
use crate::obs::{expo, EventKind, TraceRecord};
use crate::util::json::Json;
use crate::util::sync::{lock_clean, wait_clean};

use super::engine::{Engine, Inference};

// The wire-protocol limits live in `bss2-proto` (client and server must
// agree on them); re-exported here so existing `service::MAX_*` paths
// keep working.
pub use bss2_proto::{
    MAX_RECALIB_REPS, MAX_STREAM_CHUNK, MAX_WIRE_BATCH, PENDING_REPLY_DEPTH,
};

/// Level-triggered shutdown latch: an atomic flag for cheap polling plus
/// a condvar so [`Service::run_until_shutdown`] can sleep instead of
/// spinning.
struct ShutdownSignal {
    flag: AtomicBool,
    lock: Mutex<()>,
    cv: Condvar,
}

impl ShutdownSignal {
    fn new() -> ShutdownSignal {
        ShutdownSignal {
            flag: AtomicBool::new(false),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    fn signal(&self) {
        // Set under the lock so a waiter can never observe the flag
        // clear and then miss the notify.
        let _g = lock_clean(&self.lock);
        self.flag.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    fn is_set(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    fn wait(&self) {
        let mut g = lock_clean(&self.lock);
        while !self.flag.load(Ordering::SeqCst) {
            g = wait_clean(&self.cv, g);
        }
    }
}

/// Live-connection registry: the acceptor registers a socket clone before
/// spawning its handler, the handler deregisters on exit (panic-safe via
/// [`ConnGuard`]), and `stop()` shuts every registered socket down to
/// unblock readers sleeping in blocking I/O.
struct ConnRegistry {
    next_id: AtomicU64,
    streams: Mutex<HashMap<u64, TcpStream>>,
}

impl ConnRegistry {
    fn new() -> ConnRegistry {
        ConnRegistry {
            next_id: AtomicU64::new(0),
            streams: Mutex::new(HashMap::new()),
        }
    }

    fn register(&self, stream: &TcpStream) -> std::io::Result<u64> {
        let clone = stream.try_clone()?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        lock_clean(&self.streams).insert(id, clone);
        Ok(id)
    }

    fn deregister(&self, id: u64) {
        lock_clean(&self.streams).remove(&id);
    }

    fn active(&self) -> usize {
        lock_clean(&self.streams).len()
    }

    fn shutdown_all(&self) {
        for s in lock_clean(&self.streams).values() {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

/// Deregisters a connection even when its handler panics.
struct ConnGuard {
    conns: Arc<ConnRegistry>,
    id: u64,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.conns.deregister(self.id);
    }
}

/// Connection-handling model (DESIGN.md §14).  Both models speak the
/// same protocols and share the request handler; they differ only in
/// how many threads a connection costs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeModel {
    /// A fixed worker set multiplexes every connection over
    /// non-blocking sockets and `poll(2)` — thousands of connections,
    /// a handful of threads.  Unix only.
    Readiness,
    /// One reader + one writer thread per connection (the original
    /// model; the `repro loadgen` baseline).
    Threaded,
}

impl Default for ServeModel {
    fn default() -> ServeModel {
        if cfg!(unix) {
            ServeModel::Readiness
        } else {
            ServeModel::Threaded
        }
    }
}

impl ServeModel {
    pub fn as_str(self) -> &'static str {
        match self {
            ServeModel::Readiness => "readiness",
            ServeModel::Threaded => "threaded",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<ServeModel> {
        match s {
            "readiness" => Ok(ServeModel::Readiness),
            "threaded" => Ok(ServeModel::Threaded),
            other => anyhow::bail!(
                "unknown connection model {other:?} (expected \
                 \"readiness\" or \"threaded\")"
            ),
        }
    }
}

/// Where the acceptor hands an admitted connection: a freshly spawned
/// handler thread, or the readiness-loop worker pool.
enum ConnSink {
    Threaded {
        fleet: Arc<Fleet>,
        shutdown: Arc<ShutdownSignal>,
        allow_remote_shutdown: bool,
        handlers: Vec<std::thread::JoinHandle<()>>,
    },
    #[cfg(unix)]
    Readiness(readiness::WorkerPool),
}

impl ConnSink {
    fn submit(&mut self, stream: TcpStream, guard: ConnGuard) {
        match self {
            ConnSink::Threaded {
                fleet,
                shutdown,
                allow_remote_shutdown,
                handlers,
            } => {
                // Reap finished handler threads so connection churn
                // cannot grow the vector (and the thread handles it
                // retains) without bound.
                handlers.retain(|h| !h.is_finished());
                let fleet = fleet.clone();
                let sdown = shutdown.clone();
                let allow = *allow_remote_shutdown;
                let spawned = std::thread::Builder::new()
                    .name("bss2-conn".into())
                    .spawn(move || {
                        let _guard = guard;
                        let _ = threaded::handle_conn(
                            stream, fleet, sdown, allow,
                        );
                    });
                // On spawn failure the closure (and the guard inside
                // it) is dropped, which deregisters the connection.
                if let Ok(h) = spawned {
                    handlers.push(h);
                }
            }
            #[cfg(unix)]
            ConnSink::Readiness(pool) => pool.submit(stream, guard),
        }
    }

    /// Acceptor exit: join every handler / stop the worker pool.
    fn finish(self) {
        match self {
            ConnSink::Threaded { handlers, .. } => {
                for h in handlers {
                    let _ = h.join();
                }
            }
            #[cfg(unix)]
            ConnSink::Readiness(mut pool) => pool.stop(),
        }
    }
}

/// The running service handle.  Serving statistics live in
/// [`Fleet::telemetry`]: one source of truth, accumulated in integer
/// nanoseconds so mean-latency reporting keeps sub-µs precision across
/// millions of requests.
pub struct Service {
    pub addr: std::net::SocketAddr,
    pub fleet: Arc<Fleet>,
    shutdown: Arc<ShutdownSignal>,
    conns: Arc<ConnRegistry>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    /// Start a single-chip service (the paper's original topology).  The
    /// engine is constructed *inside* the worker thread (PJRT handles are
    /// not `Send`): pass a builder closure.
    ///
    /// Keeps the legacy contract: an effectively unbounded admission
    /// queue (no shed replies) and a wire-reachable `shutdown` command —
    /// the in-process test/bring-up topology.  Opt into backpressure and
    /// the hardened defaults via [`Service::start_fleet`].  One contract
    /// change kept from the fleet PR: engine-init failure fails `start`
    /// fast instead of serving per-request `engine init` errors.
    pub fn start<F>(addr: &str, make_engine: F) -> anyhow::Result<Service>
    where
        F: FnOnce() -> anyhow::Result<Engine> + Send + 'static,
    {
        let once = Mutex::new(Some(make_engine));
        let cfg = FleetConfig {
            queue_depth: usize::MAX,
            allow_remote_shutdown: true,
            ..FleetConfig::single()
        };
        Self::start_fleet(addr, cfg, move |_chip| {
            let f = lock_clean(&once)
                .take()
                .ok_or_else(|| anyhow::anyhow!("engine builder already used"))?;
            f()
        })
    }

    /// Start the service on `addr` (use port 0 for an ephemeral port)
    /// backed by a fleet of `cfg.chips` engine replicas, using the
    /// default [`ServeModel`].  `make_engine` runs once per chip, inside
    /// that chip's worker thread.  Fails fast if *every* replica's
    /// engine fails to construct (partial failures serve degraded, with
    /// the dead chips reported in `fleet_stats`).
    pub fn start_fleet<F>(
        addr: &str,
        cfg: FleetConfig,
        make_engine: F,
    ) -> anyhow::Result<Service>
    where
        F: Fn(ChipId) -> anyhow::Result<Engine> + Send + Sync + 'static,
    {
        Self::start_fleet_with(addr, cfg, ServeModel::default(), make_engine)
    }

    /// [`Service::start_fleet`] with an explicit connection-handling
    /// model (`repro serve --conn-model`, and the loadgen A/B bench).
    pub fn start_fleet_with<F>(
        addr: &str,
        cfg: FleetConfig,
        model: ServeModel,
        make_engine: F,
    ) -> anyhow::Result<Service>
    where
        F: Fn(ChipId) -> anyhow::Result<Engine> + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let allow_remote_shutdown = cfg.allow_remote_shutdown;
        let max_conns = cfg.max_connections.max(1);
        let fleet = Arc::new(Fleet::start(cfg, make_engine)?);
        let shutdown = Arc::new(ShutdownSignal::new());
        let conns = Arc::new(ConnRegistry::new());

        #[cfg(not(unix))]
        let model = match model {
            ServeModel::Readiness => {
                log::warn!(
                    "readiness loop needs poll(2); falling back to \
                     thread-per-connection"
                );
                ServeModel::Threaded
            }
            m => m,
        };
        let mut sink = match model {
            ServeModel::Threaded => ConnSink::Threaded {
                fleet: fleet.clone(),
                shutdown: shutdown.clone(),
                allow_remote_shutdown,
                handlers: Vec::new(),
            },
            #[cfg(unix)]
            ServeModel::Readiness => {
                ConnSink::Readiness(readiness::WorkerPool::spawn(
                    fleet.clone(),
                    shutdown.clone(),
                    allow_remote_shutdown,
                )?)
            }
            #[cfg(not(unix))]
            // lint:allow(panic-macro: model is forced to Threaded above on non-unix)
            ServeModel::Readiness => unreachable!("forced Threaded above"),
        };

        // Acceptor: *blocking* accept loop — no polling sleeps.  `stop()`
        // wakes it with a loopback connection after setting the flag.
        let sdown = shutdown.clone();
        let afleet = fleet.clone();
        let aconns = conns.clone();
        let accept_handle = std::thread::Builder::new()
            .name("bss2-acceptor".into())
            .spawn(move || {
                loop {
                    let stream = match listener.accept() {
                        Ok((s, _)) => s,
                        Err(ref e)
                            if e.kind() == std::io::ErrorKind::Interrupted =>
                        {
                            continue
                        }
                        Err(_) => break,
                    };
                    if sdown.is_set() {
                        break; // stop()'s wake-up connection (dropped)
                    }
                    let active = aconns.active();
                    if active >= max_conns {
                        // Explicit accept-time shed: tell the client why
                        // before hanging up, instead of a silent RST or —
                        // worse — an unbounded connection pile-up.
                        // Journal first: a client that read the refusal
                        // line can already see the event.  `queue_depth`
                        // here counts *connections* (the contended
                        // resource at this level).
                        afleet.obs().journal.log(
                            EventKind::ConnectionShed,
                            None,
                            &format!("connection limit {max_conns} reached"),
                        );
                        let mut s = stream;
                        let _ = s.write_all(
                            format!(
                                "{{\"ok\":false,\"shed\":true,\
                                 \"error\":\"connection limit reached\",\
                                 \"max_connections\":{max_conns},\
                                 \"queue_depth\":{active}}}\n"
                            )
                            .as_bytes(),
                        );
                        continue;
                    }
                    let Ok(id) = aconns.register(&stream) else {
                        continue;
                    };
                    // Re-check *after* registering: `stop()` signals and
                    // then closes every registered socket, and the
                    // registry mutex orders the two — either stop() saw
                    // this entry and closed it, or we see the flag here.
                    // Either way no handler is started on a socket that
                    // could block the final join.
                    if sdown.is_set() {
                        let _ = stream.shutdown(Shutdown::Both);
                        aconns.deregister(id);
                        break;
                    }
                    let guard = ConnGuard { conns: aconns.clone(), id };
                    sink.submit(stream, guard);
                }
                sink.finish();
            })
            .map_err(|e| anyhow::anyhow!("spawn acceptor thread: {e}"))?;

        Ok(Service {
            addr: local,
            fleet,
            shutdown,
            conns,
            accept_handle: Some(accept_handle),
        })
    }

    /// Live client connections (registered handlers).
    pub fn active_connections(&self) -> usize {
        self.conns.active()
    }

    /// Block the calling thread until a client sends `shutdown` (condvar
    /// wait — no polling), then stop.  Used by `repro serve`.
    pub fn run_until_shutdown(self) {
        self.shutdown.wait();
        self.stop();
    }

    pub fn stop(mut self) {
        self.shutdown_impl();
    }

    /// Idempotent teardown: raise the flag, close every registered client
    /// socket (unblocks readers in blocking I/O), wake the blocking
    /// acceptor with a loopback connection, then join it — which joins
    /// every handler; handler writers drain against the still-running
    /// fleet, so a handler blocked in `resp.recv()` always completes.
    fn shutdown_impl(&mut self) {
        self.shutdown.signal();
        self.conns.shutdown_all();
        if let Some(h) = self.accept_handle.take() {
            // Wildcard binds (0.0.0.0/::) are not connectable everywhere;
            // aim the wake-up connection at loopback on the bound port.
            let mut wake = self.addr;
            if wake.ip().is_unspecified() {
                wake.set_ip(match wake.ip() {
                    std::net::IpAddr::V4(_) => {
                        std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                    }
                    std::net::IpAddr::V6(_) => {
                        std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                    }
                });
            }
            let _ = TcpStream::connect(wake);
            let _ = h.join();
        }
        // All handlers joined: this Arc is the last one; drop drains+joins
        // the chip workers.
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// A message as a JSON string literal (quoted + escaped by the
/// `util::json` writer, so parser and writer can never diverge).
fn json_str(s: &str) -> String {
    Json::Str(s.to_string()).to_string()
}

fn err_json(msg: &str) -> String {
    format!("{{\"ok\":false,\"error\":{}}}", json_str(msg))
}

/// One inference as the inner JSON object of a reply.
fn inference_json(inf: &Inference) -> String {
    format!(
        "{{\"pred\":{},\"scores\":[{},{}],\"time_us\":{:.1},\
         \"energy_mj\":{:.4}}}",
        inf.pred,
        inf.scores[0],
        inf.scores[1],
        inf.sim_time_s * 1e6,
        inf.energy.total_j() * 1e3
    )
}

/// One pending reply in a connection's ordered-reply FIFO.  `Now` is
/// resolved text; the other variants hold the receiver their chip worker
/// will answer on — the writer resolves them in FIFO order, so replies
/// leave in request order while the requests themselves run concurrently.
enum Pending {
    Now(String),
    /// Write, then close the connection (the `shutdown` good-bye).
    Bye(String),
    Classify {
        chip: ChipId,
        resp: mpsc::Receiver<crate::fleet::ChipReply>,
    },
    Batch {
        chip: ChipId,
        batch: usize,
        accepted: usize,
        rejected: usize,
        retry_after_us: u64,
        resp: mpsc::Receiver<crate::fleet::ChipReply>,
    },
    Calib {
        chip: usize,
        resp: mpsc::Receiver<crate::fleet::CalibReply>,
    },
    StreamResult {
        window: u64,
        start_sample: u64,
        resp: mpsc::Receiver<crate::fleet::ChipReply>,
    },
}

/// Per-connection streaming session (`stream_open` .. `stream_close`).
struct StreamSession {
    windower: IncrementalWindower,
    dispatched: u64,
    shed: u64,
    samples: u64,
}

impl Pending {
    /// Resolve to reply text, blocking until the chip answers.  The
    /// bool is the close-after-write flag (`Bye`).  Used by the
    /// threaded writer; dropped receivers are harmless — chip workers
    /// ignore closed reply channels.
    fn resolve_blocking(self) -> (String, bool) {
        match self {
            Pending::Now(s) => (s, false),
            Pending::Bye(s) => (s, true),
            Pending::Classify { chip, resp } => {
                (resolve_classify(chip, resp.recv()), false)
            }
            Pending::Batch {
                chip,
                batch,
                accepted,
                rejected,
                retry_after_us,
                resp,
            } => (
                resolve_batch(
                    chip,
                    batch,
                    accepted,
                    rejected,
                    retry_after_us,
                    resp.recv(),
                ),
                false,
            ),
            Pending::Calib { chip, resp } => {
                (resolve_calib(chip, resp.recv()), false)
            }
            Pending::StreamResult { window, start_sample, resp } => {
                (resolve_stream(window, start_sample, resp.recv()), false)
            }
        }
    }

    /// Non-blocking resolution for the readiness loop: `Ok` when the
    /// reply text is available *now*, `Err(self)` to try again after
    /// the next chip-completion wake-up.
    #[cfg(unix)]
    fn try_resolve(self) -> Result<(String, bool), Pending> {
        // A disconnected channel resolves (to the worker-gone error);
        // only Empty defers.
        fn step<T>(
            resp: &mpsc::Receiver<T>,
        ) -> Option<Result<T, mpsc::RecvError>> {
            match resp.try_recv() {
                Ok(v) => Some(Ok(v)),
                Err(mpsc::TryRecvError::Disconnected) => {
                    Some(Err(mpsc::RecvError))
                }
                Err(mpsc::TryRecvError::Empty) => None,
            }
        }
        match self {
            Pending::Now(s) => Ok((s, false)),
            Pending::Bye(s) => Ok((s, true)),
            Pending::Classify { chip, resp } => match step(&resp) {
                Some(r) => Ok((resolve_classify(chip, r), false)),
                None => Err(Pending::Classify { chip, resp }),
            },
            Pending::Batch {
                chip,
                batch,
                accepted,
                rejected,
                retry_after_us,
                resp,
            } => match step(&resp) {
                Some(r) => Ok((
                    resolve_batch(
                        chip,
                        batch,
                        accepted,
                        rejected,
                        retry_after_us,
                        r,
                    ),
                    false,
                )),
                None => Err(Pending::Batch {
                    chip,
                    batch,
                    accepted,
                    rejected,
                    retry_after_us,
                    resp,
                }),
            },
            Pending::Calib { chip, resp } => match step(&resp) {
                Some(r) => Ok((resolve_calib(chip, r), false)),
                None => Err(Pending::Calib { chip, resp }),
            },
            Pending::StreamResult { window, start_sample, resp } => {
                match step(&resp) {
                    Some(r) => Ok((
                        resolve_stream(window, start_sample, r),
                        false,
                    )),
                    None => Err(Pending::StreamResult {
                        window,
                        start_sample,
                        resp,
                    }),
                }
            }
        }
    }
}

fn resolve_classify(
    chip: ChipId,
    recv: Result<crate::fleet::ChipReply, mpsc::RecvError>,
) -> String {
    match recv {
        Err(mpsc::RecvError) => {
            format!("{{\"ok\":false,\"error\":\"chip {chip} worker gone\"}}")
        }
        Ok(reply) => match reply.result {
            Ok(infs) => match infs.first() {
                Some(inf) => {
                    // Same field formatting as the batch reply (one
                    // source of truth: `inference_json`), plus chip.
                    let fields = inference_json(inf);
                    format!(
                        "{{\"ok\":true,{},\"chip\":{}}}",
                        // lint:allow(panic-index: inference_json is brace-wrapped, len >= 2)
                        &fields[1..fields.len() - 1],
                        reply.chip
                    )
                }
                None => format!(
                    "{{\"ok\":false,\"error\":\"chip {} empty reply\"}}",
                    reply.chip
                ),
            },
            Err(e) => err_json(&e),
        },
    }
}

fn resolve_batch(
    chip: ChipId,
    batch: usize,
    accepted: usize,
    rejected: usize,
    retry_after_us: u64,
    recv: Result<crate::fleet::ChipReply, mpsc::RecvError>,
) -> String {
    // Terminal failures still echo `batch`/`accepted`: a pipelining
    // client correlates ordered replies to requests by these fields, and
    // a failover-exhausted error must not break that correlation.
    match recv {
        Err(mpsc::RecvError) => {
            format!(
                "{{\"ok\":false,\"error\":\"chip {chip} worker gone\",\
                 \"batch\":{batch},\"accepted\":{accepted}}}"
            )
        }
        Ok(reply) => match reply.result {
            Ok(infs) => {
                let sum_us: f64 =
                    infs.iter().map(|i| i.sim_time_s).sum::<f64>() * 1e6;
                let per_us = sum_us / infs.len().max(1) as f64;
                let mut s = format!(
                    "{{\"ok\":true,\"chip\":{},\"batch\":{batch},\
                     \"accepted\":{accepted},\"shed\":{rejected},",
                    reply.chip
                );
                if rejected > 0 {
                    s.push_str(&format!(
                        "\"retry_after_us\":{retry_after_us},"
                    ));
                }
                s.push_str(&format!(
                    "\"time_us_per_sample\":{per_us:.1},\"results\":["
                ));
                for (i, inf) in infs.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    s.push_str(&inference_json(inf));
                }
                s.push_str("]}");
                s
            }
            Err(e) => format!(
                "{{\"ok\":false,\"error\":{},\"batch\":{batch},\
                 \"accepted\":{accepted}}}",
                json_str(&e)
            ),
        },
    }
}

fn resolve_calib(
    chip: usize,
    recv: Result<crate::fleet::CalibReply, mpsc::RecvError>,
) -> String {
    match recv {
        Err(mpsc::RecvError) => {
            format!("{{\"ok\":false,\"error\":\"chip {chip} worker gone\"}}")
        }
        Ok(reply) => match reply.result {
            Ok((stamp, residual)) => format!(
                "{{\"ok\":true,\"chip\":{chip},\"chip_time_us\":{stamp},\
                 \"residual_rms\":{residual:.4},\"reason\":\"{}\"}}",
                reply.reason.as_str()
            ),
            Err(e) => err_json(&e),
        },
    }
}

fn resolve_stream(
    window: u64,
    start_sample: u64,
    recv: Result<crate::fleet::ChipReply, mpsc::RecvError>,
) -> String {
    match recv {
        Err(mpsc::RecvError) => format!(
            "{{\"ok\":false,\"stream\":true,\"window\":{window},\
             \"start_sample\":{start_sample},\
             \"error\":\"chip worker gone\"}}"
        ),
        Ok(reply) => match reply.result {
            Ok(infs) => match infs.first() {
                Some(inf) => {
                    let fields = inference_json(inf);
                    format!(
                        "{{\"ok\":true,\"stream\":true,\"window\":{window},\
                         \"start_sample\":{start_sample},{},\"chip\":{}}}",
                        // lint:allow(panic-index: inference_json is brace-wrapped, len >= 2)
                        &fields[1..fields.len() - 1],
                        reply.chip
                    )
                }
                None => format!(
                    "{{\"ok\":false,\"stream\":true,\"window\":{window},\
                     \"start_sample\":{start_sample},\
                     \"error\":\"chip {} empty reply\"}}",
                    reply.chip
                ),
            },
            Err(e) => format!(
                "{{\"ok\":false,\"stream\":true,\"window\":{window},\
                 \"start_sample\":{start_sample},\"error\":{}}}",
                json_str(&e)
            ),
        },
    }
}

/// Dispatch one parsed request (both transports decode to the same
/// [`Json`] value — see [`conn`]).  Returns the pending replies to
/// enqueue (in order) and whether the connection should close after
/// they are written.  `notify` is the readiness loop's chip-completion
/// hook, cloned into every fleet dispatch; the threaded model blocks in
/// `resolve_blocking` instead and passes `None`.
fn handle_request(
    req: &Json,
    fleet: &Fleet,
    allow_remote_shutdown: bool,
    session: &mut Option<StreamSession>,
    notify: Option<&ReplyNotify>,
) -> (Vec<Pending>, bool) {
    let one = |s: String| (vec![Pending::Now(s)], false);
    match req.get("cmd").and_then(|c| c.as_str()) {
        Some("ping") => one("{\"ok\":true,\"pong\":true}".to_string()),
        Some("shutdown") => {
            if !allow_remote_shutdown {
                return one(err_json(
                    "remote shutdown disabled; start the service with \
                     --allow-remote-shutdown",
                ));
            }
            // The *writer* raises the shutdown signal, after it flushed
            // every pipelined reply ahead of the good-bye — signalling
            // here would let stop() close this very socket before the
            // client got its replies.
            (
                vec![Pending::Bye("{\"ok\":true,\"bye\":true}".to_string())],
                true,
            )
        }
        Some("stats") => {
            let t = fleet.telemetry().snapshot();
            one(format!(
                "{{\"ok\":true,\"served\":{},\"mean_time_us\":{:.3},\
                 \"chips\":{},\"shed\":{}}}",
                t.served,
                t.mean_sim_time_us,
                fleet.size(),
                fleet.shed_count()
            ))
        }
        Some("fleet_stats") => one(fleet.stats_json()),
        Some("metrics") => {
            // One snapshot feeds both formats (obs::expo), so JSON and
            // Prometheus text can never disagree about what exists.
            let samples = fleet.metrics_samples();
            let fmt = match req.get("format") {
                None => Some("json"),
                Some(f) => {
                    f.as_str().filter(|f| *f == "json" || *f == "text")
                }
            };
            match fmt {
                Some("text") => one(format!(
                    "{{\"ok\":true,\"format\":\"text\",\"body\":{}}}",
                    json_str(&expo::prometheus(&samples))
                )),
                Some(_) => one(format!(
                    "{{\"ok\":true,\"metrics\":{}}}",
                    expo::json_array(&samples)
                )),
                None => one(err_json(
                    "metrics format must be \"json\" or \"text\"",
                )),
            }
        }
        Some("trace") => {
            let cap = crate::obs::trace::TRACE_RING_CAP;
            let n = match req.get("n") {
                None => Some(16),
                Some(v) => v.as_uint().map(|n| n as usize),
            }
            .filter(|n| (1..=cap).contains(n));
            let Some(n) = n else {
                return one(err_json(&format!(
                    "n must be an integer in 1..={cap}"
                )));
            };
            let tracer = &fleet.obs().tracer;
            let mut s = format!(
                "{{\"ok\":true,\"seen\":{},\"recorded\":{},\"traces\":[",
                tracer.seen(),
                tracer.recorded()
            );
            for (i, t) in tracer.recent(n).iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                s.push_str(&trace_json(t));
            }
            s.push_str("]}");
            one(s)
        }
        Some("journal") => {
            let since = match req.get("since") {
                None => Some(0),
                Some(v) => v.as_uint(),
            };
            let Some(since) = since else {
                return one(err_json(
                    "since must be a non-negative integer",
                ));
            };
            let journal = &fleet.obs().journal;
            // Cursor *before* the scan: an event logged concurrently may
            // then show up both in this reply and after a resume from
            // `next_seq` — at-least-once, never silently skipped.
            let next_seq = journal.next_seq();
            let events = journal.since(since);
            let mut s = format!(
                "{{\"ok\":true,\"next_seq\":{next_seq},\"events\":["
            );
            for (i, e) in events.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                s.push_str(&format!(
                    "{{\"seq\":{},\"kind\":\"{}\",",
                    e.seq,
                    e.kind.as_str()
                ));
                if let Some(chip) = e.chip {
                    s.push_str(&format!("\"chip\":{chip},"));
                }
                s.push_str(&format!(
                    "\"detail\":{}}}",
                    json_str(&e.detail)
                ));
            }
            s.push_str("]}");
            one(s)
        }
        Some("recalibrate") => {
            // Malformed fields are rejected, never defaulted: a bad
            // `chip` would drain a replica the client never named, a bad
            // `reps` would silently run a measurement length they never
            // asked for.
            let chip = req
                .get("chip")
                .and_then(|c| c.as_uint())
                .map(|c| c as usize);
            let reps = match req.get("reps") {
                None => Some(32),
                Some(r) => r.as_uint().map(|r| r as usize),
            }
            .filter(|r| (1..=MAX_RECALIB_REPS).contains(r));
            match (chip, reps) {
                (None, _) => one(
                    "{\"ok\":false,\"error\":\"recalibrate requires a \
                     non-negative integer `chip` field\"}"
                        .to_string(),
                ),
                (_, None) => one(format!(
                    "{{\"ok\":false,\"error\":\"reps must be an integer \
                     in 1..={MAX_RECALIB_REPS}\"}}"
                )),
                (Some(chip), Some(reps)) => {
                    let started = match notify {
                        Some(n) => fleet
                            .recalibrate_chip_notify(chip, reps, n.clone()),
                        None => fleet.recalibrate_chip(chip, reps),
                    };
                    match started {
                        Err(e) => one(err_json(&e.to_string())),
                        Ok(rx) => {
                            (vec![Pending::Calib { chip, resp: rx }], false)
                        }
                    }
                }
            }
        }
        Some("classify") => match parse_trace(req) {
            Err(e) => one(err_json(&e.to_string())),
            Ok(trace) => {
                let outcome = match notify {
                    Some(n) => fleet.dispatch_notify(trace, n.clone()),
                    None => fleet.dispatch(trace),
                };
                match outcome {
                    DispatchOutcome::Shed { reason, retry_after_us } => {
                        // Backoff hints: how much work was already in
                        // flight (samples), and a retry horizon.
                        one(format!(
                            "{{\"ok\":false,\"shed\":true,\"error\":\"{}\",\
                             \"queue_depth\":{},\
                             \"retry_after_us\":{retry_after_us}}}",
                            reason.as_str(),
                            fleet.inflight_samples()
                        ))
                    }
                    DispatchOutcome::Enqueued { chip, resp } => {
                        (vec![Pending::Classify { chip, resp }], false)
                    }
                }
            }
        },
        Some("classify_batch") => match parse_trace_batch(req) {
            Err(e) => one(err_json(&e.to_string())),
            Ok(traces) => {
                let batch = traces.len();
                let outcome = match notify {
                    Some(n) => fleet.dispatch_batch_notify(traces, n.clone()),
                    None => fleet.dispatch_batch(traces),
                };
                match outcome {
                    BatchDispatchOutcome::Shed { reason, retry_after_us } => {
                        one(format!(
                            "{{\"ok\":false,\"shed\":true,\"error\":\"{}\",\
                             \"accepted\":0,\"batch\":{batch},\
                             \"queue_depth\":{},\
                             \"retry_after_us\":{retry_after_us}}}",
                            reason.as_str(),
                            fleet.inflight_samples()
                        ))
                    }
                    BatchDispatchOutcome::Enqueued {
                        chip,
                        accepted,
                        rejected,
                        resp,
                        retry_after_us,
                    } => (
                        vec![Pending::Batch {
                            chip,
                            batch,
                            accepted,
                            rejected,
                            retry_after_us,
                            resp,
                        }],
                        false,
                    ),
                }
            }
        },
        Some("stream_open") => {
            if session.is_some() {
                return one(err_json("stream already open on this connection"));
            }
            let hop = match req.get("hop") {
                None => Ok(c::ECG_WINDOW),
                Some(h) => h.as_uint().map(|h| h as usize).ok_or_else(|| {
                    anyhow::anyhow!("hop must be a non-negative integer")
                }),
            };
            match hop.and_then(IncrementalWindower::new) {
                Err(e) => one(err_json(&e.to_string())),
                Ok(windower) => {
                    let hop = windower.hop();
                    *session = Some(StreamSession {
                        windower,
                        dispatched: 0,
                        shed: 0,
                        samples: 0,
                    });
                    one(format!(
                        "{{\"ok\":true,\"stream\":\"open\",\"hop\":{hop},\
                         \"window\":{},\"pool_window\":{}}}",
                        c::ECG_WINDOW,
                        c::POOL_WINDOW
                    ))
                }
            }
        }
        Some("stream_push") => {
            // Session-level errors are framed with "stream":true so a
            // client draining the asynchronous result stream can tell a
            // rejected push from a window result (which always carries a
            // "window" field).
            let stream_err = |msg: &str| {
                (
                    vec![Pending::Now(format!(
                        "{{\"ok\":false,\"stream\":true,\"error\":{}}}",
                        json_str(msg)
                    ))],
                    false,
                )
            };
            let Some(sess) = session.as_mut() else {
                return stream_err(
                    "no open stream on this connection (send stream_open \
                     first)",
                );
            };
            let chunk = match parse_stream_chunk(req) {
                Err(e) => return stream_err(&e.to_string()),
                Ok(chunk) => chunk,
            };
            sess.samples += chunk[0].len() as u64;
            let frames = match sess.windower.push_chunk(&chunk) {
                Err(e) => return stream_err(&e.to_string()),
                Ok(frames) => frames,
            };
            let mut out = Vec::with_capacity(frames.len());
            for f in frames {
                let acts: Vec<i32> =
                    f.acts.iter().map(|&a| a as i32).collect();
                let outcome = match notify {
                    Some(n) => fleet.dispatch_acts_notify(acts, n.clone()),
                    None => fleet.dispatch_acts(acts),
                };
                match outcome {
                    DispatchOutcome::Enqueued { chip: _, resp } => {
                        sess.dispatched += 1;
                        out.push(Pending::StreamResult {
                            window: f.index,
                            start_sample: f.start_sample,
                            resp,
                        });
                    }
                    DispatchOutcome::Shed { reason, retry_after_us } => {
                        sess.shed += 1;
                        out.push(Pending::Now(format!(
                            "{{\"ok\":false,\"stream\":true,\"shed\":true,\
                             \"window\":{},\"start_sample\":{},\
                             \"error\":\"{}\",\"queue_depth\":{},\
                             \"retry_after_us\":{retry_after_us}}}",
                            f.index,
                            f.start_sample,
                            reason.as_str(),
                            fleet.inflight_samples()
                        )));
                    }
                }
            }
            (out, false)
        }
        Some("stream_close") => match session.take() {
            None => one(err_json("no open stream on this connection")),
            Some(sess) => one(format!(
                "{{\"ok\":true,\"stream\":\"closed\",\"windows\":{},\
                 \"dispatched\":{},\"shed\":{},\"samples\":{}}}",
                sess.windower.windows(),
                sess.dispatched,
                sess.shed,
                sess.samples
            )),
        },
        _ => one("{\"ok\":false,\"error\":\"unknown cmd\"}".to_string()),
    }
}

/// One full trace record as a wire JSON object: both stage splits carry
/// an explicit `total` so clients need not re-derive the sum.
fn trace_json(t: &TraceRecord) -> String {
    let mut s = format!(
        "{{\"id\":{},\"chip\":{},\"kind\":\"{}\",\"batch\":{},\
         \"redirects\":{},\"host_us\":{{\"total\":{:.3}",
        t.id,
        t.chip,
        t.kind,
        t.batch,
        t.redirects,
        t.host.total_ns() as f64 / 1e3
    );
    for (name, ns) in t.host.named() {
        s.push_str(&format!(",\"{name}\":{:.3}", ns as f64 / 1e3));
    }
    s.push_str(&format!("}},\"sim_us\":{{\"total\":{:.3}", t.sim.total_us()));
    for (name, us) in t.sim.named() {
        s.push_str(&format!(",\"{name}\":{us:.3}"));
    }
    s.push_str("}}");
    s
}

fn parse_trace(req: &Json) -> anyhow::Result<Trace> {
    parse_trace_value(req.req("trace")?)
}

fn parse_trace_batch(req: &Json) -> anyhow::Result<Vec<Trace>> {
    let items = req
        .req("traces")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("traces must be an array"))?;
    anyhow::ensure!(!items.is_empty(), "empty batch");
    anyhow::ensure!(
        items.len() <= MAX_WIRE_BATCH,
        "batch of {} exceeds the wire limit of {MAX_WIRE_BATCH}",
        items.len()
    );
    items.iter().map(parse_trace_value).collect()
}

/// One 12-bit sample.  Strict: non-integer values are rejected, not
/// silently truncated (`12.7` used to become `12` via `as u16`) — same
/// convention as every other numeric wire field (`Json::as_uint`).
fn parse_sample(v: &Json) -> anyhow::Result<u16> {
    let x = v.as_uint().ok_or_else(|| {
        anyhow::anyhow!("samples must be non-negative integers")
    })?;
    anyhow::ensure!(x < 4096, "sample {x} out of 12-bit range");
    Ok(x as u16)
}

fn parse_trace_value(v: &Json) -> anyhow::Result<Trace> {
    let chans = v
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("trace must be an array"))?;
    anyhow::ensure!(chans.len() == c::ECG_CHANNELS, "need 2 channels");
    let mut samples = Vec::with_capacity(c::ECG_CHANNELS);
    for ch in chans {
        let vals = ch
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("channel must be an array"))?;
        anyhow::ensure!(
            vals.len() == c::ECG_WINDOW,
            "channel needs {} samples, got {}",
            c::ECG_WINDOW,
            vals.len()
        );
        let chan =
            vals.iter().map(parse_sample).collect::<anyhow::Result<_>>()?;
        samples.push(chan);
    }
    Ok(Trace { samples, label: 0 })
}

/// Parse a `stream_push` chunk: two equal-length channels of 12-bit
/// integer samples, 1..=[`MAX_STREAM_CHUNK`] samples each.
fn parse_stream_chunk(req: &Json) -> anyhow::Result<Vec<Vec<u16>>> {
    let chans = req
        .req("samples")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("samples must be an array"))?;
    anyhow::ensure!(chans.len() == c::ECG_CHANNELS, "need 2 channels");
    let mut chunk = Vec::with_capacity(c::ECG_CHANNELS);
    for ch in chans {
        let vals = ch
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("channel must be an array"))?;
        anyhow::ensure!(!vals.is_empty(), "empty chunk");
        anyhow::ensure!(
            vals.len() <= MAX_STREAM_CHUNK,
            "chunk of {} exceeds {MAX_STREAM_CHUNK} samples per push",
            vals.len()
        );
        let chan: Vec<u16> =
            vals.iter().map(parse_sample).collect::<anyhow::Result<_>>()?;
        chunk.push(chan);
    }
    anyhow::ensure!(
        chunk[0].len() == chunk[1].len(),
        "channel lengths differ: {} vs {}",
        chunk[0].len(),
        chunk[1].len()
    );
    Ok(chunk)
}

/// Client helper (used by tests + the remote_client example).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// A second handle on the same connection, for split read/write use
    /// (e.g. one thread pushing stream chunks while another collects the
    /// asynchronous result lines).  Each handle has its own buffered
    /// reader: only ever *read* from one of them, or buffered bytes are
    /// lost to the other.
    pub fn try_clone(&self) -> anyhow::Result<Client> {
        Ok(Client {
            stream: self.stream.try_clone()?,
            reader: BufReader::new(self.stream.try_clone()?),
        })
    }

    /// Write one request line without reading a reply — the pipelining /
    /// streaming half of the protocol.  Pair with [`read_reply`].
    ///
    /// [`read_reply`]: Client::read_reply
    pub fn send(&mut self, req: &str) -> anyhow::Result<()> {
        self.stream.write_all(req.as_bytes())?;
        self.stream.write_all(b"\n")?;
        Ok(())
    }

    /// Read one reply line (blocking).
    pub fn read_reply(&mut self) -> anyhow::Result<Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "connection closed");
        Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }

    /// Request/response convenience: send one line, read one line.
    pub fn call(&mut self, req: &str) -> anyhow::Result<Json> {
        self.send(req)?;
        self.read_reply()
    }

    pub fn classify(&mut self, trace: &Trace) -> anyhow::Result<Json> {
        self.send_classify(trace)?;
        self.read_reply()
    }

    /// Write a `classify` request without waiting — lets callers pipeline
    /// several requests on one connection before collecting the ordered
    /// replies.
    pub fn send_classify(&mut self, trace: &Trace) -> anyhow::Result<()> {
        let mut req = String::from("{\"cmd\":\"classify\",\"trace\":");
        push_trace_json(trace, &mut req);
        req.push('}');
        self.send(&req)
    }

    /// Submit a whole batch as one `classify_batch` request (amortised
    /// weight reconfiguration server-side).  The reply may report partial
    /// acceptance: `accepted` < batch with the shed suffix to retry.
    pub fn classify_batch(&mut self, traces: &[Trace]) -> anyhow::Result<Json> {
        self.send_classify_batch(traces)?;
        self.read_reply()
    }

    /// Write a `classify_batch` request without waiting for the reply.
    pub fn send_classify_batch(
        &mut self,
        traces: &[Trace],
    ) -> anyhow::Result<()> {
        let mut req = String::from("{\"cmd\":\"classify_batch\",\"traces\":[");
        for (i, trace) in traces.iter().enumerate() {
            if i > 0 {
                req.push(',');
            }
            push_trace_json(trace, &mut req);
        }
        req.push_str("]}");
        self.send(&req)
    }

    /// Open a streaming session at `hop` samples per window step.
    pub fn stream_open(&mut self, hop: usize) -> anyhow::Result<Json> {
        self.call(&format!("{{\"cmd\":\"stream_open\",\"hop\":{hop}}}"))
    }

    /// Push one chunk (`chunk[ch]`, equal lengths) into the open stream.
    /// No reply is read: window results arrive asynchronously — collect
    /// them with [`read_reply`](Client::read_reply).
    pub fn stream_push(&mut self, chunk: &[Vec<u16>]) -> anyhow::Result<()> {
        let mut req = String::from("{\"cmd\":\"stream_push\",\"samples\":[");
        for (i, ch) in chunk.iter().enumerate() {
            if i > 0 {
                req.push(',');
            }
            req.push('[');
            for (j, &s) in ch.iter().enumerate() {
                if j > 0 {
                    req.push(',');
                }
                req.push_str(&s.to_string());
            }
            req.push(']');
        }
        req.push_str("]}");
        self.send(&req)
    }

    /// Send `stream_close`.  The close acknowledgement arrives *after*
    /// every pending window result (ordered-reply FIFO): keep calling
    /// [`read_reply`](Client::read_reply) until the line carries
    /// `"stream":"closed"`.
    pub fn stream_close(&mut self) -> anyhow::Result<()> {
        self.send("{\"cmd\":\"stream_close\"}")
    }
}

/// Append one trace as the nested-array wire format.
fn push_trace_json(trace: &Trace, req: &mut String) {
    req.push('[');
    for (i, ch) in trace.samples.iter().enumerate() {
        if i > 0 {
            req.push(',');
        }
        req.push('[');
        for (j, &s) in ch.iter().enumerate() {
            if j > 0 {
                req.push(',');
            }
            req.push_str(&s.to_string());
        }
        req.push(']');
    }
    req.push(']');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineConfig;

    fn test_engine() -> Engine {
        let wc = vec![1.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL];
        let w1 = vec![1.0; c::K_LOGICAL * c::FC1_OUT];
        let w2 = vec![1.0; c::FC1_OUT * c::FC2_OUT];
        let model = crate::nn::weights::TrainedModel {
            pass_weights: [
                crate::nn::mapping::pack_conv(&wc),
                crate::nn::mapping::pack_fc1(&w1),
                crate::nn::mapping::pack_fc2(&w2),
            ],
            scales: [0.02, 0.02, 0.02],
            gain: [vec![1.0; c::N_COLS], vec![1.0; c::N_COLS]],
            offset: [vec![0.0; c::N_COLS], vec![0.0; c::N_COLS]],
            noise_sigma: 0.0,
            train_metrics: Default::default(),
        };
        Engine::native(
            model,
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        )
    }

    #[test]
    fn ping_and_classify_roundtrip() {
        let svc = Service::start("127.0.0.1:0", || Ok(test_engine())).unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let pong = cl.call("{\"cmd\":\"ping\"}").unwrap();
        assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

        let trace = crate::ecg::gen::generate_trace(1, true, 1.0);
        let reply = cl.classify(&trace).unwrap();
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
        let pred = reply.get("pred").and_then(|p| p.as_f64()).unwrap();
        assert!(pred == 0.0 || pred == 1.0);
        assert!(reply.get("time_us").and_then(|t| t.as_f64()).unwrap() > 100.0);
        // Single-chip fleet: everything lands on chip 0.
        assert_eq!(reply.get("chip").and_then(|v| v.as_usize()), Some(0));

        let stats = cl.call("{\"cmd\":\"stats\"}").unwrap();
        assert_eq!(stats.get("served").and_then(|s| s.as_f64()), Some(1.0));
        assert_eq!(stats.get("chips").and_then(|s| s.as_usize()), Some(1));
        assert!(stats.get("mean_time_us").and_then(|s| s.as_f64()).unwrap() > 100.0);
        svc.stop();
    }

    #[test]
    fn malformed_requests_rejected() {
        let svc = Service::start("127.0.0.1:0", || Ok(test_engine())).unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let r = cl.call("not json at all").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = cl.call("{\"cmd\":\"classify\",\"trace\":[[1,2],[3]]}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = cl.call("{\"cmd\":\"classify_batch\",\"traces\":[]}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
        let r = cl.call("{\"cmd\":\"classify_batch\",\"traces\":3}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = cl.call("{\"cmd\":\"nope\"}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        svc.stop();
    }

    #[test]
    fn non_integer_samples_rejected() {
        // Satellite fix: `12.7` used to be silently truncated to 12 (and
        // `0.5` to 0) via `as u16`; now any non-integer sample rejects
        // the request, matching the strict `as_uint` wire convention.
        let svc = Service::start("127.0.0.1:0", || Ok(test_engine())).unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        // A classify request whose very first sample is `first`, the rest
        // a constant mid-scale 2048.
        let req_with = |first: &str| {
            let mut req =
                format!("{{\"cmd\":\"classify\",\"trace\":[[{first}");
            for _ in 1..c::ECG_WINDOW {
                req.push_str(",2048");
            }
            req.push_str("],[2048");
            for _ in 1..c::ECG_WINDOW {
                req.push_str(",2048");
            }
            req.push_str("]]}");
            req
        };
        // Sanity: the all-integer request passes ...
        let r = cl.call(&req_with("2048")).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        // ... while fractional samples are refused, not truncated.
        for v in ["12.7", "0.5"] {
            let r = cl.call(&req_with(v)).unwrap();
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{v}: {r}");
            assert!(
                r.get("error")
                    .and_then(|e| e.as_str())
                    .unwrap()
                    .contains("integer"),
                "{v}: {r}"
            );
        }
        // Negative and out-of-12-bit-range values are refused too.
        for v in ["-3", "4096", "\"2048\""] {
            let r = cl.call(&req_with(v)).unwrap();
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{v}: {r}");
        }
        svc.stop();
    }

    #[test]
    fn classify_batch_roundtrip_matches_single() {
        let svc = Service::start("127.0.0.1:0", || Ok(test_engine())).unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let traces: Vec<_> = (0..4)
            .map(|i| {
                crate::ecg::gen::generate_trace(90 + i as u64, i % 2 == 0, 1.0)
            })
            .collect();
        // Noise is off: sequential predictions are the parity reference.
        let mut want = Vec::new();
        for t in &traces {
            let r = cl.classify(t).unwrap();
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
            want.push(r.get("pred").and_then(|p| p.as_f64()).unwrap());
        }
        let reply = cl.classify_batch(&traces).unwrap();
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
        assert_eq!(reply.get("batch").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(reply.get("accepted").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(reply.get("shed").and_then(|v| v.as_usize()), Some(0));
        let results = reply.get("results").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(results.len(), 4);
        for (r, w) in results.iter().zip(&want) {
            assert_eq!(r.get("pred").and_then(|p| p.as_f64()), Some(*w));
        }
        // Amortisation is visible on the wire: per-sample time well under
        // the paper's 276 µs single-trace figure.
        let per = reply
            .get("time_us_per_sample")
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(per < 200.0, "amortised per-sample time {per} µs");
        svc.stop();
    }

    #[test]
    fn classify_batch_partial_acceptance() {
        let svc = Service::start_fleet(
            "127.0.0.1:0",
            FleetConfig { chips: 1, queue_depth: 3, ..Default::default() },
            |chip| {
                Ok(Engine::native(
                    crate::nn::weights::TrainedModel::synthetic(7),
                    EngineConfig {
                        use_pjrt: false,
                        noise_off: true,
                        ..Default::default()
                    }
                    .for_chip(chip),
                ))
            },
        )
        .unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let traces: Vec<_> = (0..5)
            .map(|i| {
                crate::ecg::gen::generate_trace(70 + i as u64, i % 2 == 1, 1.0)
            })
            .collect();
        let reply = cl.classify_batch(&traces).unwrap();
        assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
        assert_eq!(reply.get("batch").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(reply.get("accepted").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(reply.get("shed").and_then(|v| v.as_usize()), Some(2));
        assert!(
            reply
                .get("retry_after_us")
                .and_then(|v| v.as_f64())
                .unwrap()
                > 0.0,
            "partial acceptance must carry a retry hint: {reply}"
        );
        assert_eq!(
            reply.get("results").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(3)
        );
        // The shed suffix is retriable once the queue drained (the reply
        // above only arrives after the admitted prefix completed).
        let retry = cl.classify_batch(&traces[3..]).unwrap();
        assert_eq!(retry.get("ok"), Some(&Json::Bool(true)), "{retry}");
        assert_eq!(retry.get("accepted").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(retry.get("shed").and_then(|v| v.as_usize()), Some(0));
        svc.stop();
    }

    #[test]
    fn recalibrate_command_roundtrip() {
        let svc = Service::start_fleet(
            "127.0.0.1:0",
            FleetConfig { chips: 2, queue_depth: 8, ..Default::default() },
            |chip| {
                Ok(Engine::native(
                    crate::nn::weights::TrainedModel::synthetic(11),
                    EngineConfig {
                        use_pjrt: false,
                        noise_off: true,
                        fpn_seed: Some(0xCA11B),
                        ..Default::default()
                    }
                    .for_chip(chip),
                ))
            },
        )
        .unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let r = cl.call("{\"cmd\":\"recalibrate\",\"chip\":1,\"reps\":8}").unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("chip").and_then(|v| v.as_usize()), Some(1));
        assert!(r.get("residual_rms").and_then(|v| v.as_f64()).is_some());
        assert!(
            r.get("chip_time_us").and_then(|v| v.as_f64()).unwrap() > 0.0,
            "measurement consumed chip time: {r}"
        );
        // fleet_stats reports the completed recalibration per chip.
        let fs = cl.call("{\"cmd\":\"fleet_stats\"}").unwrap();
        assert_eq!(
            fs.get("recalibrations").and_then(|v| v.as_usize()),
            Some(1),
            "{fs}"
        );
        let per = fs.get("per_chip").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(
            per[1].get("recalibrations").and_then(|v| v.as_usize()),
            Some(1)
        );
        // Out-of-range chip errors cleanly.
        let bad = cl.call("{\"cmd\":\"recalibrate\",\"chip\":9}").unwrap();
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
        // Unbounded reps are rejected before touching the fleet.
        let bad = cl
            .call("{\"cmd\":\"recalibrate\",\"chip\":0,\"reps\":1000000000}")
            .unwrap();
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)), "{bad}");
        // A missing or malformed `chip` must never default to chip 0:
        // the request is rejected and no replica is drained.
        for req in [
            "{\"cmd\":\"recalibrate\"}",
            "{\"cmd\":\"recalibrate\",\"chip\":\"zero\"}",
            "{\"cmd\":\"recalibrate\",\"chip\":-1}",
            "{\"cmd\":\"recalibrate\",\"chip\":0.5}",
            "{\"cmd\":\"recalibrate\",\"chip\":0,\"reps\":\"many\"}",
            "{\"cmd\":\"recalibrate\",\"chip\":0,\"reps\":-4}",
        ] {
            let bad = cl.call(req).unwrap();
            assert_eq!(bad.get("ok"), Some(&Json::Bool(false)), "{req}");
        }
        let fs = cl.call("{\"cmd\":\"fleet_stats\"}").unwrap();
        assert_eq!(
            fs.get("recalibrations").and_then(|v| v.as_usize()),
            Some(1),
            "malformed requests must not have drained anything: {fs}"
        );
        svc.stop();
    }

    #[test]
    fn concurrent_clients_spread_over_fleet() {
        let svc = Service::start_fleet(
            "127.0.0.1:0",
            FleetConfig { chips: 2, queue_depth: 8, ..Default::default() },
            |chip| {
                Ok(Engine::native(
                    crate::nn::weights::TrainedModel::synthetic(3),
                    EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() }
                        .for_chip(chip),
                ))
            },
        )
        .unwrap();
        let addr = svc.addr;
        let mut handles = Vec::new();
        for i in 0..4u64 {
            handles.push(std::thread::spawn(move || {
                let mut cl = Client::connect(&addr).unwrap();
                let trace = crate::ecg::gen::generate_trace(10 + i, i % 2 == 1, 1.0);
                let reply = cl.classify(&trace).unwrap();
                assert_eq!(reply.get("ok"), Some(&Json::Bool(true)));
                reply.get("chip").and_then(|v| v.as_usize()).unwrap()
            }));
        }
        let chips: Vec<usize> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(svc.fleet.telemetry().served(), 4);
        // Round-robin tie-break: both chips must have served.
        assert!(chips.contains(&0) && chips.contains(&1), "{chips:?}");

        let mut cl = Client::connect(&addr).unwrap();
        let fs = cl.call("{\"cmd\":\"fleet_stats\"}").unwrap();
        assert_eq!(fs.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(fs.get("chips").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(
            fs.get("per_chip").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(2)
        );
        svc.stop();
    }

    #[test]
    fn metrics_trace_journal_over_the_wire() {
        let svc = Service::start_fleet(
            "127.0.0.1:0",
            FleetConfig {
                chips: 1,
                queue_depth: 8,
                trace_sample: 1,
                ..Default::default()
            },
            |chip| {
                Ok(Engine::native(
                    crate::nn::weights::TrainedModel::synthetic(5),
                    EngineConfig {
                        use_pjrt: false,
                        noise_off: true,
                        ..Default::default()
                    }
                    .for_chip(chip),
                ))
            },
        )
        .unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let trace = crate::ecg::gen::generate_trace(5, true, 1.0);
        let r = cl.classify(&trace).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");

        // JSON metrics: the unified snapshot carries the fleet counters.
        let m = cl.call("{\"cmd\":\"metrics\"}").unwrap();
        assert_eq!(m.get("ok"), Some(&Json::Bool(true)), "{m}");
        let arr = m.get("metrics").and_then(|v| v.as_arr()).unwrap();
        let served = arr
            .iter()
            .find(|s| {
                s.get("name").and_then(|n| n.as_str())
                    == Some("bss2_fleet_served_total")
            })
            .expect("served counter exposed");
        assert_eq!(served.get("value").and_then(|v| v.as_f64()), Some(1.0));

        // Prometheus text: same snapshot, scrape-ready.
        let t = cl.call("{\"cmd\":\"metrics\",\"format\":\"text\"}").unwrap();
        assert_eq!(t.get("ok"), Some(&Json::Bool(true)), "{t}");
        let body = t.get("body").and_then(|b| b.as_str()).unwrap();
        assert!(
            body.contains("# TYPE bss2_fleet_served_total counter"),
            "{body}"
        );
        assert!(body.contains("bss2_fleet_served_total 1"), "{body}");
        let bad = cl.call("{\"cmd\":\"metrics\",\"format\":\"xml\"}").unwrap();
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));

        // trace: sample_every = 1 kept the span; its stage splits sum to
        // the reported totals in both time bases (± wire rounding).
        let tr = cl.call("{\"cmd\":\"trace\"}").unwrap();
        assert_eq!(tr.get("ok"), Some(&Json::Bool(true)), "{tr}");
        assert_eq!(tr.get("seen").and_then(|v| v.as_usize()), Some(1));
        let traces = tr.get("traces").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(traces.len(), 1, "{tr}");
        let t0 = &traces[0];
        assert_eq!(t0.get("kind").and_then(|k| k.as_str()), Some("classify"));
        assert_eq!(t0.get("batch").and_then(|v| v.as_usize()), Some(1));
        let host = t0.get("host_us").unwrap();
        let hsum: f64 = ["queue", "execute", "retry"]
            .iter()
            .map(|k| host.get(k).and_then(|v| v.as_f64()).unwrap())
            .sum();
        let htotal = host.get("total").and_then(|v| v.as_f64()).unwrap();
        assert!((hsum - htotal).abs() < 0.01, "{hsum} vs {htotal}");
        let sim = t0.get("sim_us").unwrap();
        let stotal = sim.get("total").and_then(|v| v.as_f64()).unwrap();
        assert!(stotal > 100.0, "paper-scale chip time: {stotal}");
        let ssum: f64 = crate::obs::trace::SIM_STAGE_NAMES
            .iter()
            .map(|k| sim.get(k).and_then(|v| v.as_f64()).unwrap())
            .sum();
        assert!((ssum - stotal).abs() < 0.01, "{ssum} vs {stotal}");
        let bad = cl.call("{\"cmd\":\"trace\",\"n\":0}").unwrap();
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));

        // journal: a clean single-chip run has logged nothing.
        let j = cl.call("{\"cmd\":\"journal\"}").unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{j}");
        assert_eq!(j.get("next_seq").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(
            j.get("events").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(0)
        );
        let bad = cl.call("{\"cmd\":\"journal\",\"since\":-1}").unwrap();
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
        svc.stop();
    }

    #[test]
    fn connection_shed_lands_in_journal() {
        let svc = Service::start_fleet(
            "127.0.0.1:0",
            FleetConfig { chips: 1, max_connections: 1, ..Default::default() },
            |_| Ok(test_engine()),
        )
        .unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        cl.call("{\"cmd\":\"ping\"}").unwrap();
        // Second connection: refused at accept time with an explicit line.
        let mut shed = Client::connect(&svc.addr).unwrap();
        let refusal = shed.read_reply().unwrap();
        assert_eq!(refusal.get("ok"), Some(&Json::Bool(false)), "{refusal}");
        assert_eq!(refusal.get("shed"), Some(&Json::Bool(true)));
        // Backoff hints ride on every shed reply; at the connection
        // level `queue_depth` counts active connections.
        assert_eq!(
            refusal.get("max_connections").and_then(|v| v.as_usize()),
            Some(1)
        );
        assert_eq!(
            refusal.get("queue_depth").and_then(|v| v.as_usize()),
            Some(1),
            "{refusal}"
        );
        // The event was journalled before the refusal was written, so it
        // is already visible here.
        let j = cl.call("{\"cmd\":\"journal\"}").unwrap();
        let events = j.get("events").and_then(|v| v.as_arr()).unwrap();
        assert!(
            events.iter().any(|e| {
                e.get("kind").and_then(|k| k.as_str())
                    == Some("connection_shed")
            }),
            "{j}"
        );
        svc.stop();
    }

    #[test]
    fn json_str_escapes_via_writer() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(json_str("\u{1}"), "\"\\u0001\"");
    }

    /// The default model on unix is the readiness loop (which every
    /// other test in this module therefore exercises); the threaded
    /// model must keep serving identically behind `--conn-model`.
    #[test]
    fn threaded_model_serves_and_pipelines() {
        let svc = Service::start_fleet_with(
            "127.0.0.1:0",
            FleetConfig { chips: 1, queue_depth: 8, ..Default::default() },
            ServeModel::Threaded,
            |_| Ok(test_engine()),
        )
        .unwrap();
        let mut cl = Client::connect(&svc.addr).unwrap();
        let pong = cl.call("{\"cmd\":\"ping\"}").unwrap();
        assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));
        // Pipeline: several classifies written before any reply is read.
        let traces: Vec<_> = (0..3)
            .map(|i| crate::ecg::gen::generate_trace(40 + i, i % 2 == 0, 1.0))
            .collect();
        for t in &traces {
            cl.send_classify(t).unwrap();
        }
        for _ in &traces {
            let r = cl.read_reply().unwrap();
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        }
        assert_eq!(svc.fleet.telemetry().served(), 3);
        svc.stop();
    }
}
