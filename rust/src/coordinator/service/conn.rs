//! Protocol state machine shared by both connection models (DESIGN.md
//! §14): sniffs the transport on the first byte, then turns raw socket
//! bytes into complete requests.
//!
//! * First byte == [`handshake::MAGIC`]: a framed client.  The 8-byte
//!   hello negotiates version + encoding; afterwards every request is a
//!   length-prefixed frame carrying JSON text or a binary-encoded value.
//! * Anything else: the legacy line-oriented JSON protocol, byte-for-byte
//!   compatible with every pre-existing client.
//!
//! The state machine is transport-agnostic — the threaded reader and the
//! readiness loop both feed it whatever `read()` returned and act on the
//! drained events — and hostile-input safe: malformed payloads become
//! [`WireEvent::BadRequest`] (typed error reply, connection keeps going),
//! while protocol violations (oversized frame or line, unsupported
//! version) are [`Fatal`] — one final reply, then close.

use bss2_proto::handshake::{self, Encoding, HelloVerdict};
use bss2_proto::{bin, frame, MAX_LINE};

use crate::util::json::Json;

/// How replies are serialized back to this connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum ReplyFormat {
    /// Legacy: the reply text plus `\n`.
    Lines,
    /// A frame around the reply text.
    FramedJson,
    /// A frame around the binary encoding of the reply value.
    FramedBin,
}

impl ReplyFormat {
    pub(super) fn for_encoding(enc: Encoding) -> ReplyFormat {
        match enc {
            Encoding::Json => ReplyFormat::FramedJson,
            Encoding::Binary => ReplyFormat::FramedBin,
        }
    }

    /// Serialize one reply (the resolvers' JSON text) onto the wire.
    pub(super) fn serialize(self, text: &str, out: &mut Vec<u8>) {
        match self {
            ReplyFormat::Lines => {
                out.extend_from_slice(text.as_bytes());
                out.push(b'\n');
            }
            ReplyFormat::FramedJson => frame::encode_into(text.as_bytes(), out),
            ReplyFormat::FramedBin => {
                // Replies are produced by the (tested) reply writers, so
                // the parse cannot fail; the fallback keeps a hypothetical
                // bug observable instead of panicking the worker.
                let value = Json::parse(text)
                    .unwrap_or_else(|_| Json::Str(text.to_string()));
                frame::encode_into(&bin::encode(&value), out);
            }
        }
    }
}

/// One event drained from the byte stream.
pub(super) enum WireEvent {
    /// Accepted handshake: ack with [`handshake::ok_bytes`] and switch
    /// the connection's [`ReplyFormat`].
    Hello(Encoding),
    /// One complete, well-formed request.
    Request(Json),
    /// A complete but malformed request payload.  Reply with this error
    /// message and keep the connection (a pipelining client must keep
    /// its request/reply correlation even across its own mistakes).
    BadRequest(String),
}

/// A protocol violation: write one final reply, then close.
pub(super) enum Fatal {
    /// Raw handshake-reject bytes (the peer speaks frames, not text).
    Reject([u8; handshake::LEN]),
    /// Error message to serialize in the connection's current format.
    Error(String),
}

enum Mode {
    /// Nothing received yet: sniff the first byte.
    Detect,
    Lines,
    Frames(Encoding),
}

/// Per-connection receive state: the undrained byte buffer plus the
/// negotiated transport mode.
pub(super) struct ProtoState {
    buf: Vec<u8>,
    mode: Mode,
}

impl ProtoState {
    pub(super) fn new() -> ProtoState {
        ProtoState { buf: Vec::new(), mode: Mode::Detect }
    }

    /// The reply format matching the negotiated transport.
    pub(super) fn reply_format(&self) -> ReplyFormat {
        match self.mode {
            Mode::Detect | Mode::Lines => ReplyFormat::Lines,
            Mode::Frames(enc) => ReplyFormat::for_encoding(enc),
        }
    }

    /// Feed freshly read bytes and drain every complete event.  After a
    /// [`Fatal`] the state must not be fed again (the caller closes).
    pub(super) fn push(
        &mut self,
        bytes: &[u8],
    ) -> Result<Vec<WireEvent>, Fatal> {
        self.buf.extend_from_slice(bytes);
        let mut events = Vec::new();
        let mut cursor = 0usize;
        let result = loop {
            // lint:allow(panic-index: cursor only advances by consumed prefix lengths)
            let avail = &self.buf[cursor..];
            match self.mode {
                Mode::Detect => {
                    let Some(&first) = avail.first() else { break Ok(()) };
                    if first != handshake::MAGIC {
                        self.mode = Mode::Lines;
                        continue;
                    }
                    if avail.len() < handshake::LEN {
                        break Ok(()); // wait for the whole hello
                    }
                    let mut hello = [0u8; handshake::LEN];
                    // lint:allow(panic-index: avail.len() >= LEN checked above)
                    hello.copy_from_slice(&avail[..handshake::LEN]);
                    cursor += handshake::LEN;
                    match handshake::evaluate_hello(&hello) {
                        HelloVerdict::Accept { encoding, .. } => {
                            self.mode = Mode::Frames(encoding);
                            events.push(WireEvent::Hello(encoding));
                        }
                        HelloVerdict::Reject { reason } => {
                            break Err(Fatal::Reject(handshake::reject_bytes(
                                reason,
                            )));
                        }
                    }
                }
                Mode::Lines => {
                    let Some(nl) = avail.iter().position(|&b| b == b'\n')
                    else {
                        if avail.len() > MAX_LINE {
                            break Err(Fatal::Error(format!(
                                "request line exceeds the {MAX_LINE}-byte \
                                 limit"
                            )));
                        }
                        break Ok(());
                    };
                    // lint:allow(panic-index: nl is a position() hit inside avail)
                    let line = &avail[..nl];
                    cursor += nl + 1;
                    match std::str::from_utf8(line) {
                        Err(_) => events.push(WireEvent::BadRequest(
                            "bad json: request is not valid UTF-8".into(),
                        )),
                        Ok(text) => {
                            let text = text.trim();
                            if text.is_empty() {
                                continue;
                            }
                            events.push(match Json::parse(text) {
                                Ok(req) => WireEvent::Request(req),
                                Err(e) => WireEvent::BadRequest(format!(
                                    "bad json: {e}"
                                )),
                            });
                        }
                    }
                }
                Mode::Frames(enc) => {
                    let total = match frame::first_frame_len(avail) {
                        Err(frame::FrameError::TooLarge { len, max }) => {
                            break Err(Fatal::Error(format!(
                                "frame of {len} bytes exceeds the \
                                 {max}-byte limit"
                            )));
                        }
                        Ok(None) => break Ok(()),
                        Ok(Some(total)) => total,
                    };
                    if avail.len() < total {
                        break Ok(()); // mid-frame: wait for the rest
                    }
                    // lint:allow(panic-index: HEADER_LEN <= total <= avail.len() checked above)
                    let payload = &avail[frame::HEADER_LEN..total];
                    events.push(decode_payload(enc, payload));
                    cursor += total;
                }
            }
        };
        self.buf.drain(..cursor);
        result.map(|()| events)
    }
}

fn decode_payload(enc: Encoding, payload: &[u8]) -> WireEvent {
    match enc {
        Encoding::Json => match std::str::from_utf8(payload) {
            Err(_) => WireEvent::BadRequest(
                "bad json: request is not valid UTF-8".into(),
            ),
            Ok(text) => match Json::parse(text.trim()) {
                Ok(req) => WireEvent::Request(req),
                Err(e) => WireEvent::BadRequest(format!("bad json: {e}")),
            },
        },
        Encoding::Binary => match bin::decode(payload) {
            Ok(req) => WireEvent::Request(req),
            Err(e) => WireEvent::BadRequest(format!("bad request: {e}")),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use bss2_proto::PROTO_VERSION;

    fn req_bytes(text: &str) -> Vec<u8> {
        let mut out = Vec::new();
        frame::encode_into(text.as_bytes(), &mut out);
        out
    }

    #[test]
    fn legacy_lines_pass_through() {
        let mut st = ProtoState::new();
        // Split across pushes, with a blank line in between.
        let ev = st.push(b"{\"cmd\":\"pi").unwrap();
        assert!(ev.is_empty());
        let ev = st.push(b"ng\"}\n\n{\"cmd\":").unwrap();
        assert_eq!(ev.len(), 1);
        assert!(matches!(&ev[0], WireEvent::Request(r)
            if r.get("cmd").and_then(|c| c.as_str()) == Some("ping")));
        assert_eq!(st.reply_format(), ReplyFormat::Lines);
        let ev = st.push(b"3}\nnot json\n").unwrap();
        assert_eq!(ev.len(), 2);
        assert!(matches!(&ev[0], WireEvent::Request(_)));
        assert!(matches!(&ev[1], WireEvent::BadRequest(m)
            if m.starts_with("bad json")));
    }

    #[test]
    fn framed_json_negotiates_and_drains() {
        let mut st = ProtoState::new();
        let mut bytes =
            handshake::hello_bytes(PROTO_VERSION, Encoding::Json).to_vec();
        bytes.extend_from_slice(&req_bytes("{\"cmd\":\"ping\"}"));
        // Feed byte by byte: every split point must be handled.
        let mut events = Vec::new();
        for b in bytes {
            events.extend(st.push(&[b]).unwrap());
        }
        assert_eq!(events.len(), 2);
        assert!(matches!(events[0], WireEvent::Hello(Encoding::Json)));
        assert!(matches!(&events[1], WireEvent::Request(_)));
        assert_eq!(st.reply_format(), ReplyFormat::FramedJson);
    }

    #[test]
    fn binary_frames_decode() {
        let mut st = ProtoState::new();
        let hello = handshake::hello_bytes(PROTO_VERSION, Encoding::Binary);
        assert_eq!(st.push(&hello).unwrap().len(), 1);
        let mut m = std::collections::BTreeMap::new();
        m.insert("cmd".to_string(), Json::Str("stats".into()));
        let mut framed = Vec::new();
        frame::encode_into(&bin::encode(&Json::Obj(m)), &mut framed);
        let ev = st.push(&framed).unwrap();
        assert_eq!(ev.len(), 1);
        assert!(matches!(&ev[0], WireEvent::Request(r)
            if r.get("cmd").and_then(|c| c.as_str()) == Some("stats")));
        // Garbage inside a well-formed frame: typed error, not fatal.
        let mut garbage = Vec::new();
        frame::encode_into(&[0xfe, 0xba, 0xbe], &mut garbage);
        let ev = st.push(&garbage).unwrap();
        assert!(matches!(&ev[0], WireEvent::BadRequest(m)
            if m.starts_with("bad request")));
    }

    #[test]
    fn version_mismatch_is_fatal_reject() {
        let mut st = ProtoState::new();
        let hello = handshake::hello_bytes(PROTO_VERSION + 1, Encoding::Json);
        match st.push(&hello) {
            Err(Fatal::Reject(bytes)) => {
                assert_eq!(
                    handshake::evaluate_ack(&bytes),
                    Err(handshake::AckError::Rejected {
                        server_version: PROTO_VERSION,
                        reason: handshake::REJECT_VERSION,
                    })
                );
            }
            _ => panic!("expected a handshake reject"),
        }
    }

    #[test]
    fn oversized_frame_is_fatal() {
        let mut st = ProtoState::new();
        let hello = handshake::hello_bytes(PROTO_VERSION, Encoding::Json);
        st.push(&hello).unwrap();
        let huge = (u32::MAX).to_le_bytes();
        assert!(matches!(st.push(&huge), Err(Fatal::Error(_))));
    }
}
