//! L3 coordinator: the paper's system contribution in rust.
//!
//! * [`engine`] — standalone inference engine (the §II-D instruction-stream
//!   executor over PJRT or the native array model).
//! * [`batch`] — 500-trace block runner + Table 1 report (§IV).
//! * [`metrics`] — detection-rate / false-positive accounting.
//! * [`service`] — the experiment execution service (remote TCP protocol),
//!   dispatching through a [`crate::fleet::Fleet`] of engine replicas.

pub mod batch;
pub mod engine;
pub mod metrics;
pub mod service;
