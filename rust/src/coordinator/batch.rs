//! Block runner: the paper's §IV measurement procedure.
//!
//! "To increase the accuracy of all measurements, data was processed in
//! blocks of 500 traces.  For each block, runtime and energy consumption
//! have been measured [...] and afterwards averaged down to a single
//! inference."  Batch size stays 1 throughout (edge workload).

use crate::ecg::gen::Trace;
use crate::power::energy::{Component, ALL_COMPONENTS};
use crate::power::monitor::BlockMeasurement;

use super::engine::Engine;
use super::metrics::Confusion;

/// Aggregated results of one 500-trace block (the rows of Table 1).
#[derive(Debug, Clone)]
pub struct BlockReport {
    pub n: usize,
    /// Block wall time [s] (simulated) and per-inference time [s].
    pub block_time_s: f64,
    pub time_per_inference_s: f64,
    /// Energies per inference [J].
    pub energy_total_j: f64,
    pub energy_component_j: Vec<(Component, f64)>,
    /// Powers as the sensor pipeline measured them [W].
    pub system_power_w: f64,
    pub asic_power_w: f64,
    /// Compute figures.
    pub macs_per_inference: usize,
    pub ops_per_s: f64,
    pub ops_per_j_asic: f64,
    pub inferences_per_j_asic: f64,
    /// Classification quality.
    pub confusion: Confusion,
}

/// Run one block of traces through the engine, measuring like §IV.
pub fn run_block(
    engine: &mut Engine,
    traces: &[(Trace, u8)],
) -> anyhow::Result<BlockReport> {
    anyhow::ensure!(!traces.is_empty(), "empty block");
    let n = traces.len();
    let mut confusion = Confusion::default();
    let mut block_time = 0.0f64;
    let mut comp_j: Vec<(Component, f64)> =
        ALL_COMPONENTS.iter().map(|&c| (c, 0.0)).collect();
    let mut sensors = BlockMeasurement::new(n);

    for (trace, label) in traces {
        let inf = engine.classify(trace)?;
        confusion.add(inf.pred, *label);
        block_time += inf.sim_time_s;
        for (slot, (comp, j)) in comp_j.iter_mut().enumerate() {
            debug_assert_eq!(*comp, inf.energy.per_component[slot].0);
            *j += inf.energy.per_component[slot].1;
        }
    }
    // The sensor pipeline samples the block's mean powers (the paper's
    // sensors cannot resolve individual 276 µs inferences at 294 Hz).
    sensors.record_block(&comp_j, block_time);

    let per_inf = block_time / n as f64;
    let macs = engine.macs_per_inference();
    let asic_j_block: f64 = comp_j
        .iter()
        .filter(|(c, _)| {
            matches!(
                c,
                Component::AsicIo | Component::AsicAnalog | Component::AsicDigital
            )
        })
        .map(|(_, j)| j)
        .sum();
    let asic_j = asic_j_block / n as f64;
    let total_j: f64 = comp_j.iter().map(|(_, j)| j).sum::<f64>() / n as f64;

    Ok(BlockReport {
        n,
        block_time_s: block_time,
        time_per_inference_s: per_inf,
        energy_total_j: total_j,
        energy_component_j: comp_j
            .into_iter()
            .map(|(c, j)| (c, j / n as f64))
            .collect(),
        system_power_w: sensors.measured_system_w(),
        asic_power_w: asic_j / per_inf,
        macs_per_inference: macs,
        ops_per_s: (2 * macs) as f64 / per_inf,
        ops_per_j_asic: (2 * macs) as f64 / asic_j,
        inferences_per_j_asic: 1.0 / asic_j,
        confusion,
    })
}

impl BlockReport {
    /// Render the block as the rows of paper Table 1.
    pub fn table1(&self) -> String {
        let mut s = String::new();
        let row = |s: &mut String, q: &str, v: String, u: &str| {
            s.push_str(&format!("| {q:<42} | {v:>12} | {u:<4} |\n"));
        };
        s.push_str(&format!(
            "Table 1 — measured on a block of {} traces (batch size 1)\n",
            self.n
        ));
        s.push_str("| quantity                                   |        value | unit |\n");
        s.push_str("|--------------------------------------------|--------------|------|\n");
        row(&mut s, "time per inference",
            format!("{:.0} e-6", self.time_per_inference_s * 1e6), "s");
        row(&mut s, "power consumption (system)",
            format!("{:.1}", self.system_power_w), "W");
        row(&mut s, "power consumption (BSS-2 ASIC)",
            format!("{:.2}", self.asic_power_w), "W");
        row(&mut s, "energy (total)",
            format!("{:.2} e-3", self.energy_total_j * 1e3), "J");
        let comp = |c: Component| {
            self.energy_component_j
                .iter()
                .find(|(k, _)| *k == c)
                .map(|(_, j)| *j)
                .unwrap_or(0.0)
        };
        let ctrl = comp(Component::ArmCores)
            + comp(Component::FpgaFabric)
            + comp(Component::Dram);
        row(&mut s, "energy (system controller, total)",
            format!("{:.2} e-3", ctrl * 1e3), "J");
        row(&mut s, "energy (system controller, ARM CPU)",
            format!("{:.2} e-3", comp(Component::ArmCores) * 1e3), "J");
        row(&mut s, "energy (system controller, FPGA)",
            format!("{:.2} e-3", comp(Component::FpgaFabric) * 1e3), "J");
        row(&mut s, "energy (system controller, DRAM)",
            format!("{:.2} e-3", comp(Component::Dram) * 1e3), "J");
        let asic = comp(Component::AsicIo)
            + comp(Component::AsicAnalog)
            + comp(Component::AsicDigital);
        row(&mut s, "energy (ASIC, total)",
            format!("{:.2} e-3", asic * 1e3), "J");
        row(&mut s, "energy (ASIC, IO)",
            format!("{:.2} e-3", comp(Component::AsicIo) * 1e3), "J");
        row(&mut s, "energy (ASIC, analog)",
            format!("{:.2} e-3", comp(Component::AsicAnalog) * 1e3), "J");
        row(&mut s, "energy (ASIC, digital)",
            format!("{:.2} e-3", comp(Component::AsicDigital) * 1e3), "J");
        row(&mut s, "total operations in CDNN",
            format!("{:.1} e3", (2 * self.macs_per_inference) as f64 / 1e3), "Op");
        row(&mut s, "BSS-2 ASIC processing speed (mult./acc.)",
            format!("{:.0} e6", self.ops_per_s / 1e6), "Op/s");
        row(&mut s, "BSS-2 ASIC energy efficiency (mult./acc.)",
            format!("{:.0} e6", self.ops_per_j_asic / 1e6), "Op/J");
        row(&mut s, "BSS-2 ASIC energy efficiency (inferences)",
            format!("{:.2} e3", self.inferences_per_j_asic / 1e3), "1/J");
        row(&mut s, "detection rate",
            format!("{:.1}", self.confusion.detection_rate() * 100.0), "%");
        row(&mut s, "false positives",
            format!("{:.1}", self.confusion.false_positive_rate() * 100.0), "%");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{Engine, EngineConfig};
    use crate::ecg::gen::TraceStream;

    fn native_engine() -> Engine {
        // Reuse the tiny hand-built model from the engine tests via a
        // minimal weights.json-equivalent structure.
        let wc = vec![1.0; crate::asic::consts::CONV_CHANNELS
            * crate::asic::consts::ECG_CHANNELS
            * crate::asic::consts::CONV_KERNEL];
        let w1 = vec![1.0; crate::asic::consts::K_LOGICAL
            * crate::asic::consts::FC1_OUT];
        let w2 = vec![1.0; crate::asic::consts::FC1_OUT
            * crate::asic::consts::FC2_OUT];
        let model = crate::nn::weights::TrainedModel {
            pass_weights: [
                crate::nn::mapping::pack_conv(&wc),
                crate::nn::mapping::pack_fc1(&w1),
                crate::nn::mapping::pack_fc2(&w2),
            ],
            scales: [0.02, 0.02, 0.02],
            gain: [vec![1.0; 256], vec![1.0; 256]],
            offset: [vec![0.0; 256], vec![0.0; 256]],
            noise_sigma: 0.0,
            train_metrics: Default::default(),
        };
        Engine::native(
            model,
            EngineConfig { use_pjrt: false, noise_off: true, ..Default::default() },
        )
    }

    #[test]
    fn block_report_structure() {
        let mut eng = native_engine();
        let traces: Vec<_> = TraceStream::new(3, 1.0)
            .take(20)
            .map(|t| {
                let l = t.label;
                (t, l)
            })
            .collect();
        let rep = run_block(&mut eng, &traces).unwrap();
        assert_eq!(rep.n, 20);
        assert_eq!(rep.confusion.total(), 20);
        let us = rep.time_per_inference_s * 1e6;
        assert!((us - 276.0).abs() < 40.0, "{us} µs");
        assert!((rep.system_power_w - 5.6).abs() < 0.6, "{} W", rep.system_power_w);
        assert!(rep.ops_per_s > 1e8, "{}", rep.ops_per_s);
        let table = rep.table1();
        assert!(table.contains("detection rate"));
        assert!(table.contains("Op/s"));
    }

    #[test]
    fn empty_block_rejected() {
        let mut eng = native_engine();
        assert!(run_block(&mut eng, &[]).is_err());
    }
}
