//! Bounded structured event journal.
//!
//! Fleet state transitions that were previously invisible outside unit
//! tests — a chip crossing its error threshold into quarantine, a
//! recalibration draining and re-admitting a replica, an injected fault
//! firing, a failover budget running dry, a connection shed at accept
//! time — are appended here with a monotonic sequence number and kept in
//! a bounded ring.  Clients tail the journal over the wire
//! (`{"cmd":"journal","since":S}`) and can detect truncation: if the
//! first returned `seq` is greater than `S`, events in between aged out
//! of the ring.
//!
//! Sequence numbers are assigned under the same lock that orders the
//! ring, so ring order and `seq` order can never disagree.

use std::collections::VecDeque;
use std::sync::Mutex;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A chip crossed its error threshold and was quarantined
    /// (Healthy -> Unhealthy).
    ChipQuarantined,
    /// A chip was marked dead (init failure or permanent fault).
    ChipDead,
    /// A chip was drained for recalibration (-> Calibrating).
    CalibDrain,
    /// Recalibration finished and the chip was re-admitted (-> Healthy).
    CalibReadmit,
    /// Recalibration itself failed (-> Unhealthy).
    CalibFailed,
    /// An injected fault fired on a chip (FAULT_TAG error observed).
    FaultFired,
    /// A job exhausted its failover redirect budget (terminal error).
    RedirectExhausted,
    /// The service shed a connection at accept time (connection limit).
    ConnectionShed,
}

pub const ALL_EVENT_KINDS: [EventKind; 8] = [
    EventKind::ChipQuarantined,
    EventKind::ChipDead,
    EventKind::CalibDrain,
    EventKind::CalibReadmit,
    EventKind::CalibFailed,
    EventKind::FaultFired,
    EventKind::RedirectExhausted,
    EventKind::ConnectionShed,
];

impl EventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::ChipQuarantined => "chip_quarantined",
            EventKind::ChipDead => "chip_dead",
            EventKind::CalibDrain => "calib_drain",
            EventKind::CalibReadmit => "calib_readmit",
            EventKind::CalibFailed => "calib_failed",
            EventKind::FaultFired => "fault_fired",
            EventKind::RedirectExhausted => "redirect_exhausted",
            EventKind::ConnectionShed => "connection_shed",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Event {
    /// Monotonic, strictly increasing across the journal's lifetime.
    pub seq: u64,
    pub kind: EventKind,
    /// The chip the event concerns, when it concerns one.
    pub chip: Option<usize>,
    /// Free-form context (error text, calibration residual, ...).
    pub detail: String,
}

struct Inner {
    next_seq: u64,
    ring: VecDeque<Event>,
}

pub struct EventJournal {
    cap: usize,
    inner: Mutex<Inner>,
}

/// Default ring bound: enough to hold a whole chaos soak's transitions
/// while keeping the journal's memory a few hundred kB at worst.
pub const DEFAULT_JOURNAL_CAP: usize = 1024;

impl EventJournal {
    pub fn new(cap: usize) -> EventJournal {
        EventJournal {
            cap: cap.max(1),
            inner: Mutex::new(Inner { next_seq: 0, ring: VecDeque::new() }),
        }
    }

    /// Append one event; the oldest entry ages out past the ring bound.
    pub fn log(&self, kind: EventKind, chip: Option<usize>, detail: &str) {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        if inner.ring.len() == self.cap {
            inner.ring.pop_front();
        }
        inner.ring.push_back(Event {
            seq,
            kind,
            chip,
            detail: detail.to_string(),
        });
    }

    /// Events with `seq >= since`, oldest first (bounded by the ring).
    pub fn since(&self, since: u64) -> Vec<Event> {
        let inner = self.inner.lock().unwrap();
        inner.ring.iter().filter(|e| e.seq >= since).cloned().collect()
    }

    /// The next sequence number to be assigned (= total events logged).
    pub fn next_seq(&self) -> u64 {
        self.inner.lock().unwrap().next_seq
    }

    /// Per-kind lifetime-in-ring counts (for summaries; order follows
    /// [`ALL_EVENT_KINDS`], zero-count kinds included).
    pub fn counts_by_kind(&self) -> Vec<(EventKind, u64)> {
        let inner = self.inner.lock().unwrap();
        ALL_EVENT_KINDS
            .iter()
            .map(|&k| {
                (k, inner.ring.iter().filter(|e| e.kind == k).count() as u64)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_numbers_strictly_increase() {
        let j = EventJournal::new(16);
        for i in 0..10 {
            j.log(EventKind::FaultFired, Some(i % 3), "x");
        }
        let all = j.since(0);
        assert_eq!(all.len(), 10);
        for w in all.windows(2) {
            assert!(w[1].seq > w[0].seq);
        }
        assert_eq!(j.next_seq(), 10);
    }

    #[test]
    fn ring_is_bounded_and_truncation_is_detectable() {
        let j = EventJournal::new(4);
        for _ in 0..10 {
            j.log(EventKind::ChipQuarantined, None, "");
        }
        let all = j.since(0);
        assert_eq!(all.len(), 4, "ring bound holds");
        // Sequence numbers keep counting across evictions: a reader that
        // asked for seq >= 0 can see it missed 0..=5.
        assert_eq!(all[0].seq, 6);
        assert_eq!(all.last().unwrap().seq, 9);
        assert!(j.since(8).len() == 2);
        assert!(j.since(100).is_empty());
    }

    #[test]
    fn counts_by_kind() {
        let j = EventJournal::new(16);
        j.log(EventKind::CalibDrain, Some(1), "");
        j.log(EventKind::CalibReadmit, Some(1), "");
        j.log(EventKind::CalibDrain, Some(2), "");
        let counts = j.counts_by_kind();
        let get = |k: EventKind| {
            counts.iter().find(|(kk, _)| *kk == k).unwrap().1
        };
        assert_eq!(get(EventKind::CalibDrain), 2);
        assert_eq!(get(EventKind::CalibReadmit), 1);
        assert_eq!(get(EventKind::FaultFired), 0);
    }
}
