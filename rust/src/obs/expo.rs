//! Exposition of a metrics snapshot: Prometheus text format and the
//! wire-JSON array.
//!
//! Both renderers take the same `Vec<MetricSample>` (the unified
//! snapshot), so the two formats can never disagree about what exists.
//! The text format follows the Prometheus 0.0.4 conventions: one
//! `# HELP` / `# TYPE` pair per metric family (first occurrence wins),
//! then one `name{labels} value` line per sample.

use crate::util::json::Json;

use super::registry::MetricSample;

/// Render a value the same way in both formats: finite f64 via Rust's
/// shortest-roundtrip `Display` (integers print without a decimal
/// point); non-finite values — which only arise from bugs upstream —
/// clamp to 0 so the JSON exposition stays parseable.
fn fmt_value(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

fn escape_label_value(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Prometheus text exposition (content type `text/plain; version=0.0.4`).
pub fn prometheus(samples: &[MetricSample]) -> String {
    let mut out = String::new();
    let mut last_family: Option<&str> = None;
    for s in samples {
        if last_family != Some(s.name.as_str()) {
            out.push_str(&format!("# HELP {} {}\n", s.name, s.help));
            out.push_str(&format!("# TYPE {} {}\n", s.name, s.kind.as_str()));
            last_family = Some(s.name.as_str());
        }
        out.push_str(&s.name);
        if !s.labels.is_empty() {
            out.push('{');
            for (i, (k, v)) in s.labels.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!("{k}=\"{}\"", escape_label_value(v)));
            }
            out.push('}');
        }
        out.push(' ');
        out.push_str(&fmt_value(s.value));
        out.push('\n');
    }
    out
}

/// JSON exposition: an array of sample objects, same order as the text
/// format (and the same source snapshot).
pub fn json_array(samples: &[MetricSample]) -> String {
    let mut out = String::from("[");
    for (i, s) in samples.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"name\":{},\"kind\":\"{}\",\"value\":{}",
            Json::Str(s.name.clone()),
            s.kind.as_str(),
            fmt_value(s.value)
        ));
        if !s.labels.is_empty() {
            out.push_str(",\"labels\":{");
            for (j, (k, v)) in s.labels.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "{}:{}",
                    Json::Str(k.clone()),
                    Json::Str(v.clone())
                ));
            }
            out.push('}');
        }
        out.push('}');
    }
    out.push(']');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_set() -> Vec<MetricSample> {
        vec![
            MetricSample::counter(
                "bss2_fleet_served_total",
                "Completed inferences.",
                3.0,
            ),
            MetricSample::gauge(
                "bss2_host_latency_us",
                "Host latency quantiles.",
                276.5,
            )
            .with_label("quantile", "0.5"),
            MetricSample::gauge(
                "bss2_host_latency_us",
                "Host latency quantiles.",
                410.0,
            )
            .with_label("quantile", "0.99"),
        ]
    }

    /// Golden pin of the Prometheus text exposition format.
    #[test]
    fn prometheus_golden() {
        let got = prometheus(&sample_set());
        let want = "\
# HELP bss2_fleet_served_total Completed inferences.
# TYPE bss2_fleet_served_total counter
bss2_fleet_served_total 3
# HELP bss2_host_latency_us Host latency quantiles.
# TYPE bss2_host_latency_us gauge
bss2_host_latency_us{quantile=\"0.5\"} 276.5
bss2_host_latency_us{quantile=\"0.99\"} 410
";
        assert_eq!(got, want);
    }

    #[test]
    fn json_array_parses_and_round_trips() {
        let txt = json_array(&sample_set());
        let parsed = Json::parse(&txt).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(
            arr[0].get("name").and_then(|n| n.as_str()),
            Some("bss2_fleet_served_total")
        );
        assert_eq!(arr[0].get("value").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(
            arr[1]
                .get("labels")
                .and_then(|l| l.get("quantile"))
                .and_then(|q| q.as_str()),
            Some("0.5")
        );
    }

    #[test]
    fn label_values_are_escaped() {
        let s = vec![MetricSample::gauge("m", "H.", 1.0)
            .with_label("detail", "a\"b\\c\nd")];
        let txt = prometheus(&s);
        assert!(txt.contains("m{detail=\"a\\\"b\\\\c\\nd\"} 1"), "{txt}");
        assert!(Json::parse(&json_array(&s)).is_ok());
    }

    #[test]
    fn non_finite_values_clamp() {
        let s = vec![MetricSample::gauge("m", "H.", f64::NAN)];
        assert!(prometheus(&s).contains("m 0"));
        assert!(Json::parse(&json_array(&s)).is_ok());
    }
}
