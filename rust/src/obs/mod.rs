//! Fleet-wide observability (DESIGN.md §13).
//!
//! The paper's headline numbers are *measurements* — 276 µs/sample,
//! 192 µJ/sample on the ASIC, 1.56 mJ system-total — and a serving fleet
//! should be able to answer the same questions about itself at runtime.
//! This module is the one place those answers come from:
//!
//! * [`registry`] — named counters/gauges behind one snapshot; scattered
//!   fleet stats are folded into the same [`MetricSample`] shape and
//!   exposed via the `metrics` wire command (JSON + Prometheus text,
//!   [`expo`]).
//! * [`trace`] — stage-level spans per job in host-ns *and* simulated
//!   chip-time, aggregated into per-stage p50/p95/p99 histograms with a
//!   bounded ring of full traces (`trace` wire command,
//!   `repro serve --trace-sample N`).
//! * [`journal`] — bounded structured event journal of fleet state
//!   transitions (quarantine, calibration drain/re-admit, fault fired,
//!   redirect exhausted, connection shed) with monotonic sequence
//!   numbers (`journal` wire command).
//!
//! One [`ObsHub`] instance lives in `fleet::FleetCore`; chip workers and
//! the service write into it lock-free (registry handles, atomics) or
//! through short bounded-ring mutexes (traces, journal) — never on the
//! reply path's critical lock.

pub mod expo;
pub mod journal;
pub mod registry;
pub mod trace;

pub use journal::{Event, EventJournal, EventKind, DEFAULT_JOURNAL_CAP};
pub use registry::{Counter, Gauge, MetricKind, MetricSample, Registry};
pub use trace::{
    HostStages, SimStages, StageStat, TraceRecord, TraceRecorder,
};

/// The observability surface owned by a fleet: registry + tracer +
/// journal, constructed together so every subsystem writes to the same
/// instances.
pub struct ObsHub {
    pub registry: Registry,
    pub tracer: TraceRecorder,
    pub journal: EventJournal,
}

impl ObsHub {
    /// `trace_sample`: keep every Nth full span in the trace ring
    /// (0 disables the ring; stage histograms always record).
    pub fn new(trace_sample: u64) -> ObsHub {
        ObsHub {
            registry: Registry::new(),
            tracer: TraceRecorder::new(trace_sample),
            journal: EventJournal::new(DEFAULT_JOURNAL_CAP),
        }
    }
}
