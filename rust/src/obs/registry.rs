//! Unified metrics registry: named counters and gauges behind one
//! snapshot.
//!
//! Registration (rare) takes a mutex; the hot path — bumping a counter
//! from a chip worker or the service acceptor — is a single relaxed
//! atomic on a pre-registered handle, so instrumentation never contends
//! with serving.
//!
//! The registry only *owns* the metrics created through it.  Stats that
//! already live elsewhere (fleet telemetry, scheduler, failover
//! counters) are folded into the same snapshot shape by
//! `FleetCore::metrics_samples`, which appends [`MetricSample`]s read
//! from those sources — one snapshot, one exposition path
//! ([`super::expo`]), regardless of where a number is accumulated.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
}

impl MetricKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
        }
    }
}

/// Monotonic counter handle (clone-cheap, lock-free increments).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value gauge handle (stores f64 bits in an atomic).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

struct Entry {
    name: String,
    help: String,
    kind: MetricKind,
    cell: Arc<AtomicU64>,
}

/// One sample of the unified snapshot.  `labels` render as Prometheus
/// labels (`name{k="v"} value`) and as a JSON object in the JSON
/// exposition.
#[derive(Debug, Clone)]
pub struct MetricSample {
    pub name: String,
    pub help: String,
    pub kind: MetricKind,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl MetricSample {
    pub fn counter(name: &str, help: &str, value: f64) -> MetricSample {
        MetricSample {
            name: name.to_string(),
            help: help.to_string(),
            kind: MetricKind::Counter,
            labels: Vec::new(),
            value,
        }
    }

    pub fn gauge(name: &str, help: &str, value: f64) -> MetricSample {
        MetricSample {
            name: name.to_string(),
            help: help.to_string(),
            kind: MetricKind::Gauge,
            labels: Vec::new(),
            value,
        }
    }

    pub fn with_label(mut self, key: &str, value: impl ToString) -> Self {
        self.labels.push((key.to_string(), value.to_string()));
        self
    }
}

/// Named-metric registry.  `counter`/`gauge` are idempotent by name: a
/// second registration returns a handle onto the same cell, so callers
/// in different modules can share a metric without plumbing handles.
pub struct Registry {
    inner: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry { inner: Mutex::new(Vec::new()) }
    }

    fn cell(&self, name: &str, help: &str, kind: MetricKind) -> Arc<AtomicU64> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner.iter().find(|e| e.name == name) {
            return e.cell.clone();
        }
        let cell = Arc::new(AtomicU64::new(0));
        inner.push(Entry {
            name: name.to_string(),
            help: help.to_string(),
            kind,
            cell: cell.clone(),
        });
        cell
    }

    pub fn counter(&self, name: &str, help: &str) -> Counter {
        Counter(self.cell(name, help, MetricKind::Counter))
    }

    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        Gauge(self.cell(name, help, MetricKind::Gauge))
    }

    /// Snapshot every registered metric, in registration order.
    pub fn snapshot(&self) -> Vec<MetricSample> {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .map(|e| {
                let raw = e.cell.load(Ordering::Relaxed);
                MetricSample {
                    name: e.name.clone(),
                    help: e.help.clone(),
                    kind: e.kind,
                    labels: Vec::new(),
                    value: match e.kind {
                        MetricKind::Counter => raw as f64,
                        MetricKind::Gauge => f64::from_bits(raw),
                    },
                }
            })
            .collect()
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_snapshot() {
        let r = Registry::new();
        let c = r.counter("jobs_total", "Jobs.");
        c.inc();
        c.add(2);
        let g = r.gauge("temp_c", "Temperature.");
        g.set(36.5);
        let snap = r.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].name, "jobs_total");
        assert_eq!(snap[0].kind, MetricKind::Counter);
        assert_eq!(snap[0].value, 3.0);
        assert_eq!(snap[1].value, 36.5);
    }

    #[test]
    fn registration_is_idempotent_by_name() {
        let r = Registry::new();
        let a = r.counter("x", "X.");
        let b = r.counter("x", "ignored duplicate help");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "both handles share one cell");
        assert_eq!(r.snapshot().len(), 1);
    }

    #[test]
    fn handles_are_lock_free_across_threads() {
        let r = Registry::new();
        let c = r.counter("n", "N.");
        let mut hs = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }
}
