//! Stage-level request tracing.
//!
//! Every classify/stream job carries a span through the fleet in two
//! time bases:
//!
//! * **host stages** (wall-clock ns, measured from contiguous `Instant`
//!   reads in the dispatch path and chip worker, so the stage durations
//!   sum *exactly* to the recorded end-to-end latency):
//!   `queue` (admission -> worker dequeue), `execute` (engine run of the
//!   successful attempt), `retry` (queue + execute time burnt in failed
//!   attempts before a failover redirect landed);
//! * **simulated chip-time stages** (µs, per sample, from the engine's
//!   per-category [`ChipTiming`](crate::asic::chip::ChipTiming)
//!   accounting): where the paper's 276 µs actually goes — DMA, event
//!   streaming, weight writes, VMM integrations, ADC reads, SIMD
//!   post-processing, explicit waits, and the fixed control overhead.
//!
//! Completed spans feed per-stage latency histograms (p50/p95/p99 per
//! stage, surfaced in `fleet_stats` and `metrics`), and every
//! `sample_every`-th span is kept whole in a bounded ring fetchable via
//! the `trace` wire command.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::fleet::telemetry::LatencyHistogram;

/// Host-side span of one job, in nanoseconds.  Stages are contiguous by
/// construction: `queue + execute + retry == end-to-end` exactly (each
/// boundary is a single `Instant` read shared by the adjacent stages).
#[derive(Debug, Clone, Copy, Default)]
pub struct HostStages {
    /// Admission (or last redirect re-enqueue) to worker dequeue.
    pub queue_ns: u64,
    /// Engine execution of the attempt that produced the reply.
    pub execute_ns: u64,
    /// Queue + execute time of failed attempts (failover redirect hops).
    pub retry_ns: u64,
}

impl HostStages {
    pub fn total_ns(&self) -> u64 {
        self.queue_ns + self.execute_ns + self.retry_ns
    }

    pub fn named(&self) -> [(&'static str, u64); 3] {
        [
            ("queue", self.queue_ns),
            ("execute", self.execute_ns),
            ("retry", self.retry_ns),
        ]
    }
}

/// Simulated chip-time of one inference, split by pipeline stage [µs per
/// sample].  Sums to the inference's `sim_time_s` (± float addition
/// order).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SimStages {
    /// DMA transfer of the preprocessed window into FPGA memory.
    pub dma_us: f64,
    /// Event streaming into the analog core (link-bandwidth bound).
    pub events_us: f64,
    /// Synapse weight reconfiguration (40 µs per half-array write).
    pub weight_write_us: f64,
    /// Analog VMM integration cycles (5 µs each).
    pub vmm_us: f64,
    /// Parallel ADC readouts (1.5 µs each).
    pub adc_us: f64,
    /// Embedded SIMD CPU post-processing.
    pub simd_us: f64,
    /// Explicit waits (DMA settling etc.).
    pub wait_us: f64,
    /// Fixed control overhead + injected latency-spike extra.
    pub control_us: f64,
}

pub const SIM_STAGE_NAMES: [&str; 8] = [
    "dma",
    "events",
    "weight_write",
    "vmm",
    "adc",
    "simd",
    "wait",
    "control",
];

impl SimStages {
    pub fn total_us(&self) -> f64 {
        self.dma_us
            + self.events_us
            + self.weight_write_us
            + self.vmm_us
            + self.adc_us
            + self.simd_us
            + self.wait_us
            + self.control_us
    }

    pub fn named(&self) -> [(&'static str, f64); 8] {
        [
            ("dma", self.dma_us),
            ("events", self.events_us),
            ("weight_write", self.weight_write_us),
            ("vmm", self.vmm_us),
            ("adc", self.adc_us),
            ("simd", self.simd_us),
            ("wait", self.wait_us),
            ("control", self.control_us),
        ]
    }

    /// Uniform share (e.g. `1/B` of a batch-level span per sample).
    pub fn scaled(&self, f: f64) -> SimStages {
        SimStages {
            dma_us: self.dma_us * f,
            events_us: self.events_us * f,
            weight_write_us: self.weight_write_us * f,
            vmm_us: self.vmm_us * f,
            adc_us: self.adc_us * f,
            simd_us: self.simd_us * f,
            wait_us: self.wait_us * f,
            control_us: self.control_us * f,
        }
    }
}

/// One fully recorded span (ring entry for the `trace` wire command).
#[derive(Debug, Clone)]
pub struct TraceRecord {
    /// Monotonic trace id (counts *recorded* traces).
    pub id: u64,
    /// Chip that produced the reply (after any redirects).
    pub chip: usize,
    /// "classify" | "batch" | "acts".
    pub kind: &'static str,
    /// Samples in the job (1 for classify/acts).
    pub batch: usize,
    /// Failover hops this job survived.
    pub redirects: u32,
    pub host: HostStages,
    /// Per-sample simulated stage split.
    pub sim: SimStages,
}

/// Per-stage aggregate for stats surfaces.
#[derive(Debug, Clone)]
pub struct StageStat {
    pub name: &'static str,
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

/// Bound on the ring of full trace records.
pub const TRACE_RING_CAP: usize = 256;

pub struct TraceRecorder {
    /// Keep every Nth full span (0 disables the ring; histograms always
    /// record).
    sample_every: u64,
    seen: AtomicU64,
    recorded: AtomicU64,
    ring: Mutex<VecDeque<TraceRecord>>,
    host_hists: [LatencyHistogram; 3],
    sim_hists: [LatencyHistogram; 8],
}

pub const HOST_STAGE_NAMES: [&str; 3] = ["queue", "execute", "retry"];

impl TraceRecorder {
    pub fn new(sample_every: u64) -> TraceRecorder {
        TraceRecorder {
            sample_every,
            seen: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
            host_hists: Default::default(),
            sim_hists: Default::default(),
        }
    }

    /// Observe one completed span: always feeds the per-stage histograms;
    /// every `sample_every`-th span is additionally kept whole.
    pub fn observe(
        &self,
        chip: usize,
        kind: &'static str,
        batch: usize,
        redirects: u32,
        host: HostStages,
        sim: SimStages,
    ) {
        for (i, (_, ns)) in host.named().iter().enumerate() {
            self.host_hists[i].record_us(*ns as f64 / 1e3);
        }
        for (i, (_, us)) in sim.named().iter().enumerate() {
            self.sim_hists[i].record_us(*us);
        }
        let n = self.seen.fetch_add(1, Ordering::Relaxed);
        if self.sample_every == 0 || n % self.sample_every != 0 {
            return;
        }
        let id = self.recorded.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == TRACE_RING_CAP {
            ring.pop_front();
        }
        ring.push_back(TraceRecord {
            id,
            chip,
            kind,
            batch,
            redirects,
            host,
            sim,
        });
    }

    /// Spans observed (histogram entries).
    pub fn seen(&self) -> u64 {
        self.seen.load(Ordering::Relaxed)
    }

    /// Spans kept whole in the ring (lifetime, before eviction).
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// The most recent `n` full trace records, oldest first.
    pub fn recent(&self, n: usize) -> Vec<TraceRecord> {
        let ring = self.ring.lock().unwrap();
        let skip = ring.len().saturating_sub(n);
        ring.iter().skip(skip).cloned().collect()
    }

    /// Host-stage aggregates (values in µs).
    pub fn host_stage_stats(&self) -> Vec<StageStat> {
        HOST_STAGE_NAMES
            .iter()
            .zip(&self.host_hists)
            .map(|(name, h)| stat(name, h))
            .collect()
    }

    /// Simulated-stage aggregates (values in µs per sample).
    pub fn sim_stage_stats(&self) -> Vec<StageStat> {
        SIM_STAGE_NAMES
            .iter()
            .zip(&self.sim_hists)
            .map(|(name, h)| stat(name, h))
            .collect()
    }
}

fn stat(name: &'static str, h: &LatencyHistogram) -> StageStat {
    StageStat {
        name,
        count: h.count(),
        mean_us: h.mean_us(),
        p50_us: h.quantile_us(50.0),
        p95_us: h.quantile_us(95.0),
        p99_us: h.quantile_us(99.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(q: u64, e: u64, r: u64) -> HostStages {
        HostStages { queue_ns: q, execute_ns: e, retry_ns: r }
    }

    #[test]
    fn host_stages_sum_exactly() {
        let h = span(1_234, 276_000, 300_001);
        assert_eq!(h.total_ns(), 577_235);
        let by_name: u64 = h.named().iter().map(|(_, ns)| ns).sum();
        assert_eq!(by_name, h.total_ns());
    }

    #[test]
    fn sim_stages_total_and_scale() {
        let s = SimStages {
            dma_us: 1.0,
            events_us: 2.0,
            weight_write_us: 80.0,
            vmm_us: 15.0,
            adc_us: 4.5,
            simd_us: 1.5,
            wait_us: 0.4,
            control_us: 128.0,
        };
        assert!((s.total_us() - 232.4).abs() < 1e-9);
        let half = s.scaled(0.5);
        assert!((half.total_us() - 116.2).abs() < 1e-9);
        assert_eq!(s.named().len(), SIM_STAGE_NAMES.len());
    }

    #[test]
    fn sampling_keeps_every_nth_and_ring_is_bounded() {
        let t = TraceRecorder::new(4);
        for i in 0..2000 {
            t.observe(
                i % 3,
                "classify",
                1,
                0,
                span(100, 200, 0),
                SimStages::default(),
            );
        }
        assert_eq!(t.seen(), 2000);
        assert_eq!(t.recorded(), 500, "every 4th span recorded");
        let recent = t.recent(usize::MAX);
        assert_eq!(recent.len(), TRACE_RING_CAP, "ring bound holds");
        // Oldest-first, monotonically increasing ids, newest retained.
        for w in recent.windows(2) {
            assert!(w[1].id > w[0].id);
        }
        assert_eq!(recent.last().unwrap().id, 499);
        assert_eq!(t.recent(3).len(), 3);
    }

    #[test]
    fn sampling_disabled_still_feeds_histograms() {
        let t = TraceRecorder::new(0);
        t.observe(0, "classify", 1, 0, span(0, 276_000, 0), SimStages::default());
        assert!(t.recent(10).is_empty());
        let stats = t.host_stage_stats();
        assert_eq!(stats[1].name, "execute");
        assert_eq!(stats[1].count, 1);
        assert!((stats[1].mean_us - 276.0).abs() < 1e-6);
    }
}
