//! Poison-tolerant locking helpers (DESIGN.md §16, panic-safety family).
//!
//! `Mutex::lock().unwrap()` turns one panicked holder into a cascade: every
//! later `lock()` sees the poison flag and panics too, which in the serving
//! layer tears down worker threads that were nowhere near the original bug.
//! All server-path state guarded by our mutexes (connection registries,
//! telemetry windows, health strings, reply queues) stays structurally
//! valid even if a holder unwound mid-update, so the right recovery is to
//! take the guard and keep serving.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Acquire `m`, recovering the guard from a poisoned lock instead of
/// propagating the panic to this thread.
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait`] with the same poison recovery as [`lock_clean`].
pub fn wait_clean<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_clean_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        assert_eq!(*lock_clean(&m), 7);
        *lock_clean(&m) = 9;
        assert_eq!(*lock_clean(&m), 9);
    }
}
