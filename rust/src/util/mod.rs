//! Hand-rolled substrate utilities.
//!
//! The offline build environment only vendors the `xla` crate and a few
//! leaf dependencies, so the usual ecosystem crates (serde, clap, criterion,
//! proptest, rand) are replaced by small, tested, purpose-built modules:
//!
//! * [`rng`] — SplitMix64 PRNG, bit-identical to the python mirror.
//! * [`json`] — JSON parser/writer for the artifact formats (re-exported
//!   from `bss2-proto`, where it doubles as the wire value type).
//! * [`cli`] — argument parsing for the `repro` binary.
//! * [`stats`] — summaries/percentiles for the measurement pipeline.
//! * [`benchkit`] — the bench harness driving `cargo bench` targets.
//! * [`propcheck`] — mini property-testing kit for invariant tests.
//! * [`sync`] — poison-tolerant `Mutex`/`Condvar` helpers for server paths.

pub mod benchkit;
pub mod cli;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod sync;

pub use bss2_proto::json;
