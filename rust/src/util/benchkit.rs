//! Hand-rolled bench harness (criterion is not available offline).
//!
//! Each `cargo bench` target is a `harness = false` binary that drives
//! [`Bench`]: warmup, timed iterations, outlier-robust statistics, and a
//! stable text report format that `bench_output.txt` captures.

use std::time::{Duration, Instant};

use super::stats::Summary;

pub struct Bench {
    pub name: String,
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_time: Duration,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall-clock seconds.
    pub summary: Summary,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            target_time: Duration::from_secs(2),
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    pub fn iters(mut self, min: usize, max: usize) -> Self {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    pub fn target(mut self, d: Duration) -> Self {
        self.target_time = d;
        self
    }

    /// Run `f` repeatedly until both `min_iters` and `target_time` are
    /// reached (or `max_iters`), and report per-iteration timings.
    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        loop {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            let done_time = start.elapsed() >= self.target_time;
            if (samples.len() >= self.min_iters && done_time)
                || samples.len() >= self.max_iters
            {
                break;
            }
        }
        BenchResult {
            name: self.name.clone(),
            iters: samples.len(),
            summary: Summary::from(&samples),
        }
    }
}

impl BenchResult {
    /// One-line report: `name  mean ± std  [min .. p99]  (n iters)`.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:>10}  p50 {:>12}  p99 {:>12}  ({} iters)",
            self.name,
            fmt_time(self.summary.mean),
            fmt_time(self.summary.std),
            fmt_time(self.summary.p50),
            fmt_time(self.summary.p99),
            self.iters
        )
    }

    pub fn print(&self) -> &Self {
        println!("{}", self.report());
        self
    }

    /// Throughput helper: items per second at the mean time.
    pub fn per_second(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.summary.mean
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Section header used by all bench binaries to keep output greppable.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_minimum_iterations() {
        let b = Bench::new("noop")
            .warmup(1)
            .iters(5, 50)
            .target(Duration::from_millis(1));
        let r = b.run(|| { std::hint::black_box(1 + 1); });
        assert!(r.iters >= 5 && r.iters <= 50);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn respects_max_iters() {
        let b = Bench::new("noop")
            .warmup(0)
            .iters(1, 7)
            .target(Duration::from_secs(60));
        let r = b.run(|| std::hint::black_box(()));
        assert_eq!(r.iters, 7);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn per_second() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            summary: Summary::from(&[0.5]),
        };
        assert!((r.per_second(10.0) - 20.0).abs() < 1e-9);
    }
}
