//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Each subcommand of the `repro` binary builds one `Args` from
//! `std::env::args()`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Option keys that were consumed via typed getters (for strict mode).
    known: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from raw arguments (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut a = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.options.insert(body.to_string(), v);
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn from_env() -> (String, Args) {
        let mut raw: Vec<String> = std::env::args().skip(1).collect();
        let cmd = if raw.is_empty() { String::new() } else { raw.remove(0) };
        (cmd, Args::parse(raw))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.known.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.known.borrow_mut().push(name.to_string());
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{v}`")),
        }
    }

    /// Error on unknown options (catches typos like `--trace` vs `--traces`).
    pub fn check_unknown(&self) -> anyhow::Result<()> {
        let known = self.known.borrow();
        for key in self.options.keys() {
            if !known.iter().any(|k| k == key) {
                anyhow::bail!("unknown option --{key}");
            }
        }
        for key in &self.flags {
            if !known.iter().any(|k| k == key) {
                anyhow::bail!("unknown flag --{key}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_styles() {
        let a = parse("--n 5 --mode=fast run");
        assert_eq!(a.get("n"), Some("5"));
        assert_eq!(a.get("mode"), Some("fast"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("--verbose --n 3");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b");
        assert!(a.flag("a") && a.flag("b"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("--x 2.5 --n 7");
        assert_eq!(a.f64_or("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.usize_or("n", 0).unwrap(), 7);
        assert_eq!(a.usize_or("missing", 9).unwrap(), 9);
        assert!(a.usize_or("x", 0).is_err());
    }

    #[test]
    fn unknown_detection() {
        let a = parse("--known 1 --typo 2");
        let _ = a.get("known");
        assert!(a.check_unknown().is_err());
        let _ = a.get("typo");
        assert!(a.check_unknown().is_ok());
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("--offset -3");
        // `-3` does not start with `--`, so it is consumed as the value.
        assert_eq!(a.get("offset"), Some("-3"));
    }
}
