//! Mini property-testing kit (proptest is not available offline).
//!
//! A property is a closure over a [`Gen`] (seeded PRNG with sampling
//! helpers).  [`check`] runs it for N random cases; on failure it reports
//! the failing seed so the case can be replayed deterministically with
//! [`replay`].  Used by the coordinator/partitioner invariant tests.

use super::rng::SplitMix64;

/// Case generator: a seeded PRNG plus convenience samplers.
pub struct Gen {
    pub rng: SplitMix64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: SplitMix64::new(seed), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo <= hi);
        lo + self.rng.below((hi as i64 - lo as i64 + 1) as u64) as i32
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_i32(&mut self, len: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..len).map(|_| self.i32_in(lo, hi)).collect()
    }

    pub fn vec_f32(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f32> {
        (0..len).map(|_| self.f64_in(lo, hi) as f32).collect()
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `prop` for `cases` random cases derived from `base_seed`.
/// Panics with the failing case seed on the first failure.
pub fn check<F>(name: &str, cases: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for i in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(i as u64);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property `{name}` failed on case {i} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed (for debugging).
pub fn replay<F>(seed: u64, mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    prop(&mut Gen::new(seed))
}

/// Assertion helpers for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 25, 1, |g| {
            count += 1;
            let v = g.usize_in(3, 9);
            prop_assert!((3..=9).contains(&v), "out of range: {v}");
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property `failing` failed")]
    fn failing_property_panics_with_seed() {
        check("failing", 10, 2, |g| {
            let v = g.i32_in(0, 100);
            prop_assert!(v < 5, "got {v}");
            Ok(())
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = None;
        let f = |g: &mut Gen| {
            let v = g.usize_in(0, 1_000_000);
            Err(format!("{v}"))
        };
        let a = replay(1234, f).unwrap_err();
        let b = replay(1234, f).unwrap_err();
        assert_eq!(a, b);
        first.replace(a);
    }

    #[test]
    fn samplers_respect_bounds() {
        let mut g = Gen::new(5);
        for _ in 0..200 {
            assert!((-3..=7).contains(&g.i32_in(-3, 7)));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let v = g.vec_i32(16, 0, 3);
        assert_eq!(v.len(), 16);
        assert!(v.iter().all(|x| (0..=3).contains(x)));
    }
}
