//! Summary statistics for the measurement pipeline and the bench kit.

/// Running summary of a sample set (mean/std/min/max/percentiles).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn from(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::from on empty slice");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        // Bessel-corrected *sample* variance (n - 1): the paper reports
        // mean ± std over repeated measurement runs, which estimates the
        // spread of the underlying distribution, not of the finite sample.
        // A single sample has no spread estimate — report 0.
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `q` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean ± std formatted the way the paper reports metrics.
pub fn pm(mean: f64, std: f64, digits: usize) -> String {
    format!("{mean:.digits$} ± {std:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_std_is_bessel_corrected() {
        let s = Summary::from(&[2.0, 2.0, 2.0]);
        assert_eq!(s.std, 0.0);
        // Sample std of {0, 2}: sqrt(((0-1)² + (2-1)²) / (2-1)) = sqrt(2),
        // not the population value 1.
        let s = Summary::from(&[0.0, 2.0]);
        assert!((s.std - 2.0f64.sqrt()).abs() < 1e-12, "std {}", s.std);
        // Cross-check on a paper-style repeated-runs set.
        let s = Summary::from(&[93.2, 94.4, 93.6, 94.0]);
        let want = (0.8 / 3.0f64).sqrt(); // Σ(x-x̄)² = 0.8 over n-1 = 3
        assert!((s.std - want).abs() < 1e-12, "std {} want {want}", s.std);
    }

    #[test]
    fn summary_singleton_has_zero_std() {
        let s = Summary::from(&[42.0]);
        assert_eq!(s.n, 1);
        assert_eq!(s.std, 0.0, "n == 1: no spread estimate, not NaN");
        assert_eq!(s.mean, 42.0);
        assert_eq!(s.p50, 42.0);
    }

    #[test]
    fn percentile_interpolation() {
        let v = vec![0.0, 10.0];
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn percentile_singleton() {
        assert_eq!(percentile_sorted(&[7.0], 99.0), 7.0);
        assert_eq!(percentile_sorted(&[7.0], 0.0), 7.0);
        assert_eq!(percentile_sorted(&[7.0], 100.0), 7.0);
    }

    #[test]
    fn percentile_extremes_hit_min_and_max() {
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 5.0);
        // q = 100 must not index one past the end (pos == n - 1 exactly).
        let big: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&big, 100.0), 999.0);
        assert_eq!(percentile_sorted(&big, 0.0), 0.0);
    }

    #[test]
    fn percentile_duplicate_values() {
        let v = vec![3.0, 3.0, 3.0, 3.0];
        for q in [0.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile_sorted(&v, q), 3.0);
        }
        let v = vec![1.0, 1.0, 9.0, 9.0];
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 9.0);
    }

    #[test]
    fn pm_format() {
        assert_eq!(pm(93.71, 0.68, 1), "93.7 ± 0.7");
    }
}
