//! Deterministic PRNG — SplitMix64, mirrored bit-for-bit against
//! `python/compile/data.py::SplitMix64` (goldens cross-checked in both
//! test-suites).  Used for synthetic ECG generation, temporal-noise
//! injection on the inference hot path, and the mini property-testing kit.

/// SplitMix64: tiny, fast, full-period 64-bit generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi)` built from the top 53 bits (same construction
    /// as the python mirror, so the float streams coincide).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        let u = self.next_u64() >> 11;
        lo + (hi - lo) * (u as f64 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn unit(&mut self) -> f64 {
        self.uniform(0.0, 1.0)
    }

    /// Standard normal via Box-Muller, consuming two uniforms in the same
    /// order as the python mirror.
    #[inline]
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.uniform(1e-12, 1.0);
        let u2 = self.unit();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Rejection-free modulo is fine for our n << 2^64 use-cases.
        self.next_u64() % n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_stream_seed0() {
        // Must match python/tests/test_data.py::test_prng_splitmix64_reference.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(r.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(r.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn golden_stream_seed42() {
        let mut r = SplitMix64::new(42);
        assert_eq!(r.next_u64(), 0xBDD732262FEB6E95);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let u = r.unit();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = SplitMix64::new(7);
        let m: f64 = (0..4000).map(|_| r.unit()).sum::<f64>() / 4000.0;
        assert!((m - 0.5).abs() < 0.03, "mean {m}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = SplitMix64::new(8);
        let gs: Vec<f64> = (0..4000).map(|_| r.gauss()).collect();
        let mean = gs.iter().sum::<f64>() / gs.len() as f64;
        let var = gs.iter().map(|g| (g - mean).powi(2)).sum::<f64>()
            / gs.len() as f64;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var.sqrt() - 1.0).abs() < 0.1, "std {}", var.sqrt());
    }

    #[test]
    fn below_bounds() {
        let mut r = SplitMix64::new(9);
        for _ in 0..100 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let a = SplitMix64::new(1).next_u64();
        let b = SplitMix64::new(2).next_u64();
        assert_ne!(a, b);
    }
}
