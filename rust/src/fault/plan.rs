//! Fault plans: seeded, serialisable schedules of hardware faults.
//!
//! A plan is a list of [`FaultSpec`]s, each targeting one chip with a
//! fault window in **chip time** (µs): active from `at_us` for
//! `duration_us` (or forever when absent).  Chip time only advances with
//! that chip's own activity (served programs, failed attempts, idle
//! aging), so a plan is independent of host scheduling — the property
//! the `repro chaos` determinism contract rests on.
//!
//! Plans travel as JSON (`--fault-plan` accepts a path or an inline
//! object):
//!
//! ```json
//! {"seed": 1, "faults": [
//!   {"kind": "chip_death",     "chip": 1, "at_us": 2000, "duration_us": 8000},
//!   {"kind": "dead_columns",   "chip": 0, "half": 1, "columns": [3, 17],
//!    "at_us": 0},
//!   {"kind": "adc_saturation", "chip": 2, "half": 0, "at_us": 500,
//!    "duration_us": 1500},
//!   {"kind": "link_corruption","chip": 0, "ber": 0.001, "at_us": 0},
//!   {"kind": "frame_drops",    "chip": 1, "rate": 0.2, "at_us": 0},
//!   {"kind": "latency_spike",  "chip": 3, "extra_us": 5000, "at_us": 100}
//! ]}
//! ```

use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// What breaks.  Windowing (`at_us`/`duration_us`) lives in
/// [`FaultSpec`]; this is the fault's mechanism and parameters.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// The whole chip stops answering: every program errors while the
    /// window is active.  A failed attempt still consumes chip time
    /// (the host's timeout), so *transient* deaths recover once enough
    /// re-admission probes have burned through the window.
    ChipDeath,
    /// Synapse columns of one array half disconnect: their accumulated
    /// charge reads as zero (output = offset + noise only).  Silent.
    DeadColumns { half: usize, columns: Vec<usize> },
    /// CADC reference collapse on one half: every column reads
    /// full-scale.  Silent.
    AdcSaturation { half: usize },
    /// Bit-error rate on the highspeed event link: corrupted frames
    /// fail parity and are dropped (`asic::packets`), thinning the
    /// event stream.  Silent.
    LinkCorruption { ber: f64 },
    /// Per-program probability that a DMA descriptor transfer loses its
    /// frame; the program aborts with an error.  Erroring.
    FrameDrops { rate: f64 },
    /// Extra host-visible latency added to every program in the window
    /// (a wedged FPGA round trip).  Slow, but correct.
    LatencySpike { extra_us: u64 },
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::ChipDeath => "chip_death",
            FaultKind::DeadColumns { .. } => "dead_columns",
            FaultKind::AdcSaturation { .. } => "adc_saturation",
            FaultKind::LinkCorruption { .. } => "link_corruption",
            FaultKind::FrameDrops { .. } => "frame_drops",
            FaultKind::LatencySpike { .. } => "latency_spike",
        }
    }

    /// Whether the fault makes programs *fail* (vs silently corrupting
    /// numerics or only slowing them down).
    pub fn is_erroring(&self) -> bool {
        matches!(self, FaultKind::ChipDeath | FaultKind::FrameDrops { .. })
    }
}

/// One scheduled fault on one chip.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub chip: usize,
    /// Chip time at which the fault arms [µs].
    pub at_us: u64,
    /// Fault window length [µs]; `None` = permanent.
    pub duration_us: Option<u64>,
    pub kind: FaultKind,
}

impl FaultSpec {
    /// Is the fault active at chip time `t_us`?
    pub fn active_at(&self, t_us: u64) -> bool {
        t_us >= self.at_us
            && match self.duration_us {
                Some(d) => t_us < self.at_us.saturating_add(d),
                None => true,
            }
    }

    /// One human-readable summary line (deterministic — the chaos
    /// survival report prints these).
    pub fn describe(&self) -> String {
        let window = match self.duration_us {
            Some(d) => format!("at {} µs for {} µs", self.at_us, d),
            None => format!("at {} µs, permanent", self.at_us),
        };
        let what = match &self.kind {
            FaultKind::ChipDeath => "chip death".to_string(),
            FaultKind::DeadColumns { half, columns } => {
                format!("{} dead column(s) on half {half}", columns.len())
            }
            FaultKind::AdcSaturation { half } => {
                format!("ADC saturation on half {half}")
            }
            FaultKind::LinkCorruption { ber } => {
                format!("link corruption (BER {ber})")
            }
            FaultKind::FrameDrops { rate } => {
                format!("DMA frame drops (rate {rate})")
            }
            FaultKind::LatencySpike { extra_us } => {
                format!("latency spike (+{extra_us} µs)")
            }
        };
        format!("chip {}: {what} {window}", self.chip)
    }

    fn to_json(&self) -> String {
        let mut s = format!(
            "{{\"kind\":\"{}\",\"chip\":{},\"at_us\":{}",
            self.kind.name(),
            self.chip,
            self.at_us
        );
        if let Some(d) = self.duration_us {
            s.push_str(&format!(",\"duration_us\":{d}"));
        }
        match &self.kind {
            FaultKind::ChipDeath => {}
            FaultKind::DeadColumns { half, columns } => {
                s.push_str(&format!(",\"half\":{half},\"columns\":["));
                for (i, c) in columns.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    s.push_str(&c.to_string());
                }
                s.push(']');
            }
            FaultKind::AdcSaturation { half } => {
                s.push_str(&format!(",\"half\":{half}"));
            }
            FaultKind::LinkCorruption { ber } => {
                s.push_str(&format!(",\"ber\":{ber}"));
            }
            FaultKind::FrameDrops { rate } => {
                s.push_str(&format!(",\"rate\":{rate}"));
            }
            FaultKind::LatencySpike { extra_us } => {
                s.push_str(&format!(",\"extra_us\":{extra_us}"));
            }
        }
        s.push('}');
        s
    }
}

/// A seeded schedule of faults across the fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed of every stochastic draw under this plan (frame-drop rolls,
    /// link bit flips), split per chip so replicas stay decorrelated.
    pub seed: u64,
    pub faults: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Faults targeting `chip`, in schedule order.
    pub fn faults_for(&self, chip: usize) -> Vec<FaultSpec> {
        self.faults.iter().filter(|f| f.chip == chip).cloned().collect()
    }

    /// Chips carrying at least one [`FaultKind::ChipDeath`] spec (the
    /// bound [`FaultPlan::random`] keeps under `chips / 2`).
    pub fn death_chips(&self, chips: usize) -> usize {
        self.chips_matching(chips, |k| matches!(k, FaultKind::ChipDeath))
    }

    /// Chips carrying at least one **erroring** fault
    /// ([`FaultKind::is_erroring`]) — the only chips the plan can get
    /// quarantined.  Silent and slow faults never cost serving capacity,
    /// so a fleet of `chips` replicas holds a serving floor of
    /// `chips - erroring_chips(..)` under this plan; the `repro chaos`
    /// verdict and the chaos soak tests both measure against it.
    pub fn erroring_chips(&self, chips: usize) -> usize {
        self.chips_matching(chips, FaultKind::is_erroring)
    }

    fn chips_matching<P: Fn(&FaultKind) -> bool>(
        &self,
        chips: usize,
        pred: P,
    ) -> usize {
        let mut hit = vec![false; chips];
        for f in &self.faults {
            if pred(&f.kind) && f.chip < chips {
                hit[f.chip] = true;
            }
        }
        hit.iter().filter(|&&h| h).count()
    }

    /// Reject a plan that names chips outside a fleet of `chips`
    /// replicas.  Same strictness rule as the parser: a typo'd plan
    /// (say, a 1-based chip index) must fail loudly, not silently arm
    /// nothing and let a chaos run report survival of faults that were
    /// never injected.  `Fleet::start` calls this before spinning up.
    pub fn validate_for(&self, chips: usize) -> anyhow::Result<()> {
        for (i, f) in self.faults.iter().enumerate() {
            anyhow::ensure!(
                f.chip < chips,
                "fault {i} ({}) targets chip {} but the fleet has {chips} \
                 chip(s) (valid: 0..={})",
                f.kind.name(),
                f.chip,
                chips.saturating_sub(1)
            );
        }
        Ok(())
    }

    /// Load from a path, or parse inline when the argument itself is a
    /// JSON object (starts with `{`).
    pub fn load(path_or_inline: &str) -> anyhow::Result<FaultPlan> {
        let text = if path_or_inline.trim_start().starts_with('{') {
            path_or_inline.to_string()
        } else {
            std::fs::read_to_string(path_or_inline).map_err(|e| {
                anyhow::anyhow!("fault plan {path_or_inline}: {e}")
            })?
        };
        Self::parse(&text)
    }

    /// Strict parse: malformed fields are rejected, never defaulted —
    /// a typo'd plan must not silently arm different faults.
    pub fn parse(text: &str) -> anyhow::Result<FaultPlan> {
        let v = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("fault plan: {e}"))?;
        let seed = match v.get("seed") {
            None => 0,
            Some(s) => s
                .as_uint()
                .ok_or_else(|| anyhow::anyhow!("seed must be a non-negative integer"))?,
        };
        let items = v
            .req("faults")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("faults must be an array"))?;
        let mut faults = Vec::with_capacity(items.len());
        for (i, item) in items.iter().enumerate() {
            faults.push(
                Self::parse_spec(item)
                    .map_err(|e| anyhow::anyhow!("fault {i}: {e}"))?,
            );
        }
        Ok(FaultPlan { seed, faults })
    }

    fn parse_spec(item: &Json) -> anyhow::Result<FaultSpec> {
        let uint = |key: &str| -> anyhow::Result<u64> {
            item.req(key)?.as_uint().ok_or_else(|| {
                anyhow::anyhow!("`{key}` must be a non-negative integer")
            })
        };
        let rate = |key: &str| -> anyhow::Result<f64> {
            let r = item.req(key)?.as_f64().ok_or_else(|| {
                anyhow::anyhow!("`{key}` must be a number")
            })?;
            anyhow::ensure!(
                (0.0..=1.0).contains(&r),
                "`{key}` must be in [0, 1], got {r}"
            );
            Ok(r)
        };
        let chip = uint("chip")? as usize;
        let at_us = uint("at_us")?;
        let duration_us = match item.get("duration_us") {
            None => None,
            Some(d) => Some(d.as_uint().ok_or_else(|| {
                anyhow::anyhow!("`duration_us` must be a non-negative integer")
            })?),
        };
        let kind_name = item
            .req("kind")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("`kind` must be a string"))?;
        let half = || -> anyhow::Result<usize> {
            let h = uint("half")? as usize;
            anyhow::ensure!(h < 2, "`half` must be 0 or 1, got {h}");
            Ok(h)
        };
        let kind = match kind_name {
            "chip_death" => FaultKind::ChipDeath,
            "dead_columns" => {
                let cols = item
                    .req("columns")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("`columns` must be an array"))?;
                let columns = cols
                    .iter()
                    .map(|c| {
                        c.as_uint().map(|c| c as usize).ok_or_else(|| {
                            anyhow::anyhow!(
                                "`columns` entries must be non-negative integers"
                            )
                        })
                    })
                    .collect::<anyhow::Result<Vec<usize>>>()?;
                anyhow::ensure!(!columns.is_empty(), "`columns` is empty");
                FaultKind::DeadColumns { half: half()?, columns }
            }
            "adc_saturation" => FaultKind::AdcSaturation { half: half()? },
            "link_corruption" => {
                FaultKind::LinkCorruption { ber: rate("ber")? }
            }
            "frame_drops" => FaultKind::FrameDrops { rate: rate("rate")? },
            "latency_spike" => {
                FaultKind::LatencySpike { extra_us: uint("extra_us")? }
            }
            other => anyhow::bail!("unknown fault kind `{other}`"),
        };
        Ok(FaultSpec { chip, at_us, duration_us, kind })
    }

    /// Serialise back to the wire format ([`parse`](FaultPlan::parse)
    /// round-trips it).
    pub fn to_json(&self) -> String {
        let mut s = format!("{{\"seed\":{},\"faults\":[", self.seed);
        for (i, f) in self.faults.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&f.to_json());
        }
        s.push_str("]}");
        s
    }

    /// Draw a deterministic chaos plan for a fleet of `chips` replicas
    /// whose per-chip time is expected to reach roughly `horizon_us`.
    ///
    /// Structure, not free-for-all: at most `chips / 2` replicas get a
    /// [`ChipDeath`](FaultKind::ChipDeath) (mostly transient), so the
    /// fleet can never lose more than half its replicas to the plan;
    /// every chip gets a chance of one or two non-fatal faults.  All
    /// randomness comes from `seed` — the same seed gives the same plan
    /// byte for byte.
    pub fn random(seed: u64, chips: usize, horizon_us: u64) -> FaultPlan {
        let mut rng = SplitMix64::new(seed ^ 0xFA17_FA17_FA17_FA17);
        let horizon = horizon_us.max(1000);
        let mut faults = Vec::new();
        let window = |rng: &mut SplitMix64| -> (u64, Option<u64>) {
            let at = rng.below(horizon * 3 / 4);
            let dur = horizon / 20 + rng.below(horizon / 4);
            (at, Some(dur.max(1)))
        };
        // Death faults on a strict subset of the fleet.
        let deadly = if chips >= 2 { chips / 2 } else { 0 };
        for d in 0..deadly {
            // Spread deaths over distinct chips deterministically.
            let chip = (d * 2 + rng.below(2) as usize) % chips;
            let (at_us, mut duration_us) = window(&mut rng);
            if rng.unit() < 0.25 {
                duration_us = None; // permanent
            }
            faults.push(FaultSpec {
                chip,
                at_us,
                duration_us,
                kind: FaultKind::ChipDeath,
            });
        }
        // Non-fatal faults, one or two per chip with probability.
        for chip in 0..chips {
            for _ in 0..2 {
                if rng.unit() < 0.4 {
                    continue;
                }
                let (at_us, duration_us) = window(&mut rng);
                let kind = match rng.below(5) {
                    0 => FaultKind::DeadColumns {
                        half: rng.below(2) as usize,
                        columns: (0..(1 + rng.below(6) as usize))
                            .map(|_| rng.below(64) as usize)
                            .collect(),
                    },
                    1 => FaultKind::AdcSaturation {
                        half: rng.below(2) as usize,
                    },
                    2 => FaultKind::LinkCorruption {
                        // Integer-derived BER in [1e-4, 1e-2]: `powf`
                        // goes through platform libm and is not
                        // bit-identical across hosts, which would break
                        // the chaos report's cross-host byte-identity.
                        ber: (1 + rng.below(99)) as f64 * 1e-4,
                    },
                    3 => FaultKind::FrameDrops {
                        rate: rng.uniform(0.05, 0.4),
                    },
                    _ => FaultKind::LatencySpike {
                        extra_us: 500 + rng.below(5000),
                    },
                };
                faults.push(FaultSpec { chip, at_us, duration_us, kind });
            }
        }
        FaultPlan { seed, faults }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_are_half_open() {
        let f = FaultSpec {
            chip: 0,
            at_us: 100,
            duration_us: Some(50),
            kind: FaultKind::ChipDeath,
        };
        assert!(!f.active_at(99));
        assert!(f.active_at(100));
        assert!(f.active_at(149));
        assert!(!f.active_at(150));
        let forever = FaultSpec { duration_us: None, ..f };
        assert!(forever.active_at(u64::MAX));
        assert!(!forever.active_at(99));
    }

    #[test]
    fn parse_roundtrip_every_kind() {
        let plan = FaultPlan {
            seed: 7,
            faults: vec![
                FaultSpec {
                    chip: 1,
                    at_us: 2000,
                    duration_us: Some(8000),
                    kind: FaultKind::ChipDeath,
                },
                FaultSpec {
                    chip: 0,
                    at_us: 0,
                    duration_us: None,
                    kind: FaultKind::DeadColumns {
                        half: 1,
                        columns: vec![3, 17],
                    },
                },
                FaultSpec {
                    chip: 2,
                    at_us: 500,
                    duration_us: Some(1500),
                    kind: FaultKind::AdcSaturation { half: 0 },
                },
                FaultSpec {
                    chip: 0,
                    at_us: 0,
                    duration_us: None,
                    kind: FaultKind::LinkCorruption { ber: 0.001 },
                },
                FaultSpec {
                    chip: 1,
                    at_us: 0,
                    duration_us: Some(10),
                    kind: FaultKind::FrameDrops { rate: 0.2 },
                },
                FaultSpec {
                    chip: 3,
                    at_us: 100,
                    duration_us: None,
                    kind: FaultKind::LatencySpike { extra_us: 5000 },
                },
            ],
        };
        let re = FaultPlan::parse(&plan.to_json()).unwrap();
        assert_eq!(re, plan);
    }

    #[test]
    fn strict_parse_rejects_malformed_fields() {
        for bad in [
            // missing faults array
            "{\"seed\":1}",
            // negative chip
            "{\"faults\":[{\"kind\":\"chip_death\",\"chip\":-1,\"at_us\":0}]}",
            // fractional at_us
            "{\"faults\":[{\"kind\":\"chip_death\",\"chip\":0,\"at_us\":0.5}]}",
            // unknown kind
            "{\"faults\":[{\"kind\":\"gremlins\",\"chip\":0,\"at_us\":0}]}",
            // rate out of range
            "{\"faults\":[{\"kind\":\"frame_drops\",\"chip\":0,\"at_us\":0,\
             \"rate\":1.5}]}",
            // half out of range
            "{\"faults\":[{\"kind\":\"adc_saturation\",\"chip\":0,\"at_us\":0,\
             \"half\":2}]}",
            // empty columns
            "{\"faults\":[{\"kind\":\"dead_columns\",\"chip\":0,\"at_us\":0,\
             \"half\":0,\"columns\":[]}]}",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted: {bad}");
        }
        // Inline load path parses objects directly.
        let p =
            FaultPlan::load("{\"seed\":3,\"faults\":[]}").unwrap();
        assert_eq!(p.seed, 3);
        assert!(p.faults.is_empty());
    }

    #[test]
    fn random_plans_are_seed_deterministic_and_bounded() {
        let a = FaultPlan::random(42, 4, 30_000);
        let b = FaultPlan::random(42, 4, 30_000);
        assert_eq!(a, b, "same seed must give the same plan");
        assert_eq!(a.to_json(), b.to_json());
        let c = FaultPlan::random(43, 4, 30_000);
        assert_ne!(a, c, "different seeds must differ");
        // Never more than half the fleet with death faults.
        for seed in 0..32u64 {
            for chips in 1..6usize {
                let p = FaultPlan::random(seed, chips, 30_000);
                assert!(
                    p.death_chips(chips) <= chips / 2,
                    "seed {seed}, {chips} chips: too deadly"
                );
                for f in &p.faults {
                    assert!(f.chip < chips);
                }
            }
        }
    }

    #[test]
    fn validate_rejects_out_of_fleet_chips() {
        let p = FaultPlan {
            seed: 1,
            faults: vec![FaultSpec {
                chip: 4,
                at_us: 0,
                duration_us: None,
                kind: FaultKind::ChipDeath,
            }],
        };
        let err = p.validate_for(4).unwrap_err();
        assert!(err.to_string().contains("targets chip 4"), "{err}");
        assert!(p.validate_for(5).is_ok());
        assert!(FaultPlan { seed: 0, faults: vec![] }.validate_for(1).is_ok());
    }

    #[test]
    fn erroring_chips_counts_only_quarantinable_faults() {
        let plan = FaultPlan {
            seed: 2,
            faults: vec![
                FaultSpec {
                    chip: 0,
                    at_us: 0,
                    duration_us: None,
                    kind: FaultKind::LinkCorruption { ber: 0.01 },
                },
                FaultSpec {
                    chip: 1,
                    at_us: 0,
                    duration_us: None,
                    kind: FaultKind::FrameDrops { rate: 0.5 },
                },
                FaultSpec {
                    chip: 1,
                    at_us: 0,
                    duration_us: None,
                    kind: FaultKind::ChipDeath,
                },
                FaultSpec {
                    chip: 2,
                    at_us: 0,
                    duration_us: None,
                    kind: FaultKind::LatencySpike { extra_us: 100 },
                },
            ],
        };
        assert_eq!(plan.death_chips(4), 1);
        assert_eq!(plan.erroring_chips(4), 1, "silent/slow faults excluded");
    }

    #[test]
    fn faults_for_filters_by_chip() {
        let plan = FaultPlan::random(5, 4, 30_000);
        for chip in 0..4 {
            for f in plan.faults_for(chip) {
                assert_eq!(f.chip, chip);
            }
        }
        let total: usize = (0..4).map(|c| plan.faults_for(c).len()).sum();
        assert_eq!(total, plan.faults.len());
    }
}
