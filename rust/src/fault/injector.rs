//! Per-chip fault arming: evaluates a [`FaultPlan`] against the chip's
//! clock, once per program.
//!
//! The engine owns one injector (armed via `Engine::arm_faults`) and
//! asks it at every program start what is currently broken
//! ([`FaultInjector::begin_program`]).  All stochastic draws (frame-drop
//! rolls, link bit flips) come from per-chip streams split off the
//! plan's seed, and are only consumed while the corresponding fault
//! window is active — so a chip with no active stochastic fault has a
//! bit-identical execution to an unarmed one, and a seeded soak replays
//! exactly as long as each chip sees the same job sequence.

use crate::asic::array::ArrayFaults;
use crate::asic::packets::Event;
use crate::fpga::link::{LinkConfig, LinkLayer};
use crate::util::rng::SplitMix64;

use super::plan::{FaultKind, FaultPlan, FaultSpec};

/// Golden-ratio stream split (the same constant `EngineConfig::for_chip`
/// uses), so every chip rolls its own independent fault stream.
const CHIP_SPLIT: u64 = 0x9E37_79B9_7F4A_7C15;

/// What is broken for the program starting now.
#[derive(Debug, Clone, Default)]
pub struct ProgramFaults {
    /// The chip does not answer: the program must fail.
    pub chip_dead: bool,
    /// This program's DMA transfer loses a frame: the program must fail.
    pub drop_frame: bool,
    /// Extra host-visible latency charged to this program [µs].
    pub latency_extra_us: f64,
    /// Active bit-error rate on the event link (0 = clean).
    pub link_ber: f64,
    /// Analog faults per array half (dead columns, ADC saturation).
    pub array: [ArrayFaults; 2],
}

/// Running tally of what the injector actually did (unit tests and the
/// chaos report read these; all counts are deterministic per seed).
#[derive(Debug, Clone, Default)]
pub struct FaultCounters {
    /// Programs that began with at least one fault active.
    pub faulted_programs: u64,
    /// Programs refused because the chip was dead.
    pub dead_programs: u64,
    /// Programs aborted by an injected DMA frame drop.
    pub frame_drops: u64,
    /// Programs that were charged a latency spike.
    pub latency_spikes: u64,
    /// Event frames lost to injected link corruption.
    pub link_events_dropped: u64,
}

/// One chip's armed fault schedule.
pub struct FaultInjector {
    chip: usize,
    specs: Vec<FaultSpec>,
    /// Frame-drop rolls (consumed only inside active drop windows).
    rng: SplitMix64,
    /// Link model applying the active BER (its own seeded flip stream).
    link: LinkLayer,
    /// BER of the program currently executing (set by `begin_program`).
    current_ber: f64,
    counters: FaultCounters,
}

impl FaultInjector {
    /// Arm `plan` on `chip`.  Returns `None` when the plan has no fault
    /// for this chip — an unarmed engine pays zero per-program cost.
    pub fn from_plan(plan: &FaultPlan, chip: usize) -> Option<FaultInjector> {
        let specs = plan.faults_for(chip);
        if specs.is_empty() {
            return None;
        }
        let split = plan.seed.wrapping_add((chip as u64).wrapping_mul(CHIP_SPLIT));
        Some(FaultInjector {
            chip,
            specs,
            rng: SplitMix64::new(split ^ 0xD0D0_FA17),
            link: LinkLayer::with_seed(
                LinkConfig::default(),
                split ^ 0x11C4_B17F,
            ),
            current_ber: 0.0,
            counters: FaultCounters::default(),
        })
    }

    pub fn chip(&self) -> usize {
        self.chip
    }

    /// Whether the schedule contains analog array faults (dead columns,
    /// ADC saturation).  Those inject into the native array model only —
    /// the staged PJRT artifact has no per-column substrate to corrupt —
    /// so the engine warns loudly when arming them on a PJRT backend
    /// instead of silently reporting survival of faults that never
    /// happened.
    pub fn has_analog_faults(&self) -> bool {
        self.specs.iter().any(|s| {
            matches!(
                s.kind,
                FaultKind::DeadColumns { .. } | FaultKind::AdcSaturation { .. }
            )
        })
    }

    pub fn counters(&self) -> &FaultCounters {
        &self.counters
    }

    /// Evaluate the schedule at chip time `t_us` and account the
    /// program.  Called exactly once per program by the engine;
    /// `dma_transfer` says whether this program performs the raw-trace
    /// DMA at all — streaming `classify_acts` programs don't (the
    /// windower already ran FPGA-side), so frame-drop faults neither
    /// roll nor count against them.
    pub fn begin_program(
        &mut self,
        t_us: u64,
        dma_transfer: bool,
    ) -> ProgramFaults {
        let mut out = ProgramFaults::default();
        let mut any = false;
        for spec in &self.specs {
            if !spec.active_at(t_us) {
                continue;
            }
            any = true;
            match &spec.kind {
                FaultKind::ChipDeath => out.chip_dead = true,
                FaultKind::DeadColumns { half, columns } => {
                    let h = &mut out.array[*half & 1];
                    for &c in columns {
                        if !h.dead_columns.contains(&c) {
                            h.dead_columns.push(c);
                        }
                    }
                }
                FaultKind::AdcSaturation { half } => {
                    out.array[*half & 1].adc_saturated = true;
                }
                FaultKind::LinkCorruption { ber } => {
                    out.link_ber = out.link_ber.max(*ber);
                }
                FaultKind::FrameDrops { rate } => {
                    // Roll only inside the window and only for programs
                    // with a DMA transfer to lose: otherwise the RNG is
                    // untouched and execution matches an unarmed chip.
                    if dma_transfer && self.rng.unit() < *rate {
                        out.drop_frame = true;
                    }
                }
                FaultKind::LatencySpike { extra_us } => {
                    out.latency_extra_us += *extra_us as f64;
                }
            }
        }
        if any {
            self.counters.faulted_programs += 1;
        }
        if out.chip_dead {
            self.counters.dead_programs += 1;
        } else if out.drop_frame {
            self.counters.frame_drops += 1;
        }
        if out.latency_extra_us > 0.0 && !out.chip_dead {
            self.counters.latency_spikes += 1;
        }
        self.current_ber = out.link_ber;
        out
    }

    /// Pass an event burst through the (possibly corrupting) link.
    /// With no active BER the burst is returned untouched and the flip
    /// stream is not consumed.
    pub fn transfer_events(&mut self, events: Vec<Event>) -> Vec<Event> {
        if self.current_ber <= 0.0 {
            return events;
        }
        self.link.set_ber(self.current_ber);
        let before = self.link.stats.events_dropped;
        let out = self.link.transfer(&events);
        self.counters.link_events_dropped +=
            self.link.stats.events_dropped - before;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::plan::FaultSpec;

    fn plan(faults: Vec<FaultSpec>) -> FaultPlan {
        FaultPlan { seed: 9, faults }
    }

    #[test]
    fn unaffected_chip_gets_no_injector() {
        let p = plan(vec![FaultSpec {
            chip: 1,
            at_us: 0,
            duration_us: None,
            kind: FaultKind::ChipDeath,
        }]);
        assert!(FaultInjector::from_plan(&p, 0).is_none());
        assert!(FaultInjector::from_plan(&p, 1).is_some());
    }

    #[test]
    fn schedule_windows_gate_activation() {
        let p = plan(vec![
            FaultSpec {
                chip: 0,
                at_us: 1000,
                duration_us: Some(500),
                kind: FaultKind::ChipDeath,
            },
            FaultSpec {
                chip: 0,
                at_us: 2000,
                duration_us: None,
                kind: FaultKind::LatencySpike { extra_us: 300 },
            },
        ]);
        let mut inj = FaultInjector::from_plan(&p, 0).unwrap();
        assert!(!inj.begin_program(0, true).chip_dead);
        assert!(inj.begin_program(1000, true).chip_dead);
        assert!(inj.begin_program(1499, true).chip_dead);
        let after = inj.begin_program(1500, true);
        assert!(!after.chip_dead);
        assert_eq!(after.latency_extra_us, 0.0);
        let late = inj.begin_program(5000, true);
        assert_eq!(late.latency_extra_us, 300.0);
        let c = inj.counters();
        assert_eq!(c.dead_programs, 2);
        assert_eq!(c.latency_spikes, 1);
        assert_eq!(c.faulted_programs, 3);
    }

    #[test]
    fn array_faults_merge_across_specs() {
        let p = plan(vec![
            FaultSpec {
                chip: 0,
                at_us: 0,
                duration_us: None,
                kind: FaultKind::DeadColumns { half: 1, columns: vec![3, 5] },
            },
            FaultSpec {
                chip: 0,
                at_us: 0,
                duration_us: None,
                kind: FaultKind::DeadColumns { half: 1, columns: vec![5, 9] },
            },
            FaultSpec {
                chip: 0,
                at_us: 0,
                duration_us: None,
                kind: FaultKind::AdcSaturation { half: 0 },
            },
        ]);
        let mut inj = FaultInjector::from_plan(&p, 0).unwrap();
        let f = inj.begin_program(0, true);
        assert_eq!(f.array[1].dead_columns, vec![3, 5, 9], "deduplicated");
        assert!(f.array[0].adc_saturated);
        assert!(!f.array[1].adc_saturated);
        assert!(!f.chip_dead);
    }

    #[test]
    fn frame_drops_are_seed_deterministic() {
        let p = plan(vec![FaultSpec {
            chip: 2,
            at_us: 0,
            duration_us: None,
            kind: FaultKind::FrameDrops { rate: 0.5 },
        }]);
        let roll = |p: &FaultPlan| -> Vec<bool> {
            let mut inj = FaultInjector::from_plan(p, 2).unwrap();
            (0..64).map(|i| inj.begin_program(i * 100, true).drop_frame).collect()
        };
        let a = roll(&p);
        assert_eq!(a, roll(&p), "same seed, same rolls");
        let hits = a.iter().filter(|&&d| d).count();
        assert!(hits > 10 && hits < 54, "rate 0.5 should hit ~half: {hits}");
        let other = FaultPlan { seed: 10, ..p.clone() };
        assert_ne!(a, roll(&other), "different plan seed, different rolls");
    }

    #[test]
    fn link_corruption_thins_event_bursts() {
        let p = plan(vec![FaultSpec {
            chip: 0,
            at_us: 0,
            duration_us: Some(100),
            kind: FaultKind::LinkCorruption { ber: 1.0 },
        }]);
        let mut inj = FaultInjector::from_plan(&p, 0).unwrap();
        let burst: Vec<Event> =
            (0..50).map(|i| Event::new(i, (i % 32) as u8)).collect();
        // Outside the window: untouched (same Vec length, same content).
        inj.begin_program(200, true);
        let clean = inj.transfer_events(burst.clone());
        assert_eq!(clean.len(), 50);
        assert_eq!(inj.counters().link_events_dropped, 0);
        // Inside: every frame gets a flipped bit; parity drops most.
        inj.begin_program(0, true);
        let noisy = inj.transfer_events(burst);
        assert!(noisy.len() < 50, "BER 1.0 must drop frames");
        assert_eq!(
            inj.counters().link_events_dropped,
            50 - noisy.len() as u64
        );
    }
}
