//! Deterministic fault injection for the simulated hardware.
//!
//! The paper's headline claim is operational: the mobile system "has
//! enabled the BrainScaleS-2 ASIC to be operated reliably outside a
//! specialized lab setting".  Our fleet has reliability machinery
//! (`fleet::health` error thresholds, quarantine + re-probe, shed
//! replies) — but nothing in the simulator could *break*, so none of it
//! had ever been driven by an actual fault.  This subsystem makes the
//! simulated hardware breakable, **deterministically**:
//!
//! * [`plan`] — [`FaultPlan`]: a seeded, serialisable schedule of faults
//!   (`--fault-plan` on `repro serve`, `repro chaos`).  Every fault is a
//!   window in **chip time**, the same clock that drives the analog
//!   drift field (`calib::drift`), so a plan replays bit-identically on
//!   any host as long as the job sequence per chip is the same.
//! * [`injector`] — [`FaultInjector`]: the per-chip arming of a plan.
//!   The engine consults it once per program
//!   (`Engine::begin_faulted_program`) and applies whatever is active:
//!   dead synapse columns and ADC saturation on the analog halves
//!   (`asic::array::ArrayFaults`), bit corruption on the highspeed link
//!   (`fpga::link` BER), DMA frame drops (`fpga::dma`), latency spikes,
//!   and whole-chip death (transient or permanent).
//!
//! Fault *classes* split along an axis the failover design cares about:
//!
//! * **erroring faults** (chip death, frame drops) make the program
//!   fail — the fleet sees the error, strikes the chip, and
//!   transparently retries the job on a healthy replica
//!   (`fleet::pool` failover, bounded redirect budget);
//! * **silent faults** (dead columns, ADC saturation, link corruption)
//!   corrupt numerics without erroring — failover cannot catch those by
//!   design; they are what the calibration monitors
//!   (`calib::monitor` margin EWMA) and the recalibration policy exist
//!   for.
//!
//! Error messages of injected faults carry the [`FAULT_TAG`] prefix so
//! telemetry can distinguish injected failures from organic ones.

pub mod injector;
pub mod plan;

pub use injector::{FaultCounters, FaultInjector, ProgramFaults};
pub use plan::{FaultKind, FaultPlan, FaultSpec};

/// Prefix of every injected-fault error message (telemetry filter).
pub const FAULT_TAG: &str = "fault:";
